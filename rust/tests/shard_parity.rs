//! Shard/merge bit-parity pins: for every figure, the Monte-Carlo
//! theorem tables, the ablation studies, and `mean_std`, running the
//! trial range as {1, 2, 3, 7} disjoint shards (each with its own
//! thread count), serializing every shard through the on-disk JSON
//! artifact format, and merging must reproduce the single-process
//! entry points **bit-for-bit** — the contract the `repro shard` /
//! `repro merge` / `repro run --fanout` CLI paths and the CI fan-out
//! jobs rely on. Also pins tree-reduction (`merge_partial` folds equal
//! the flat merge byte-for-byte) and the `verify` accept/reject cases.

use gradcode::codes::Scheme;
use gradcode::sim::figures::{
    figure2, figure2_partials, figure3, figure3_partials, figure4, figure4_partials, figure5,
    figure5_partials, finalize_fig_points, FigPoint, FigureConfig,
};
use gradcode::sim::shard::{Partial, ShardPoints, ABLATION_IDS};
use gradcode::sim::tables::{
    finalize_table_points, thm21_partials, thm21_table, thm5_partials, thm5_table, thm6_partials,
    thm6_table, thm8_partials, thm8_table, TableRow,
};
use gradcode::sim::{JobKind, JobSpec, MonteCarlo, Shard, ShardArtifact};
use gradcode::stragglers::Scenario;
use gradcode::util::Rng;

const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 7];

/// The default (uniform) scenario every pre-spine CSV was produced
/// under; the parity tests below pin that it still produces those
/// bytes.
fn sc() -> Scenario {
    Scenario::default()
}

/// Wrap per-shard points in artifacts, push every one of them through
/// the JSON on-disk format, and merge.
fn roundtrip_and_merge(job: &JobSpec, per_shard: Vec<ShardPoints>) -> ShardPoints {
    let num_shards = per_shard.len();
    let artifacts: Vec<ShardArtifact> = per_shard
        .into_iter()
        .enumerate()
        .map(|(sid, points)| {
            let art =
                ShardArtifact { job: job.clone(), shard_ids: vec![sid], num_shards, points };
            let text = art.to_json_string();
            ShardArtifact::parse(&text).expect("artifact JSON round-trip")
        })
        .collect();
    ShardArtifact::merge(artifacts).expect("merge").points
}

fn fig_job(trials: usize, id: &str) -> JobSpec {
    JobSpec {
        kind: JobKind::Figure,
        id: id.into(),
        trials,
        seed: 0, // metadata only for the wrap-and-merge tests
        k: 0,
        s: 0,
        tmax: 0,
        scenario: Scenario::default(),
    }
}

fn table_job(trials: usize, id: &str) -> JobSpec {
    JobSpec {
        kind: JobKind::Table,
        id: id.into(),
        trials,
        seed: 0,
        k: 0,
        s: 0,
        tmax: 0,
        scenario: Scenario::default(),
    }
}

fn assert_fig_points_bit_equal(merged: &ShardPoints, whole: &[FigPoint], ctx: &str) {
    let ShardPoints::Fig(points) = merged else {
        panic!("{ctx}: expected figure points");
    };
    let finalized = finalize_fig_points(points);
    assert_eq!(finalized.len(), whole.len(), "{ctx}: point count");
    for (a, b) in finalized.iter().zip(whole) {
        assert_eq!(a.figure, b.figure, "{ctx}");
        assert_eq!(a.scheme, b.scheme, "{ctx}");
        assert_eq!((a.s, a.t), (b.s, b.t), "{ctx}");
        assert_eq!(a.delta.to_bits(), b.delta.to_bits(), "{ctx}");
        assert_eq!(
            a.value.to_bits(),
            b.value.to_bits(),
            "{ctx}: {}/{} s={} delta={} t={}: {} vs {}",
            a.figure,
            a.scheme,
            a.s,
            a.delta,
            a.t,
            a.value,
            b.value
        );
    }
}

fn assert_table_rows_bit_equal(merged: &ShardPoints, whole: &[TableRow], ctx: &str) {
    let ShardPoints::Table(points) = merged else {
        panic!("{ctx}: expected table points");
    };
    let finalized = finalize_table_points(points);
    assert_eq!(finalized.len(), whole.len(), "{ctx}: row count");
    for (a, b) in finalized.iter().zip(whole) {
        assert_eq!(a.table, b.table, "{ctx}");
        assert_eq!(a.label, b.label, "{ctx}");
        assert_eq!(a.note, b.note, "{ctx}");
        // NaN-safe comparisons (thm21's expected column is NaN).
        assert_eq!(a.expected.to_bits(), b.expected.to_bits(), "{ctx}: {}", a.label);
        assert_eq!(
            a.measured.to_bits(),
            b.measured.to_bits(),
            "{ctx}: {}: {} vs {}",
            a.label,
            a.measured,
            b.measured
        );
    }
}

/// Per-shard thread counts deliberately differ (1, 2, 3, ...): neither
/// sharding nor threading may move a bit.
fn shard_threads(sid: usize) -> usize {
    1 + (sid % 3)
}

fn tiny_fig_cfg(trials: usize, threads: usize) -> FigureConfig {
    FigureConfig {
        k: 20,
        s_values: vec![5],
        deltas: vec![0.2, 0.5],
        mc: MonteCarlo::new(trials, 42).with_threads(threads),
    }
}

#[test]
fn figure2_shard_merge_bit_parity() {
    let trials = 60;
    let whole = figure2(&tiny_fig_cfg(trials, 4));
    for &n in &SHARD_COUNTS {
        let per_shard: Vec<ShardPoints> = (0..n)
            .map(|sid| {
                let cfg = tiny_fig_cfg(trials, shard_threads(sid));
                ShardPoints::Fig(figure2_partials(&cfg, &sc(), Shard::new(sid, n).unwrap()))
            })
            .collect();
        let merged = roundtrip_and_merge(&fig_job(trials, "2"), per_shard);
        assert_fig_points_bit_equal(&merged, &whole, &format!("fig2 n={n}"));
    }
}

#[test]
fn figure3_shard_merge_bit_parity() {
    let trials = 40;
    let whole = figure3(&tiny_fig_cfg(trials, 4));
    for &n in &SHARD_COUNTS {
        let per_shard: Vec<ShardPoints> = (0..n)
            .map(|sid| {
                let cfg = tiny_fig_cfg(trials, shard_threads(sid));
                ShardPoints::Fig(figure3_partials(&cfg, &sc(), Shard::new(sid, n).unwrap()))
            })
            .collect();
        let merged = roundtrip_and_merge(&fig_job(trials, "3"), per_shard);
        assert_fig_points_bit_equal(&merged, &whole, &format!("fig3 n={n}"));
    }
}

#[test]
fn figure4_shard_merge_bit_parity() {
    let trials = 30;
    let whole = figure4(&tiny_fig_cfg(trials, 2));
    for &n in &[2usize, 7] {
        let per_shard: Vec<ShardPoints> = (0..n)
            .map(|sid| {
                let cfg = tiny_fig_cfg(trials, shard_threads(sid));
                ShardPoints::Fig(figure4_partials(&cfg, &sc(), Shard::new(sid, n).unwrap()))
            })
            .collect();
        let merged = roundtrip_and_merge(&fig_job(trials, "4"), per_shard);
        assert_fig_points_bit_equal(&merged, &whole, &format!("fig4 n={n}"));
    }
}

#[test]
fn figure5_curve_shard_merge_bit_parity() {
    let trials = 24;
    let t_max = 3;
    let cfg = |threads| FigureConfig {
        k: 16,
        s_values: vec![4],
        deltas: vec![],
        mc: MonteCarlo::new(trials, 9).with_threads(threads),
    };
    let whole = figure5(&cfg(4), t_max);
    for &n in &SHARD_COUNTS {
        let per_shard: Vec<ShardPoints> = (0..n)
            .map(|sid| {
                ShardPoints::Fig(figure5_partials(
                    &cfg(shard_threads(sid)),
                    t_max,
                    &sc(),
                    Shard::new(sid, n).unwrap(),
                ))
            })
            .collect();
        let merged = roundtrip_and_merge(&fig_job(trials, "5"), per_shard);
        assert_fig_points_bit_equal(&merged, &whole, &format!("fig5 n={n}"));
    }
}

#[test]
fn thm5_and_thm6_shard_merge_bit_parity() {
    let (k, s) = (20usize, 5usize);
    let deltas = [0.25, 0.5];
    let trials = 60;
    let mc = |threads| MonteCarlo::new(trials, 17).with_threads(threads);
    let whole5 = thm5_table(k, s, &deltas, &mc(4));
    let whole6 = thm6_table(k, s, &deltas, &mc(4));
    for &n in &SHARD_COUNTS {
        let shards5: Vec<ShardPoints> = (0..n)
            .map(|sid| {
                ShardPoints::Table(thm5_partials(
                    k,
                    s,
                    &deltas,
                    &sc(),
                    &mc(shard_threads(sid)),
                    Shard::new(sid, n).unwrap(),
                ))
            })
            .collect();
        let merged5 = roundtrip_and_merge(&table_job(trials, "thm5"), shards5);
        assert_table_rows_bit_equal(&merged5, &whole5, &format!("thm5 n={n}"));

        let shards6: Vec<ShardPoints> = (0..n)
            .map(|sid| {
                ShardPoints::Table(thm6_partials(
                    k,
                    s,
                    &deltas,
                    &sc(),
                    &mc(shard_threads(sid)),
                    Shard::new(sid, n).unwrap(),
                ))
            })
            .collect();
        let merged6 = roundtrip_and_merge(&table_job(trials, "thm6"), shards6);
        assert_table_rows_bit_equal(&merged6, &whole6, &format!("thm6 n={n}"));
    }
}

#[test]
fn thm8_probability_shard_merge_bit_parity() {
    let k = 20usize;
    let alphas = [0usize];
    let deltas = [0.25];
    let trials = 60;
    let mc = |threads| MonteCarlo::new(trials, 23).with_threads(threads);
    let whole = thm8_table(k, &alphas, &deltas, &mc(4));
    for &n in &SHARD_COUNTS {
        let per_shard: Vec<ShardPoints> = (0..n)
            .map(|sid| {
                ShardPoints::Table(thm8_partials(
                    k,
                    &alphas,
                    &deltas,
                    &sc(),
                    &mc(shard_threads(sid)),
                    Shard::new(sid, n).unwrap(),
                ))
            })
            .collect();
        let merged = roundtrip_and_merge(&table_job(trials, "thm8"), per_shard);
        assert_table_rows_bit_equal(&merged, &whole, &format!("thm8 n={n}"));
    }
}

#[test]
fn thm21_postmap_and_nan_expected_shard_merge_bit_parity() {
    // thm21's rows carry a NaN expected column and a sqrt post-map —
    // both must survive the JSON round trip and apply after merging.
    let ks = [20usize, 40];
    let s_of_k = |k: usize| ((k as f64).ln().ceil() as usize).max(2);
    let trials = 40;
    let mc = |threads: usize| MonteCarlo::new(trials, 31).with_threads(threads);
    let whole = thm21_table(Scheme::Bgc, &ks, s_of_k, 0.25, &mc(4));
    for &n in &SHARD_COUNTS {
        let per_shard: Vec<ShardPoints> = (0..n)
            .map(|sid| {
                ShardPoints::Table(thm21_partials(
                    Scheme::Bgc,
                    &ks,
                    s_of_k,
                    0.25,
                    &sc(),
                    &mc(shard_threads(sid)),
                    Shard::new(sid, n).unwrap(),
                ))
            })
            .collect();
        let merged = roundtrip_and_merge(&table_job(trials, "thm21"), per_shard);
        assert_table_rows_bit_equal(&merged, &whole, &format!("thm21 n={n}"));
    }
}

#[test]
fn jobspec_sharded_run_reproduces_unsharded_csv() {
    // End to end through the exact code path the CLI uses: JobSpec::run
    // for the full range vs ShardArtifact::compute per shard + merge.
    // The merged CSV must equal the unsharded CSV byte for byte.
    let jobs = [
        JobSpec {
            kind: JobKind::Figure,
            id: "2".into(),
            trials: 8,
            seed: 2017,
            k: 16,
            s: 0,
            tmax: 0,
            scenario: Scenario::default(),
        },
        JobSpec {
            kind: JobKind::Table,
            id: "thm6".into(),
            trials: 40,
            seed: 2017,
            k: 12,
            s: 3,
            tmax: 0,
            scenario: Scenario::default(),
        },
        JobSpec {
            kind: JobKind::Table,
            id: "thm11".into(),
            trials: 10,
            seed: 3,
            k: 12,
            s: 3,
            tmax: 0,
            scenario: Scenario::default(),
        },
    ];
    for job in &jobs {
        let unsharded = job.run(Shard::full(), Some(3)).unwrap().to_csv();
        // Thread count must not change the CSV either.
        let other_threads = job.run(Shard::full(), Some(1)).unwrap().to_csv();
        assert_eq!(unsharded, other_threads, "{}: thread dependence", job.id);
        for &n in &[2usize, 4] {
            let artifacts: Vec<ShardArtifact> = (0..n)
                .map(|sid| {
                    let art = ShardArtifact::compute(
                        job,
                        Shard::new(sid, n).unwrap(),
                        Some(shard_threads(sid)),
                    )
                    .unwrap();
                    ShardArtifact::parse(&art.to_json_string()).unwrap()
                })
                .collect();
            let merged = ShardArtifact::merge(artifacts).unwrap();
            assert_eq!(merged.to_csv(), unsharded, "{} n={n}", job.id);
        }
    }
}

#[test]
fn mean_std_shard_merge_bit_parity() {
    // The moments accumulator behind mean_std: any shard partition ×
    // any per-shard thread count merges to the single-process bits.
    let mc = |threads: usize| MonteCarlo::new(271, 77).with_threads(threads);
    let trial = |_: &mut (), rng: &mut Rng| {
        let x = rng.f64();
        x * x - 0.3
    };
    let (m_whole, s_whole) = mc(4).mean_std(|rng| {
        let x = rng.f64();
        x * x - 0.3
    });
    // The partial_ws path at Shard::full() is the same thing.
    let (m_full, s_full) = mc(2).mean_std_partial_ws(Shard::full(), || (), trial).mean_std();
    assert_eq!(m_full.to_bits(), m_whole.to_bits());
    assert_eq!(s_full.to_bits(), s_whole.to_bits());
    for &n in &SHARD_COUNTS {
        let mut merged: Option<Partial> = None;
        for sid in 0..n {
            let part = mc(shard_threads(sid)).mean_std_partial_ws(
                Shard::new(sid, n).unwrap(),
                || (),
                trial,
            );
            match merged.as_mut() {
                None => merged = Some(part),
                Some(m) => m.merge(&part).unwrap(),
            }
        }
        let (m, s) = merged.unwrap().mean_std();
        assert_eq!(m.to_bits(), m_whole.to_bits(), "mean, n={n}");
        assert_eq!(s.to_bits(), s_whole.to_bits(), "std, n={n}");
    }
}

#[test]
fn ablation_studies_shard_merge_to_unsharded_csv() {
    // All four registered studies, end to end through the exact code
    // path the CLI uses: JobSpec::run for the full range vs
    // ShardArtifact::compute per shard + JSON round trip + merge.
    for &id in &ABLATION_IDS {
        let job = JobSpec {
            kind: JobKind::Ablation,
            id: id.into(),
            trials: 30,
            seed: 17,
            k: 20,
            s: 4,
            tmax: 0,
            scenario: Scenario::default(),
        };
        let unsharded = job.run(Shard::full(), Some(3)).unwrap().to_csv();
        let other_threads = job.run(Shard::full(), Some(1)).unwrap().to_csv();
        assert_eq!(unsharded, other_threads, "{id}: thread dependence");
        assert!(unsharded.starts_with("study,setting,value\n"), "{id}: {unsharded}");
        for &n in &SHARD_COUNTS {
            let artifacts: Vec<ShardArtifact> = (0..n)
                .map(|sid| {
                    let art = ShardArtifact::compute(
                        &job,
                        Shard::new(sid, n).unwrap(),
                        Some(shard_threads(sid)),
                    )
                    .unwrap();
                    ShardArtifact::parse(&art.to_json_string()).unwrap()
                })
                .collect();
            let merged = ShardArtifact::merge(artifacts).unwrap();
            assert_eq!(merged.to_csv(), unsharded, "{id} n={n}");
        }
    }
}

#[test]
fn tree_reduction_matches_flat_merge_byte_for_byte() {
    let job = JobSpec {
        kind: JobKind::Table,
        id: "thm5".into(),
        trials: 64,
        seed: 5,
        k: 20,
        s: 5,
        tmax: 0,
        scenario: Scenario::default(),
    };
    let arts: Vec<ShardArtifact> = (0..8)
        .map(|sid| {
            ShardArtifact::compute(&job, Shard::new(sid, 8).unwrap(), Some(1 + sid % 2)).unwrap()
        })
        .collect();
    let flat = ShardArtifact::merge(arts.clone()).unwrap().to_csv();
    let unsharded = job.run(Shard::full(), Some(2)).unwrap().to_csv();
    assert_eq!(flat, unsharded, "flat merge vs unsharded");

    // 8 -> 2 -> 1, every intermediate pushed through the JSON format.
    let lo = ShardArtifact::merge_partial(arts[0..4].to_vec()).unwrap();
    let hi = ShardArtifact::merge_partial(arts[4..8].to_vec()).unwrap();
    assert_eq!(lo.shard_ids, vec![0, 1, 2, 3]);
    assert_eq!(hi.shard_ids, vec![4, 5, 6, 7]);
    let lo = ShardArtifact::parse(&lo.to_json_string()).unwrap();
    let hi = ShardArtifact::parse(&hi.to_json_string()).unwrap();
    let tree = ShardArtifact::merge(vec![lo.clone(), hi.clone()]).unwrap().to_csv();
    assert_eq!(tree, flat, "8->2->1 tree differs from flat merge");

    // A deeper, unbalanced tree: ((0,1) + (2..6)) + (6,7).
    let a = ShardArtifact::merge_partial(arts[0..2].to_vec()).unwrap();
    let b = ShardArtifact::merge_partial(arts[2..6].to_vec()).unwrap();
    let ab = ShardArtifact::merge_partial(vec![a, b]).unwrap();
    let c = ShardArtifact::merge_partial(arts[6..8].to_vec()).unwrap();
    let deep = ShardArtifact::merge(vec![ab, c]).unwrap().to_csv();
    assert_eq!(deep, flat, "unbalanced tree differs from flat merge");

    // Overlapping folds and incomplete full merges are rejected.
    assert!(ShardArtifact::merge_partial(vec![arts[0].clone(), lo.clone()]).is_err());
    assert!(ShardArtifact::merge(vec![lo]).is_err());
}

#[test]
fn verify_accepts_complete_sets_and_rejects_bad_ones() {
    let job = JobSpec {
        kind: JobKind::Table,
        id: "thm6".into(),
        trials: 30,
        seed: 7,
        k: 12,
        s: 3,
        tmax: 0,
        scenario: Scenario::default(),
    };
    let arts: Vec<ShardArtifact> = (0..3)
        .map(|sid| {
            let art =
                ShardArtifact::compute(&job, Shard::new(sid, 3).unwrap(), Some(1)).unwrap();
            ShardArtifact::parse(&art.to_json_string()).unwrap()
        })
        .collect();
    // Complete set verifies.
    assert!(ShardArtifact::verify_set(&arts).is_ok());
    // Missing shard.
    assert!(ShardArtifact::verify_set(&arts[0..2]).is_err());
    // Overlapping coverage: a compound artifact plus one of its parts.
    let pair = ShardArtifact::merge_partial(arts[0..2].to_vec()).unwrap();
    assert!(
        ShardArtifact::verify_set(&[pair.clone(), arts[1].clone(), arts[2].clone()]).is_err()
    );
    // Compound + disjoint remainder verifies (tree-reduction-ready).
    assert!(ShardArtifact::verify_set(&[pair, arts[2].clone()]).is_ok());
    // Mismatched jobs are rejected.
    let mut other_job = job.clone();
    other_job.seed = 8;
    let alien = ShardArtifact::compute(&other_job, Shard::new(2, 3).unwrap(), Some(1)).unwrap();
    assert!(ShardArtifact::verify_set(&[arts[0].clone(), arts[1].clone(), alien]).is_err());
    // Corrupted payload: the checksum catches body tampering.
    let text = arts[0].to_json_string();
    let tampered = text.replacen("\"trials\": 30", "\"trials\": 31", 1);
    assert_ne!(tampered, text, "tamper target must exist in the artifact text");
    assert!(ShardArtifact::parse(&tampered).is_err());
}

#[test]
fn merge_rejects_incomplete_or_mismatched_sets() {
    let job = JobSpec {
        kind: JobKind::Table,
        id: "thm11".into(),
        trials: 10,
        seed: 3,
        k: 12,
        s: 3,
        tmax: 0,
        scenario: Scenario::default(),
    };
    let art = |sid: usize, n: usize, job: &JobSpec| {
        ShardArtifact::compute(job, Shard::new(sid, n).unwrap(), Some(1)).unwrap()
    };
    // Complete set merges.
    assert!(ShardArtifact::merge(vec![art(0, 2, &job), art(1, 2, &job)]).is_ok());
    // Missing shard.
    assert!(ShardArtifact::merge(vec![art(0, 2, &job)]).is_err());
    // Duplicate shard.
    assert!(ShardArtifact::merge(vec![art(0, 2, &job), art(0, 2, &job)]).is_err());
    // Job mismatch (different seed -> different deterministic values,
    // and the job header differs).
    let mut other = job.clone();
    other.seed = 4;
    assert!(ShardArtifact::merge(vec![art(0, 2, &job), art(1, 2, &other)]).is_err());
    // Out-of-order input is fine (merge sorts by shard id).
    assert!(ShardArtifact::merge(vec![art(1, 2, &job), art(0, 2, &job)]).is_ok());
}

#[test]
fn artifact_json_is_parseable_and_stable() {
    // Serialize -> parse -> serialize must be a fixed point (the byte
    // form is what multi-machine runs ship around).
    let job = JobSpec {
        kind: JobKind::Figure,
        id: "2".into(),
        trials: 8,
        seed: 2017,
        k: 16,
        s: 0,
        tmax: 0,
        scenario: Scenario::default(),
    };
    let art = ShardArtifact::compute(&job, Shard::new(1, 3).unwrap(), Some(2)).unwrap();
    let text = art.to_json_string();
    let reparsed = ShardArtifact::parse(&text).unwrap();
    assert_eq!(reparsed.to_json_string(), text);
    // Sanity: the artifact names its format, shard coverage, checksum.
    assert!(text.contains("gradcode-shard/v3"));
    assert!(text.contains("\"shard_ids\""));
    assert!(text.contains("\"checksum\""));
}

#[test]
fn scenario_tta_shard_merge_reproduces_unsharded_csv() {
    // The scenario job family shards like everything else: {1, 2, 3, 7}
    // shards x varying per-shard thread counts x the JSON artifact
    // round trip == the unsharded CSV, byte for byte.
    let job = JobSpec {
        kind: JobKind::Scenario,
        id: "tta".into(),
        trials: 24,
        seed: 19,
        k: 12,
        s: 3,
        tmax: 0,
        scenario: Scenario::parse("pareto:0.05,1.5").unwrap(),
    };
    let unsharded = job.run(Shard::full(), Some(3)).unwrap().to_csv();
    let other_threads = job.run(Shard::full(), Some(1)).unwrap().to_csv();
    assert_eq!(unsharded, other_threads, "tta: thread dependence");
    assert!(unsharded.starts_with("scenario,scheme,policy,s,delta,gather,err1\n"));
    for &n in &SHARD_COUNTS {
        let artifacts: Vec<ShardArtifact> = (0..n)
            .map(|sid| {
                let art = ShardArtifact::compute(
                    &job,
                    Shard::new(sid, n).unwrap(),
                    Some(shard_threads(sid)),
                )
                .unwrap();
                ShardArtifact::parse(&art.to_json_string()).unwrap()
            })
            .collect();
        ShardArtifact::verify_set(&artifacts).expect("tta artifact set verifies");
        let merged = ShardArtifact::merge(artifacts).unwrap();
        assert_eq!(merged.to_csv(), unsharded, "tta n={n}");
    }
}

#[test]
fn scenario_tta3_optimal_arm_is_shard_partition_invariant() {
    // The tta3 study (PR 8) adds the survivor-set-optimal decoder as a
    // third arm; its LSQR solves are per-trial pure (warm-started at
    // ρ·1 from a fresh workspace state each trial), so the arm must be
    // exactly as partition-invariant as the one-step arms: any shard
    // split x thread counts x the artifact round trip merges to the
    // unsharded bytes.
    let job = JobSpec {
        kind: JobKind::Scenario,
        id: "tta3".into(),
        trials: 20,
        seed: 19,
        k: 12,
        s: 3,
        tmax: 0,
        scenario: Scenario::parse("pareto:0.05,1.5").unwrap(),
    };
    let unsharded = job.run(Shard::full(), Some(3)).unwrap().to_csv();
    let other_threads = job.run(Shard::full(), Some(1)).unwrap().to_csv();
    assert_eq!(unsharded, other_threads, "tta3: thread dependence");
    assert!(unsharded.starts_with("scenario,scheme,policy,s,delta,gather,err1\n"));
    // All three arms are present, and the one-step arms precede the
    // optimal arm (TTA3_POLICIES is a strict superset of TTA_POLICIES,
    // so tta rows keep their positions).
    for arm in ["fastest-r", "deadline", "optimal"] {
        assert!(unsharded.contains(&format!(",{arm},")), "missing arm {arm}");
    }
    for &n in &SHARD_COUNTS {
        let artifacts: Vec<ShardArtifact> = (0..n)
            .map(|sid| {
                let art = ShardArtifact::compute(
                    &job,
                    Shard::new(sid, n).unwrap(),
                    Some(shard_threads(sid)),
                )
                .unwrap();
                ShardArtifact::parse(&art.to_json_string()).unwrap()
            })
            .collect();
        ShardArtifact::verify_set(&artifacts).expect("tta3 artifact set verifies");
        let merged = ShardArtifact::merge(artifacts).unwrap();
        assert_eq!(merged.to_csv(), unsharded, "tta3 n={n}");
    }

    // The two one-step arms are bit-identical to the plain tta study
    // on the same job parameters: the third arm rides alongside
    // without perturbing a single published tta byte.
    let tta_job = JobSpec { id: "tta".into(), ..job };
    let tta_csv = tta_job.run(Shard::full(), Some(2)).unwrap().to_csv();
    let tta_rows: Vec<&str> = tta_csv.lines().collect();
    let tta3_rows: Vec<&str> = unsharded.lines().collect();
    assert!(tta3_rows.len() > tta_rows.len());
    for (i, row) in tta_rows.iter().enumerate() {
        let expect = if i == 0 { row.to_string() } else { row.replacen("tta,", "tta3,", 1) };
        assert_eq!(tta3_rows[i], expect, "tta3 row {i} diverges from tta");
    }
}

#[test]
fn non_uniform_scenarios_shard_merge_bit_parity_for_figures_and_tables() {
    // Latency and adversarial scenarios ride the same shard machinery:
    // sharded runs merge to the single-process bytes for a figure and a
    // table job under each.
    for spec in ["pareto:0.05,1.5", "bimodal:0.1,5,0.3,deadline:0.6", "adversarial:greedy"] {
        let jobs = [
            JobSpec {
                kind: JobKind::Figure,
                id: "2".into(),
                trials: 12,
                seed: 23,
                k: 14,
                s: 0,
                tmax: 0,
                scenario: Scenario::parse(spec).unwrap(),
            },
            JobSpec {
                kind: JobKind::Table,
                id: "thm5".into(),
                trials: 30,
                seed: 23,
                k: 15,
                s: 3,
                tmax: 0,
                scenario: Scenario::parse(spec).unwrap(),
            },
        ];
        for job in &jobs {
            let unsharded = job.run(Shard::full(), Some(2)).unwrap().to_csv();
            for &n in &[3usize] {
                let artifacts: Vec<ShardArtifact> = (0..n)
                    .map(|sid| {
                        let art = ShardArtifact::compute(
                            job,
                            Shard::new(sid, n).unwrap(),
                            Some(shard_threads(sid)),
                        )
                        .unwrap();
                        ShardArtifact::parse(&art.to_json_string()).unwrap()
                    })
                    .collect();
                let merged = ShardArtifact::merge(artifacts).unwrap();
                assert_eq!(merged.to_csv(), unsharded, "{spec}: {} n={n}", job.id);
            }
        }
    }
}
