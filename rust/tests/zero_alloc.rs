//! Proof that the steady-state trial loop is allocation-free: a
//! counting global allocator (test binary only) wraps the system
//! allocator with per-thread counters, the straggler→decode pipeline
//! runs a warmup to grow every workspace buffer, and the measured loop
//! must then perform exactly zero heap allocations.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use gradcode::codes::{GradientCode, Scheme};
use gradcode::decode::DecodeWorkspace;
use gradcode::linalg::LsqrOptions;
use gradcode::util::Rng;

struct CountingAllocator;

thread_local! {
    static ALLOC_COUNT: Cell<u64> = const { Cell::new(0) };
}

fn allocations_on_this_thread() -> u64 {
    ALLOC_COUNT.with(|c| c.get())
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAllocator = CountingAllocator;

/// The fused one-step trial loop: sample stragglers, accumulate
/// coverage from G, square — zero allocations at steady state.
#[test]
fn onestep_trial_loop_is_allocation_free_after_warmup() {
    let (k, s, r) = (200usize, 10usize, 150usize);
    let rho = k as f64 / (r as f64 * s as f64);
    // FRC: fixed per-column degree, so submatrix capacity is constant.
    let g = Scheme::Frc.build(k, k, s).assignment(&mut Rng::new(11));
    let mut ws = DecodeWorkspace::new();
    let mut rng = Rng::new(12);

    let mut warmup_sum = 0.0;
    for _ in 0..5 {
        warmup_sum += ws.onestep_trial(&g, r, rho, &mut rng);
    }
    assert!(warmup_sum.is_finite());

    let before = allocations_on_this_thread();
    let mut sum = 0.0;
    for _ in 0..100 {
        sum += ws.onestep_trial(&g, r, rho, &mut rng);
    }
    let allocs = allocations_on_this_thread() - before;
    assert!(sum.is_finite() && sum >= 0.0);
    assert_eq!(allocs, 0, "steady-state one-step loop allocated {allocs} times");
}

/// The full fused straggler→decode pipeline including the optimal
/// (LSQR) decoder with warm start: zero allocations at steady state.
#[test]
fn optimal_trial_loop_is_allocation_free_after_warmup() {
    let (k, s, r) = (200usize, 10usize, 150usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let g = Scheme::Frc.build(k, k, s).assignment(&mut Rng::new(13));
    let mut ws = DecodeWorkspace::new();
    let opts = LsqrOptions::default();
    let mut rng = Rng::new(14);

    let mut warmup_sum = 0.0;
    for _ in 0..5 {
        warmup_sum += ws.optimal_trial(&g, r, &opts, Some(rho), &mut rng);
        warmup_sum += ws.optimal_trial(&g, r, &opts, None, &mut rng);
    }
    assert!(warmup_sum.is_finite());

    let before = allocations_on_this_thread();
    let mut sum = 0.0;
    for _ in 0..50 {
        sum += ws.optimal_trial(&g, r, &opts, Some(rho), &mut rng);
        sum += ws.optimal_trial(&g, r, &opts, None, &mut rng);
    }
    let allocs = allocations_on_this_thread() - before;
    assert!(sum.is_finite() && sum >= 0.0);
    assert_eq!(allocs, 0, "steady-state optimal loop allocated {allocs} times");
}

/// The CSR-cached workspace path: mirror G once, then the streamed
/// one-step trial loop (sample + row-major err_1 sweep) must be
/// allocation-free at steady state.
#[test]
fn csr_streamed_trial_loop_is_allocation_free_after_warmup() {
    let (k, s, r) = (200usize, 10usize, 150usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let g = Scheme::Bgc.build(k, k, s).assignment(&mut Rng::new(21));
    let mut ws = DecodeWorkspace::new();
    ws.mirror_csr(&g);
    let mut rng = Rng::new(22);

    let mut warmup_sum = 0.0;
    for _ in 0..5 {
        warmup_sum += ws.onestep_trial_streamed(r, rho, &mut rng);
    }
    assert!(warmup_sum.is_finite());

    let before = allocations_on_this_thread();
    let mut sum = 0.0;
    for _ in 0..100 {
        sum += ws.onestep_trial_streamed(r, rho, &mut rng);
    }
    let allocs = allocations_on_this_thread() - before;
    assert!(sum.is_finite() && sum >= 0.0);
    assert_eq!(allocs, 0, "steady-state CSR-streamed loop allocated {allocs} times");

    // Re-mirroring an identically-shaped G reuses the same buffers too.
    let before = allocations_on_this_thread();
    ws.mirror_csr(&g);
    let allocs = allocations_on_this_thread() - before;
    assert_eq!(allocs, 0, "re-mirroring same-shape G allocated {allocs} times");
}

/// The `assignment_into` re-draw loop: randomized schemes re-draw G
/// itself every trial through the workspace, and with the worst-case
/// reserve the whole draw→sample→decode loop performs zero heap
/// allocations — including the very first trial. All schemes run at a
/// dense s = 6: for s-regular, a configuration draw is simple with
/// probability ≈ exp(−(s²−1)/4) ≈ 1.6e-4, so essentially every trial
/// exhausts the configuration attempts and lands on the **flat-buffer
/// edge-swap repair** — the path this test pins as allocation-free
/// (it fell back to an allocating repair before PR 4).
#[test]
fn redraw_trial_loop_is_allocation_free_for_randomized_schemes() {
    let (k, s, r) = (60usize, 6usize, 45usize);
    for scheme in [Scheme::Bgc, Scheme::Rbgc, Scheme::RegularGraph, Scheme::Frc, Scheme::Cyclic] {
        let rho = k as f64 / (r as f64 * s as f64);
        let code = scheme.build(k, k, s);

        // RNG-stream pin: before counting allocations, check a few
        // redraw trials against the legacy allocating sequence — the
        // flat repair must not move a bit.
        let mut legacy_ws = DecodeWorkspace::new();
        let mut legacy_rng = Rng::new(23);
        let mut check_ws = DecodeWorkspace::new();
        let mut check_rng = Rng::new(23);
        for trial in 0..3 {
            let g = code.assignment(&mut legacy_rng);
            let want = legacy_ws.onestep_trial(&g, r, rho, &mut legacy_rng);
            let got = check_ws.onestep_redraw_trial(code.as_ref(), r, rho, &mut check_rng);
            assert_eq!(want.to_bits(), got.to_bits(), "{}: trial {trial}", code.name());
        }
        assert_eq!(legacy_rng.next_u64(), check_rng.next_u64(), "{}: rng", code.name());

        let mut ws = DecodeWorkspace::new();
        // Reserve the k·n worst case up front: afterwards even a
        // maximally dense Bernoulli draw fits without reallocating.
        ws.reserve_redraw(k, k, s);
        let mut rng = Rng::new(23);

        let mut warmup_sum = 0.0;
        for _ in 0..3 {
            warmup_sum += ws.onestep_redraw_trial(code.as_ref(), r, rho, &mut rng);
        }
        assert!(warmup_sum.is_finite());

        let before = allocations_on_this_thread();
        let mut sum = 0.0;
        for _ in 0..100 {
            sum += ws.onestep_redraw_trial(code.as_ref(), r, rho, &mut rng);
        }
        let allocs = allocations_on_this_thread() - before;
        assert!(sum.is_finite() && sum >= 0.0);
        assert_eq!(
            allocs, 0,
            "{}: steady-state redraw loop allocated {allocs} times",
            code.name()
        );
    }
}

/// One ablation loop (satellite of the ablation sharding PR): the
/// thresholded-BGC study code plus both one-step variants the
/// `normalization` study uses — boolean and column-normalized — run
/// allocation-free through the workspace after the worst-case reserve.
#[test]
fn ablation_trial_loops_are_allocation_free_after_reserve() {
    use gradcode::codes::ThresholdedBernoulliCode;
    let (k, s, r) = (60usize, 6usize, 45usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let rho_norm = k as f64 / r as f64;
    let code = ThresholdedBernoulliCode::new(k, k, s, 2.0, 1.0);
    let mut ws = DecodeWorkspace::new();
    ws.reserve_redraw(k, k, s);
    let mut rng = Rng::new(31);

    let mut warmup_sum = 0.0;
    for _ in 0..3 {
        warmup_sum += ws.onestep_redraw_trial(&code, r, rho, &mut rng);
        warmup_sum += ws.onestep_normalized_redraw_trial(&code, r, rho_norm, &mut rng);
    }
    assert!(warmup_sum.is_finite());

    let before = allocations_on_this_thread();
    let mut sum = 0.0;
    for _ in 0..100 {
        sum += ws.onestep_redraw_trial(&code, r, rho, &mut rng);
        sum += ws.onestep_normalized_redraw_trial(&code, r, rho_norm, &mut rng);
    }
    let allocs = allocations_on_this_thread() - before;
    assert!(sum.is_finite() && sum >= 0.0);
    assert_eq!(allocs, 0, "steady-state ablation loop allocated {allocs} times");
}

/// The optimal (LSQR) decoder composed with per-trial G re-draw: zero
/// steady-state allocations once LSQR's iteration vectors have warmed.
#[test]
fn optimal_redraw_trial_loop_is_allocation_free_after_warmup() {
    let (k, s, r) = (60usize, 6usize, 45usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let code = Scheme::Rbgc.build(k, k, s);
    let mut ws = DecodeWorkspace::new();
    ws.reserve_redraw(k, k, s);
    let opts = LsqrOptions::default();
    let mut rng = Rng::new(24);

    let mut warmup_sum = 0.0;
    for _ in 0..5 {
        warmup_sum += ws.optimal_redraw_trial(code.as_ref(), r, &opts, Some(rho), &mut rng);
    }
    assert!(warmup_sum.is_finite());

    let before = allocations_on_this_thread();
    let mut sum = 0.0;
    for _ in 0..50 {
        sum += ws.optimal_redraw_trial(code.as_ref(), r, &opts, Some(rho), &mut rng);
    }
    let allocs = allocations_on_this_thread() - before;
    assert!(sum.is_finite() && sum >= 0.0);
    assert_eq!(allocs, 0, "steady-state optimal redraw loop allocated {allocs} times");
}

/// The scenario spine: straggler selection through
/// `StragglerModel::non_stragglers_into` (uniform, latency with both
/// deadline policies, adversarial replay) runs the redraw trial loop
/// with zero steady-state heap allocations, like the hard-coded
/// uniform draw it replaces.
#[test]
fn scenario_spine_trial_loops_are_allocation_free_after_warmup() {
    use gradcode::stragglers::{
        AdversarialStragglers, AttackKind, DeadlinePolicy, LatencyModel, LatencyStragglers,
        StragglerModel, UniformStragglers,
    };
    let (k, s, r) = (60usize, 6usize, 45usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let code = Scheme::Bgc.build(k, k, s);
    let g = code.assignment(&mut Rng::new(41));

    let uniform = UniformStragglers::new(0.25);
    let pareto = LatencyModel::Pareto { scale: 0.05, shape: 1.5 };
    let fastest = LatencyStragglers { model: pareto, policy: DeadlinePolicy::FastestR(r) };
    let deadline = LatencyStragglers { model: pareto, policy: DeadlinePolicy::Fixed(0.2) };
    let adversarial = AdversarialStragglers::plan(&g, r, s, AttackKind::Greedy);
    let models: [(&str, &dyn StragglerModel); 4] = [
        ("uniform", &uniform),
        ("latency/fastest-r", &fastest),
        ("latency/deadline", &deadline),
        ("adversarial", &adversarial),
    ];

    for (name, model) in models {
        let mut ws = DecodeWorkspace::new();
        ws.reserve_redraw(k, k, s);
        let mut rng = Rng::new(42);

        let mut warmup_sum = 0.0;
        for _ in 0..3 {
            warmup_sum += ws.onestep_redraw_trial_with(code.as_ref(), model, rho, &mut rng);
        }
        assert!(warmup_sum.is_finite());

        let before = allocations_on_this_thread();
        let mut sum = 0.0;
        for _ in 0..100 {
            sum += ws.onestep_redraw_trial_with(code.as_ref(), model, rho, &mut rng);
        }
        let allocs = allocations_on_this_thread() - before;
        assert!(sum.is_finite() && sum >= 0.0);
        assert_eq!(allocs, 0, "{name}: steady-state scenario loop allocated {allocs} times");
    }
}

/// The panel decode loop (PR 6): after warmup has grown the count
/// panel, the flattened selection buffers, and every LSQR lane's
/// iteration vectors, a steady-state loop of W-trials-per-call panel
/// kernels — one-step coverage and both optimal (cold / warm-started)
/// multi-RHS solves — performs zero heap allocations.
#[test]
fn panel_trial_loop_is_allocation_free_after_warmup() {
    use gradcode::decode::PanelWorkspace;
    let (k, s, r) = (200usize, 10usize, 150usize);
    let rho = k as f64 / (r as f64 * s as f64);
    // FRC: boolean with fixed per-column degree, so the panel's count
    // and selection capacities are constant across draws.
    let g = Scheme::Frc.build(k, k, s).assignment(&mut Rng::new(51));
    let w = 4usize;
    let mut pw = PanelWorkspace::new(w);
    pw.mirror_csr(&g);
    let opts = LsqrOptions::default();
    let root = Rng::new(52);
    let mut out = vec![0.0f64; w];

    let mut warmup_sum = 0.0;
    for p in 0..3u64 {
        pw.onestep_panel(&g, r, rho, &root, p * w as u64, w, &mut out);
        warmup_sum += out[0];
        pw.optimal_panel(&g, r, &opts, None, &root, p * w as u64, w, &mut out);
        warmup_sum += out[0];
        pw.optimal_panel(&g, r, &opts, Some(rho), &root, p * w as u64, w, &mut out);
        warmup_sum += out[0];
    }
    assert!(warmup_sum.is_finite());

    let before = allocations_on_this_thread();
    let mut sum = 0.0;
    for p in 3..53u64 {
        pw.onestep_panel(&g, r, rho, &root, p * w as u64, w, &mut out);
        sum += out[0];
        pw.optimal_panel(&g, r, &opts, None, &root, p * w as u64, w, &mut out);
        sum += out[1];
        pw.optimal_panel(&g, r, &opts, Some(rho), &root, p * w as u64, w, &mut out);
        sum += out[2];
    }
    let allocs = allocations_on_this_thread() - before;
    assert!(sum.is_finite() && sum >= 0.0);
    assert_eq!(allocs, 0, "steady-state panel loop allocated {allocs} times");

    // A ragged tail call (fewer lanes than width) reuses the same
    // buffers — the count panel is lane-strided, so narrower calls
    // only ever shrink the working set.
    let before = allocations_on_this_thread();
    pw.onestep_panel(&g, r, rho, &root, 500, 3, &mut out[..3]);
    pw.optimal_panel(&g, r, &opts, Some(rho), &root, 500, 3, &mut out[..3]);
    let allocs = allocations_on_this_thread() - before;
    assert_eq!(allocs, 0, "ragged panel tail allocated {allocs} times");
}

/// The fused redraw panels (PR 9): after `reserve_redraw` has sized
/// the per-lane assignment scratch, the straggler buffers, and the
/// lane-strided coverage panel, a steady-state loop of
/// W-redraw-trials-per-call fused panels — fresh G per lane, one
/// batched err₁ sweep — performs zero heap allocations, for both the
/// uniform and the latency straggler models, including ragged tails.
#[test]
fn redraw_panel_loop_is_allocation_free_after_reserve() {
    use gradcode::decode::PanelWorkspace;
    use gradcode::stragglers::{
        DeadlinePolicy, LatencyModel, LatencyStragglers, StragglerModel, UniformStragglers,
    };
    let (k, s, r) = (60usize, 6usize, 45usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let code = Scheme::Bgc.build(k, k, s);
    let uniform = UniformStragglers::new(0.25);
    let pareto = LatencyModel::Pareto { scale: 0.05, shape: 1.5 };
    let fastest = LatencyStragglers { model: pareto, policy: DeadlinePolicy::FastestR(r) };
    let models: [(&str, &dyn StragglerModel); 2] =
        [("uniform", &uniform), ("latency/fastest-r", &fastest)];

    for (name, model) in models {
        let w = 4usize;
        let mut pw = PanelWorkspace::new(w);
        pw.reserve_redraw(k, k, s);
        let root = Rng::new(71);
        let mut out = vec![0.0f64; w];

        let mut warmup_sum = 0.0;
        for p in 0..3u64 {
            pw.onestep_redraw_panel_with(code.as_ref(), model, rho, &root, p * w as u64, w, &mut out);
            warmup_sum += out[0];
        }
        assert!(warmup_sum.is_finite());

        let before = allocations_on_this_thread();
        let mut sum = 0.0;
        for p in 3..103u64 {
            pw.onestep_redraw_panel_with(code.as_ref(), model, rho, &root, p * w as u64, w, &mut out);
            sum += out[0];
        }
        let allocs = allocations_on_this_thread() - before;
        assert!(sum.is_finite() && sum >= 0.0);
        assert_eq!(allocs, 0, "{name}: steady-state redraw panel loop allocated {allocs} times");

        // Ragged tail: fewer lanes than width reuses the same buffers.
        let before = allocations_on_this_thread();
        pw.onestep_redraw_panel_with(code.as_ref(), model, rho, &root, 500, 3, &mut out[..3]);
        let allocs = allocations_on_this_thread() - before;
        assert_eq!(allocs, 0, "{name}: ragged redraw panel tail allocated {allocs} times");
    }
}

/// The incremental anytime spine (PR 8): after `reserve_redraw`, the
/// arrival-ordered per-survivor update loop — redraw G, draw
/// stragglers, sort the arrival order, feed survivors one at a time
/// through the `IncrementalDecoder` — performs zero steady-state heap
/// allocations, with and without the anytime stopping rules (which
/// query the exact prefix err₁ after every arrival).
#[test]
fn incremental_arrival_loop_is_allocation_free_after_reserve() {
    use gradcode::stragglers::{
        DeadlinePolicy, LatencyModel, LatencyStragglers, StragglerModel, UniformStragglers,
    };
    let (k, s, r) = (60usize, 6usize, 45usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let code = Scheme::Bgc.build(k, k, s);
    let pareto = LatencyModel::Pareto { scale: 0.05, shape: 1.5 };
    let fastest = LatencyStragglers { model: pareto, policy: DeadlinePolicy::FastestR(r) };
    let uniform = UniformStragglers::new(0.25);
    let models: [(&str, &dyn StragglerModel); 2] =
        [("latency/fastest-r", &fastest), ("uniform", &uniform)];

    for (name, model) in models {
        let mut ws = DecodeWorkspace::new();
        ws.reserve_redraw(k, k, s);
        let mut rng = Rng::new(61);

        let mut warmup_sum = 0.0;
        for _ in 0..3 {
            warmup_sum +=
                ws.onestep_incremental_redraw_trial_with(code.as_ref(), model, rho, &mut rng);
            let (gather, err1) = ws.onestep_incremental_anytime_redraw_trial_with(
                code.as_ref(),
                model,
                rho,
                Some(0.5),
                Some((0.1, 0.2)),
                &mut rng,
            );
            // Uniform draws have no time axis: gather is NaN there.
            warmup_sum += err1 + if gather.is_nan() { 0.0 } else { gather };
        }
        assert!(warmup_sum.is_finite());

        let before = allocations_on_this_thread();
        let mut sum = 0.0;
        for _ in 0..100 {
            sum += ws.onestep_incremental_redraw_trial_with(code.as_ref(), model, rho, &mut rng);
            let (_gather, err1) = ws.onestep_incremental_anytime_redraw_trial_with(
                code.as_ref(),
                model,
                rho,
                Some(0.5),
                Some((0.1, 0.2)),
                &mut rng,
            );
            sum += err1;
        }
        let allocs = allocations_on_this_thread() - before;
        assert!(sum.is_finite() && sum >= 0.0);
        assert_eq!(
            allocs, 0,
            "{name}: steady-state incremental arrival loop allocated {allocs} times"
        );
    }
}

/// Control: the counter itself works — the legacy allocating path must
/// register allocations (otherwise the two tests above prove nothing).
#[test]
fn counting_allocator_detects_legacy_allocations() {
    let (k, s, r) = (200usize, 10usize, 150usize);
    let g = Scheme::Frc.build(k, k, s).assignment(&mut Rng::new(15));
    let mut rng = Rng::new(16);
    let before = allocations_on_this_thread();
    let idx = rng.sample_indices(k, r);
    let a = g.select_columns(&idx);
    let sums = a.row_sums();
    assert!(sums.iter().sum::<f64>() > 0.0);
    let allocs = allocations_on_this_thread() - before;
    assert!(allocs >= 4, "legacy path should allocate (got {allocs})");
}
