//! Integration tests across the runtime boundary: AOT HLO artifacts
//! (from `make artifacts`) loaded and executed via PJRT, validated
//! against the native Rust reference implementation — the end-to-end
//! L1/L2 ⇄ L3 numerics contract.
//!
//! These tests are skipped (with a loud message) if artifacts/ has not
//! been built; `make test` always builds artifacts first.

use gradcode::coordinator::{compute_message, ModelKind, WorkerSpec};
use gradcode::runtime::{native, Backend, CombineKind, EnginePool, Manifest};
use gradcode::training::data::{LinearDataset, MlpDataset};
use gradcode::util::Rng;

fn manifest() -> Option<Manifest> {
    // Tests run from the crate root; artifacts live in ./artifacts.
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP pjrt integration: {e:#} (run `make artifacts`)");
            None
        }
    }
}

fn randf(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

#[test]
fn pjrt_linear_grad_matches_native() {
    let Some(m) = manifest() else { return };
    let pool = EnginePool::start(m, 1).expect("engine pool");
    let backend = Backend::Pjrt(pool.handle());
    let dims = backend.linear_dims();
    let mut rng = Rng::new(1);
    for _ in 0..5 {
        let x = randf(&mut rng, dims.m * dims.d);
        let w = randf(&mut rng, dims.d);
        let y = randf(&mut rng, dims.m);
        let pjrt = backend.linear_grad(&x, &w, &y).unwrap();
        let native = native::linear_grad(dims, &x, &w, &y).unwrap();
        for (a, b) in pjrt.iter().zip(&native) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }
}

#[test]
fn pjrt_mlp_grad_matches_native() {
    let Some(m) = manifest() else { return };
    let pool = EnginePool::start(m, 1).expect("engine pool");
    let backend = Backend::Pjrt(pool.handle());
    let dims = backend.mlp_dims();
    let mut rng = Rng::new(2);
    let theta: Vec<f32> = (0..dims.flat_dim).map(|_| (rng.normal() * 0.1) as f32).collect();
    let x = randf(&mut rng, dims.m * dims.d_in);
    let y = randf(&mut rng, dims.m * dims.d_out);
    let (loss_p, grad_p) = backend.mlp_grad(&theta, &x, &y).unwrap();
    let (loss_n, grad_n) = native::mlp_grad(dims, &theta, &x, &y).unwrap();
    assert!((loss_p - loss_n).abs() < 1e-4 * (1.0 + loss_n.abs()), "{loss_p} vs {loss_n}");
    let mut max_gap = 0.0f32;
    for (a, b) in grad_p.iter().zip(&grad_n) {
        max_gap = max_gap.max((a - b).abs());
    }
    assert!(max_gap < 1e-4, "max grad gap {max_gap}");
}

#[test]
fn pjrt_combine_matches_native() {
    let Some(m) = manifest() else { return };
    let pool = EnginePool::start(m, 1).expect("engine pool");
    let backend = Backend::Pjrt(pool.handle());
    let d = backend.linear_dims().d;
    let s = backend.s_max();
    let mut rng = Rng::new(3);
    let grads = randf(&mut rng, s * d);
    let coeffs = randf(&mut rng, s);
    let pjrt = backend.combine(CombineKind::Linear, &grads, &coeffs).unwrap();
    let native = native::coded_combine(s, d, &grads, &coeffs).unwrap();
    for (a, b) in pjrt.iter().zip(&native) {
        assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
    }
}

#[test]
fn pjrt_worker_message_matches_native_backend() {
    let Some(m) = manifest() else { return };
    let pool = EnginePool::start(m, 2).expect("engine pool");
    let pjrt = Backend::Pjrt(pool.handle());
    let native_b = Backend::Native {
        linear: pjrt.linear_dims(),
        mlp: pjrt.mlp_dims(),
        s_max: pjrt.s_max(),
    };
    let dims = pjrt.linear_dims();
    let mut rng = Rng::new(4);
    let ds = LinearDataset::generate(dims, 6, 0.1, &mut rng);
    let params = randf(&mut rng, dims.d);
    let spec = WorkerSpec { id: 0, tasks: vec![0, 2, 5], coeffs: vec![1.0, 1.0, 1.0] };
    let mp = compute_message(&pjrt, ModelKind::Linear, &params, &ds.shards, &spec).unwrap();
    let mn = compute_message(&native_b, ModelKind::Linear, &params, &ds.shards, &spec).unwrap();
    for (a, b) in mp.payload.iter().zip(&mn.payload) {
        assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
    }
}

#[test]
fn pjrt_fused_message_matches_pertask() {
    // The §Perf fused module (one dispatch) must produce the same
    // message as the per-task path (s + 1 dispatches).
    use gradcode::coordinator::{compute_message_via, MessagePath};
    let Some(m) = manifest() else { return };
    let pool = EnginePool::start(m, 1).expect("engine pool");
    let backend = Backend::Pjrt(pool.handle());
    assert!(backend.has_fused_message(), "artifacts missing msg_* modules");
    let mut rng = Rng::new(6);

    // Linear model.
    let ld = backend.linear_dims();
    let ds = LinearDataset::generate(ld, 8, 0.1, &mut rng);
    let params = randf(&mut rng, ld.d);
    let spec = WorkerSpec { id: 0, tasks: vec![1, 4, 6], coeffs: vec![1.0, 1.0, 1.0] };
    let fused =
        compute_message_via(&backend, ModelKind::Linear, &params, &ds.shards, &spec, MessagePath::Fused)
            .unwrap();
    let pertask =
        compute_message_via(&backend, ModelKind::Linear, &params, &ds.shards, &spec, MessagePath::PerTask)
            .unwrap();
    for (a, b) in fused.payload.iter().zip(&pertask.payload) {
        assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "linear: {a} vs {b}");
    }

    // MLP model (losses must match too).
    let md = backend.mlp_dims();
    let ds = MlpDataset::generate(md, 6, &mut rng);
    let theta: Vec<f32> = (0..md.flat_dim).map(|_| (rng.normal() * 0.1) as f32).collect();
    let spec = WorkerSpec { id: 1, tasks: vec![0, 3], coeffs: vec![1.0, 1.0] };
    let fused =
        compute_message_via(&backend, ModelKind::Mlp, &theta, &ds.shards, &spec, MessagePath::Fused)
            .unwrap();
    let pertask =
        compute_message_via(&backend, ModelKind::Mlp, &theta, &ds.shards, &spec, MessagePath::PerTask)
            .unwrap();
    assert!((fused.loss_sum - pertask.loss_sum).abs() < 1e-4 * (1.0 + pertask.loss_sum));
    for (a, b) in fused.payload.iter().zip(&pertask.payload) {
        assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "mlp: {a} vs {b}");
    }
}

#[test]
fn pjrt_pool_parallel_submission() {
    let Some(m) = manifest() else { return };
    let pool = EnginePool::start(m, 2).expect("engine pool");
    let backend = Backend::Pjrt(pool.handle());
    let dims = backend.mlp_dims();
    let mut rng = Rng::new(5);
    let ds = MlpDataset::generate(dims, 8, &mut rng);
    let theta: Vec<f32> = (0..dims.flat_dim).map(|_| (rng.normal() * 0.1) as f32).collect();
    // Hammer the pool from several threads at once.
    let losses = gradcode::util::parallel::parallel_map(8, 4, |i| {
        let (loss, _) = backend.mlp_grad(&theta, &ds.shards[i].x, &ds.shards[i].y).unwrap();
        loss as f64
    });
    assert!(losses.iter().all(|&l| l.is_finite() && l > 0.0));
    // Same shard -> same loss regardless of which engine served it.
    let (l0, _) = backend.mlp_grad(&theta, &ds.shards[0].x, &ds.shards[0].y).unwrap();
    assert!((l0 as f64 - losses[0]).abs() < 1e-7);
}
