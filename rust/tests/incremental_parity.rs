//! Prefix-parity suite for the incremental anytime decoder
//! (`decode::incremental`) — the PR's binding contract: after the
//! first i arrivals, incremental state must be bit-identical to a
//! batch decode on exactly those i survivors, for **every** prefix,
//! every code scheme, every straggler model family, and any arrival
//! permutation. Plus: the warm-started LSQR chain agrees with a cold
//! solve at the final prefix (summary-level equality — LSQR from two
//! starting points converges to the same least-squares optimum, not
//! the same bit pattern).

use gradcode::codes::Scheme;
use gradcode::decode::{DecodeWorkspace, IncrementalDecoder};
use gradcode::linalg::{CscMatrix, LsqrOptions};
use gradcode::stragglers::{
    AdversarialStragglers, AttackKind, DeadlinePolicy, LatencyModel, LatencyStragglers,
    StragglerModel, StragglerScratch, UniformStragglers,
};
use gradcode::util::Rng;

const ALL_SCHEMES: [Scheme; 5] =
    [Scheme::Frc, Scheme::Bgc, Scheme::Rbgc, Scheme::RegularGraph, Scheme::Cyclic];

/// Drive a fresh `IncrementalDecoder` through `arrivals` one survivor
/// at a time, pinning the exact err₁ (and the coverage row counts) of
/// **every** prefix — 0, 1, …, len — bit-for-bit against the batch
/// workspace decode of exactly that prefix set.
fn check_prefix_parity(
    g: &CscMatrix,
    rho: f64,
    arrivals: &[usize],
    ws: &mut DecodeWorkspace,
    label: &str,
) {
    let mut inc = IncrementalDecoder::new();
    inc.begin(g.rows, rho);
    let want_empty = ws.err1_fused(g, &[], rho);
    assert_eq!(inc.err1().to_bits(), want_empty.to_bits(), "{label}: empty prefix");
    for i in 0..arrivals.len() {
        inc.arrive(g, arrivals[i]);
        let want = ws.err1_fused(g, &arrivals[..i + 1], rho);
        assert_eq!(
            inc.err1().to_bits(),
            want.to_bits(),
            "{label}: prefix {} of {}",
            i + 1,
            arrivals.len()
        );
    }
}

/// The full matrix: five schemes × four straggler model families ×
/// (real arrival order + a shuffled permutation of it) × every prefix,
/// over several independent draws. The ragged ends i ∈ {0, 1, len−1,
/// len} ride along since every prefix is checked.
#[test]
fn every_scheme_model_and_prefix_is_bit_identical_to_batch() {
    let (k, n, s) = (48usize, 48usize, 5usize);
    let r = 36usize;
    let rho = k as f64 / (r as f64 * s as f64);
    let mut ws = DecodeWorkspace::new();
    for (si, scheme) in ALL_SCHEMES.iter().enumerate() {
        let g = scheme.build(k, n, s).assignment(&mut Rng::new(100 + si as u64));
        let pareto = LatencyModel::Pareto { scale: 0.05, shape: 1.5 };
        let shifted = LatencyModel::ShiftedExp { base: 0.05, rate: 10.0 };
        let uniform = UniformStragglers::new(0.25);
        let fastest = LatencyStragglers { model: pareto, policy: DeadlinePolicy::FastestR(r) };
        let deadline = LatencyStragglers { model: shifted, policy: DeadlinePolicy::Fixed(0.2) };
        let adversarial = AdversarialStragglers::plan(&g, r, s, AttackKind::Greedy);
        let models: [(&str, &dyn StragglerModel); 4] = [
            ("uniform", &uniform),
            ("latency/fastest-r", &fastest),
            ("latency/deadline", &deadline),
            ("adversarial", &adversarial),
        ];
        for (mi, (mname, model)) in models.iter().enumerate() {
            let mut scratch = StragglerScratch::new();
            let mut rng = Rng::new(1 + 7 * si as u64 + mi as u64);
            for trial in 0..4 {
                model.non_stragglers_into(n, &mut rng, &mut scratch);
                scratch.compute_arrivals();
                let arrivals = scratch.arrivals.clone();
                let label = format!("{}/{mname}/trial {trial}", scheme.name());
                check_prefix_parity(&g, rho, &arrivals, &mut ws, &label);

                // Permuted arrival order: different prefix *sets*, but
                // each prefix must still match batch on exactly that
                // set (boolean coverage adds are exact, so order never
                // moves a bit).
                let mut permuted = arrivals.clone();
                rng.shuffle(&mut permuted);
                check_prefix_parity(&g, rho, &permuted, &mut ws, &format!("{label}/permuted"));
            }
        }
    }
}

/// Arrival order from a time-axis draw is sorted by (latency, worker
/// index) — the incremental prefix after i arrivals is the i fastest
/// workers, so the prefix err₁ curve pinned above is the real anytime
/// decode-at-deadline curve, not an artifact of index order.
#[test]
fn time_axis_arrival_prefixes_are_the_fastest_workers() {
    let (n, r) = (40usize, 30usize);
    let model = LatencyStragglers {
        model: LatencyModel::Pareto { scale: 0.05, shape: 1.2 },
        policy: DeadlinePolicy::FastestR(r),
    };
    let mut scratch = StragglerScratch::new();
    let mut rng = Rng::new(33);
    model.non_stragglers_into(n, &mut rng, &mut scratch);
    scratch.compute_arrivals();
    assert_eq!(scratch.arrivals.len(), r);
    for w in scratch.arrivals.windows(2) {
        assert!(
            scratch.latencies[w[0]] <= scratch.latencies[w[1]],
            "arrivals out of latency order"
        );
    }
    // The last arrival is exactly the gather time of a fastest-r draw.
    let last = *scratch.arrivals.last().unwrap();
    assert_eq!(scratch.latencies[last].to_bits(), scratch.gather_time.to_bits());
}

/// Warm-start rule, summary level: a chain of LSQR solves at growing
/// prefixes (each warm-started from the previous prefix's solution)
/// lands on the same optimum as one cold solve at the final prefix,
/// for every scheme. The cold incremental solve itself is bit-identical
/// to the batch workspace warm path (`warm = Some(rho)`).
#[test]
fn warm_started_lsqr_chain_agrees_with_cold_solve_at_final_prefix() {
    let (k, n, s, r) = (40usize, 40usize, 4usize, 30usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let opts = LsqrOptions::default();
    for (si, scheme) in ALL_SCHEMES.iter().enumerate() {
        let g = scheme.build(k, n, s).assignment(&mut Rng::new(200 + si as u64));
        let model = LatencyStragglers {
            model: LatencyModel::Pareto { scale: 0.05, shape: 1.5 },
            policy: DeadlinePolicy::FastestR(r),
        };
        let mut scratch = StragglerScratch::new();
        let mut rng = Rng::new(300 + si as u64);
        model.non_stragglers_into(n, &mut rng, &mut scratch);
        scratch.compute_arrivals();
        let arrivals = scratch.arrivals.clone();

        // Warm chain: re-solve every few arrivals, then at the end.
        let mut warm = IncrementalDecoder::new();
        warm.begin(k, rho);
        let mut warm_err = f64::NAN;
        for (i, &j) in arrivals.iter().enumerate() {
            warm.arrive(&g, j);
            if (i + 1) % 6 == 0 || i + 1 == arrivals.len() {
                warm_err = warm.optimal_err(&g, &opts);
            }
        }
        let warm_summary = warm.last_lsqr_summary().expect("warm chain solved");

        // Cold: a fresh decoder fed the same arrivals, one solve.
        let mut cold = IncrementalDecoder::new();
        cold.begin(k, rho);
        for &j in &arrivals {
            cold.arrive(&g, j);
        }
        let cold_err = cold.optimal_err(&g, &opts);
        let cold_summary = cold.last_lsqr_summary().expect("cold solve ran");

        // The cold first solve IS the batch warm path, bit for bit.
        let mut ws = DecodeWorkspace::new();
        let batch = ws.optimal_err(&g, &arrivals, &opts, Some(rho));
        assert_eq!(cold_err.to_bits(), batch.to_bits(), "{}", scheme.name());

        // Summary equality at the final prefix: same convergence
        // verdict, same optimum up to the solver's own tolerance.
        assert_eq!(
            warm_summary.converged,
            cold_summary.converged,
            "{}: convergence verdicts differ",
            scheme.name()
        );
        assert!(
            (warm_err - cold_err).abs() <= 1e-6 * (1.0 + cold_err.abs()),
            "{}: warm {warm_err} vs cold {cold_err}",
            scheme.name()
        );
        assert!(
            (warm_summary.residual_norm - cold_summary.residual_norm).abs()
                <= 1e-6 * (1.0 + cold_summary.residual_norm.abs()),
            "{}: residual norms diverge ({} vs {})",
            scheme.name(),
            warm_summary.residual_norm,
            cold_summary.residual_norm,
        );
        // err(A) ≤ err₁(A): the optimal decode starts at the one-step
        // weights and only improves.
        let err1 = ws.err1_fused(&g, &arrivals, rho);
        assert!(
            cold_err <= err1 + 1e-9 * (1.0 + err1),
            "{}: optimal {cold_err} worse than one-step {err1}",
            scheme.name()
        );
    }
}

/// The workspace-level prefix trial helpers used by the serve daemon:
/// at prefix == r they are bit-identical to the full-draw trial
/// methods (same RNG stream, same survivor draw), and the one-step
/// prefix trial matches a hand-driven incremental decode of the same
/// prefix.
#[test]
fn workspace_prefix_trials_pin_the_serve_daemon_route() {
    let (k, s, r) = (32usize, 4usize, 24usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let opts = LsqrOptions::default();
    for (si, scheme) in ALL_SCHEMES.iter().enumerate() {
        let g = scheme.build(k, k, s).assignment(&mut Rng::new(400 + si as u64));
        let mut ws = DecodeWorkspace::new();

        // Full prefix == full trial, bit for bit, on lockstep streams.
        let mut rng_a = Rng::new(41);
        let mut rng_b = Rng::new(41);
        for _ in 0..3 {
            let full = ws.onestep_trial(&g, r, rho, &mut rng_a);
            let prefixed = ws.onestep_prefix_trial(&g, r, r, rho, &mut rng_b);
            assert_eq!(full.to_bits(), prefixed.to_bits(), "{}", scheme.name());
        }
        assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{}: rng drift", scheme.name());

        // A strict prefix matches the hand-driven incremental decode
        // of the same draw's first p survivors.
        let p = r / 2;
        let mut rng_c = Rng::new(42);
        let got = ws.onestep_prefix_trial(&g, r, p, rho, &mut rng_c);
        let drawn = Rng::new(42).sample_indices(k, r);
        let mut inc = IncrementalDecoder::new();
        inc.begin(k, rho);
        for &j in &drawn[..p] {
            inc.arrive(&g, j);
        }
        assert_eq!(got.to_bits(), inc.err1().to_bits(), "{}: prefix trial", scheme.name());

        // Optimal prefix trial at full prefix == the warm optimal trial.
        let mut rng_d = Rng::new(43);
        let mut rng_e = Rng::new(43);
        let full = ws.optimal_trial(&g, r, &opts, Some(rho), &mut rng_d);
        let prefixed = ws.optimal_prefix_trial(&g, r, r, &opts, Some(rho), &mut rng_e);
        assert_eq!(full.to_bits(), prefixed.to_bits(), "{}: optimal prefix", scheme.name());
    }
}
