//! Randomized linalg parity suite — the pin that lets the CSR mirror
//! and the blocked kernels replace the CSC scalar paths on the hot
//! loops without any drift.
//!
//! A seeded mini-proptest harness over `util::rng` (no external deps)
//! generates ≥ 200 random sparse matrices across shapes, densities,
//! and edge cases (empty rows, empty columns, zero-size dimensions,
//! duplicate entries, boolean and weighted values) and asserts:
//!
//! * CSR vs CSC **bit-identical** results for `matvec`, `t_matvec`,
//!   and `row_sums` (the conversion preserves per-row accumulation
//!   order, so this is exact equality, not tolerance);
//! * `to_csr_into` on a reused buffer == fresh `to_csr`;
//! * blocked vs scalar kernel parity: bit-exact on integer-valued
//!   data, ≤ 1e-12 relative on arbitrary floats;
//! * the streamed err_1 (CSR + column counts) is bit-identical to the
//!   fused CSC accumulation on boolean matrices.

use gradcode::decode::{err1_from_supports, err1_streamed_counts};
use gradcode::linalg::{blocked, CscMatrix, CsrMatrix};
use gradcode::util::Rng;

/// One random CSC matrix: shape, density, and value style all drawn
/// from `rng`, with explicit edge cases mixed in via `case_idx`.
fn random_matrix(rng: &mut Rng, case_idx: usize) -> CscMatrix {
    // Cycle through deliberate edge shapes before falling back to
    // general random shapes, so the suite always covers them.
    let (rows, cols) = match case_idx % 8 {
        0 => (1, 1),
        1 => (1 + rng.usize(6), 0),            // no columns
        2 => (1, 1 + rng.usize(30)),           // single row
        3 => (1 + rng.usize(30), 1),           // single column
        _ => (1 + rng.usize(40), 1 + rng.usize(40)),
    };
    let density = [0.0, 0.05, 0.3, 0.9][rng.usize(4)];
    let boolean = rng.bernoulli(0.5);
    let mut columns: Vec<Vec<(usize, f64)>> = Vec::with_capacity(cols);
    for _ in 0..cols {
        let mut col: Vec<(usize, f64)> = (0..rows)
            .filter(|_| rng.bernoulli(density))
            .map(|i| (i, if boolean { 1.0 } else { rng.normal() }))
            .collect();
        // Occasionally force an empty column or a duplicate entry.
        if rng.bernoulli(0.1) {
            col.clear();
        } else if !col.is_empty() && rng.bernoulli(0.15) {
            let dup = col[rng.usize(col.len())];
            col.push(dup);
        }
        columns.push(col);
    }
    // Occasionally blank an entire row (empty-row edge case).
    if rows > 1 && rng.bernoulli(0.3) {
        let blank = rng.usize(rows);
        for col in columns.iter_mut() {
            col.retain(|&(r, _)| r != blank);
        }
    }
    CscMatrix::from_columns(rows, columns)
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str, case: usize) {
    assert_eq!(a.len(), b.len(), "{what} length (case {case})");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}] (case {case}): {x} vs {y}");
    }
}

/// The headline pin: every CSR kernel is bit-identical to its CSC
/// counterpart on ≥ 200 random matrices.
#[test]
fn csr_kernels_bit_identical_to_csc_over_200_cases() {
    let mut rng = Rng::new(0xC5C_C5A);
    let mut csr_buf = CsrMatrix::empty();
    let cases = 220;
    for case in 0..cases {
        let a = random_matrix(&mut rng, case);
        let csr = a.to_csr();

        // Reused-buffer conversion must equal the fresh one.
        a.to_csr_into(&mut csr_buf);
        assert_eq!(csr_buf, csr, "to_csr_into mismatch (case {case})");

        // Structure: same dims/nnz, dense forms agree, rows sorted.
        assert_eq!((csr.rows, csr.cols, csr.nnz()), (a.rows, a.cols, a.nnz()));
        assert_eq!(csr.to_dense(), a.to_dense(), "dense mismatch (case {case})");
        for i in 0..csr.rows {
            let cols_of_row: Vec<usize> = csr.row(i).map(|(c, _)| c).collect();
            assert!(
                cols_of_row.windows(2).all(|w| w[0] <= w[1]),
                "row {i} not in column order (case {case})"
            );
        }

        // Kernels: bit-identical, including a zero-laden x (the CSC
        // matvec skips zero x entries; CSR must skip identically).
        let mut x_cols: Vec<f64> = (0..a.cols).map(|_| rng.normal()).collect();
        for xi in x_cols.iter_mut() {
            if rng.bernoulli(0.25) {
                *xi = 0.0;
            }
        }
        let x_rows: Vec<f64> = (0..a.rows).map(|_| rng.normal()).collect();
        assert_bits_eq(&a.matvec(&x_cols), &csr.matvec(&x_cols), "matvec", case);
        assert_bits_eq(&a.t_matvec(&x_rows), &csr.t_matvec(&x_rows), "t_matvec", case);
        assert_bits_eq(&a.row_sums(), &csr.row_sums(), "row_sums", case);
        assert_eq!(a.row_degrees(), csr.row_degrees(), "row_degrees (case {case})");
    }
}

/// Blocked reductions vs the scalar definitions: exact on integers,
/// ≤ 1e-12 relative on floats, across lengths that exercise every
/// tail residue (len mod 4 ∈ {0,1,2,3}).
#[test]
fn blocked_kernels_match_scalar_over_all_tail_residues() {
    let mut rng = Rng::new(0xB10C);
    for case in 0..120 {
        let n = case % 4 + 4 * rng.usize(12); // every residue, up to ~48
        let integer = case % 2 == 0;
        let gen = |rng: &mut Rng| -> f64 {
            if integer {
                rng.usize(200) as f64 - 100.0
            } else {
                rng.normal()
            }
        };
        let a: Vec<f64> = (0..n).map(|_| gen(&mut rng)).collect();
        let b: Vec<f64> = (0..n).map(|_| gen(&mut rng)).collect();

        let dot_ref: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let sum_ref: f64 = a.iter().sum();
        let nsq_ref: f64 = a.iter().map(|x| x * x).sum();
        let diff_ref: f64 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();

        if integer {
            assert_eq!(blocked::dot(&a, &b).to_bits(), dot_ref.to_bits(), "dot case {case}");
            assert_eq!(blocked::sum(&a).to_bits(), sum_ref.to_bits(), "sum case {case}");
            assert_eq!(blocked::norm2_sq(&a).to_bits(), nsq_ref.to_bits(), "nsq case {case}");
            assert_eq!(
                blocked::diff_norm2_sq(&a, &b).to_bits(),
                diff_ref.to_bits(),
                "diff case {case}"
            );
        } else {
            let tol = |r: f64| 1e-12 * (1.0 + r.abs());
            assert!((blocked::dot(&a, &b) - dot_ref).abs() <= tol(dot_ref), "dot case {case}");
            assert!((blocked::sum(&a) - sum_ref).abs() <= tol(sum_ref), "sum case {case}");
            assert!((blocked::norm2_sq(&a) - nsq_ref).abs() <= tol(nsq_ref), "nsq case {case}");
            assert!(
                (blocked::diff_norm2_sq(&a, &b) - diff_ref).abs() <= tol(diff_ref),
                "diff case {case}"
            );
        }

        // Elementwise kernels are bit-identical regardless of values.
        let alpha = gen(&mut rng);
        let mut y_scalar = b.clone();
        for (yi, xi) in y_scalar.iter_mut().zip(&a) {
            *yi += alpha * xi;
        }
        let mut y_blocked = b.clone();
        blocked::axpy(alpha, &a, &mut y_blocked);
        assert_bits_eq(&y_scalar, &y_blocked, "axpy", case);
    }
}

/// Streamed err_1 (CSR + counts) is bit-identical to the fused CSC
/// accumulation on boolean matrices — any straggler set, including
/// repeats and the empty set.
#[test]
fn streamed_err1_bit_identical_to_fused_on_boolean_matrices() {
    let mut rng = Rng::new(0xE221);
    let mut row_acc = Vec::new();
    for case in 0..80 {
        let (rows, cols) = (1 + rng.usize(50), 1 + rng.usize(50));
        let density = [0.05, 0.2, 0.6][rng.usize(3)];
        let supports: Vec<Vec<usize>> = (0..cols)
            .map(|_| (0..rows).filter(|_| rng.bernoulli(density)).collect())
            .collect();
        let g = CscMatrix::from_supports(rows, supports);
        let csr = g.to_csr();

        // Selection: sometimes empty, sometimes with repeats.
        let sel: Vec<usize> = match case % 3 {
            0 => Vec::new(),
            1 => (0..1 + rng.usize(cols)).map(|_| rng.usize(cols)).collect(), // repeats ok
            _ => rng.sample_indices(cols, 1 + rng.usize(cols)),
        };
        let rho = 0.25 + rng.f64();

        let fused = err1_from_supports(&g, &sel, rho, &mut row_acc);
        let mut counts = vec![0u32; cols];
        for &j in &sel {
            counts[j] += 1;
        }
        let streamed = err1_streamed_counts(&csr, &counts, rho);
        assert_eq!(
            fused.to_bits(),
            streamed.to_bits(),
            "case {case}: fused {fused} vs streamed {streamed}"
        );
    }
}
