//! Integration test for the resumable fan-out driver:
//! `repro run --fanout N --resume DIR` must reuse the valid shard
//! artifacts already on disk, respawn only the absent/corrupt ones,
//! and still emit the unsharded-identical CSV — the "kill one shard
//! and pick the run back up" workflow.

use std::path::Path;
use std::process::Command;

const BIN: &str = env!("CARGO_BIN_EXE_gradcode");

/// Run the binary, assert success, return (stdout, stderr).
fn run_ok(args: &[&str]) -> (String, String) {
    let out = Command::new(BIN).args(args).output().expect("spawning repro");
    assert!(
        out.status.success(),
        "repro {args:?} failed (status {:?}):\n{}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    (
        String::from_utf8(out.stdout).expect("utf-8 stdout"),
        String::from_utf8(out.stderr).expect("utf-8 stderr"),
    )
}

fn artifact_paths(dir: &Path) -> Vec<std::path::PathBuf> {
    let mut v: Vec<_> = std::fs::read_dir(dir)
        .expect("artifacts dir")
        .map(|e| e.expect("dir entry").path())
        .filter(|p| p.extension().is_some_and(|e| e == "json"))
        .collect();
    v.sort();
    v
}

#[test]
fn resume_respawns_only_missing_and_corrupt_shards() {
    let dir = std::env::temp_dir().join(format!("gradcode-resume-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dir_s = dir.to_str().expect("utf-8 temp dir");

    let job_args =
        ["--table", "thm5", "--trials", "40", "--k", "12", "--s", "3", "--threads", "1"];

    // Reference: the unsharded run.
    let mut unsharded_cmd: Vec<&str> = vec!["tables"];
    unsharded_cmd.extend_from_slice(&job_args);
    let (unsharded, _) = run_ok(&unsharded_cmd);

    // Full fan-out, keeping the artifacts.
    let mut run_cmd: Vec<&str> = vec!["run", "--fanout", "4", "--artifacts-dir", dir_s];
    run_cmd.extend_from_slice(&job_args);
    let (first_csv, _) = run_ok(&run_cmd);
    assert_eq!(first_csv, unsharded, "fan-out CSV != unsharded CSV");
    let paths = artifact_paths(&dir);
    assert_eq!(paths.len(), 4, "expected 4 shard artifacts, got {paths:?}");

    // Simulate a killed run: one shard never finished (file missing),
    // another died mid-write (corrupt file).
    std::fs::remove_file(&paths[1]).expect("deleting shard artifact");
    std::fs::write(&paths[2], "{\"format\": \"gradcode-shard/v3\", truncated").expect("corrupting");

    // Resume: only the two damaged shards get respawned; the merged CSV
    // is still byte-identical to the unsharded run.
    let mut resume_cmd: Vec<&str> = vec!["run", "--fanout", "4", "--resume", dir_s];
    resume_cmd.extend_from_slice(&job_args);
    let (resumed_csv, stderr) = run_ok(&resume_cmd);
    assert_eq!(resumed_csv, unsharded, "resumed CSV != unsharded CSV");
    assert!(
        stderr.contains("2/4 shard(s) present"),
        "resume accounting missing from stderr:\n{stderr}"
    );
    assert!(stderr.contains("respawning [1, 2]"), "wrong respawn set:\n{stderr}");
    assert!(stderr.contains("discarding corrupt"), "corrupt artifact not reported:\n{stderr}");

    // All four artifacts are back on disk and a second resume finds the
    // set complete (respawns nothing).
    assert_eq!(artifact_paths(&dir).len(), 4);
    let (again_csv, stderr) = run_ok(&resume_cmd);
    assert_eq!(again_csv, unsharded);
    assert!(
        stderr.contains("4/4 shard(s) present") && stderr.contains("respawning []"),
        "complete resume should respawn nothing:\n{stderr}"
    );

    // --resume and --artifacts-dir together is a usage error (exit 2).
    let mut bad_cmd: Vec<&str> =
        vec!["run", "--fanout", "4", "--resume", dir_s, "--artifacts-dir", dir_s];
    bad_cmd.extend_from_slice(&job_args);
    let out = Command::new(BIN).args(&bad_cmd).output().expect("spawning repro");
    assert_eq!(out.status.code(), Some(2), "expected usage exit");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn non_resume_run_refuses_a_dir_with_stale_artifacts() {
    let dir = std::env::temp_dir().join(format!("gradcode-stale-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dir_s = dir.to_str().expect("utf-8 temp dir");

    let job_args =
        ["--table", "thm5", "--trials", "40", "--k", "12", "--s", "3", "--threads", "1"];
    let mut run_cmd: Vec<&str> = vec!["run", "--fanout", "2", "--artifacts-dir", dir_s];
    run_cmd.extend_from_slice(&job_args);

    // First run populates the directory (simulating a crashed or
    // completed earlier run that left its shard artifacts behind).
    run_ok(&run_cmd);
    assert_eq!(artifact_paths(&dir).len(), 2);

    // A second NON-resume run pointed at the same directory must
    // refuse: silently reusing (or mixing with) the stale artifacts
    // would corrupt the fresh verify/merge set.
    let out = Command::new(BIN).args(&run_cmd).output().expect("spawning repro");
    assert!(!out.status.success(), "non-resume run accepted a dir holding stale artifacts");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("already holds") && stderr.contains("--resume"),
        "refusal should name the hazard and point at --resume:\n{stderr}"
    );
    // Refusal happens before any child spawns: the stale artifacts are
    // untouched, so --resume can still pick them up.
    assert_eq!(artifact_paths(&dir).len(), 2, "refusal must not disturb the artifacts");

    // The escape hatches both work: --resume reuses the set as-is...
    let (unsharded, _) = {
        let mut c: Vec<&str> = vec!["tables"];
        c.extend_from_slice(&job_args);
        run_ok(&c)
    };
    let mut resume_cmd: Vec<&str> = vec!["run", "--fanout", "2", "--resume", dir_s];
    resume_cmd.extend_from_slice(&job_args);
    let (csv, stderr) = run_ok(&resume_cmd);
    assert_eq!(csv, unsharded);
    assert!(stderr.contains("2/2 shard(s) present"), "resume should reuse both:\n{stderr}");

    // ...and a clean directory satisfies the non-resume path.
    let _ = std::fs::remove_dir_all(&dir);
    let (csv, _) = run_ok(&run_cmd);
    assert_eq!(csv, unsharded);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_ignores_foreign_artifacts() {
    let dir = std::env::temp_dir().join(format!("gradcode-resume-foreign-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("creating dir");
    let dir_s = dir.to_str().expect("utf-8 temp dir");

    // Seed the dir with an artifact from a DIFFERENT job (other seed).
    // thm11 derives s internally and rejects --s, so it is left off.
    let mut other_cmd: Vec<&str> = vec![
        "shard", "--table", "thm11", "--trials", "10", "--k", "12", "--seed", "9", "--shard-id",
        "0", "--num-shards", "2",
    ];
    let foreign = dir.join("foreign.json");
    let foreign_s = foreign.to_str().expect("utf-8 path");
    other_cmd.extend_from_slice(&["--out", foreign_s]);
    run_ok(&other_cmd);

    // A resumed run of another job must skip it and still succeed.
    let (unsharded, _) = run_ok(&[
        "tables", "--table", "thm11", "--trials", "10", "--k", "12", "--threads", "1",
    ]);
    let (csv, stderr) = run_ok(&[
        "run", "--fanout", "2", "--resume", dir_s, "--table", "thm11", "--trials", "10", "--k",
        "12", "--threads", "1",
    ]);
    assert_eq!(csv, unsharded);
    assert!(
        stderr.contains("skipping") && stderr.contains("0/2 shard(s) present"),
        "foreign artifact not skipped:\n{stderr}"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
