//! Parity pins for the zero-allocation decode pipeline: the fused and
//! workspace-reused paths must reproduce the allocating reference paths
//! exactly (one-step: bit-identical; ISSUE acceptance: ≤ 1e-12 across
//! 100 seeded trials), and `parallel_map` results must not depend on
//! thread count.

use gradcode::codes::{GradientCode, Scheme};
use gradcode::decode::{err1_from_supports, DecodeWorkspace, OneStepDecoder, OptimalDecoder};
use gradcode::linalg::{lsqr, lsqr_with, LsqrOptions, LsqrWorkspace};
use gradcode::sim::MonteCarlo;
use gradcode::util::parallel::parallel_map_with;
use gradcode::util::Rng;

/// 100 seeded trials, three schemes: fused vs materialized one-step
/// error. The paths share accumulation order, so the agreement is in
/// fact bit-for-bit — far inside the 1e-12 acceptance band.
#[test]
fn fused_err1_matches_materialized_100_trials() {
    let schemes = [Scheme::Frc, Scheme::Bgc, Scheme::RegularGraph];
    let (k, s) = (300usize, 10usize);
    let mut ws = DecodeWorkspace::new();
    let mut trials = 0;
    for (si, &scheme) in schemes.iter().enumerate() {
        let mut rng = Rng::new(1000 + si as u64);
        let g = scheme.build(k, k, s).assignment(&mut rng);
        for _ in 0..34 {
            let r = 1 + rng.usize(k);
            let idx = rng.sample_indices(k, r);
            let rho = k as f64 / (r as f64 * s as f64);

            // Seed reference: materialize A, row-sum, square.
            let a = g.select_columns(&idx);
            let seed_path = OneStepDecoder::new(rho).err1(&a);

            let fused = ws.err1_fused(&g, &idx, rho);
            assert!(
                (fused - seed_path).abs() <= 1e-12,
                "{scheme:?} r={r}: fused {fused} vs seed {seed_path}"
            );
            assert_eq!(fused.to_bits(), seed_path.to_bits(), "{scheme:?} r={r}");

            let materialized = ws.err1_materialized(&g, &idx, rho);
            assert_eq!(fused.to_bits(), materialized.to_bits());
            trials += 1;
        }
    }
    assert!(trials >= 100, "only {trials} trials");
}

/// The free-function fused path with a bare buffer agrees with the
/// workspace method (they are the same code; this pins the public API).
#[test]
fn free_function_matches_workspace_method() {
    let g = Scheme::Bgc.build(60, 60, 6).assignment(&mut Rng::new(5));
    let mut ws = DecodeWorkspace::new();
    let mut buf = Vec::new();
    let mut rng = Rng::new(6);
    for _ in 0..20 {
        let idx = rng.sample_indices(60, 45);
        let a = err1_from_supports(&g, &idx, 0.2, &mut buf);
        let b = ws.err1_fused(&g, &idx, 0.2);
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

/// Workspace LSQR (cold) is bit-identical to the allocating decoder.
#[test]
fn workspace_optimal_matches_allocating_decoder() {
    let (k, s) = (120usize, 6usize);
    let mut ws = DecodeWorkspace::new();
    let opts = LsqrOptions::default();
    for (si, scheme) in [Scheme::Frc, Scheme::Bgc].into_iter().enumerate() {
        let mut rng = Rng::new(2000 + si as u64);
        let g = scheme.build(k, k, s).assignment(&mut rng);
        for _ in 0..15 {
            let r = 1 + rng.usize(k);
            let idx = rng.sample_indices(k, r);
            let reference = OptimalDecoder::new().err(&g.select_columns(&idx));
            let cold = ws.optimal_err(&g, &idx, &opts, None);
            assert_eq!(
                cold.to_bits(),
                reference.to_bits(),
                "{scheme:?} r={r}: {cold} vs {reference}"
            );
        }
    }
}

/// Warm-started optimal decode reaches the same minimum (the residual
/// of a least-squares problem is unique even when x is not). Covers
/// both BGC and the rank-deficient FRC regime — duplicate columns are
/// the solver's hardest case and the one production warm-start call
/// site (thm6_table) runs exclusively on FRC submatrices.
#[test]
fn warm_start_reaches_same_error() {
    let k = 100usize;
    let mut ws = DecodeWorkspace::new();
    let opts = LsqrOptions::default();
    // FRC needs s | k, hence s = 10 there.
    for (seed, scheme, s) in [(77u64, Scheme::Bgc, 8usize), (78, Scheme::Frc, 10)] {
        let mut rng = Rng::new(seed);
        let g = scheme.build(k, k, s).assignment(&mut rng);
        for _ in 0..20 {
            let r = (k / 2) + rng.usize(k / 2);
            let idx = rng.sample_indices(k, r);
            let rho = k as f64 / (r as f64 * s as f64);
            let cold = ws.optimal_err(&g, &idx, &opts, None);
            let warm = ws.optimal_err(&g, &idx, &opts, Some(rho));
            assert!(
                (warm - cold).abs() <= 1e-7 * (1.0 + cold.abs()),
                "{scheme:?} r={r}: warm {warm} vs cold {cold}"
            );
        }
    }
}

/// thm6's exact production shape: FRC, warm start at ρ·1_r, compared
/// against the allocating cold reference across the δ range the table
/// sweeps — the published values must not drift.
#[test]
fn thm6_shape_warm_start_matches_cold_reference() {
    let (k, s) = (20usize, 5usize);
    let mut ws = DecodeWorkspace::new();
    let opts = LsqrOptions::default();
    let mut rng = Rng::new(79);
    for &delta in &[0.0, 0.25, 0.5, 0.75] {
        let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
        let rho = k as f64 / (r as f64 * s as f64);
        for _ in 0..10 {
            let g = Scheme::Frc.build(k, k, s).assignment(&mut rng);
            let idx = rng.sample_indices(k, r);
            let reference = OptimalDecoder::new().err(&g.select_columns(&idx));
            let warm = ws.optimal_err(&g, &idx, &opts, Some(rho));
            assert!(
                (warm - reference).abs() <= 1e-7 * (1.0 + reference.abs()),
                "delta={delta} r={r}: warm {warm} vs reference {reference}"
            );
        }
    }
}

/// lsqr_with(None) == lsqr, down to the bit, on rank-deficient FRC
/// submatrices (duplicate columns) — the solver's hardest regime.
#[test]
fn lsqr_with_parity_on_rank_deficient_instances() {
    let g = Scheme::Frc.build(40, 40, 5).assignment(&mut Rng::new(3));
    let mut rng = Rng::new(4);
    let mut ws = LsqrWorkspace::new();
    let opts = LsqrOptions::default();
    for _ in 0..25 {
        let idx = rng.sample_indices(40, 25);
        let a = g.select_columns(&idx);
        let b = vec![1.0; a.rows];
        let reference = lsqr(&a, &b, &opts);
        let summary = lsqr_with(&a, &b, &opts, None, &mut ws);
        assert_eq!(summary.residual_norm.to_bits(), reference.residual_norm.to_bits());
        assert_eq!(summary.iterations, reference.iterations);
        assert_eq!(ws.x(), &reference.x[..]);
    }
}

/// The CSR-streamed `err1` pins against the PR 1 paths (fused CSC
/// accumulation AND the materialized `select_columns` + `row_sums`
/// reference) on the exact table configurations: thm5/thm10 (FRC,
/// k=20, s=5), thm8's threshold shapes, and the thm21/thm24 k-sweep
/// (BGC and rBGC). All boolean codes, so the agreement is bit-for-bit.
#[test]
fn csr_streamed_err1_matches_pr1_paths_on_thm_configurations() {
    let mut ws = DecodeWorkspace::new();
    // (scheme, k, s) of the published table sweeps.
    let configs = [
        (Scheme::Frc, 20usize, 5usize),       // thm5 / thm6 / thm10
        (Scheme::Frc, 20, 10),                // thm8 threshold shape
        (Scheme::Bgc, 30, 4),                 // thm21 sweep point
        (Scheme::Bgc, 60, 5),                 // thm21 sweep point
        (Scheme::Rbgc, 30, 4),                // thm24 sweep point
        (Scheme::RegularGraph, 30, 5),        // fig. 2-4 companion
    ];
    for (ci, &(scheme, k, s)) in configs.iter().enumerate() {
        let mut rng = Rng::new(4000 + ci as u64);
        let g = scheme.build(k, k, s).assignment(&mut rng);
        ws.mirror_csr(&g);
        for &delta in &[0.0, 0.25, 0.5, 0.75] {
            let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
            let rho = k as f64 / (r as f64 * s as f64);
            for _ in 0..5 {
                let idx = rng.sample_indices(k, r);
                let seed_path = OneStepDecoder::new(rho).err1(&g.select_columns(&idx));
                let fused = ws.err1_fused(&g, &idx, rho);
                let streamed = ws.err1_streamed(&idx, rho);
                assert_eq!(
                    streamed.to_bits(),
                    seed_path.to_bits(),
                    "{scheme:?} k={k} s={s} delta={delta}: streamed {streamed} vs seed {seed_path}"
                );
                assert_eq!(streamed.to_bits(), fused.to_bits());
            }
        }
    }
}

/// The table refactor onto the re-draw trials must not move a single
/// bit: a Monte-Carlo mean through `onestep_redraw_trial` /
/// `optimal_redraw_trial` (warm-started, thm6's production shape)
/// equals the PR 1 closure form (`assignment` + `*_trial`) exactly.
#[test]
fn redraw_monte_carlo_means_match_pr1_closure_form() {
    let (k, s) = (20usize, 5usize);
    let opts = LsqrOptions::default();
    for &delta in &[0.25, 0.5] {
        let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
        let rho = k as f64 / (r as f64 * s as f64);
        let mc = MonteCarlo::new(150, 31).with_threads(4);

        let legacy_onestep = mc.mean_ws(DecodeWorkspace::new, |ws, rng| {
            let g = Scheme::Frc.build(k, k, s).assignment(rng);
            ws.onestep_trial(&g, r, rho, rng)
        });
        let code = Scheme::Frc.build(k, k, s);
        let redraw_onestep = mc.mean_ws(DecodeWorkspace::new, |ws, rng| {
            ws.onestep_redraw_trial(code.as_ref(), r, rho, rng)
        });
        assert_eq!(legacy_onestep.to_bits(), redraw_onestep.to_bits(), "delta={delta}");

        let legacy_optimal = mc.mean_ws(DecodeWorkspace::new, |ws, rng| {
            let g = Scheme::Frc.build(k, k, s).assignment(rng);
            ws.optimal_trial(&g, r, &opts, Some(rho), rng)
        });
        let redraw_optimal = mc.mean_ws(DecodeWorkspace::new, |ws, rng| {
            ws.optimal_redraw_trial(code.as_ref(), r, &opts, Some(rho), rng)
        });
        assert_eq!(legacy_optimal.to_bits(), redraw_optimal.to_bits(), "delta={delta}");
    }
}

/// Monte-Carlo means through the workspace pipeline are identical for
/// every thread count (the per-trial RNG fork plus position-addressed
/// output writes make scheduling invisible).
#[test]
fn workspace_monte_carlo_thread_invariance() {
    let (k, s, r) = (40usize, 5usize, 30usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let run = |threads: usize| {
        MonteCarlo::new(200, 9).with_threads(threads).mean_ws(DecodeWorkspace::new, |ws, rng| {
            let g = Scheme::Bgc.build(k, k, s).assignment(rng);
            ws.onestep_trial(&g, r, rho, rng)
        })
    };
    let a = run(1);
    let b = run(4);
    let c = run(11);
    assert_eq!(a.to_bits(), b.to_bits());
    assert_eq!(b.to_bits(), c.to_bits());
}

/// parallel_map_with output is bit-identical across thread counts even
/// for heavier per-item work (LSQR solves of varying difficulty).
#[test]
fn parallel_map_with_bit_identical_across_threads() {
    let g = Scheme::Bgc.build(30, 30, 4).assignment(&mut Rng::new(8));
    let opts = LsqrOptions::default();
    let run = |threads: usize| {
        parallel_map_with(
            64,
            threads,
            DecodeWorkspace::new,
            |ws, i| {
                let mut rng = Rng::new(500 + i as u64);
                let r = 5 + (i % 20);
                ws.optimal_trial(&g, r, &opts, None, &mut rng)
            },
        )
    };
    let a = run(2);
    let b = run(7);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

/// The scenario-spine acceptance pin: under the **default uniform
/// scenario**, the refactored sweeps emit CSVs bit-identical to the
/// pre-refactor hard-coded-sampling pipeline. The pre-refactor form is
/// reconstructed here from the r-based workspace trial methods (which
/// the spine's uniform model must reproduce RNG-draw for RNG-draw):
/// fig3 (LSQR sweep) and thm6 (warm-started FRC table) — the two
/// production shapes the ISSUE names.
#[test]
fn uniform_scenario_csv_matches_pre_refactor_fig3_and_thm6() {
    use gradcode::sim::figures::{FigureConfig, FIG_SCHEMES};
    use gradcode::sim::tables::thm6_expected;
    use gradcode::sim::{JobKind, JobSpec, Shard};
    use gradcode::stragglers::Scenario;

    // ---- fig3 through the spine (JobSpec::run, default scenario).
    let (k, trials, seed) = (16usize, 20usize, 2017u64);
    let job = JobSpec {
        kind: JobKind::Figure,
        id: "3".into(),
        trials,
        seed,
        k,
        s: 0,
        tmax: 0,
        scenario: Scenario::default(),
    };
    let spine_csv = job.run(Shard::full(), Some(2)).unwrap().to_csv();

    // The pre-refactor sweep, reconstructed: same point order, the
    // r-based `optimal_redraw_trial`, same CSV formatting.
    let mut cfg = FigureConfig::paper(trials, seed);
    cfg.k = k;
    cfg.mc = MonteCarlo::new(trials, seed).with_threads(2);
    let opts = LsqrOptions::default();
    let mut legacy = String::from("figure,scheme,s,delta,t,value\n");
    for &scheme in &FIG_SCHEMES {
        for &s in &cfg.s_values {
            for &delta in &cfg.deltas {
                let r = cfg.r(delta);
                let code = scheme.build(k, k, s);
                let mean = cfg.mc.mean_ws(DecodeWorkspace::new, |ws, rng| {
                    ws.optimal_redraw_trial(code.as_ref(), r, &opts, None, rng)
                });
                legacy.push_str(&format!(
                    "fig3,{},{},{:.3},0,{:.6e}\n",
                    scheme.name(),
                    s,
                    delta,
                    mean / k as f64
                ));
            }
        }
    }
    assert_eq!(spine_csv, legacy, "fig3 CSV drifted from the pre-refactor bytes");

    // ---- thm6 through the spine.
    let (k, s, trials, seed) = (12usize, 3usize, 30usize, 2017u64);
    let job = JobSpec {
        kind: JobKind::Table,
        id: "thm6".into(),
        trials,
        seed,
        k,
        s,
        tmax: 0,
        scenario: Scenario::default(),
    };
    let spine_csv = job.run(Shard::full(), Some(2)).unwrap().to_csv();

    let mc = MonteCarlo::new(trials, seed).with_threads(2);
    let code = Scheme::Frc.build(k, k, s);
    let mut legacy = String::from("table,label,expected,measured,note\n");
    for &delta in &[0.1, 0.25, 0.5, 0.75] {
        let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
        let rho = k as f64 / (r as f64 * s as f64);
        let mean = mc.mean_ws(DecodeWorkspace::new, |ws, rng| {
            ws.optimal_redraw_trial(code.as_ref(), r, &opts, Some(rho), rng)
        });
        legacy.push_str(&format!(
            "thm6,k={k} s={s} delta={delta:.2},{:.6e},{:.6e},E[err(A_frc)]\n",
            thm6_expected(k, r, s),
            mean
        ));
    }
    assert_eq!(spine_csv, legacy, "thm6 CSV drifted from the pre-refactor bytes");
}

/// The tta scenario acceptance pin (PR 8): the `tta` study now streams
/// every trial's survivors through the incremental decoder in arrival
/// order, and its CSV must stay byte-identical to the legacy post-hoc
/// path (one batch err₁ decode after the gather) under the default
/// scenario configuration — the prefix-parity contract at the full
/// prefix, observed at the published-artifact level.
#[test]
fn tta_csv_from_incremental_path_matches_post_hoc_reconstruction() {
    use gradcode::sim::figures::FIG_SCHEMES;
    use gradcode::sim::scenario::{tta_deltas, ScenarioPartialPoint, TTA_POLICIES};
    use gradcode::sim::{JobKind, JobSpec, Shard};
    use gradcode::stragglers::{DeadlinePolicy, LatencyStragglers, Scenario, StragglerModel};

    let (k, s, trials, seed) = (16usize, 4usize, 12usize, 2017u64);
    let scenario = Scenario::parse("pareto:0.02,1.5").unwrap();
    let job = JobSpec {
        kind: JobKind::Scenario,
        id: "tta".into(),
        trials,
        seed,
        k,
        s,
        tmax: 0,
        scenario: scenario.clone(),
    };
    let spine_csv = job.run(Shard::full(), Some(2)).unwrap().to_csv();

    // Post-hoc reconstruction: the identical sweep, but each trial
    // decodes once on the full survivor set (the pre-incremental
    // batch trial) instead of streaming arrivals.
    let mc = MonteCarlo::new(trials, seed).with_threads(2);
    let Scenario::Latency { model: latency, .. } = scenario else { panic!("latency scenario") };
    let mut legacy = String::from("scenario,scheme,policy,s,delta,gather,err1\n");
    for &policy_arm in &TTA_POLICIES {
        for &scheme in &FIG_SCHEMES {
            for delta in tta_deltas() {
                let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
                let rho = k as f64 / (r as f64 * s as f64);
                let code = scheme.build(k, k, s);
                let policy = match policy_arm {
                    "deadline" => DeadlinePolicy::Fixed(latency.quantile(1.0 - delta)),
                    _ => DeadlinePolicy::FastestR(r),
                };
                let model = LatencyStragglers { model: latency, policy };
                let partial =
                    mc.mean_curve_partial_ws(2, Shard::full(), DecodeWorkspace::new, |ws, rng| {
                        let err = ws.onestep_redraw_trial_with(
                            code.as_ref(),
                            &model as &dyn StragglerModel,
                            rho,
                            rng,
                        );
                        vec![ws.last_gather_time(), err]
                    });
                let point = ScenarioPartialPoint {
                    study: "tta",
                    scheme: scheme.name().to_string(),
                    policy: policy_arm,
                    s,
                    delta,
                    k,
                    partial,
                };
                legacy.push_str(&point.finalize().to_csv());
                legacy.push('\n');
            }
        }
    }
    assert_eq!(spine_csv, legacy, "tta CSV drifted from the post-hoc decode path");
}

/// Panel decode (PR 6): the W-trials-per-call kernels must reproduce
/// every scalar trial bit for bit, at every width, including ragged
/// tails (trials = 11 is not divisible by 3, 4, or 8) — and the RNG
/// fork contract is lockstep: lane `l` of the panel at `base` consumes
/// exactly the stream `root.fork(base + l)`, the scalar trial's stream
/// for trial index `base + l`.
#[test]
fn panel_trials_bit_identical_to_scalar_for_all_widths() {
    use gradcode::decode::PanelWorkspace;
    let (k, s, trials) = (50usize, 5usize, 11usize);
    let g = Scheme::Bgc.build(k, k, s).assignment(&mut Rng::new(90));
    let opts = LsqrOptions::default();
    let root = Rng::new(91);
    let mut ws = DecodeWorkspace::new();
    for &delta in &[0.2, 0.5] {
        let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
        let rho = k as f64 / (r as f64 * s as f64);

        // Scalar references: trial j runs on root.fork(j).
        let mut ref_one = Vec::new();
        let mut ref_cold = Vec::new();
        let mut ref_warm = Vec::new();
        for j in 0..trials {
            let mut rng = root.fork(j as u64);
            ref_one.push(ws.onestep_trial(&g, r, rho, &mut rng));
            let mut rng = root.fork(j as u64);
            ref_cold.push(ws.optimal_trial(&g, r, &opts, None, &mut rng));
            let mut rng = root.fork(j as u64);
            ref_warm.push(ws.optimal_trial(&g, r, &opts, Some(rho), &mut rng));
        }

        for &w in &[1usize, 3, 4, 8] {
            let mut pw = PanelWorkspace::new(w);
            pw.mirror_csr(&g);
            let mut got_one = vec![0.0f64; trials];
            let mut got_cold = vec![0.0f64; trials];
            let mut got_warm = vec![0.0f64; trials];
            let mut p = 0;
            while p < trials {
                let lanes = w.min(trials - p);
                pw.onestep_panel(&g, r, rho, &root, p as u64, lanes, &mut got_one[p..p + lanes]);
                pw.optimal_panel(
                    &g, r, &opts, None, &root, p as u64, lanes,
                    &mut got_cold[p..p + lanes],
                );
                pw.optimal_panel(
                    &g, r, &opts, Some(rho), &root, p as u64, lanes,
                    &mut got_warm[p..p + lanes],
                );
                p += lanes;
            }
            for j in 0..trials {
                assert_eq!(
                    got_one[j].to_bits(),
                    ref_one[j].to_bits(),
                    "one-step w={w} j={j} delta={delta}"
                );
                assert_eq!(
                    got_cold[j].to_bits(),
                    ref_cold[j].to_bits(),
                    "optimal cold w={w} j={j} delta={delta}"
                );
                assert_eq!(
                    got_warm[j].to_bits(),
                    ref_warm[j].to_bits(),
                    "optimal warm w={w} j={j} delta={delta}"
                );
            }
        }
    }
}

/// Panel decode at the Monte-Carlo level: `mean_partial_panel_ws` over
/// `PanelWorkspace` kernels yields Partials bit-identical to the scalar
/// `mean_partial_ws` pipeline on real decode workloads, for every panel
/// width and across thread counts (101 trials is prime to every width,
/// so the last panel is always ragged).
#[test]
fn panel_monte_carlo_partials_match_scalar_on_decode_workloads() {
    use gradcode::decode::PanelWorkspace;
    use gradcode::sim::Shard;
    let (k, s, r) = (30usize, 4usize, 22usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let opts = LsqrOptions::default();
    let g = Scheme::Bgc.build(k, k, s).assignment(&mut Rng::new(95));

    let mc = MonteCarlo::new(101, 96).with_threads(4);
    let ref_one = mc.mean_partial_ws(Shard::full(), DecodeWorkspace::new, |ws, rng| {
        ws.onestep_trial(&g, r, rho, rng)
    });
    let ref_opt = mc.mean_partial_ws(Shard::full(), DecodeWorkspace::new, |ws, rng| {
        ws.optimal_trial(&g, r, &opts, Some(rho), rng)
    });

    for width in [3usize, 4, 8] {
        for threads in [1usize, 4] {
            let mc_t = MonteCarlo { threads, ..mc };
            let init = || {
                let mut pw = PanelWorkspace::new(width);
                pw.mirror_csr(&g);
                pw
            };
            let pan_one = mc_t.mean_partial_panel_ws(
                Shard::full(),
                width,
                init,
                |pw, root, base, lanes, out| pw.onestep_panel(&g, r, rho, root, base, lanes, out),
            );
            assert_eq!(
                pan_one.value().to_bits(),
                ref_one.value().to_bits(),
                "one-step width {width} threads {threads}"
            );
            let pan_opt = mc_t.mean_partial_panel_ws(
                Shard::full(),
                width,
                init,
                |pw, root, base, lanes, out| {
                    pw.optimal_panel(&g, r, &opts, Some(rho), root, base, lanes, out)
                },
            );
            assert_eq!(
                pan_opt.value().to_bits(),
                ref_opt.value().to_bits(),
                "optimal width {width} threads {threads}"
            );
        }
    }
}

/// The redraw panel arms (fresh G per lane, so lanes delegate to the
/// scalar workspace) stay bit-identical per lane to the scalar redraw
/// trials under the scenario spine's straggler models.
#[test]
fn panel_redraw_arms_match_scalar_redraw_trials() {
    use gradcode::decode::PanelWorkspace;
    use gradcode::stragglers::UniformStragglers;
    let (k, s, r) = (20usize, 5usize, 15usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let rho_norm = k as f64 / r as f64;
    let opts = LsqrOptions::default();
    let code = Scheme::Frc.build(k, k, s);
    let model = UniformStragglers::new(0.25);
    let root = Rng::new(97);
    let trials = 10usize;

    let mut ws = DecodeWorkspace::new();
    let mut ref_one = Vec::new();
    let mut ref_opt = Vec::new();
    let mut ref_norm = Vec::new();
    for j in 0..trials {
        let mut rng = root.fork(j as u64);
        ref_one.push(ws.onestep_redraw_trial_with(code.as_ref(), &model, rho, &mut rng));
        let mut rng = root.fork(j as u64);
        ref_opt.push(ws.optimal_redraw_trial_with(
            code.as_ref(),
            &model,
            &opts,
            Some(rho),
            &mut rng,
        ));
        let mut rng = root.fork(j as u64);
        ref_norm.push(ws.onestep_normalized_redraw_trial_with(
            code.as_ref(),
            &model,
            rho_norm,
            &mut rng,
        ));
    }

    let w = 4usize;
    let mut pw = PanelWorkspace::new(w);
    let mut got = vec![0.0f64; w];
    let mut p = 0;
    while p < trials {
        let lanes = w.min(trials - p);
        pw.onestep_redraw_panel_with(
            code.as_ref(), &model, rho, &root, p as u64, lanes, &mut got[..lanes],
        );
        for l in 0..lanes {
            assert_eq!(got[l].to_bits(), ref_one[p + l].to_bits(), "one-step trial {}", p + l);
        }
        pw.optimal_redraw_panel_with(
            code.as_ref(), &model, &opts, Some(rho), &root, p as u64, lanes, &mut got[..lanes],
        );
        for l in 0..lanes {
            assert_eq!(got[l].to_bits(), ref_opt[p + l].to_bits(), "optimal trial {}", p + l);
        }
        pw.onestep_normalized_redraw_panel_with(
            code.as_ref(), &model, rho_norm, &root, p as u64, lanes, &mut got[..lanes],
        );
        for l in 0..lanes {
            assert_eq!(got[l].to_bits(), ref_norm[p + l].to_bits(), "normalized trial {}", p + l);
        }
        p += lanes;
    }
}

/// The sweeps panelized in PR 9 — figure points (both the uniform
/// redraw arm and the adversarial standing-G arm) and the thm21/thm24
/// k-sweeps — publish byte-identical CSVs at every `--panel-width`:
/// the width is an execution hint only, because panel lane `l` at
/// `base` replays exactly the scalar trial `base + l`'s RNG fork.
/// Trials = 11 is prime to 3, 8, and 16, so every width ends on a
/// ragged tail panel.
#[test]
fn panelized_sweep_csvs_invariant_across_panel_widths() {
    use gradcode::sim::{JobKind, JobSpec, Shard};
    use gradcode::stragglers::Scenario;

    let jobs = [
        (JobKind::Figure, "2", 16usize, 0usize, Scenario::default()),
        (JobKind::Figure, "2", 16, 0, Scenario::parse("adversarial:greedy").unwrap()),
        (JobKind::Table, "thm21", 50, 0, Scenario::default()),
        (JobKind::Table, "thm24", 50, 0, Scenario::default()),
    ];
    for (kind, id, k, s, scenario) in jobs {
        let job = JobSpec {
            kind,
            id: id.into(),
            trials: 11,
            seed: 2017,
            k,
            s,
            tmax: 0,
            scenario,
        };
        let reference = job.run(Shard::full(), Some(2)).unwrap().to_csv();
        for w in [1usize, 3, 8, 16] {
            let got = job.run_hinted(Shard::full(), Some(2), Some(w)).unwrap().to_csv();
            assert_eq!(got, reference, "{id} width {w} drifted from the default-width bytes");
        }
    }
}

/// The panelized redraw arms at the Monte-Carlo level:
/// `mean_partial_panel_ws` driving the fused redraw kernels yields
/// Partials bit-identical to the scalar `mean_partial_ws` +
/// `*_redraw_trial_with` pipeline — the exact equivalence the
/// figure/table sweeps rely on — for uniform and latency models at
/// every width (13 trials is prime, so the tail panel is ragged at
/// every width but 1).
#[test]
fn panel_redraw_monte_carlo_partials_match_scalar_pipeline() {
    use gradcode::decode::PanelWorkspace;
    use gradcode::sim::Shard;
    use gradcode::stragglers::{
        DeadlinePolicy, LatencyModel, LatencyStragglers, StragglerModel, UniformStragglers,
    };

    let (k, s, r) = (24usize, 4usize, 18usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let opts = LsqrOptions::default();
    let code = Scheme::Bgc.build(k, k, s);
    let models: [Box<dyn StragglerModel>; 2] = [
        Box::new(UniformStragglers::new(0.25)),
        Box::new(LatencyStragglers {
            model: LatencyModel::Pareto { scale: 0.02, shape: 1.5 },
            policy: DeadlinePolicy::FastestR(r),
        }),
    ];
    let mc = MonteCarlo::new(13, 41).with_threads(2);
    for model in &models {
        let ref_one = mc.mean_partial_ws(Shard::full(), DecodeWorkspace::new, |ws, rng| {
            ws.onestep_redraw_trial_with(code.as_ref(), model.as_ref(), rho, rng)
        });
        let ref_opt = mc.mean_partial_ws(Shard::full(), DecodeWorkspace::new, |ws, rng| {
            ws.optimal_redraw_trial_with(code.as_ref(), model.as_ref(), &opts, Some(rho), rng)
        });
        for width in [1usize, 3, 8, 16] {
            let pan_one = mc.mean_partial_panel_ws(
                Shard::full(),
                width,
                || PanelWorkspace::new(width),
                |pw, root, base, lanes, out| {
                    pw.onestep_redraw_panel_with(
                        code.as_ref(), model.as_ref(), rho, root, base, lanes, out,
                    )
                },
            );
            assert_eq!(
                pan_one.value().to_bits(),
                ref_one.value().to_bits(),
                "one-step {} width {width}",
                model.name()
            );
            let pan_opt = mc.mean_partial_panel_ws(
                Shard::full(),
                width,
                || PanelWorkspace::new(width),
                |pw, root, base, lanes, out| {
                    pw.optimal_redraw_panel_with(
                        code.as_ref(), model.as_ref(), &opts, Some(rho), root, base, lanes, out,
                    )
                },
            );
            assert_eq!(
                pan_opt.value().to_bits(),
                ref_opt.value().to_bits(),
                "optimal {} width {width}",
                model.name()
            );
        }
    }
}

/// PR 9's fused redraw panels (W batched G draws scattered into one
/// lane-strided coverage panel, one fused err₁ sweep) vs explicit
/// lane-by-lane delegation to the scalar workspace — same forks, same
/// bits, under a latency model whose straggler draw consumes a
/// different RNG stream shape than the uniform draw.
#[test]
fn fused_redraw_panels_match_lane_by_lane_delegation() {
    use gradcode::decode::PanelWorkspace;
    use gradcode::stragglers::{DeadlinePolicy, LatencyModel, LatencyStragglers};

    let (k, s, r) = (20usize, 5usize, 15usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let code = Scheme::Bgc.build(k, k, s);
    let model = LatencyStragglers {
        model: LatencyModel::Pareto { scale: 0.02, shape: 1.5 },
        policy: DeadlinePolicy::FastestR(r),
    };
    let root = Rng::new(113);
    let trials = 13usize;

    // Lane-by-lane delegation: lane l of the panel at `base` is the
    // scalar redraw trial on root.fork(base + l).
    let mut ws = DecodeWorkspace::new();
    let mut reference = Vec::new();
    for j in 0..trials {
        let mut rng = root.fork(j as u64);
        reference.push(ws.onestep_redraw_trial_with(code.as_ref(), &model, rho, &mut rng));
    }

    for &w in &[8usize, 16] {
        let mut pw = PanelWorkspace::new(w);
        pw.reserve_redraw(k, k, s);
        let mut got = vec![0.0f64; w];
        let mut p = 0;
        while p < trials {
            let lanes = w.min(trials - p);
            pw.onestep_redraw_panel_with(
                code.as_ref(), &model, rho, &root, p as u64, lanes, &mut got[..lanes],
            );
            for l in 0..lanes {
                assert_eq!(got[l].to_bits(), reference[p + l].to_bits(), "w={w} trial {}", p + l);
            }
            p += lanes;
        }
    }
}
