//! End-to-end integration: the full three-layer stack (AOT artifacts →
//! PJRT engines → coded workers → master decode → GD update) trains a
//! model and the loss goes down. Skipped if artifacts are not built.

use gradcode::codes::Scheme;
use gradcode::coordinator::{DecoderKind, ModelKind};
use gradcode::runtime::{Backend, EnginePool, Manifest};
use gradcode::stragglers::{DeadlinePolicy, LatencyModel};
use gradcode::training::{train, TrainConfig};

fn pjrt_backend(engines: usize) -> Option<(EnginePool, Backend)> {
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => {
            let pool = EnginePool::start(m, engines).expect("engine pool");
            let b = Backend::Pjrt(pool.handle());
            Some((pool, b))
        }
        Err(e) => {
            eprintln!("SKIP e2e training: {e:#} (run `make artifacts`)");
            None
        }
    }
}

fn base_cfg(scheme: Scheme, model: ModelKind, k: usize, s: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new(scheme, k, s, model);
    cfg.steps = 25;
    cfg.lr = 0.4;
    cfg.coordinator.seed = 11;
    cfg.coordinator.latency = LatencyModel::Pareto { scale: 0.02, shape: 1.5 };
    cfg.coordinator.deadline = DeadlinePolicy::FastestR((k * 3) / 4);
    cfg
}

#[test]
fn linear_model_trains_through_pjrt_with_frc() {
    let Some((_pool, backend)) = pjrt_backend(2) else { return };
    let cfg = base_cfg(Scheme::Frc, ModelKind::Linear, 20, 5);
    let out = train(&backend, &cfg).unwrap();
    let (first, last) = (out.history.rounds[0].loss, out.history.final_loss());
    assert!(last < 0.5 * first, "loss {first} -> {last}");
}

#[test]
fn mlp_trains_through_pjrt_with_bgc_stragglers() {
    let Some((_pool, backend)) = pjrt_backend(2) else { return };
    let mut cfg = base_cfg(Scheme::Bgc, ModelKind::Mlp, 16, 5);
    cfg.steps = 30;
    cfg.lr = 1.0;
    let out = train(&backend, &cfg).unwrap();
    let (first, last) = (out.history.rounds[0].loss, out.history.final_loss());
    assert!(last < 0.9 * first, "mlp loss {first} -> {last}");
    // Straggler machinery actually dropped workers every round.
    assert!(out.history.rounds.iter().all(|r| r.survivors == 12));
}

#[test]
fn pjrt_and_native_training_agree() {
    // Same config, same seed: the PJRT and native backends must produce
    // (numerically) the same trajectory — the runtime is behaviourally
    // transparent.
    let Some((_pool, pjrt)) = pjrt_backend(1) else { return };
    let native = Backend::Native {
        linear: pjrt.linear_dims(),
        mlp: pjrt.mlp_dims(),
        s_max: pjrt.s_max(),
    };
    let cfg = base_cfg(Scheme::Frc, ModelKind::Linear, 12, 4);
    let out_p = train(&pjrt, &cfg).unwrap();
    let out_n = train(&native, &cfg).unwrap();
    for (a, b) in out_p.params.iter().zip(&out_n.params) {
        assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

#[test]
fn optimal_decoder_no_worse_than_onestep_e2e() {
    let Some((_pool, backend)) = pjrt_backend(2) else { return };
    let mut one = base_cfg(Scheme::Bgc, ModelKind::Linear, 20, 5);
    one.coordinator.decoder = DecoderKind::OneStep;
    let mut opt = one.clone();
    opt.coordinator.decoder = DecoderKind::Optimal;
    let out_one = train(&backend, &one).unwrap();
    let out_opt = train(&backend, &opt).unwrap();
    // Decode error comparison is the paper's guarantee (per-round).
    assert!(
        out_opt.history.mean_decode_err() <= out_one.history.mean_decode_err() + 1e-9,
        "optimal {} > one-step {}",
        out_opt.history.mean_decode_err(),
        out_one.history.mean_decode_err()
    );
}
