//! Integration tests for the serving layer: the `repro serve` daemon
//! and the `repro load` traffic generator.
//!
//! Each test spawns its own daemon on an ephemeral port (`--addr
//! 127.0.0.1:0`) and reads the bound address off the readiness line,
//! so tests run in parallel without port races. The frame helpers come
//! from the library itself (`gradcode::serve::frame`) except where a
//! test deliberately writes garbage bytes.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use gradcode::codes::Scheme;
use gradcode::decode::{DecodeWorkspace, OneStepDecoder};
use gradcode::serve::frame;
use gradcode::sim::{JobKind, JobSpec};
use gradcode::stragglers::Scenario;
use gradcode::util::{Json, Rng};

const BIN: &str = env!("CARGO_BIN_EXE_gradcode");

/// A daemon child on an ephemeral port, killed on drop.
struct Server {
    child: Option<Child>,
    addr: String,
}

impl Server {
    fn start() -> Server {
        Server::start_with(&[])
    }

    /// `start` with extra daemon flags (e.g. `--panel-width`).
    fn start_with(extra: &[&str]) -> Server {
        let mut args = vec!["serve", "--addr", "127.0.0.1:0"];
        args.extend_from_slice(extra);
        let mut child = Command::new(BIN)
            .args(&args)
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning repro serve");
        let stdout = child.stdout.take().expect("daemon stdout");
        let line = BufReader::new(stdout)
            .lines()
            .next()
            .expect("daemon readiness line")
            .expect("utf-8 readiness line");
        let addr = line
            .strip_prefix("listening on ")
            .unwrap_or_else(|| panic!("unexpected readiness line {line:?}"))
            .to_string();
        Server { child: Some(child), addr }
    }

    fn connect(&self) -> TcpStream {
        let s = TcpStream::connect(&self.addr).expect("connecting to daemon");
        s.set_read_timeout(Some(Duration::from_secs(120))).expect("read timeout");
        s
    }

    /// Graceful stop: shutdown frame, ok reply, clean exit status.
    fn shutdown(mut self) {
        let mut conn = self.connect();
        let reply = request(&mut conn, "{\"cmd\":\"shutdown\"}");
        assert!(reply.contains("\"ok\":true"), "shutdown not acknowledged: {reply}");
        let status = self.child.take().expect("child").wait().expect("waiting for daemon");
        assert!(status.success(), "daemon exited with {status:?}");
    }

    /// Wait for an exit the test already initiated in-band (a shutdown
    /// frame it wrote itself) and assert it was clean.
    fn wait_exit(mut self) {
        let status = self.child.take().expect("child").wait().expect("waiting for daemon");
        assert!(status.success(), "daemon exited with {status:?}");
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(mut c) = self.child.take() {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// One request/reply exchange over an open connection.
fn request(conn: &mut TcpStream, body: &str) -> String {
    frame::write_frame(conn, body).expect("sending frame");
    frame::read_frame(conn).expect("reading reply frame")
}

/// Tag a request body with a pipelining id through the library's own
/// canonical serializer (the same one the daemon echoes with).
fn tag(body: &str, id: u64) -> String {
    gradcode::serve::protocol::with_id(Json::parse(body).expect("request JSON"), Some(id)).write()
}

/// The echoed pipelining id of a reply frame.
fn reply_id(reply: &str) -> Option<u64> {
    let parsed = Json::parse(reply).expect("reply JSON");
    let id = parsed.get("id").ok()?;
    Some(id.as_str().expect("id is a string").parse().expect("decimal id"))
}

/// Scrape one counter off the HTTP `/metrics` endpoint.
fn metric(addr: &str, name: &str) -> u64 {
    let mut conn = TcpStream::connect(addr).expect("connecting for /metrics");
    conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").expect("http request");
    let mut response = String::new();
    conn.read_to_string(&mut response).expect("http response");
    response
        .lines()
        .find_map(|l| match l.split_once(' ') {
            Some((n, v)) if n == name => Some(v.trim().parse().expect("counter value")),
            _ => None,
        })
        .unwrap_or_else(|| panic!("missing {name}:\n{response}"))
}

/// Run `repro load` against `addr`, assert success, return
/// (stdout replay, stderr report).
fn load(addr: &str, extra: &[&str]) -> (String, String) {
    let mut args = vec!["load", "--addr", addr];
    args.extend_from_slice(extra);
    let out = Command::new(BIN).args(&args).output().expect("spawning repro load");
    assert!(
        out.status.success(),
        "repro {args:?} failed (status {:?}):\n{}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    (
        String::from_utf8(out.stdout).expect("utf-8 stdout"),
        String::from_utf8(out.stderr).expect("utf-8 stderr"),
    )
}

#[test]
fn load_replay_is_byte_identical_across_runs_and_concurrency() {
    let server = Server::start();
    let base = ["--requests", "10", "--seed", "7", "--k", "24", "--s", "4", "--rounds", "3"];

    let run = |concurrency: &str, seed: &str| {
        let mut extra = base.to_vec();
        extra.extend_from_slice(&["--concurrency", concurrency, "--seed", seed]);
        load(&server.addr, &extra)
    };

    // Same seed, same concurrency: byte-identical replays.
    let (a, report) = run("2", "7");
    let (b, _) = run("2", "7");
    assert_eq!(a, b, "replay differs between identical runs");

    // Same seed, different concurrency: still byte-identical — the
    // replay is a pure function of (seed, template), not of scheduling.
    let (c, _) = run("5", "7");
    assert_eq!(a, c, "replay depends on concurrency");

    // Different seed: different bytes.
    let (d, _) = run("2", "8");
    assert_ne!(a, d, "seed does not reach the replay");

    // Shape: header comment, per-request rows, error histogram.
    assert!(a.starts_with("# repro load replay: seed=7"), "missing header:\n{a}");
    assert!(a.contains("request,seed,mean_err"), "missing row header:\n{a}");
    assert!(a.contains("bucket,count"), "missing histogram:\n{a}");
    let data_rows = a
        .lines()
        .skip_while(|l| !l.starts_with("request,seed"))
        .skip(1)
        .take_while(|l| *l != "bucket,count")
        .count();
    assert_eq!(data_rows, 10, "expected one replay row per request:\n{a}");
    assert!(report.contains("latency:"), "missing latency report:\n{report}");
    assert!(report.contains("throughput:"), "missing throughput report:\n{report}");
}

#[test]
fn protocol_errors_reply_error_frames_and_do_not_kill_the_daemon() {
    let server = Server::start();

    // Malformed JSON: error frame, connection stays usable.
    let mut conn = server.connect();
    let reply = request(&mut conn, "{not json");
    assert!(reply.contains("\"ok\":false"), "malformed JSON not rejected: {reply}");
    let pong = request(&mut conn, "{\"cmd\":\"ping\"}");
    assert!(pong.contains("\"ok\":true"), "connection dead after bad JSON: {pong}");

    // Unknown command: same deal.
    let reply = request(&mut conn, "{\"cmd\":\"frobnicate\"}");
    assert!(
        reply.contains("\"ok\":false") && reply.contains("unknown cmd"),
        "unknown cmd not rejected: {reply}"
    );
    assert!(request(&mut conn, "{\"cmd\":\"ping\"}").contains("\"ok\":true"));

    // Oversized length prefix: error frame, then the server closes (the
    // frame boundary is unrecoverable).
    let mut conn = server.connect();
    conn.write_all(&u32::MAX.to_be_bytes()).expect("writing oversized prefix");
    conn.flush().expect("flush");
    let reply = frame::read_frame(&mut conn).expect("error frame for oversized prefix");
    assert!(
        reply.contains("\"ok\":false") && reply.contains("exceeds"),
        "oversized prefix not rejected: {reply}"
    );
    let mut rest = Vec::new();
    conn.read_to_end(&mut rest).expect("server should close the connection");
    assert!(rest.is_empty(), "unexpected bytes after the error frame");

    // Truncated frame then drop: client promises 100 bytes, sends 3,
    // hangs up. The daemon must just log the error internally.
    let mut conn = server.connect();
    conn.write_all(&100u32.to_be_bytes()).expect("prefix");
    conn.write_all(b"abc").expect("partial body");
    drop(conn);

    // Drop mid-exchange: connect and hang up without a full prefix.
    let mut conn = server.connect();
    conn.write_all(&[0u8, 0]).expect("half a prefix");
    drop(conn);

    // After all of the above, the daemon is still serving.
    let mut conn = server.connect();
    assert!(request(&mut conn, "{\"cmd\":\"ping\"}").contains("\"ok\":true"));
    server.shutdown();
}

#[test]
fn concurrent_clients_share_the_standing_assignment_and_agree() {
    let server = Server::start();
    let (k, n, s, r, rounds) = (30usize, 30usize, 5usize, 24usize, 4usize);
    let body = format!(
        "{{\"cmd\":\"decode\",\"scheme\":\"bgc\",\"k\":{k},\"n\":{n},\"s\":{s},\"r\":{r},\
         \"rounds\":{rounds},\"assign_seed\":\"11\",\"seed\":\"42\"}}"
    );

    // Four clients fire the identical request concurrently; the server
    // must hand every one the same memoized assignment and therefore
    // the same reply bytes.
    let replies: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let body = body.clone();
                let server = &server;
                scope.spawn(move || request(&mut server.connect(), &body))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    for reply in &replies[1..] {
        assert_eq!(reply, &replies[0], "concurrent identical requests disagree");
    }
    assert!(replies[0].contains("\"ok\":true"), "decode failed: {}", replies[0]);

    // Cross-check against an in-process decode of the same standing
    // assignment: round t of seed w uses Rng::new(w).fork(t), and the
    // reply's shortest-round-trip JSON floats parse back bit-exact.
    let reply = Json::parse(&replies[0]).expect("reply JSON");
    let errs: Vec<f64> = reply
        .get("errs")
        .expect("errs")
        .as_arr()
        .expect("errs array")
        .iter()
        .map(|e| e.as_f64().expect("err"))
        .collect();
    assert_eq!(errs.len(), rounds);
    let g = Scheme::Bgc.build(k, n, s).assignment(&mut Rng::new(11));
    let rho = OneStepDecoder::canonical(k, r, s).rho;
    let mut ws = DecodeWorkspace::new();
    let root = Rng::new(42);
    for (t, &err) in errs.iter().enumerate() {
        let expect = ws.onestep_trial(&g, r, rho, &mut root.fork(t as u64));
        assert_eq!(err, expect, "round {t} differs from the in-process decode");
    }
    server.shutdown();
}

/// The serve panel fast path (PR 9): full (non-prefix) decode requests
/// with rounds ≥ the session's panel width run through the panel
/// kernels — W rounds per kernel call — and the reply must stay
/// bit-equal to an in-process scalar per-round decode, for both
/// decoders, at the default width and under an explicit
/// `--panel-width 3`, with a ragged final panel either way
/// (19 = 2·8 + 3 = 6·3 + 1).
#[test]
fn panel_fast_path_replies_bit_equal_to_scalar_decode() {
    use gradcode::linalg::LsqrOptions;

    let (k, n, s, r, rounds) = (30usize, 30usize, 5usize, 24usize, 19usize);
    let g = Scheme::Bgc.build(k, n, s).assignment(&mut Rng::new(11));
    let rho = OneStepDecoder::canonical(k, r, s).rho;
    let opts = LsqrOptions::default();
    let root = Rng::new(42);
    let mut ws = DecodeWorkspace::new();

    // Scalar references: round t decodes on Rng::new(seed).fork(t).
    let mut ref_one = Vec::new();
    let mut ref_opt = Vec::new();
    for t in 0..rounds {
        ref_one.push(ws.onestep_trial(&g, r, rho, &mut root.fork(t as u64)));
        ref_opt.push(ws.optimal_trial(&g, r, &opts, Some(rho), &mut root.fork(t as u64)));
    }

    let body = |decoder: &str| {
        format!(
            "{{\"cmd\":\"decode\",\"scheme\":\"bgc\",\"k\":{k},\"n\":{n},\"s\":{s},\"r\":{r},\
             \"rounds\":{rounds},\"decoder\":\"{decoder}\",\"assign_seed\":\"11\",\
             \"seed\":\"42\"}}"
        )
    };
    let errs_of = |reply: &str| -> Vec<f64> {
        let parsed = Json::parse(reply).expect("reply JSON");
        assert!(matches!(parsed.get("ok"), Ok(Json::Bool(true))), "decode failed: {reply}");
        parsed
            .get("errs")
            .expect("errs")
            .as_arr()
            .expect("errs array")
            .iter()
            .map(|e| e.as_f64().expect("err"))
            .collect()
    };

    for width_flags in [&[][..], &["--panel-width", "3"][..]] {
        let server = Server::start_with(width_flags);
        let mut conn = server.connect();
        let one = errs_of(&request(&mut conn, &body("onestep")));
        let opt = errs_of(&request(&mut conn, &body("optimal")));
        assert_eq!(one.len(), rounds);
        assert_eq!(opt.len(), rounds);
        for t in 0..rounds {
            assert_eq!(
                one[t].to_bits(),
                ref_one[t].to_bits(),
                "one-step round {t} (flags {width_flags:?}) differs from scalar decode"
            );
            assert_eq!(
                opt[t].to_bits(),
                ref_opt[t].to_bits(),
                "optimal round {t} (flags {width_flags:?}) differs from scalar decode"
            );
        }
        server.shutdown();
    }
}

#[test]
fn http_metrics_endpoint_reports_counters() {
    let server = Server::start();

    // Generate some traffic first so the counters are non-zero.
    let mut conn = server.connect();
    assert!(request(&mut conn, "{\"cmd\":\"ping\"}").contains("\"ok\":true"));
    drop(conn);

    // A raw HTTP GET on the same port: the "GET " bytes cannot be a
    // legal frame prefix, so the server switches protocols.
    let mut conn = server.connect();
    conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").expect("http request");
    conn.flush().expect("flush");
    let mut response = String::new();
    conn.read_to_string(&mut response).expect("http response");
    assert!(response.starts_with("HTTP/1.0 200"), "bad status line:\n{response}");
    for counter in [
        "gradcode_connections_total",
        "gradcode_requests_total",
        "gradcode_errors_total",
        "gradcode_rounds_total",
        "gradcode_jobs_total",
        "gradcode_inflight_requests",
        "gradcode_reactor_wakeups_total",
        "gradcode_request_latency_p99_us",
    ] {
        assert!(response.contains(counter), "missing {counter}:\n{response}");
    }

    // Unknown paths get a 404, not a hang or a crash.
    let mut conn = server.connect();
    conn.write_all(b"GET /nope HTTP/1.0\r\n\r\n").expect("http request");
    let mut response = String::new();
    conn.read_to_string(&mut response).expect("http response");
    assert!(response.starts_with("HTTP/1.0 404"), "bad status line:\n{response}");

    // The frame-level metrics command reports the same counters.
    let mut conn = server.connect();
    let reply = request(&mut conn, "{\"cmd\":\"metrics\"}");
    assert!(
        reply.contains("\"ok\":true") && reply.contains("gradcode_requests_total"),
        "metrics frame missing counters: {reply}"
    );
    server.shutdown();
}

#[test]
fn job_request_runs_the_fanout_scheduler() {
    // Reference: the same table computed unsharded, straight from the CLI.
    let reference = {
        let out = Command::new(BIN)
            .args(["tables", "--table", "thm5", "--trials", "24", "--k", "12", "--s", "3",
                   "--threads", "1"])
            .output()
            .expect("spawning repro tables");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8(out.stdout).expect("utf-8 csv")
    };

    let server = Server::start();
    let job = JobSpec {
        kind: JobKind::Table,
        id: "thm5".into(),
        trials: 24,
        seed: 2017,
        k: 12,
        s: 3,
        tmax: 0,
        scenario: Scenario::default(),
    };
    let mut m = std::collections::BTreeMap::new();
    m.insert("cmd".to_string(), Json::Str("job".into()));
    m.insert("fanout".to_string(), Json::Num(2.0));
    m.insert("job".to_string(), job.to_json());
    let body = Json::Obj(m).write();

    let mut conn = server.connect();
    let reply = request(&mut conn, &body);
    let parsed = Json::parse(&reply).expect("reply JSON");
    assert!(
        matches!(parsed.get("ok"), Ok(Json::Bool(true))),
        "job request failed: {reply}"
    );
    let csv = parsed.get("csv").expect("csv").as_str().expect("csv string");
    assert_eq!(csv, reference, "daemon-scheduled fan-out CSV != unsharded CSV");
    server.shutdown();
}

/// A decode request body covering one scheme/decoder/prefix corner.
fn decode_body(decoder: &str, rounds: usize, prefix: Option<usize>, seed: u64) -> String {
    let prefix = prefix.map(|p| format!(",\"prefix\":{p}")).unwrap_or_default();
    format!(
        "{{\"cmd\":\"decode\",\"scheme\":\"bgc\",\"k\":20,\"n\":20,\"s\":4,\"r\":16,\
         \"rounds\":{rounds},\"decoder\":\"{decoder}\"{prefix},\"assign_seed\":\"11\",\
         \"seed\":\"{seed}\"}}"
    )
}

/// The PR 10 tentpole pin: the epoll reactor (default) and the legacy
/// thread-per-connection loop answer every request kind with
/// byte-identical frames — ping, scalar decode, panel-path decode,
/// anytime prefix decode, optimal decode, fan-out job — both bare and
/// tagged with pipelining ids (which must be echoed).
#[test]
fn reactor_and_legacy_loops_reply_byte_identically() {
    let reactor = Server::start_with(&["--serve-threads", "reactor"]);
    let legacy = Server::start_with(&["--serve-threads", "legacy"]);

    let job = {
        let job = JobSpec {
            kind: JobKind::Table,
            id: "thm5".into(),
            trials: 12,
            seed: 2017,
            k: 10,
            s: 2,
            tmax: 0,
            scenario: Scenario::default(),
        };
        let mut m = std::collections::BTreeMap::new();
        m.insert("cmd".to_string(), Json::Str("job".into()));
        m.insert("fanout".to_string(), Json::Num(2.0));
        m.insert("job".to_string(), job.to_json());
        Json::Obj(m).write()
    };
    let bodies = [
        "{\"cmd\":\"ping\"}".to_string(),
        decode_body("onestep", 3, None, 42),  // scalar loop (rounds < panel width)
        decode_body("onestep", 9, None, 42),  // panel fast path (default width 8)
        decode_body("onestep", 4, Some(12), 42), // anytime prefix route
        decode_body("optimal", 3, None, 42),
        job,
    ];

    let mut rconn = reactor.connect();
    let mut lconn = legacy.connect();
    for body in &bodies {
        assert_eq!(
            request(&mut rconn, body),
            request(&mut lconn, body),
            "session loops disagree on {body}"
        );
    }
    for (i, body) in bodies.iter().enumerate() {
        let id = 1000 + i as u64;
        let tagged = tag(body, id);
        let r = request(&mut rconn, &tagged);
        assert_eq!(r, request(&mut lconn, &tagged), "session loops disagree on {tagged}");
        assert_eq!(reply_id(&r), Some(id), "id not echoed: {r}");
    }
    reactor.shutdown();
    legacy.shutdown();
}

/// Pipelining: a client may write many id-tagged requests before
/// reading anything. The daemon answers all of them (in completion
/// order), and each reply is byte-identical to the lockstep reply for
/// the identical request — replies are pure functions of requests, so
/// reordering cannot change bytes.
#[test]
fn pipelined_burst_replies_match_lockstep_per_id() {
    let server = Server::start();
    let n = 8u64;
    let body = |i: u64| tag(&decode_body("onestep", 2, None, 100 + i), i);

    // Lockstep references, one request at a time.
    let mut conn = server.connect();
    let reference: Vec<String> = (0..n).map(|i| request(&mut conn, &body(i))).collect();

    // Burst: every frame in one write, with a light ping pipelined
    // behind the heavy decodes, then match replies by echoed id.
    let mut burst = Vec::new();
    for i in 0..n {
        burst.extend_from_slice(&frame::encode_frame(&body(i)));
    }
    burst.extend_from_slice(&frame::encode_frame(&tag("{\"cmd\":\"ping\"}", 999)));
    let mut conn = server.connect();
    conn.write_all(&burst).expect("burst write");
    conn.flush().expect("flush");
    let mut got = std::collections::HashMap::new();
    for _ in 0..=n {
        let reply = frame::read_frame(&mut conn).expect("pipelined reply");
        let id = reply_id(&reply).expect("pipelined reply without an id");
        assert!(got.insert(id, reply).is_none(), "duplicate reply id {id}");
    }
    assert!(got[&999].contains("\"pong\":true"), "ping starved by the burst: {}", got[&999]);
    for i in 0..n {
        assert_eq!(got[&i], reference[i as usize], "pipelined reply {i} differs from lockstep");
    }
    server.shutdown();
}

/// `repro load --pipeline D`: the replay is a pure function of
/// (seed, template), so its bytes cannot depend on the pipeline depth
/// or on which session loop the daemon runs.
#[test]
fn pipelined_replay_is_byte_identical_across_depths_and_loops() {
    let reactor = Server::start();
    let legacy = Server::start_with(&["--serve-threads", "legacy"]);
    let base =
        ["--requests", "12", "--seed", "3", "--k", "20", "--s", "4", "--rounds", "2",
         "--concurrency", "3"];
    let run = |addr: &str, depth: &str| {
        let mut extra = base.to_vec();
        extra.extend_from_slice(&["--pipeline", depth]);
        load(addr, &extra).0
    };

    let baseline = run(&reactor.addr, "1");
    for depth in ["4", "16"] {
        assert_eq!(baseline, run(&reactor.addr, depth), "replay depends on pipeline depth {depth}");
    }
    assert_eq!(baseline, run(&legacy.addr, "8"), "replay depends on the session loop");
    reactor.shutdown();
    legacy.shutdown();
}

/// Partial-frame delivery: the reactor's resumable frame decoder must
/// reassemble frames from whatever chunks arrive — a byte-at-a-time
/// dribble, and a pipelined pair split mid-second-frame.
#[test]
fn dribbled_bytes_and_split_frames_still_decode() {
    let server = Server::start();

    // One frame delivered a byte at a time.
    let mut conn = server.connect();
    let bytes = frame::encode_frame("{\"cmd\":\"ping\"}");
    for b in &bytes {
        conn.write_all(std::slice::from_ref(b)).expect("dribbled byte");
        conn.flush().expect("flush");
        std::thread::sleep(Duration::from_millis(1));
    }
    let reply = frame::read_frame(&mut conn).expect("reply to dribbled frame");
    assert!(reply.contains("\"pong\":true"), "dribbled ping misparsed: {reply}");

    // Two pipelined frames where the first chunk ends mid-way through
    // the second frame's body.
    let f1 = frame::encode_frame(&tag("{\"cmd\":\"ping\"}", 1));
    let f2 = frame::encode_frame(&tag(&decode_body("onestep", 2, None, 7), 2));
    let mut all = f1.clone();
    all.extend_from_slice(&f2);
    let cut = f1.len() + f2.len() / 2;
    conn.write_all(&all[..cut]).expect("first chunk");
    conn.flush().expect("flush");
    std::thread::sleep(Duration::from_millis(30));
    conn.write_all(&all[cut..]).expect("second chunk");
    conn.flush().expect("flush");
    let mut ids = Vec::new();
    for _ in 0..2 {
        let reply = frame::read_frame(&mut conn).expect("reply to split frames");
        assert!(reply.contains("\"ok\":true"), "split frame misparsed: {reply}");
        ids.push(reply_id(&reply).expect("id"));
    }
    ids.sort_unstable();
    assert_eq!(ids, vec![1, 2], "replies lost or duplicated across the split");
    server.shutdown();
}

/// A client that writes half a frame and stalls must not wedge the
/// daemon (other connections keep being served) and must not make the
/// reactor busy-spin (the wakeup counter barely moves while the
/// half-frame sits in the decoder).
#[test]
fn stalled_half_written_frame_neither_blocks_nor_spins_the_daemon() {
    let server = Server::start();
    let mut stalled = server.connect();
    stalled.write_all(&64u32.to_be_bytes()).expect("prefix");
    stalled.write_all(&[b'x'; 20]).expect("half the promised body");
    stalled.flush().expect("flush");
    std::thread::sleep(Duration::from_millis(50));

    // Other connections are served while the stalled one waits.
    let mut conn = server.connect();
    assert!(request(&mut conn, "{\"cmd\":\"ping\"}").contains("\"pong\":true"));
    assert_eq!(metric(&server.addr, "gradcode_inflight_requests"), 0, "phantom in-flight");

    // Quiet window: a level-triggered loop that forgot to deregister
    // interest would spin through tens of thousands of wakeups here;
    // the two scrapes themselves only cost a handful.
    let w0 = metric(&server.addr, "gradcode_reactor_wakeups_total");
    std::thread::sleep(Duration::from_millis(400));
    let w1 = metric(&server.addr, "gradcode_reactor_wakeups_total");
    assert!(w1 - w0 < 50, "reactor busy-spins on a stalled connection: {w0} -> {w1}");

    // The stalled client finishes its frame (garbage JSON) and still
    // gets its answer: an error frame on a connection that stays up.
    stalled.write_all(&[b'x'; 44]).expect("rest of the body");
    stalled.flush().expect("flush");
    let reply = frame::read_frame(&mut stalled).expect("late reply");
    assert!(reply.contains("\"ok\":false"), "garbage body accepted: {reply}");
    assert!(request(&mut stalled, "{\"cmd\":\"ping\"}").contains("\"pong\":true"));
    server.shutdown();
}

/// Shutdown drains: every request accepted before the shutdown frame —
/// here a burst pipelined ahead of it on the same connection — is
/// answered before the daemon exits. No accepted request is dropped.
#[test]
fn shutdown_drains_pipelined_in_flight_requests() {
    let server = Server::start();
    let n = 6u64;
    let mut burst = Vec::new();
    for i in 0..n {
        burst.extend_from_slice(&frame::encode_frame(&tag(&decode_body(
            "onestep",
            4,
            None,
            200 + i,
        ), i)));
    }
    burst.extend_from_slice(&frame::encode_frame("{\"cmd\":\"shutdown\"}"));
    let mut conn = server.connect();
    conn.write_all(&burst).expect("burst write");
    conn.flush().expect("flush");

    // n decode replies plus the shutdown ack, in completion order.
    let mut ids = std::collections::HashSet::new();
    let mut acked = false;
    for _ in 0..=n {
        let reply = frame::read_frame(&mut conn).expect("drained reply");
        assert!(reply.contains("\"ok\":true"), "in-flight request dropped or failed: {reply}");
        match reply_id(&reply) {
            Some(id) => {
                assert!(ids.insert(id), "duplicate drained reply {id}");
            }
            None => acked = true,
        }
    }
    assert!(acked, "shutdown never acknowledged");
    assert_eq!(ids.len(), n as usize, "missing pipelined replies: {ids:?}");

    // After the drain the daemon closes the connection and exits clean.
    let mut rest = Vec::new();
    conn.read_to_end(&mut rest).expect("clean close after the drain");
    assert!(rest.is_empty(), "stray bytes after the drain");
    server.wait_exit();
}

/// The latparam workload sweeps the latency-parameter template grid;
/// like the fixed workload, its replay is byte-reproducible across
/// runs and pipeline depths, and it labels itself in the header.
#[test]
fn latparam_workload_replay_is_reproducible_and_labeled() {
    let server = Server::start();
    let base = ["--workload", "latparam", "--requests", "12", "--seed", "9", "--k", "16",
                "--s", "4", "--rounds", "2", "--concurrency", "2"];
    let run = |depth: &str| {
        let mut extra = base.to_vec();
        extra.extend_from_slice(&["--pipeline", depth]);
        load(&server.addr, &extra).0
    };

    let a = run("4");
    assert_eq!(a, run("4"), "latparam replay differs between identical runs");
    assert_eq!(a, run("1"), "latparam replay depends on pipeline depth");
    assert!(a.contains("# workload latparam:"), "missing workload header:\n{a}");
    let data_rows = a
        .lines()
        .skip_while(|l| !l.starts_with("request,seed"))
        .skip(1)
        .take_while(|l| *l != "bucket,count")
        .count();
    assert_eq!(data_rows, 12, "expected one replay row per request:\n{a}");
    server.shutdown();
}
