//! Integration tests for the serving layer: the `repro serve` daemon
//! and the `repro load` traffic generator.
//!
//! Each test spawns its own daemon on an ephemeral port (`--addr
//! 127.0.0.1:0`) and reads the bound address off the readiness line,
//! so tests run in parallel without port races. The frame helpers come
//! from the library itself (`gradcode::serve::frame`) except where a
//! test deliberately writes garbage bytes.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use gradcode::codes::Scheme;
use gradcode::decode::{DecodeWorkspace, OneStepDecoder};
use gradcode::serve::frame;
use gradcode::sim::{JobKind, JobSpec};
use gradcode::stragglers::Scenario;
use gradcode::util::{Json, Rng};

const BIN: &str = env!("CARGO_BIN_EXE_gradcode");

/// A daemon child on an ephemeral port, killed on drop.
struct Server {
    child: Option<Child>,
    addr: String,
}

impl Server {
    fn start() -> Server {
        Server::start_with(&[])
    }

    /// `start` with extra daemon flags (e.g. `--panel-width`).
    fn start_with(extra: &[&str]) -> Server {
        let mut args = vec!["serve", "--addr", "127.0.0.1:0"];
        args.extend_from_slice(extra);
        let mut child = Command::new(BIN)
            .args(&args)
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning repro serve");
        let stdout = child.stdout.take().expect("daemon stdout");
        let line = BufReader::new(stdout)
            .lines()
            .next()
            .expect("daemon readiness line")
            .expect("utf-8 readiness line");
        let addr = line
            .strip_prefix("listening on ")
            .unwrap_or_else(|| panic!("unexpected readiness line {line:?}"))
            .to_string();
        Server { child: Some(child), addr }
    }

    fn connect(&self) -> TcpStream {
        let s = TcpStream::connect(&self.addr).expect("connecting to daemon");
        s.set_read_timeout(Some(Duration::from_secs(120))).expect("read timeout");
        s
    }

    /// Graceful stop: shutdown frame, ok reply, clean exit status.
    fn shutdown(mut self) {
        let mut conn = self.connect();
        let reply = request(&mut conn, "{\"cmd\":\"shutdown\"}");
        assert!(reply.contains("\"ok\":true"), "shutdown not acknowledged: {reply}");
        let status = self.child.take().expect("child").wait().expect("waiting for daemon");
        assert!(status.success(), "daemon exited with {status:?}");
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(mut c) = self.child.take() {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// One request/reply exchange over an open connection.
fn request(conn: &mut TcpStream, body: &str) -> String {
    frame::write_frame(conn, body).expect("sending frame");
    frame::read_frame(conn).expect("reading reply frame")
}

/// Run `repro load` against `addr`, assert success, return
/// (stdout replay, stderr report).
fn load(addr: &str, extra: &[&str]) -> (String, String) {
    let mut args = vec!["load", "--addr", addr];
    args.extend_from_slice(extra);
    let out = Command::new(BIN).args(&args).output().expect("spawning repro load");
    assert!(
        out.status.success(),
        "repro {args:?} failed (status {:?}):\n{}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    (
        String::from_utf8(out.stdout).expect("utf-8 stdout"),
        String::from_utf8(out.stderr).expect("utf-8 stderr"),
    )
}

#[test]
fn load_replay_is_byte_identical_across_runs_and_concurrency() {
    let server = Server::start();
    let base = ["--requests", "10", "--seed", "7", "--k", "24", "--s", "4", "--rounds", "3"];

    let run = |concurrency: &str, seed: &str| {
        let mut extra = base.to_vec();
        extra.extend_from_slice(&["--concurrency", concurrency, "--seed", seed]);
        load(&server.addr, &extra)
    };

    // Same seed, same concurrency: byte-identical replays.
    let (a, report) = run("2", "7");
    let (b, _) = run("2", "7");
    assert_eq!(a, b, "replay differs between identical runs");

    // Same seed, different concurrency: still byte-identical — the
    // replay is a pure function of (seed, template), not of scheduling.
    let (c, _) = run("5", "7");
    assert_eq!(a, c, "replay depends on concurrency");

    // Different seed: different bytes.
    let (d, _) = run("2", "8");
    assert_ne!(a, d, "seed does not reach the replay");

    // Shape: header comment, per-request rows, error histogram.
    assert!(a.starts_with("# repro load replay: seed=7"), "missing header:\n{a}");
    assert!(a.contains("request,seed,mean_err"), "missing row header:\n{a}");
    assert!(a.contains("bucket,count"), "missing histogram:\n{a}");
    let data_rows = a
        .lines()
        .skip_while(|l| !l.starts_with("request,seed"))
        .skip(1)
        .take_while(|l| *l != "bucket,count")
        .count();
    assert_eq!(data_rows, 10, "expected one replay row per request:\n{a}");
    assert!(report.contains("latency:"), "missing latency report:\n{report}");
    assert!(report.contains("throughput:"), "missing throughput report:\n{report}");
}

#[test]
fn protocol_errors_reply_error_frames_and_do_not_kill_the_daemon() {
    let server = Server::start();

    // Malformed JSON: error frame, connection stays usable.
    let mut conn = server.connect();
    let reply = request(&mut conn, "{not json");
    assert!(reply.contains("\"ok\":false"), "malformed JSON not rejected: {reply}");
    let pong = request(&mut conn, "{\"cmd\":\"ping\"}");
    assert!(pong.contains("\"ok\":true"), "connection dead after bad JSON: {pong}");

    // Unknown command: same deal.
    let reply = request(&mut conn, "{\"cmd\":\"frobnicate\"}");
    assert!(
        reply.contains("\"ok\":false") && reply.contains("unknown cmd"),
        "unknown cmd not rejected: {reply}"
    );
    assert!(request(&mut conn, "{\"cmd\":\"ping\"}").contains("\"ok\":true"));

    // Oversized length prefix: error frame, then the server closes (the
    // frame boundary is unrecoverable).
    let mut conn = server.connect();
    conn.write_all(&u32::MAX.to_be_bytes()).expect("writing oversized prefix");
    conn.flush().expect("flush");
    let reply = frame::read_frame(&mut conn).expect("error frame for oversized prefix");
    assert!(
        reply.contains("\"ok\":false") && reply.contains("exceeds"),
        "oversized prefix not rejected: {reply}"
    );
    let mut rest = Vec::new();
    conn.read_to_end(&mut rest).expect("server should close the connection");
    assert!(rest.is_empty(), "unexpected bytes after the error frame");

    // Truncated frame then drop: client promises 100 bytes, sends 3,
    // hangs up. The daemon must just log the error internally.
    let mut conn = server.connect();
    conn.write_all(&100u32.to_be_bytes()).expect("prefix");
    conn.write_all(b"abc").expect("partial body");
    drop(conn);

    // Drop mid-exchange: connect and hang up without a full prefix.
    let mut conn = server.connect();
    conn.write_all(&[0u8, 0]).expect("half a prefix");
    drop(conn);

    // After all of the above, the daemon is still serving.
    let mut conn = server.connect();
    assert!(request(&mut conn, "{\"cmd\":\"ping\"}").contains("\"ok\":true"));
    server.shutdown();
}

#[test]
fn concurrent_clients_share_the_standing_assignment_and_agree() {
    let server = Server::start();
    let (k, n, s, r, rounds) = (30usize, 30usize, 5usize, 24usize, 4usize);
    let body = format!(
        "{{\"cmd\":\"decode\",\"scheme\":\"bgc\",\"k\":{k},\"n\":{n},\"s\":{s},\"r\":{r},\
         \"rounds\":{rounds},\"assign_seed\":\"11\",\"seed\":\"42\"}}"
    );

    // Four clients fire the identical request concurrently; the server
    // must hand every one the same memoized assignment and therefore
    // the same reply bytes.
    let replies: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let body = body.clone();
                let server = &server;
                scope.spawn(move || request(&mut server.connect(), &body))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    for reply in &replies[1..] {
        assert_eq!(reply, &replies[0], "concurrent identical requests disagree");
    }
    assert!(replies[0].contains("\"ok\":true"), "decode failed: {}", replies[0]);

    // Cross-check against an in-process decode of the same standing
    // assignment: round t of seed w uses Rng::new(w).fork(t), and the
    // reply's shortest-round-trip JSON floats parse back bit-exact.
    let reply = Json::parse(&replies[0]).expect("reply JSON");
    let errs: Vec<f64> = reply
        .get("errs")
        .expect("errs")
        .as_arr()
        .expect("errs array")
        .iter()
        .map(|e| e.as_f64().expect("err"))
        .collect();
    assert_eq!(errs.len(), rounds);
    let g = Scheme::Bgc.build(k, n, s).assignment(&mut Rng::new(11));
    let rho = OneStepDecoder::canonical(k, r, s).rho;
    let mut ws = DecodeWorkspace::new();
    let root = Rng::new(42);
    for (t, &err) in errs.iter().enumerate() {
        let expect = ws.onestep_trial(&g, r, rho, &mut root.fork(t as u64));
        assert_eq!(err, expect, "round {t} differs from the in-process decode");
    }
    server.shutdown();
}

/// The serve panel fast path (PR 9): full (non-prefix) decode requests
/// with rounds ≥ the session's panel width run through the panel
/// kernels — W rounds per kernel call — and the reply must stay
/// bit-equal to an in-process scalar per-round decode, for both
/// decoders, at the default width and under an explicit
/// `--panel-width 3`, with a ragged final panel either way
/// (19 = 2·8 + 3 = 6·3 + 1).
#[test]
fn panel_fast_path_replies_bit_equal_to_scalar_decode() {
    use gradcode::linalg::LsqrOptions;

    let (k, n, s, r, rounds) = (30usize, 30usize, 5usize, 24usize, 19usize);
    let g = Scheme::Bgc.build(k, n, s).assignment(&mut Rng::new(11));
    let rho = OneStepDecoder::canonical(k, r, s).rho;
    let opts = LsqrOptions::default();
    let root = Rng::new(42);
    let mut ws = DecodeWorkspace::new();

    // Scalar references: round t decodes on Rng::new(seed).fork(t).
    let mut ref_one = Vec::new();
    let mut ref_opt = Vec::new();
    for t in 0..rounds {
        ref_one.push(ws.onestep_trial(&g, r, rho, &mut root.fork(t as u64)));
        ref_opt.push(ws.optimal_trial(&g, r, &opts, Some(rho), &mut root.fork(t as u64)));
    }

    let body = |decoder: &str| {
        format!(
            "{{\"cmd\":\"decode\",\"scheme\":\"bgc\",\"k\":{k},\"n\":{n},\"s\":{s},\"r\":{r},\
             \"rounds\":{rounds},\"decoder\":\"{decoder}\",\"assign_seed\":\"11\",\
             \"seed\":\"42\"}}"
        )
    };
    let errs_of = |reply: &str| -> Vec<f64> {
        let parsed = Json::parse(reply).expect("reply JSON");
        assert!(matches!(parsed.get("ok"), Ok(Json::Bool(true))), "decode failed: {reply}");
        parsed
            .get("errs")
            .expect("errs")
            .as_arr()
            .expect("errs array")
            .iter()
            .map(|e| e.as_f64().expect("err"))
            .collect()
    };

    for width_flags in [&[][..], &["--panel-width", "3"][..]] {
        let server = Server::start_with(width_flags);
        let mut conn = server.connect();
        let one = errs_of(&request(&mut conn, &body("onestep")));
        let opt = errs_of(&request(&mut conn, &body("optimal")));
        assert_eq!(one.len(), rounds);
        assert_eq!(opt.len(), rounds);
        for t in 0..rounds {
            assert_eq!(
                one[t].to_bits(),
                ref_one[t].to_bits(),
                "one-step round {t} (flags {width_flags:?}) differs from scalar decode"
            );
            assert_eq!(
                opt[t].to_bits(),
                ref_opt[t].to_bits(),
                "optimal round {t} (flags {width_flags:?}) differs from scalar decode"
            );
        }
        server.shutdown();
    }
}

#[test]
fn http_metrics_endpoint_reports_counters() {
    let server = Server::start();

    // Generate some traffic first so the counters are non-zero.
    let mut conn = server.connect();
    assert!(request(&mut conn, "{\"cmd\":\"ping\"}").contains("\"ok\":true"));
    drop(conn);

    // A raw HTTP GET on the same port: the "GET " bytes cannot be a
    // legal frame prefix, so the server switches protocols.
    let mut conn = server.connect();
    conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").expect("http request");
    conn.flush().expect("flush");
    let mut response = String::new();
    conn.read_to_string(&mut response).expect("http response");
    assert!(response.starts_with("HTTP/1.0 200"), "bad status line:\n{response}");
    for counter in [
        "gradcode_connections_total",
        "gradcode_requests_total",
        "gradcode_errors_total",
        "gradcode_rounds_total",
        "gradcode_jobs_total",
        "gradcode_request_latency_p99_us",
    ] {
        assert!(response.contains(counter), "missing {counter}:\n{response}");
    }

    // Unknown paths get a 404, not a hang or a crash.
    let mut conn = server.connect();
    conn.write_all(b"GET /nope HTTP/1.0\r\n\r\n").expect("http request");
    let mut response = String::new();
    conn.read_to_string(&mut response).expect("http response");
    assert!(response.starts_with("HTTP/1.0 404"), "bad status line:\n{response}");

    // The frame-level metrics command reports the same counters.
    let mut conn = server.connect();
    let reply = request(&mut conn, "{\"cmd\":\"metrics\"}");
    assert!(
        reply.contains("\"ok\":true") && reply.contains("gradcode_requests_total"),
        "metrics frame missing counters: {reply}"
    );
    server.shutdown();
}

#[test]
fn job_request_runs_the_fanout_scheduler() {
    // Reference: the same table computed unsharded, straight from the CLI.
    let reference = {
        let out = Command::new(BIN)
            .args(["tables", "--table", "thm5", "--trials", "24", "--k", "12", "--s", "3",
                   "--threads", "1"])
            .output()
            .expect("spawning repro tables");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8(out.stdout).expect("utf-8 csv")
    };

    let server = Server::start();
    let job = JobSpec {
        kind: JobKind::Table,
        id: "thm5".into(),
        trials: 24,
        seed: 2017,
        k: 12,
        s: 3,
        tmax: 0,
        scenario: Scenario::default(),
    };
    let mut m = std::collections::BTreeMap::new();
    m.insert("cmd".to_string(), Json::Str("job".into()));
    m.insert("fanout".to_string(), Json::Num(2.0));
    m.insert("job".to_string(), job.to_json());
    let body = Json::Obj(m).write();

    let mut conn = server.connect();
    let reply = request(&mut conn, &body);
    let parsed = Json::parse(&reply).expect("reply JSON");
    assert!(
        matches!(parsed.get("ok"), Ok(Json::Bool(true))),
        "job request failed: {reply}"
    );
    let csv = parsed.get("csv").expect("csv").as_str().expect("csv string");
    assert_eq!(csv, reference, "daemon-scheduled fan-out CSV != unsharded CSV");
    server.shutdown();
}
