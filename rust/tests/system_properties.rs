//! Cross-module property tests: the paper's structural invariants,
//! checked over randomized instances via the hand-rolled property
//! harness (`gradcode::util::check`). Each property cites the paper
//! statement it guards.

use gradcode::adversary::{asp_objective, frc_worst_stragglers, greedy_stragglers};
use gradcode::codes::Scheme;
use gradcode::decode::{
    algorithmic_error_curve, decode_error, Decoder, OneStepDecoder, OptimalDecoder, StepSize,
};
use gradcode::graph::bipartite::{lemma15_error, uncovered_tasks};
use gradcode::sim::tables::{thm5_exact, thm6_expected};
use gradcode::util::check::{close, ensure, property};
use gradcode::util::Rng;

/// Random (scheme, k, s, r) instance at test scale.
fn random_instance(rng: &mut Rng) -> (Scheme, usize, usize, usize) {
    let schemes = [Scheme::Frc, Scheme::Bgc, Scheme::Rbgc, Scheme::RegularGraph, Scheme::Cyclic];
    let scheme = schemes[rng.usize(schemes.len())];
    // FRC needs s | k; regular graph needs k*s even. Pick from a safe grid.
    let (k, s) = [(20, 4), (20, 5), (24, 6), (30, 5), (40, 8)][rng.usize(5)];
    let r = 1 + rng.usize(k - 1);
    (scheme, k, s, r)
}

fn draw_a(scheme: Scheme, k: usize, s: usize, r: usize, rng: &mut Rng) -> gradcode::linalg::CscMatrix {
    let g = scheme.build(k, k, s).assignment(rng);
    g.select_columns(&rng.sample_indices(k, r))
}

#[test]
fn prop_error_bounds_0_le_err_le_k() {
    // Paper §2.2: "for any A, 0 <= err(A) <= k".
    property(60, 101, |rng| {
        let (scheme, k, s, r) = random_instance(rng);
        let a = draw_a(scheme, k, s, r, rng);
        let err = OptimalDecoder::new().err(&a);
        ensure(
            (-1e-9..=k as f64 + 1e-9).contains(&err),
            format!("{} err {err} outside [0, {k}]", scheme.name()),
        )
    });
}

#[test]
fn prop_onestep_dominates_optimal() {
    // Paper §2.2: err_1(A) >= err(A) for every A.
    property(60, 102, |rng| {
        let (scheme, k, s, r) = random_instance(rng);
        let a = draw_a(scheme, k, s, r, rng);
        let opt = OptimalDecoder::new().err(&a);
        let one = OneStepDecoder::canonical(k, r, s).err1(&a);
        ensure(
            one >= opt - 1e-7,
            format!("{}: err1 {one} < err {opt}", scheme.name()),
        )
    });
}

#[test]
fn prop_uncovered_tasks_lower_bound_err() {
    // Tasks covered by no survivor contribute >= 1 each to err(A).
    property(50, 103, |rng| {
        let (scheme, k, s, r) = random_instance(rng);
        let a = draw_a(scheme, k, s, r, rng);
        let err = OptimalDecoder::new().err(&a);
        let unc = uncovered_tasks(&a) as f64;
        ensure(err >= unc - 1e-7, format!("err {err} < uncovered {unc}"))
    });
}

#[test]
fn prop_frc_error_is_alpha_s() {
    // Paper §3: err(A_frc) = αs, α = number of fully-straggled blocks.
    property(50, 104, |rng| {
        let k = 20;
        let s = [4usize, 5][rng.usize(2)];
        let r = 1 + rng.usize(k - 1);
        let g = Scheme::Frc.build(k, k, s).assignment(rng);
        let cols = rng.sample_indices(k, r);
        let a = g.select_columns(&cols);
        // Count missing blocks directly.
        let mut present = vec![false; k / s];
        for &j in &cols {
            present[j / s] = true;
        }
        let alpha = present.iter().filter(|&&p| !p).count();
        let err = OptimalDecoder::new().err(&a);
        close(err, (alpha * s) as f64, 1e-7)
    });
}

#[test]
fn prop_algorithmic_curve_monotone_and_above_optimal() {
    // Lemma 12: ||u_t||^2 decreasing (nu >= ||A||^2) and >= err(A).
    property(30, 105, |rng| {
        let (scheme, k, s, r) = random_instance(rng);
        let a = draw_a(scheme, k, s, r, rng);
        let curve = algorithmic_error_curve(&a, StepSize::SpectralNormSq, 25, rng);
        let opt = OptimalDecoder::new().err(&a);
        for w in curve.windows(2) {
            ensure(w[1] <= w[0] + 1e-8, format!("not monotone: {} -> {}", w[0], w[1]))?;
        }
        ensure(
            curve.iter().all(|&e| e >= opt - 1e-6),
            "curve dipped below err(A)",
        )
    });
}

#[test]
fn prop_lemma15_walk_expansion_matches_iterates() {
    // Lemma 15: the alternating walk-moment sum equals ||u_t||^2 (small
    // t; the sum is numerically fragile for large t).
    property(30, 106, |rng| {
        let (scheme, k, s, r) = random_instance(rng);
        let a = draw_a(scheme, k, s, r, rng);
        let nu = {
            let mut prng = rng.fork(7);
            StepSize::SpectralNormSq.resolve(&a, &mut prng)
        };
        for t in 1..=2 {
            let direct = algorithmic_error_curve(&a, StepSize::Fixed(nu), t, rng)[t];
            let viawalks = lemma15_error(&a, nu, t);
            close(direct, viawalks, 1e-6)?;
        }
        Ok(())
    });
}

#[test]
fn prop_decoder_weights_realize_reported_error() {
    // For every decoder the reported error equals the error of the
    // weights it returns (no bookkeeping drift).
    property(40, 107, |rng| {
        let (scheme, k, s, r) = random_instance(rng);
        let a = draw_a(scheme, k, s, r, rng);
        let dec = OptimalDecoder::new();
        let w = dec.weights(&a);
        close(decode_error(&a, &w), dec.err(&a), 1e-6)?;
        let one = OneStepDecoder::canonical(k, r, s);
        let w1 = one.weights(&a);
        close(decode_error(&a, &w1), one.err1(&a), 1e-9)
    });
}

#[test]
fn prop_adversary_at_least_random() {
    // Greedy adversary never does worse than a random straggler draw
    // (it starts from all-alive and removes only improving columns).
    property(25, 108, |rng| {
        let (_, k, s, _) = random_instance(rng);
        let r = (k * 2) / 3;
        let rho = k as f64 / (r as f64 * s as f64);
        let g = Scheme::Bgc.build(k, k, s).assignment(rng);
        let adv = asp_objective(&g, &greedy_stragglers(&g, r, rho), rho);
        let rand = asp_objective(&g, &rng.sample_indices(k, r), rho);
        ensure(adv >= rand - 1e-7, format!("greedy {adv} < random {rand}"))
    });
}

#[test]
fn prop_frc_attack_matches_thm10_floor() {
    // Thm 10: block attack achieves floor((k-r)/s)*s optimal error.
    property(30, 109, |rng| {
        let k = 20;
        let s = [4usize, 5][rng.usize(2)];
        let r = 1 + rng.usize(k - 1);
        let g = Scheme::Frc.build(k, k, s).assignment(rng);
        let ns = frc_worst_stragglers(&g, r);
        let err = OptimalDecoder::new().err(&g.select_columns(&ns));
        close(err, (((k - r) / s) * s) as f64, 1e-7)
    });
}

#[test]
fn prop_closed_forms_are_sane() {
    // thm5/thm6 closed forms: non-negative, bounded by k, decreasing in r.
    property(40, 110, |rng| {
        let k = 20 + 5 * rng.usize(5);
        let s = 1 + rng.usize(5);
        let r1 = 1 + rng.usize(k - 2);
        let r2 = r1 + 1;
        for &f in &[thm6_expected as fn(usize, usize, usize) -> f64] {
            let (e1, e2) = (f(k, r1, s), f(k, r2, s));
            ensure(e1 >= -1e-9 && e1 <= k as f64 + 1e-9, format!("thm6 {e1} out of range"))?;
            ensure(e2 <= e1 + 1e-9, format!("thm6 not decreasing: {e1} -> {e2}"))?;
        }
        let e = thm5_exact(k, r1, s);
        ensure(e >= -1e-6, format!("thm5 exact negative: {e}"))
    });
}

#[test]
fn prop_column_degree_caps_respected() {
    // rBGC: col degree <= 2s (Alg. 3); FRC/cyclic/s-regular: exactly s.
    property(40, 111, |rng| {
        let (k, s) = [(20usize, 4usize), (30, 5), (24, 6)][rng.usize(3)];
        let rbgc = Scheme::Rbgc.build(k, k, s).assignment(rng);
        for j in 0..k {
            ensure(rbgc.col_nnz(j) <= 2 * s, format!("rBGC col {j} degree {}", rbgc.col_nnz(j)))?;
        }
        for scheme in [Scheme::Frc, Scheme::Cyclic, Scheme::RegularGraph] {
            let g = scheme.build(k, k, s).assignment(rng);
            for j in 0..k {
                ensure(
                    g.col_nnz(j) == s,
                    format!("{} col {j} degree {} != {s}", scheme.name(), g.col_nnz(j)),
                )?;
            }
        }
        Ok(())
    });
}
