//! End-to-end coordination benchmark (EXP-E2E §Perf): per-step wall
//! time of the full coded training loop, split by backend, and the
//! gather/decode overhead relative to worker compute. The paper's L3
//! claim: coordination must not be the bottleneck.
//!
//! Run (after `make artifacts`): `cargo bench --bench e2e_training`.

mod common;

use gradcode::codes::Scheme;
use gradcode::coordinator::{DecoderKind, ModelKind};
use gradcode::runtime::{Backend, EnginePool, LinearDims, Manifest, MlpDims};
use gradcode::stragglers::{DeadlinePolicy, LatencyModel};
use gradcode::training::{train, TrainConfig};

fn bench_backend(label: &str, backend: &Backend, steps: usize) {
    for (scheme, decoder) in [
        (Scheme::Frc, DecoderKind::OneStep),
        (Scheme::Frc, DecoderKind::Optimal),
        (Scheme::Bgc, DecoderKind::OneStep),
    ] {
        let k = 50;
        let mut cfg = TrainConfig::new(scheme, k, 10, ModelKind::Mlp);
        cfg.steps = steps;
        cfg.lr = 1.0;
        cfg.coordinator.seed = 3;
        cfg.coordinator.latency = LatencyModel::Pareto { scale: 0.02, shape: 1.5 };
        cfg.coordinator.deadline = DeadlinePolicy::FastestR(40);
        let t0 = std::time::Instant::now();
        let out = train(backend, &cfg).expect("train");
        let per_step = t0.elapsed().as_secs_f64() / steps as f64;
        println!(
            "e2e/{label}/{}/{}: {:.2}ms/step (k={k}, final loss {:.4})",
            scheme.name(),
            decoder.name(),
            per_step * 1e3,
            out.history.final_loss()
        );
    }
}

fn main() {
    let steps = if common::quick() { 3 } else { 10 };

    let native = Backend::Native {
        linear: LinearDims { m: 32, d: 64 },
        mlp: MlpDims { m: 32, d_in: 32, d_hidden: 64, d_out: 16, flat_dim: 3152 },
        s_max: 10,
    };
    bench_backend("native", &native, steps);

    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => {
            let engines = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
            let pool = EnginePool::start(m, engines).expect("pool");
            let backend = Backend::Pjrt(pool.handle());
            println!("pjrt engines: {engines}");
            bench_backend("pjrt", &backend, steps);
            bench_message_paths(&backend);
        }
        Err(e) => println!("SKIP pjrt e2e bench: {e} (run `make artifacts`)"),
    }
}

/// §Perf before/after: per-worker message cost, fused (1 dispatch) vs
/// per-task (s + 1 dispatches).
fn bench_message_paths(backend: &Backend) {
    use gradcode::coordinator::{compute_message_via, MessagePath, WorkerSpec};
    use gradcode::training::MlpDataset;
    use gradcode::util::Rng;

    let b = common::bencher();
    let dims = backend.mlp_dims();
    let mut rng = Rng::new(9);
    let ds = MlpDataset::generate(dims, 10, &mut rng);
    let theta: Vec<f32> = (0..dims.flat_dim).map(|_| (rng.normal() * 0.1) as f32).collect();
    let spec = WorkerSpec {
        id: 0,
        tasks: (0..backend.s_max()).collect(),
        coeffs: vec![1.0; backend.s_max()],
    };
    for (label, path) in
        [("fused", MessagePath::Fused), ("per-task", MessagePath::PerTask)]
    {
        b.bench(&format!("e2e/worker-message/mlp/{label}"), || {
            compute_message_via(backend, ModelKind::Mlp, &theta, &ds.shards, &spec, path).unwrap()
        });
    }
}
