//! Bench + regeneration for paper Figure 4: one-step vs optimal
//! decoding error per scheme (six panels: {BGC, s-regular, FRC} ×
//! s ∈ {5, 10}).
//!
//! Run: `cargo bench --bench fig4_compare`.

mod common;

use gradcode::sim::figures::{figure4, FigPoint, FigureConfig};

fn main() {
    common::banner("fig4", "one-step vs optimal per scheme");
    let cfg = FigureConfig { mc: common::mc(2017), ..FigureConfig::paper(common::trials(), 2017) };
    let t0 = std::time::Instant::now();
    let pts = figure4(&cfg);
    let elapsed = t0.elapsed();
    println!("{}", FigPoint::csv_header());
    for p in &pts {
        println!("{}", p.to_csv());
    }
    println!(
        "fig4 total: {:.2}s for {} points ({} trials each)",
        elapsed.as_secs_f64(),
        pts.len(),
        cfg.mc.trials
    );

    // Headline check: the one-step/optimal gap per scheme at delta=0.5.
    println!("\nfig4 gap summary (delta closest to 0.5, s=10):");
    for scheme in ["FRC", "BGC", "s-regular"] {
        let get = |dec: &str| {
            pts.iter()
                .filter(|p| p.scheme == format!("{scheme}/{dec}") && p.s == 10)
                .min_by(|a, b| {
                    (a.delta - 0.5).abs().partial_cmp(&(b.delta - 0.5).abs()).unwrap()
                })
                .map(|p| p.value)
                .unwrap_or(f64::NAN)
        };
        let (one, opt) = (get("one-step"), get("optimal"));
        println!("  {scheme:<10} one-step {one:.4}  optimal {opt:.4}  gap {:.1}x", one / opt.max(1e-12));
    }
}
