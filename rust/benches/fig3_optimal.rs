//! Bench + regeneration for paper Figure 3: average optimal decoding
//! error err(A)/k vs δ for FRC / BGC / s-regular (k=100, s ∈ {5, 10}).
//!
//! Run: `cargo bench --bench fig3_optimal` (BENCH_TRIALS=5000 for the
//! full paper protocol).

mod common;

use gradcode::codes::Scheme;
use gradcode::decode::OptimalDecoder;
use gradcode::sim::figures::{draw_non_straggler_matrix, figure3, FigPoint, FigureConfig};
use gradcode::util::bench::black_box;
use gradcode::util::Rng;

fn main() {
    common::banner("fig3", "optimal error vs delta");
    let cfg = FigureConfig { mc: common::mc(2017), ..FigureConfig::paper(common::trials(), 2017) };
    let t0 = std::time::Instant::now();
    let pts = figure3(&cfg);
    let elapsed = t0.elapsed();
    println!("{}", FigPoint::csv_header());
    for p in &pts {
        println!("{}", p.to_csv());
    }
    println!(
        "fig3 total: {:.2}s for {} points ({} trials each)",
        elapsed.as_secs_f64(),
        pts.len(),
        cfg.mc.trials
    );

    // Micro: LSQR decode cost per scheme at the paper's size.
    let b = common::bencher();
    for scheme in [Scheme::Frc, Scheme::Bgc, Scheme::RegularGraph] {
        let mut rng = Rng::new(2);
        let a = draw_non_straggler_matrix(scheme, 100, 10, 80, &mut rng);
        b.bench(&format!("fig3/lsqr-decode/{}", scheme.name()), || {
            black_box(OptimalDecoder::new().err(&a))
        });
    }
}
