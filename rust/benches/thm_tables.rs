//! Bench + regeneration for the paper's closed-form results:
//! Thm 5 / Thm 6 / Thm 8 / Thm 10 / Thm 11 / Thm 21 / Thm 24 —
//! theorem-predicted vs Monte-Carlo-measured, as CSV rows.
//!
//! Run: `cargo bench --bench thm_tables`.

mod common;

use gradcode::codes::Scheme;
use gradcode::sim::tables::{
    thm10_table, thm11_table, thm21_table, thm5_table, thm6_table, thm8_table, TableRow,
};

fn main() {
    let mc = common::mc(2017);
    let (k, s) = (100usize, 10usize);
    let deltas = [0.1, 0.25, 0.5, 0.75];

    println!("{}", TableRow::csv_header());
    let t0 = std::time::Instant::now();

    for row in thm5_table(k, s, &deltas, &mc) {
        println!("{}", row.to_csv());
    }
    for row in thm6_table(k, s, &deltas, &mc) {
        println!("{}", row.to_csv());
    }
    for row in thm8_table(k, &[0, 1], &[0.1, 0.25], &mc) {
        println!("{}", row.to_csv());
    }
    for row in thm10_table(k, s, &[25, 50, 75], &mc) {
        println!("{}", row.to_csv());
    }
    for row in thm11_table(2017) {
        println!("{}", row.to_csv());
    }
    let ks: &[usize] = if common::quick() { &[50, 100] } else { &[50, 100, 200] };
    let s_of_k = |k: usize| ((k as f64).ln().ceil() as usize).max(2);
    for row in thm21_table(Scheme::Bgc, ks, s_of_k, 0.25, &mc) {
        println!("{}", row.to_csv());
    }
    for row in thm21_table(Scheme::Rbgc, ks, s_of_k, 0.25, &mc) {
        println!("{}", row.to_csv());
    }

    println!("thm tables total: {:.2}s", t0.elapsed().as_secs_f64());
}
