//! Bench for adversarial straggler selection (paper §4 / EXP-T10/T11):
//! wall-time of each adversary and the objective it reaches, across
//! codes and sizes. The paper claims the FRC attack is linear-time and
//! general worst-case selection is NP-hard — so the block attack should
//! be microseconds while the heuristics scale polynomially and still
//! fall short of exhaustive.
//!
//! Run: `cargo bench --bench adversary_bench`.

mod common;

use gradcode::adversary::{
    asp_objective, frc_worst_stragglers, greedy_stragglers, local_search_stragglers,
};
use gradcode::codes::Scheme;
use gradcode::util::bench::black_box;
use gradcode::util::Rng;

fn main() {
    let b = common::bencher();
    let sizes: &[(usize, usize)] =
        if common::quick() { &[(100, 10)] } else { &[(100, 10), (200, 10), (400, 20)] };

    for &(k, s) in sizes {
        let r = (k * 4) / 5;
        let rho = k as f64 / (r as f64 * s as f64);
        for scheme in [Scheme::Frc, Scheme::Bgc] {
            let g = scheme.build(k, k, s).assignment(&mut Rng::new(1));
            b.bench(&format!("adversary/block-attack/{}/k{k}", scheme.name()), || {
                black_box(frc_worst_stragglers(&g, r))
            });
            b.bench(&format!("adversary/greedy/{}/k{k}", scheme.name()), || {
                black_box(greedy_stragglers(&g, r, rho))
            });
            if k <= 200 {
                b.bench(&format!("adversary/local-search/{}/k{k}", scheme.name()), || {
                    black_box(local_search_stragglers(&g, r, rho, 2))
                });
            }
            // Objective values reached (reported once, not timed).
            let obj_block = asp_objective(&g, &frc_worst_stragglers(&g, r), rho);
            let obj_greedy = asp_objective(&g, &greedy_stragglers(&g, r, rho), rho);
            println!(
                "objective {} k={k}: block-attack {obj_block:.3} greedy {obj_greedy:.3}",
                scheme.name()
            );
        }
    }
}
