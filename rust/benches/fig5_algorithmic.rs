//! Bench + regeneration for paper Figure 5: algorithmic decoding error
//! ||u_t||²/k of a BGC vs iteration t, for δ ∈ {0.1,...,0.8},
//! s ∈ {5, 10}, ν = ||A||² (k = 100).
//!
//! Run: `cargo bench --bench fig5_algorithmic`.

mod common;

use gradcode::codes::Scheme;
use gradcode::decode::{algorithmic_error_curve, StepSize};
use gradcode::sim::figures::{draw_non_straggler_matrix, figure5, FigPoint, FigureConfig};
use gradcode::util::bench::black_box;
use gradcode::util::Rng;

fn main() {
    common::banner("fig5", "algorithmic error ||u_t||^2/k vs t (BGC)");
    let cfg = FigureConfig { mc: common::mc(2017), ..FigureConfig::paper(common::trials(), 2017) };
    let t_max = 15;
    let t0 = std::time::Instant::now();
    let pts = figure5(&cfg, t_max);
    let elapsed = t0.elapsed();
    println!("{}", FigPoint::csv_header());
    for p in &pts {
        println!("{}", p.to_csv());
    }
    println!(
        "fig5 total: {:.2}s for {} points ({} trials each)",
        elapsed.as_secs_f64(),
        pts.len(),
        cfg.mc.trials
    );

    // Micro: one curve evaluation (power iteration + t_max iterates).
    let b = common::bencher();
    let mut rng = Rng::new(3);
    let a = draw_non_straggler_matrix(Scheme::Bgc, 100, 10, 80, &mut rng);
    b.bench("fig5/curve-eval/spectral-nu", || {
        let mut r = Rng::new(4);
        black_box(algorithmic_error_curve(&a, StepSize::SpectralNormSq, t_max, &mut r))
    });
    b.bench("fig5/curve-eval/lemma17-nu", || {
        let mut r = Rng::new(4);
        black_box(algorithmic_error_curve(
            &a,
            StepSize::Lemma17 { k: 100, r: 80, s: 10 },
            t_max,
            &mut r,
        ))
    });
}
