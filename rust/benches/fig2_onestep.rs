//! Bench + regeneration for paper Figure 2: average one-step decoding
//! error err_1(A)/k vs straggler fraction δ for FRC / BGC / s-regular
//! (k=100, s ∈ {5, 10}, ρ = k/(rs)).
//!
//! Run: `cargo bench --bench fig2_onestep` (BENCH_TRIALS=5000 for the
//! paper's full protocol). Prints the CSV series + timing of the
//! per-point Monte-Carlo pipeline.

mod common;

use gradcode::sim::figures::{draw_non_straggler_matrix, figure2, FigPoint, FigureConfig};
use gradcode::codes::Scheme;
use gradcode::decode::OneStepDecoder;
use gradcode::util::bench::black_box;
use gradcode::util::Rng;

fn main() {
    common::banner("fig2", "one-step error vs delta");
    let cfg = FigureConfig { mc: common::mc(2017), ..FigureConfig::paper(common::trials(), 2017) };
    let t0 = std::time::Instant::now();
    let pts = figure2(&cfg);
    let elapsed = t0.elapsed();
    println!("{}", FigPoint::csv_header());
    for p in &pts {
        println!("{}", p.to_csv());
    }
    println!(
        "fig2 total: {:.2}s for {} points ({} trials each)",
        elapsed.as_secs_f64(),
        pts.len(),
        cfg.mc.trials
    );

    // Micro: cost of one trial per scheme (draw G + select + err1).
    let b = common::bencher();
    for scheme in [Scheme::Frc, Scheme::Bgc, Scheme::RegularGraph] {
        let mut rng = Rng::new(1);
        b.bench(&format!("fig2/trial/{}", scheme.name()), || {
            let a = draw_non_straggler_matrix(scheme, 100, 10, 80, &mut rng);
            black_box(OneStepDecoder::canonical(100, 80, 10).err1(&a))
        });
    }
}
