#![allow(dead_code)] // each bench target uses a subset of these helpers

//! Shared bench plumbing: trial budgets and CSV emission.
//!
//! All figure benches honor two env vars:
//!   BENCH_TRIALS  — Monte-Carlo trials per point (default 300; the
//!                   paper uses 5000 — set BENCH_TRIALS=5000 to match).
//!   BENCH_QUICK   — =1 shrinks everything for CI smoke runs.

use gradcode::sim::MonteCarlo;
use gradcode::util::bench::Bencher;

pub fn trials() -> usize {
    if quick() {
        60
    } else {
        std::env::var("BENCH_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(300)
    }
}

pub fn quick() -> bool {
    gradcode::util::bench::quick_mode()
}

pub fn bencher() -> Bencher {
    if quick() {
        Bencher::quick()
    } else {
        Bencher::default()
    }
}

pub fn mc(seed: u64) -> MonteCarlo {
    MonteCarlo::new(trials(), seed)
}

/// Print a figure/table banner so bench logs are self-describing.
pub fn banner(name: &str, what: &str) {
    println!("\n=== {name}: {what} (trials={}) ===", trials());
}

/// One decode-benchmark measurement, emitted to `BENCH_decode.json` so
/// future PRs can diff throughput trajectories against fixed seeds.
pub struct DecodeBenchRecord {
    /// What was measured, e.g. "one-step/fused".
    pub label: String,
    pub scheme: String,
    pub k: usize,
    pub n: usize,
    pub s: usize,
    /// Non-straggler count of the benchmarked instance.
    pub r: usize,
    /// RNG seed the instance was drawn with (fixed across PRs).
    pub seed: u64,
    pub ns_per_decode: f64,
    pub decodes_per_sec: f64,
}

impl DecodeBenchRecord {
    fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"label\": \"{}\", \"scheme\": \"{}\", \"k\": {}, \"n\": {}, ",
                "\"s\": {}, \"r\": {}, \"seed\": {}, \"ns_per_decode\": {:.3}, ",
                "\"decodes_per_sec\": {:.3e}}}"
            ),
            self.label,
            self.scheme,
            self.k,
            self.n,
            self.s,
            self.r,
            self.seed,
            self.ns_per_decode,
            self.decodes_per_sec,
        )
    }
}

/// Write the benchmark trajectory file (`BENCH_decode.json` in the
/// crate root, or `$BENCH_JSON_DIR` if set). Records use fixed seeds so
/// regressions show up as pure-throughput deltas across PRs.
pub fn write_decode_bench_json(records: &[DecodeBenchRecord]) {
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    let path = std::path::Path::new(&dir).join("BENCH_decode.json");
    let body: Vec<String> = records.iter().map(|r| format!("    {}", r.to_json())).collect();
    let json = format!(
        "{{\n  \"bench\": \"decode_throughput\",\n  \"quick\": {},\n  \"records\": [\n{}\n  ]\n}}\n",
        quick(),
        body.join(",\n")
    );
    match std::fs::write(&path, json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("WARN: could not write {}: {e}", path.display()),
    }
}
