#![allow(dead_code)] // each bench target uses a subset of these helpers

//! Shared bench plumbing: trial budgets and CSV emission.
//!
//! All figure benches honor two env vars:
//!   BENCH_TRIALS  — Monte-Carlo trials per point (default 300; the
//!                   paper uses 5000 — set BENCH_TRIALS=5000 to match).
//!   BENCH_QUICK   — =1 shrinks everything for CI smoke runs.

use gradcode::sim::MonteCarlo;
use gradcode::util::bench::Bencher;

pub fn trials() -> usize {
    if quick() {
        60
    } else {
        std::env::var("BENCH_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(300)
    }
}

pub fn quick() -> bool {
    gradcode::util::bench::quick_mode()
}

pub fn bencher() -> Bencher {
    if quick() {
        Bencher::quick()
    } else {
        Bencher::default()
    }
}

pub fn mc(seed: u64) -> MonteCarlo {
    MonteCarlo::new(trials(), seed)
}

/// Print a figure/table banner so bench logs are self-describing.
pub fn banner(name: &str, what: &str) {
    println!("\n=== {name}: {what} (trials={}) ===", trials());
}
