//! Decoder hot-path benchmarks — the §Perf targets for L3.
//!
//! * fused vs seed one-step decode at k = n = 1000, s = 10: the
//!   acceptance target is fused ≥ 3× (no A materialization, no
//!   allocation, single pass over G's selected columns).
//! * **row-pass CSR vs CSC** at k = n = 1000 (the PR 2 acceptance
//!   instance): row sums over the materialized A, and the streamed
//!   CSR err_1 vs the fused CSC accumulation.
//! * blocked vs scalar dense kernels (the LSQR inner-loop reductions).
//! * `assignment_into` re-draw vs the allocating `assignment`.
//! * workspace vs allocating LSQR, cold vs warm-started.
//! * one-step decode: a single sparse pass; target >= 1e8 nnz/s.
//! * scaling in k at fixed density.
//! * **panel decode** (PR 6): W-trials-per-call batched kernels vs the
//!   scalar trial loop at k = n = 1000 for W ∈ {4, 8, 16}, plus the
//!   Aᵀx CSC-column-walk vs per-trial-CSR-conversion measurement that
//!   settles the queued CSR-backed-LSQR question.
//! * **serve/load** (PR 7): sustained decode rounds/sec through the
//!   `repro serve` daemon (sockets, framing, memoized assignments, hot
//!   workspaces) under a closed-loop `repro load` at k = n = 1000.
//! * **SIMD lane tiers + fused redraw panels** (PR 9): the W = 8 panel
//!   re-measured with the runtime dispatcher capped at each available
//!   tier (portable/SSE2/AVX2/AVX-512 — all bit-identical, only
//!   wall-clock differs), and the fused fresh-G redraw panel vs the
//!   scalar fork-per-trial redraw loop.
//!
//! Emits `BENCH_decode.json` (fixed seeds) for cross-PR trajectories.
//!
//! Run: `cargo bench --bench decode_throughput`.

mod common;

use common::DecodeBenchRecord;
use gradcode::codes::{AssignmentScratch, GradientCode, Scheme};
use gradcode::decode::{
    algorithmic_error_curve, DecodeWorkspace, IncrementalDecoder, OneStepDecoder, OptimalDecoder,
    StepSize,
};
use gradcode::linalg::{blocked, spectral_norm, CscMatrix, CsrMatrix, LsqrOptions};
use gradcode::sim::figures::{draw_non_straggler_matrix, FigPartialPoint};
use gradcode::sim::shard::ShardPoints;
use gradcode::sim::{JobKind, JobSpec, MonteCarlo, Shard, ShardArtifact};
use gradcode::util::bench::black_box;
use gradcode::util::Rng;

fn main() {
    // One startup note when the build asked for SIMD but the target has
    // no x86-64 tier (e.g. aarch64): every kernel silently runs the
    // portable loops, and the per-tier records below collapse to one
    // tier. Bench-only — the library itself never prints.
    if cfg!(feature = "simd")
        && gradcode::linalg::detected_simd_tier() == gradcode::linalg::SimdTier::Portable
    {
        eprintln!(
            "WARN: the `simd` feature is enabled but no x86-64 SIMD tier is available on \
             this target; panel kernels run the portable scalar loops"
        );
    }
    let b = common::bencher();
    let mut records: Vec<DecodeBenchRecord> = Vec::new();

    // ------------------------------------------------- headline: fused
    // k = n = 1000, s = 10 — the ISSUE's acceptance instance. The seed
    // path materializes A (three Vecs) and then row-sums it; the fused
    // path accumulates coverage straight from G.
    let (k1, s1, r1, seed1) = (1000usize, 10usize, 900usize, 42u64);
    let mut rng = Rng::new(seed1);
    let g1 = Scheme::Bgc.build(k1, k1, s1).assignment(&mut rng);
    let idx1 = rng.sample_indices(k1, r1);
    let rho1 = k1 as f64 / (r1 as f64 * s1 as f64);

    let t_seed = b.bench("decode/one-step/seed-path/k1000", || {
        let a = g1.select_columns(&idx1);
        let sums = a.row_sums();
        black_box(sums.iter().map(|&v| (rho1 * v - 1.0).powi(2)).sum::<f64>())
    });
    let mut ws = DecodeWorkspace::new();
    let t_fused = b.bench("decode/one-step/fused/k1000", || {
        black_box(ws.err1_fused(&g1, &idx1, rho1))
    });
    let speedup = t_seed.as_secs_f64() / t_fused.as_secs_f64();
    println!(
        "bench decode/one-step/fused-speedup/k1000               {speedup:.2}x (target >= 3x)"
    );
    for (label, t) in [("one-step/seed-path", t_seed), ("one-step/fused", t_fused)] {
        records.push(DecodeBenchRecord {
            label: label.to_string(),
            scheme: "BGC".to_string(),
            k: k1,
            n: k1,
            s: s1,
            r: r1,
            seed: seed1,
            ns_per_decode: t.as_nanos() as f64,
            decodes_per_sec: 1.0 / t.as_secs_f64(),
        });
    }

    // ------------- incremental anytime decode (PR 8): per-arrival cost
    // One iteration replays the full r = 900 survivor arrival stream
    // through `IncrementalDecoder::arrive` — O(deg) coverage + running
    // err₁ per survivor — so the per-arrival figure is replay time / r.
    // The exact err₁ query is the O(k) fold an anytime stopping rule
    // pays at each prefix it actually inspects; the batch comparison is
    // the fused one-step decode above (same survivors, one shot).
    let mut inc = IncrementalDecoder::new();
    inc.reserve(k1, k1);
    let t_replay = b.bench("decode/incremental/replay-r-arrivals/k1000", || {
        inc.begin(k1, rho1);
        for &j in &idx1 {
            inc.arrive(&g1, j);
        }
        black_box(inc.err1_running())
    });
    inc.begin(k1, rho1);
    for &j in &idx1 {
        inc.arrive(&g1, j);
    }
    let t_exact = b.bench("decode/incremental/exact-err1-query/k1000", || black_box(inc.err1()));
    println!(
        "bench decode/incremental/per-arrival/k1000             {:.0} ns/arrival (full replay \
         {:.2}x one batch fused decode; exact err1 query {})",
        t_replay.as_secs_f64() * 1e9 / r1 as f64,
        t_replay.as_secs_f64() / t_fused.as_secs_f64(),
        gradcode::util::bench::fmt_duration(t_exact)
    );
    for (label, t, per) in [
        ("incremental/replay-full-arrival-stream", t_replay, r1 as f64),
        ("incremental/exact-err1-query", t_exact, 1.0),
    ] {
        records.push(DecodeBenchRecord {
            label: label.to_string(),
            scheme: "BGC".to_string(),
            k: k1,
            n: k1,
            s: s1,
            r: r1,
            seed: seed1,
            // Per-arrival cost for the replay: one closure call feeds r survivors.
            ns_per_decode: t.as_nanos() as f64 / per,
            decodes_per_sec: per / t.as_secs_f64(),
        });
    }

    // --------------------------- PR 2 headline: row-pass CSR vs CSC
    // The same A both ways: CSC scatters row accumulation through the
    // column walk; the CSR mirror streams each row contiguously.
    let a1 = g1.select_columns(&idx1);
    let a1_csr = a1.to_csr();
    let mut row_buf: Vec<f64> = Vec::new();
    let t_rows_csc = b.bench("decode/row-sums/csc/k1000", || {
        a1.row_sums_into(&mut row_buf);
        black_box(row_buf.last().copied())
    });
    let t_rows_csr = b.bench("decode/row-sums/csr/k1000", || {
        a1_csr.row_sums_into(&mut row_buf);
        black_box(row_buf.last().copied())
    });
    let row_speedup = t_rows_csc.as_secs_f64() / t_rows_csr.as_secs_f64();
    println!(
        "bench decode/row-sums/csr-speedup/k1000                {row_speedup:.2}x ({:+.1}%)",
        (row_speedup - 1.0) * 100.0
    );
    for (label, t) in [("row-sums/csc", t_rows_csc), ("row-sums/csr", t_rows_csr)] {
        records.push(DecodeBenchRecord {
            label: label.to_string(),
            scheme: "BGC".to_string(),
            k: k1,
            n: k1,
            s: s1,
            r: r1,
            seed: seed1,
            ns_per_decode: t.as_nanos() as f64,
            decodes_per_sec: 1.0 / t.as_secs_f64(),
        });
    }

    // Streamed err_1 over the workspace-cached CSR mirror of G vs the
    // fused CSC accumulation (same straggler set, bit-identical value).
    ws.mirror_csr(&g1);
    let t_streamed = b.bench("decode/one-step/csr-streamed/k1000", || {
        black_box(ws.err1_streamed(&idx1, rho1))
    });
    let streamed_speedup = t_fused.as_secs_f64() / t_streamed.as_secs_f64();
    println!(
        "bench decode/one-step/csr-vs-fused-speedup/k1000       {streamed_speedup:.2}x ({:+.1}%)",
        (streamed_speedup - 1.0) * 100.0
    );
    records.push(DecodeBenchRecord {
        label: "one-step/csr-streamed".to_string(),
        scheme: "BGC".to_string(),
        k: k1,
        n: k1,
        s: s1,
        r: r1,
        seed: seed1,
        ns_per_decode: t_streamed.as_nanos() as f64,
        decodes_per_sec: 1.0 / t_streamed.as_secs_f64(),
    });

    // One-step on the materialized A, CSR vs CSC (the err1_csr path).
    let onestep1 = OneStepDecoder::new(rho1);
    let t_err1_csc = b.bench("decode/err1-materialized/csc/k1000", || {
        black_box(onestep1.err1(&a1))
    });
    let t_err1_csr = b.bench("decode/err1-materialized/csr/k1000", || {
        black_box(onestep1.err1_csr(&a1_csr))
    });

    // Mirror construction cost (amortized over a figure point's trials).
    let mut csr_buf = CsrMatrix::empty();
    let t_to_csr = b.bench("linalg/to-csr-into/k1000", || {
        g1.to_csr_into(&mut csr_buf);
        black_box(csr_buf.nnz())
    });

    // Blocked vs scalar dense reductions at the LSQR working size.
    let xv: Vec<f64> = (0..k1).map(|i| (i as f64).sin()).collect();
    let yv: Vec<f64> = (0..k1).map(|i| (i as f64).cos()).collect();
    let t_dot_scalar = b.bench("kernel/dot/scalar/n1000", || {
        black_box(gradcode::linalg::dot(&xv, &yv))
    });
    let t_dot_blocked = b.bench("kernel/dot/blocked4/n1000", || black_box(blocked::dot(&xv, &yv)));
    println!(
        "bench kernel/dot/blocked4-speedup/n1000                {:.2}x",
        t_dot_scalar.as_secs_f64() / t_dot_blocked.as_secs_f64()
    );
    for (label, t) in [
        ("err1-materialized/csc", t_err1_csc),
        ("err1-materialized/csr", t_err1_csr),
        ("to-csr-into", t_to_csr),
        ("kernel/dot-scalar", t_dot_scalar),
        ("kernel/dot-blocked4", t_dot_blocked),
    ] {
        records.push(DecodeBenchRecord {
            label: label.to_string(),
            scheme: "BGC".to_string(),
            k: k1,
            n: k1,
            s: s1,
            r: r1,
            seed: seed1,
            ns_per_decode: t.as_nanos() as f64,
            decodes_per_sec: 1.0 / t.as_secs_f64(),
        });
    }

    // Re-draw: allocating assignment vs workspace assignment_into.
    let code1 = Scheme::Bgc.build(k1, k1, s1);
    let mut redraw_rng = Rng::new(seed1);
    let t_draw_alloc = b.bench("codes/assignment/alloc/k1000", || {
        black_box(code1.assignment(&mut redraw_rng).nnz())
    });
    let mut g_buf = CscMatrix::empty();
    let mut scratch = AssignmentScratch::new();
    let t_draw_into = b.bench("codes/assignment-into/k1000", || {
        code1.assignment_into(&mut redraw_rng, &mut g_buf, &mut scratch);
        black_box(g_buf.nnz())
    });
    for (label, t) in [("redraw/alloc", t_draw_alloc), ("redraw/into", t_draw_into)] {
        records.push(DecodeBenchRecord {
            label: label.to_string(),
            scheme: "BGC".to_string(),
            k: k1,
            n: k1,
            s: s1,
            r: r1,
            seed: seed1,
            ns_per_decode: t.as_nanos() as f64,
            decodes_per_sec: 1.0 / t.as_secs_f64(),
        });
    }

    // -------------- scenario spine: straggler-model trial overhead
    // The k = n = 1000 one-step redraw trial under (a) the legacy
    // r-based uniform draw, (b) the spine's uniform model (same RNG
    // stream through a vtable — should be noise), and (c/d) latency
    // models, which add n latency draws plus the deadline policy
    // (fastest-r pays an O(n log n) order-statistic sort).
    {
        use gradcode::stragglers::{
            DeadlinePolicy, LatencyModel, LatencyStragglers, UniformStragglers,
        };
        let code = Scheme::Bgc.build(k1, k1, s1);
        let mut rng = Rng::new(seed1);
        let uniform = UniformStragglers::new(0.1); // r = 900 = r1
        let pareto = LatencyModel::Pareto { scale: 0.02, shape: 1.5 };
        let fastest = LatencyStragglers { model: pareto, policy: DeadlinePolicy::FastestR(r1) };
        let deadline = LatencyStragglers { model: pareto, policy: DeadlinePolicy::Fixed(0.08) };
        let t_legacy = b.bench("scenario/onestep-redraw/legacy-r/k1000", || {
            black_box(ws.onestep_redraw_trial(code.as_ref(), r1, rho1, &mut rng))
        });
        let t_uniform = b.bench("scenario/onestep-redraw/uniform-model/k1000", || {
            black_box(ws.onestep_redraw_trial_with(code.as_ref(), &uniform, rho1, &mut rng))
        });
        let t_fastest = b.bench("scenario/onestep-redraw/pareto-fastest-r/k1000", || {
            black_box(ws.onestep_redraw_trial_with(code.as_ref(), &fastest, rho1, &mut rng))
        });
        let t_deadline = b.bench("scenario/onestep-redraw/pareto-deadline/k1000", || {
            black_box(ws.onestep_redraw_trial_with(code.as_ref(), &deadline, rho1, &mut rng))
        });
        println!(
            "bench scenario/spine-overhead/k1000                    uniform {:+.1}%, \
             pareto fastest-r {:+.1}%, pareto deadline {:+.1}% vs legacy",
            (t_uniform.as_secs_f64() / t_legacy.as_secs_f64() - 1.0) * 100.0,
            (t_fastest.as_secs_f64() / t_legacy.as_secs_f64() - 1.0) * 100.0,
            (t_deadline.as_secs_f64() / t_legacy.as_secs_f64() - 1.0) * 100.0
        );
        for (label, t) in [
            ("scenario/legacy-r", t_legacy),
            ("scenario/uniform-model", t_uniform),
            ("scenario/pareto-fastest-r", t_fastest),
            ("scenario/pareto-deadline", t_deadline),
        ] {
            records.push(DecodeBenchRecord {
                label: label.to_string(),
                scheme: "BGC".to_string(),
                k: k1,
                n: k1,
                s: s1,
                r: r1,
                seed: seed1,
                ns_per_decode: t.as_nanos() as f64,
                decodes_per_sec: 1.0 / t.as_secs_f64(),
            });
        }
    }

    // ------------------------------------- optimal decode: LSQR paths
    let opts = LsqrOptions::default();
    let t_alloc = b.bench("decode/optimal-lsqr/alloc/k1000", || {
        black_box(OptimalDecoder::new().err(&g1.select_columns(&idx1)))
    });
    let t_ws = b.bench("decode/optimal-lsqr/workspace/k1000", || {
        black_box(ws.optimal_err(&g1, &idx1, &opts, None))
    });
    let t_warm = b.bench("decode/optimal-lsqr/warm-start/k1000", || {
        black_box(ws.optimal_err(&g1, &idx1, &opts, Some(rho1)))
    });
    for (label, t) in [
        ("optimal/alloc", t_alloc),
        ("optimal/workspace", t_ws),
        ("optimal/warm-start", t_warm),
    ] {
        records.push(DecodeBenchRecord {
            label: label.to_string(),
            scheme: "BGC".to_string(),
            k: k1,
            n: k1,
            s: s1,
            r: r1,
            seed: seed1,
            ns_per_decode: t.as_nanos() as f64,
            decodes_per_sec: 1.0 / t.as_secs_f64(),
        });
    }

    // --------------------------------------------- paper-sized (k=100)
    let mut rng = Rng::new(1);
    let a100 = draw_non_straggler_matrix(Scheme::Bgc, 100, 10, 80, &mut rng);
    let nnz = a100.nnz() as u64;

    b.bench_throughput("decode/one-step/k100 (nnz/s)", nnz, || {
        black_box(OneStepDecoder::canonical(100, 80, 10).err1(&a100))
    });
    b.bench("decode/optimal-lsqr/k100", || black_box(OptimalDecoder::new().err(&a100)));
    b.bench("decode/algorithmic-10-iters/k100", || {
        let mut r = Rng::new(2);
        black_box(algorithmic_error_curve(&a100, StepSize::Lemma17 { k: 100, r: 80, s: 10 }, 10, &mut r))
    });
    b.bench("decode/spectral-norm/k100", || {
        let mut r = Rng::new(3);
        black_box(spectral_norm(&a100, &mut r, 300, 1e-10))
    });

    // ------------------------- scaling sweep in k at log2(k)-ish density
    let ks: &[usize] = if common::quick() { &[100, 400] } else { &[100, 400, 1600, 6400] };
    for &k in ks {
        let s = ((k as f64).log2().ceil() as usize).max(4);
        let r = (k * 4) / 5;
        let mut rng = Rng::new(k as u64);
        let g = Scheme::Bgc.build(k, k, s).assignment(&mut rng);
        let idx = rng.sample_indices(k, r);
        let rho = k as f64 / (r as f64 * s as f64);
        let nnz: u64 = idx.iter().map(|&j| g.col_nnz(j) as u64).sum();
        b.bench_throughput(&format!("decode/one-step/fused/k{k} (nnz/s)"), nnz, || {
            black_box(ws.err1_fused(&g, &idx, rho))
        });
        let t = b.bench(&format!("decode/optimal-lsqr/workspace/k{k}"), || {
            black_box(ws.optimal_err(&g, &idx, &opts, None))
        });
        records.push(DecodeBenchRecord {
            label: "optimal/workspace-scaling".to_string(),
            scheme: "BGC".to_string(),
            k,
            n: k,
            s,
            r,
            seed: k as u64,
            ns_per_decode: t.as_nanos() as f64,
            decodes_per_sec: 1.0 / t.as_secs_f64(),
        });
    }

    // ----------------------- shard overhead at the k = n = 1000 instance
    // The distributed path's cost vs in-process aggregation: (a) one
    // figure point's mean through `mean_ws` (the num_shards = 1 case),
    // (b) the same mean as a 4-shard fan-out including the full JSON
    // artifact round trip and merge, and (c) serialize+parse+merge
    // alone on prebuilt partials — the pure shard overhead a multi-
    // process run pays on top of the trials themselves.
    let shard_trials = if common::quick() { 48 } else { 128 };
    let mc_shard = MonteCarlo::new(shard_trials, seed1).with_threads(1);
    let shard_job = JobSpec {
        kind: JobKind::Figure,
        id: "2".to_string(),
        trials: shard_trials,
        seed: seed1,
        k: k1,
        s: 0,
        tmax: 0,
        scenario: gradcode::stragglers::Scenario::default(),
    };
    let num_shards = 4usize;

    let t_inproc = b.bench("shard/in-process-mean/k1000", || {
        black_box(mc_shard.mean_ws(DecodeWorkspace::new, |ws, rng| {
            ws.onestep_redraw_trial(code1.as_ref(), r1, rho1, rng)
        }))
    });

    let make_artifact_text = |sid: usize| -> String {
        let shard = Shard::new(sid, num_shards).unwrap();
        let partial = mc_shard.mean_partial_ws(shard, DecodeWorkspace::new, |ws, rng| {
            ws.onestep_redraw_trial(code1.as_ref(), r1, rho1, rng)
        });
        let point = FigPartialPoint {
            figure: "fig2",
            scheme: "BGC".to_string(),
            s: s1,
            delta: 0.1,
            k: k1,
            partial,
        };
        let art = ShardArtifact {
            job: shard_job.clone(),
            shard_ids: vec![sid],
            num_shards,
            points: ShardPoints::Fig(vec![point]),
        };
        art.to_json_string()
    };

    let t_fanout = b.bench("shard/4shard-fanout+merge/k1000", || {
        let texts: Vec<String> = (0..num_shards).map(|sid| make_artifact_text(sid)).collect();
        let parsed: Vec<ShardArtifact> =
            texts.iter().map(|t| ShardArtifact::parse(t).unwrap()).collect();
        let merged = ShardArtifact::merge(parsed).unwrap();
        black_box(merged.to_csv().len())
    });

    // Pure overhead: artifacts prebuilt once, bench only the byte-level
    // round trip and the merge/finalize work.
    let prebuilt: Vec<String> = (0..num_shards).map(|sid| make_artifact_text(sid)).collect();
    let t_merge_only = b.bench("shard/serialize+merge-only/4shards", || {
        let parsed: Vec<ShardArtifact> =
            prebuilt.iter().map(|t| ShardArtifact::parse(t).unwrap()).collect();
        let merged = ShardArtifact::merge(parsed).unwrap();
        black_box(merged.to_csv().len())
    });
    println!(
        "bench shard/overhead/k1000                             {:+.1}% fan-out vs in-process \
         (merge-only {})",
        (t_fanout.as_secs_f64() / t_inproc.as_secs_f64() - 1.0) * 100.0,
        gradcode::util::bench::fmt_duration(t_merge_only)
    );
    for (label, t) in [
        ("shard/in-process-mean", t_inproc),
        ("shard/4shard-fanout+merge", t_fanout),
        ("shard/serialize+merge-only", t_merge_only),
    ] {
        records.push(DecodeBenchRecord {
            label: label.to_string(),
            scheme: "BGC".to_string(),
            k: k1,
            n: k1,
            s: s1,
            r: r1,
            seed: seed1,
            ns_per_decode: t.as_nanos() as f64,
            decodes_per_sec: 1.0 / t.as_secs_f64(),
        });
    }

    // -------------- fan-out *driver* overhead at the k = n = 1000 instance
    // The real multi-process path: `repro run --fanout 4` (spawn 4 shard
    // processes, wait, verify, merge) vs the unsharded CLI on the same
    // job — thm5 at k = n = 1000 (4 deltas, FRC one-step trials). One
    // timed run each: the child processes execute enough trials that
    // process spawn jitter is a small fraction of the total.
    let bin = env!("CARGO_BIN_EXE_gradcode");
    let driver_trials = if common::quick() { 16usize } else { 64 };
    let trials_str = driver_trials.to_string();
    let time_cli = |args: &[&str]| -> std::time::Duration {
        let t0 = std::time::Instant::now();
        let status = std::process::Command::new(bin)
            .args(args)
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .status()
            .expect("spawning the repro binary");
        assert!(status.success(), "repro {args:?} failed");
        t0.elapsed()
    };
    let t_cli = time_cli(&[
        "tables", "--table", "thm5", "--trials", &trials_str, "--k", "1000", "--s", "10",
    ]);
    let t_driver = time_cli(&[
        "run", "--fanout", "4", "--table", "thm5", "--trials", &trials_str, "--k", "1000",
        "--s", "10",
    ]);
    println!(
        "bench shard/fanout-driver/k1000                        {} vs unsharded CLI {} ({:+.1}%)",
        gradcode::util::bench::fmt_duration(t_driver),
        gradcode::util::bench::fmt_duration(t_cli),
        (t_driver.as_secs_f64() / t_cli.as_secs_f64() - 1.0) * 100.0
    );
    for (label, t) in [
        ("shard/unsharded-cli", t_cli),
        ("shard/fanout-driver-4proc", t_driver),
    ] {
        records.push(DecodeBenchRecord {
            label: label.to_string(),
            scheme: "FRC".to_string(),
            k: k1,
            n: k1,
            s: 10,
            r: 0, // thm5 sweeps deltas; no single r
            seed: 2017,
            ns_per_decode: t.as_nanos() as f64,
            decodes_per_sec: 1.0 / t.as_secs_f64(),
        });
    }

    // ------------------- panel decode: W trials per kernel call (PR 6)
    // Scalar trial baselines replicate the Monte-Carlo fork-per-trial
    // structure (trial j draws from `root.fork(j)`), so the panel and
    // scalar closures do identical RNG + draw work per trial and the
    // comparison isolates the kernel batching. Panel time is divided by
    // W to report per-trial cost.
    {
        use gradcode::decode::PanelWorkspace;

        let root = Rng::new(seed1);
        let mut sbase = 0u64;
        let t_scalar_one = b.bench("decode/panel/one-step/scalar-trial/k1000", || {
            let mut r = root.fork(sbase);
            sbase += 1;
            black_box(ws.onestep_trial(&g1, r1, rho1, &mut r))
        });
        let mut sbase_opt = 0u64;
        let t_scalar_opt = b.bench("decode/panel/optimal/scalar-trial/k1000", || {
            let mut r = root.fork(sbase_opt);
            sbase_opt += 1;
            black_box(ws.optimal_trial(&g1, r1, &opts, None, &mut r))
        });
        for (label, t) in [
            ("panel/one-step/scalar-trial", t_scalar_one),
            ("panel/optimal/scalar-trial", t_scalar_opt),
        ] {
            records.push(DecodeBenchRecord {
                label: label.to_string(),
                scheme: "BGC".to_string(),
                k: k1,
                n: k1,
                s: s1,
                r: r1,
                seed: seed1,
                ns_per_decode: t.as_nanos() as f64,
                decodes_per_sec: 1.0 / t.as_secs_f64(),
            });
        }

        for &w in &[4usize, 8, 16] {
            let mut pw = PanelWorkspace::new(w);
            pw.mirror_csr(&g1);
            let mut out = vec![0.0f64; w];

            let mut pbase = 0u64;
            let t_panel_one = b.bench(&format!("decode/panel/one-step/w{w}/k1000"), || {
                pw.onestep_panel(&g1, r1, rho1, &root, pbase, w, &mut out);
                pbase += w as u64;
                black_box(out[0])
            });
            let mut obase = 0u64;
            let t_panel_opt = b.bench(&format!("decode/panel/optimal/w{w}/k1000"), || {
                pw.optimal_panel(&g1, r1, &opts, None, &root, obase, w, &mut out);
                obase += w as u64;
                black_box(out[0])
            });
            println!(
                "bench decode/panel/per-trial-speedup/w{w}/k1000         one-step {:.2}x, \
                 optimal {:.2}x vs scalar",
                t_scalar_one.as_secs_f64() / (t_panel_one.as_secs_f64() / w as f64),
                t_scalar_opt.as_secs_f64() / (t_panel_opt.as_secs_f64() / w as f64)
            );
            for (label, t) in [
                (format!("panel/one-step/w{w}"), t_panel_one),
                (format!("panel/optimal/w{w}"), t_panel_opt),
            ] {
                records.push(DecodeBenchRecord {
                    label,
                    scheme: "BGC".to_string(),
                    k: k1,
                    n: k1,
                    s: s1,
                    r: r1,
                    seed: seed1,
                    // Per-trial cost: one closure call runs W trials.
                    ns_per_decode: t.as_nanos() as f64 / w as f64,
                    decodes_per_sec: w as f64 / t.as_secs_f64(),
                });
            }
        }

        // ---------------- per-lane-tier panel throughput (PR 9)
        // Cap the runtime dispatcher at each tier at or below the one
        // this machine detects and re-measure the W = 8 panels. Every
        // tier produces bit-identical errors (independent per-lane IEEE
        // accumulators, no FMA) — only wall-clock differs — so the
        // records chart the SSE2 → AVX2 (→ AVX-512) trajectory on
        // capable hardware and collapse to one row elsewhere.
        {
            use gradcode::linalg::{
                cap_simd_tier, detected_simd_tier, uncap_simd_tier, SimdTier,
            };

            let detected = detected_simd_tier();
            println!("bench decode/panel/simd-tier/detected                  {}", detected.name());
            let w = 8usize;
            let mut pw = PanelWorkspace::new(w);
            pw.mirror_csr(&g1);
            let mut out = vec![0.0f64; w];
            for tier in [SimdTier::Portable, SimdTier::Sse2, SimdTier::Avx2, SimdTier::Avx512] {
                if tier > detected {
                    continue;
                }
                cap_simd_tier(tier);
                let mut pbase = 0u64;
                let t_tier_one =
                    b.bench(&format!("decode/panel/one-step/w8/{}/k1000", tier.name()), || {
                        pw.onestep_panel(&g1, r1, rho1, &root, pbase, w, &mut out);
                        pbase += w as u64;
                        black_box(out[0])
                    });
                let mut obase = 0u64;
                let t_tier_opt =
                    b.bench(&format!("decode/panel/optimal/w8/{}/k1000", tier.name()), || {
                        pw.optimal_panel(&g1, r1, &opts, None, &root, obase, w, &mut out);
                        obase += w as u64;
                        black_box(out[0])
                    });
                for (label, t) in [
                    (format!("panel/one-step/w8/{}", tier.name()), t_tier_one),
                    (format!("panel/optimal/w8/{}", tier.name()), t_tier_opt),
                ] {
                    records.push(DecodeBenchRecord {
                        label,
                        scheme: "BGC".to_string(),
                        k: k1,
                        n: k1,
                        s: s1,
                        r: r1,
                        seed: seed1,
                        ns_per_decode: t.as_nanos() as f64 / w as f64,
                        decodes_per_sec: w as f64 / t.as_secs_f64(),
                    });
                }
            }
            uncap_simd_tier();
            // The tier the uncapped dispatcher actually chose, recorded
            // as a zero-cost marker row so BENCH_decode.json states the
            // hardware context of every panel/* record above.
            records.push(DecodeBenchRecord {
                label: format!("panel/simd-tier/{}", detected.name()),
                scheme: "BGC".to_string(),
                k: k1,
                n: k1,
                s: s1,
                r: r1,
                seed: seed1,
                ns_per_decode: 0.0,
                decodes_per_sec: 0.0,
            });
        }

        // ---------------- fused redraw panels (PR 9)
        // Fresh-G arms: trial j draws a new assignment from
        // `root.fork(j)` before decoding. The scalar baseline pays one
        // full draw + decode per call; the fused panel batches W draws
        // into a lane-strided coverage panel and runs one fused err₁
        // sweep. Per-trial cost is panel time / W, as above.
        {
            use gradcode::stragglers::UniformStragglers;

            let model = UniformStragglers::new(0.1); // r = 900 = r1
            let mut sbase_rd = 0u64;
            let t_redraw_scalar =
                b.bench("decode/panel/redraw/one-step/scalar-trial/k1000", || {
                    let mut r = root.fork(sbase_rd);
                    sbase_rd += 1;
                    black_box(ws.onestep_redraw_trial_with(code1.as_ref(), &model, rho1, &mut r))
                });
            let w = 8usize;
            let mut pw = PanelWorkspace::new(w);
            pw.reserve_redraw(k1, k1, s1);
            let mut out = vec![0.0f64; w];
            let mut pbase_rd = 0u64;
            let t_redraw_panel = b.bench("decode/panel/redraw/one-step/w8/k1000", || {
                pw.onestep_redraw_panel_with(
                    code1.as_ref(),
                    &model,
                    rho1,
                    &root,
                    pbase_rd,
                    w,
                    &mut out,
                );
                pbase_rd += w as u64;
                black_box(out[0])
            });
            println!(
                "bench decode/panel/redraw/per-trial-speedup/w8/k1000   {:.2}x vs scalar",
                t_redraw_scalar.as_secs_f64() / (t_redraw_panel.as_secs_f64() / w as f64)
            );
            for (label, t, per) in [
                ("panel/redraw/one-step/scalar-trial", t_redraw_scalar, 1usize),
                ("panel/redraw/one-step/w8", t_redraw_panel, w),
            ] {
                records.push(DecodeBenchRecord {
                    label: label.to_string(),
                    scheme: "BGC".to_string(),
                    k: k1,
                    n: k1,
                    s: s1,
                    r: r1,
                    seed: seed1,
                    ns_per_decode: t.as_nanos() as f64 / per as f64,
                    decodes_per_sec: per as f64 / t.as_secs_f64(),
                });
            }
        }

        // The queued CSR-backed-LSQR question, settled by measurement:
        // Aᵀx per LSQR iteration as (a) the CSC column walk over the
        // implicit selection — what `lsqr_selected_panel` does — vs (b)
        // converting the materialized A to CSR once per trial and using
        // the row-major transpose kernel. (b) pays O(nnz) conversion
        // up front; at LSQR's typical iteration counts on these
        // instances the walk wins, and the decision is recorded here so
        // future PRs can revisit it against real numbers.
        let xr: Vec<f64> = (0..k1).map(|i| ((i * 37 + 11) % 97) as f64 / 97.0).collect();
        let mut yt = vec![0.0f64; r1];
        let t_tm_csc = b.bench("linalg/t-matvec/csc-selected/k1000", || {
            gradcode::linalg::t_matvec_selected_into(&g1, &idx1, &xr, &mut yt);
            black_box(yt[0])
        });
        let mut a_csr_buf = CsrMatrix::empty();
        let t_tm_csr = b.bench("linalg/t-matvec/csr-per-trial-convert/k1000", || {
            a1.to_csr_into(&mut a_csr_buf);
            a_csr_buf.t_matvec_into(&xr, &mut yt);
            black_box(yt[0])
        });
        println!(
            "bench linalg/t-matvec/decision/k1000                   {} (csc walk {} vs \
             csr-convert {})",
            if t_tm_csc <= t_tm_csr { "keep CSC column walk" } else { "CSR conversion wins" },
            gradcode::util::bench::fmt_duration(t_tm_csc),
            gradcode::util::bench::fmt_duration(t_tm_csr)
        );
        for (label, t) in [
            ("panel/t-matvec/csc-selected", t_tm_csc),
            ("panel/t-matvec/csr-per-trial-convert", t_tm_csr),
        ] {
            records.push(DecodeBenchRecord {
                label: label.to_string(),
                scheme: "BGC".to_string(),
                k: k1,
                n: k1,
                s: s1,
                r: r1,
                seed: seed1,
                ns_per_decode: t.as_nanos() as f64,
                decodes_per_sec: 1.0 / t.as_secs_f64(),
            });
        }
    }

    // --------------- serve/load: sustained daemon decode throughput
    // The PR 7 acceptance record: rounds/sec the `repro serve` daemon
    // sustains end-to-end (framing, request parsing, memoized standing
    // assignment, hot per-connection workspaces) under a closed-loop
    // `repro load` at the k = n = 1000 headline instance. Measured
    // in-process through `gradcode::load::run_load` against a spawned
    // daemon binary so the number includes the real socket path.
    {
        use gradcode::coordinator::DecoderKind;
        use gradcode::load::{run_load, Arrival, LoadConfig, Workload};
        use gradcode::serve::{frame, DecodeRequest};
        use std::io::BufRead;

        let (requests, rounds) = if common::quick() { (8usize, 16usize) } else { (32, 64) };

        let spawn_daemon = |session_loop: &str| {
            let mut child = std::process::Command::new(bin)
                .args(["serve", "--addr", "127.0.0.1:0", "--serve-threads", session_loop])
                .stdout(std::process::Stdio::piped())
                .stderr(std::process::Stdio::null())
                .spawn()
                .expect("spawning repro serve");
            let stdout = child.stdout.take().expect("daemon stdout");
            let line = std::io::BufReader::new(stdout)
                .lines()
                .next()
                .expect("daemon readiness line")
                .expect("utf-8 readiness line");
            let addr =
                line.strip_prefix("listening on ").expect("readiness line").to_string();
            (child, addr)
        };
        let shutdown = |mut child: std::process::Child, addr: &str| {
            // Graceful shutdown so every record reflects a clean exit.
            let mut conn = std::net::TcpStream::connect(addr).expect("shutdown connection");
            frame::write_frame(&mut conn, "{\"cmd\":\"shutdown\"}").expect("shutdown frame");
            let _ = frame::read_frame(&mut conn);
            let _ = child.wait();
        };
        let make_cfg = |addr: &str, concurrency: usize, pipeline: usize| LoadConfig {
            addr: addr.to_string(),
            requests,
            concurrency,
            pipeline,
            arrival: Arrival::Closed,
            seed: 2017,
            slo_p99_ms: 0.0,
            template: DecodeRequest {
                scheme: Scheme::Frc,
                k: k1,
                n: k1,
                s: s1,
                r: r1,
                rounds,
                decoder: DecoderKind::OneStep,
                assign_seed: 2017,
                seed: 0,
                prefix: None,
            },
            workload: Workload::Fixed,
        };

        let (child, addr) = spawn_daemon("reactor");
        let outcome = run_load(&make_cfg(&addr, 4, 1)).expect("load run against the daemon");
        println!(
            "bench serve/load/one-step-sustained/k1000              {:.0} rounds/s \
             ({} requests x {} rounds over {:.3} s)",
            outcome.rounds_per_sec, requests, rounds, outcome.elapsed
        );
        records.push(DecodeBenchRecord {
            label: "serve/load/one-step-sustained".to_string(),
            scheme: "FRC".to_string(),
            k: k1,
            n: k1,
            s: s1,
            r: r1,
            seed: 2017,
            ns_per_decode: 1e9 * outcome.elapsed / outcome.total_rounds as f64,
            decodes_per_sec: outcome.rounds_per_sec,
        });

        // PR 10 acceptance records: rounds/sec over 2 connections as
        // the per-connection pipeline depth grows. Depth 1 is the
        // lockstep baseline; deeper pipelines keep the daemon's worker
        // pool busy while replies are still in flight. The legacy
        // thread-per-connection loop at depth 1 anchors the comparison.
        for depth in [1usize, 8, 32] {
            let outcome =
                run_load(&make_cfg(&addr, 2, depth)).expect("pipelined load run");
            println!(
                "bench serve/pipelined-sustained/depth{depth:<2}                 {:.0} rounds/s \
                 ({} requests x {} rounds over {:.3} s)",
                outcome.rounds_per_sec, requests, rounds, outcome.elapsed
            );
            records.push(DecodeBenchRecord {
                label: format!("serve/pipelined-sustained/depth{depth}"),
                scheme: "FRC".to_string(),
                k: k1,
                n: k1,
                s: s1,
                r: r1,
                seed: 2017,
                ns_per_decode: 1e9 * outcome.elapsed / outcome.total_rounds as f64,
                decodes_per_sec: outcome.rounds_per_sec,
            });
        }
        shutdown(child, &addr);

        let (child, addr) = spawn_daemon("legacy");
        let outcome = run_load(&make_cfg(&addr, 2, 1)).expect("legacy load run");
        println!(
            "bench serve/pipelined-sustained/legacy-depth1          {:.0} rounds/s \
             ({} requests x {} rounds over {:.3} s)",
            outcome.rounds_per_sec, requests, rounds, outcome.elapsed
        );
        records.push(DecodeBenchRecord {
            label: "serve/pipelined-sustained/legacy-depth1".to_string(),
            scheme: "FRC".to_string(),
            k: k1,
            n: k1,
            s: s1,
            r: r1,
            seed: 2017,
            ns_per_decode: 1e9 * outcome.elapsed / outcome.total_rounds as f64,
            decodes_per_sec: outcome.rounds_per_sec,
        });
        shutdown(child, &addr);
    }

    common::write_decode_bench_json(&records);
}
