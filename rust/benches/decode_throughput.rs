//! Decoder hot-path benchmarks — the §Perf targets for L3.
//!
//! * one-step decode: a single sparse pass; target >= 1e8 nnz/s.
//! * optimal decode (LSQR): target << 1ms at the paper's k=100.
//! * algorithmic iterates: per-iteration cost (2 sparse matvecs).
//! * scaling in k at fixed density.
//!
//! Run: `cargo bench --bench decode_throughput`.

mod common;

use gradcode::codes::Scheme;
use gradcode::decode::{algorithmic_error_curve, OneStepDecoder, OptimalDecoder, StepSize};
use gradcode::linalg::spectral_norm;
use gradcode::sim::figures::draw_non_straggler_matrix;
use gradcode::util::bench::black_box;
use gradcode::util::Rng;

fn main() {
    let b = common::bencher();

    // Paper-sized instance.
    let mut rng = Rng::new(1);
    let a100 = draw_non_straggler_matrix(Scheme::Bgc, 100, 10, 80, &mut rng);
    let nnz = a100.nnz() as u64;

    b.bench_throughput("decode/one-step/k100 (nnz/s)", nnz, || {
        black_box(OneStepDecoder::canonical(100, 80, 10).err1(&a100))
    });
    b.bench("decode/optimal-lsqr/k100", || black_box(OptimalDecoder::new().err(&a100)));
    b.bench("decode/algorithmic-10-iters/k100", || {
        let mut r = Rng::new(2);
        black_box(algorithmic_error_curve(&a100, StepSize::Lemma17 { k: 100, r: 80, s: 10 }, 10, &mut r))
    });
    b.bench("decode/spectral-norm/k100", || {
        let mut r = Rng::new(3);
        black_box(spectral_norm(&a100, &mut r, 300, 1e-10))
    });

    // Scaling sweep in k at s = log2(k)-ish density.
    let ks: &[usize] = if common::quick() { &[100, 400] } else { &[100, 400, 1600, 6400] };
    for &k in ks {
        let s = ((k as f64).log2().ceil() as usize).max(4);
        let r = (k * 4) / 5;
        let mut rng = Rng::new(k as u64);
        let a = draw_non_straggler_matrix(Scheme::Bgc, k, s, r, &mut rng);
        let nnz = a.nnz() as u64;
        b.bench_throughput(&format!("decode/one-step/k{k} (nnz/s)"), nnz, || {
            black_box(OneStepDecoder::canonical(k, r, s).err1(&a))
        });
        b.bench(&format!("decode/optimal-lsqr/k{k}"), || {
            black_box(OptimalDecoder::new().err(&a))
        });
    }
}
