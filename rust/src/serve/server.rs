//! The `repro serve` daemon: TCP listener, per-connection sessions,
//! shared assignment memo, metrics, shutdown.
//!
//! One OS thread per connection. Each session owns a hot
//! [`DecodeWorkspace`] reused across every request on that connection
//! (steady-state decode rounds allocate nothing), plus the CSR mirror
//! of whichever standing assignment it decoded last — switching
//! assignments re-mirrors, staying on one does not. The standing
//! assignments themselves are memoized process-wide behind a mutex
//! keyed by `(scheme, k, n, s, assign_seed)`, so concurrent clients
//! decoding the same configuration share one `Arc<CscMatrix>` instead
//! of redrawing G per request.
//!
//! Sessions also own a [`PanelWorkspace`]: full (non-prefix) decode
//! requests with at least `--panel-width` rounds run their rounds
//! through the batched panel kernels instead of the round-at-a-time
//! scalar loop. Round `t` forks stream `t` off the request seed in
//! both paths, and panel lane `l` at base `t0` replays exactly the
//! fork `t0 + l`, so the reply is **bit-equal** to the scalar path —
//! the fast path changes wall-clock only, never bytes (pinned by
//! `tests/serve_load.rs`). Prefix (anytime) requests and short
//! requests stay on the scalar loop.
//!
//! The same port speaks two protocols, disambiguated by the first four
//! bytes: a legal frame prefix is at most [`frame::MAX_FRAME`]
//! (16 MiB), while ASCII `"GET "` reads as ~1.2e9, so an HTTP request
//! can never be mistaken for a frame. HTTP gets the plain-text
//! `/metrics` counters ([`ServeMetrics::render`]) and the connection
//! closes; everything else is length-prefixed JSON frames
//! ([`super::protocol`]).
//!
//! A request that panics (a parameter combination an assignment
//! builder asserts on) kills only its session thread — the client sees
//! a dropped connection, the daemon keeps serving.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{DecoderKind, ServeMetrics};
use crate::decode::{DecodeWorkspace, OneStepDecoder, PanelWorkspace, DEFAULT_PANEL_WIDTH};
use crate::linalg::{CscMatrix, LsqrOptions};
use crate::util::{Json, Rng};

use super::frame::{self, FrameError};
use super::protocol::{error_response, ok_response, DecodeRequest, Request};
use super::scheduler::{run_fanout, ArtifactDir, FanoutPlan};

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7117`; port 0 picks an ephemeral
    /// port (the bound address is printed as `listening on ADDR`).
    pub addr: String,
    /// Path of the `repro` binary to spawn for fan-out `job` requests
    /// (the daemon schedules them through `scheduler::run_fanout`).
    pub exe: PathBuf,
    /// `--panel-width`: lanes per batched decode panel on the serve
    /// fast path (`None` = [`DEFAULT_PANEL_WIDTH`]). Execution hint
    /// only: replies are bit-identical at every width.
    pub panel_width: Option<usize>,
}

/// Memo key of a standing assignment. `Scheme::name()` is a unique
/// `&'static str` per variant, which keeps the key `Hash + Eq` without
/// demanding those derives of `Scheme` itself.
type AssignKey = (&'static str, usize, usize, usize, u64);

struct Shared {
    metrics: ServeMetrics,
    assignments: Mutex<HashMap<AssignKey, Arc<CscMatrix>>>,
    shutdown: AtomicBool,
    listen_addr: SocketAddr,
    exe: PathBuf,
    /// Resolved panel width every session's fast path uses (>= 1).
    panel_width: usize,
}

/// Run the daemon until a `shutdown` request arrives. Blocks the
/// calling thread; prints `listening on ADDR` to stdout once the
/// socket is bound (stdout is line-buffered, so supervisors and tests
/// can wait for that line even through a pipe).
pub fn serve(cfg: &ServeConfig) -> Result<()> {
    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let listen_addr = listener.local_addr().context("reading the bound address")?;
    println!("listening on {listen_addr}");
    eprintln!(
        "repro serve: length-prefixed JSON frames on {listen_addr} \
         (HTTP GET /metrics on the same port); send {{\"cmd\": \"shutdown\"}} to stop"
    );
    let shared = Arc::new(Shared {
        metrics: ServeMetrics::new(),
        assignments: Mutex::new(HashMap::new()),
        shutdown: AtomicBool::new(false),
        listen_addr,
        exe: cfg.exe.clone(),
        panel_width: cfg.panel_width.unwrap_or(DEFAULT_PANEL_WIDTH).max(1),
    });
    for conn in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || session(stream, shared));
            }
            Err(e) => eprintln!("repro serve: accept failed: {e}"),
        }
    }
    eprintln!(
        "repro serve: shutting down after {} request(s) on {} connection(s)",
        shared.metrics.requests.load(Ordering::Relaxed),
        shared.metrics.connections.load(Ordering::Relaxed),
    );
    Ok(())
}

/// What handling one request produced.
struct Handled {
    reply: Json,
    is_error: bool,
    /// Decode rounds executed (for the rounds counter).
    rounds: u64,
    shutdown: bool,
}

fn session(stream: TcpStream, shared: Arc<Shared>) {
    shared.metrics.observe_connection();
    let _ = stream.set_nodelay(true);
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(e) => {
            eprintln!("repro serve: cloning connection: {e}");
            return;
        }
    };
    let mut writer = BufWriter::new(stream);
    // Per-connection hot state: the workspaces survive across requests,
    // and each `*mirrored` names the standing assignment whose CSR
    // mirror its workspace currently holds (one-step decodes re-mirror
    // only on switch). The panel workspace drives the batched fast
    // path for full decode requests of >= panel_width rounds.
    let mut ws = DecodeWorkspace::new();
    let mut mirrored: Option<AssignKey> = None;
    let mut panel = PanelWorkspace::new(shared.panel_width);
    let mut panel_mirrored: Option<AssignKey> = None;
    loop {
        let prefix = match frame::read_prefix(&mut reader) {
            Ok(p) => p,
            Err(FrameError::Closed) => return,
            Err(_) => {
                // EOF mid-prefix or a socket error: dropped client.
                shared.metrics.observe_error();
                return;
            }
        };
        if &prefix == b"GET " {
            let _ = serve_http(&mut reader, &mut writer, &shared);
            return;
        }
        let body = match frame::read_body(&mut reader, u32::from_be_bytes(prefix)) {
            Ok(b) => b,
            Err(e @ (FrameError::Oversized { .. } | FrameError::BadUtf8)) => {
                // The frame boundary is lost (Oversized never consumed
                // the body), so reply with an error frame and close.
                shared.metrics.observe_error();
                let _ = frame::write_frame(&mut writer, &error_response(&e.to_string()).write());
                return;
            }
            Err(_) => {
                // Truncated mid-body or socket error: dropped client.
                shared.metrics.observe_error();
                return;
            }
        };
        let start = Instant::now();
        let handled =
            handle(&body, &shared, &mut ws, &mut mirrored, &mut panel, &mut panel_mirrored);
        // Record metrics before replying, so a client that has seen its
        // reply also sees itself in a subsequent /metrics scrape.
        shared.metrics.observe_request(start.elapsed().as_nanos() as u64);
        if handled.is_error {
            shared.metrics.observe_error();
        }
        if handled.rounds > 0 {
            shared.metrics.add_rounds(handled.rounds);
        }
        if frame::write_frame(&mut writer, &handled.reply.write()).is_err() {
            return;
        }
        if handled.shutdown {
            shared.shutdown.store(true, Ordering::SeqCst);
            // Wake the acceptor loop so it observes the flag.
            let _ = TcpStream::connect(shared.listen_addr);
            return;
        }
    }
}

fn handle(
    body: &str,
    shared: &Arc<Shared>,
    ws: &mut DecodeWorkspace,
    mirrored: &mut Option<AssignKey>,
    panel: &mut PanelWorkspace,
    panel_mirrored: &mut Option<AssignKey>,
) -> Handled {
    let parsed = Json::parse(body).and_then(|j| Request::from_json(&j));
    let req = match parsed {
        Ok(r) => r,
        Err(e) => {
            return Handled {
                reply: error_response(&format!("{e:#}")),
                is_error: true,
                rounds: 0,
                shutdown: false,
            }
        }
    };
    match req {
        Request::Ping => Handled {
            reply: ok_response(vec![("pong", Json::Bool(true))]),
            is_error: false,
            rounds: 0,
            shutdown: false,
        },
        Request::Metrics => Handled {
            reply: ok_response(vec![("metrics", Json::Str(shared.metrics.render()))]),
            is_error: false,
            rounds: 0,
            shutdown: false,
        },
        Request::Shutdown => Handled {
            reply: ok_response(vec![("shutdown", Json::Bool(true))]),
            is_error: false,
            rounds: 0,
            shutdown: true,
        },
        Request::Decode(d) => match run_decode(&d, shared, ws, mirrored, panel, panel_mirrored) {
            Ok(reply) => {
                Handled { reply, is_error: false, rounds: d.rounds as u64, shutdown: false }
            }
            Err(e) => Handled {
                reply: error_response(&format!("{e:#}")),
                is_error: true,
                rounds: 0,
                shutdown: false,
            },
        },
        Request::Job { job, fanout } => {
            shared.metrics.observe_job();
            let plan = FanoutPlan {
                job,
                fanout,
                dir: ArtifactDir::Temp,
                threads: None,
                panel_width: None,
            };
            match run_fanout(&shared.exe, &plan) {
                Ok(merged) => Handled {
                    reply: ok_response(vec![("csv", Json::Str(merged.to_csv()))]),
                    is_error: false,
                    rounds: 0,
                    shutdown: false,
                },
                Err(e) => Handled {
                    reply: error_response(&format!("{e:#}")),
                    is_error: true,
                    rounds: 0,
                    shutdown: false,
                },
            }
        }
    }
}

/// The memoized standing assignment for a decode request; first use
/// draws it from `assign_seed` (inside the lock: concurrent first
/// requests serialize briefly, but G is built exactly once).
fn standing_assignment(shared: &Shared, d: &DecodeRequest) -> Arc<CscMatrix> {
    let key: AssignKey = (d.scheme.name(), d.k, d.n, d.s, d.assign_seed);
    let mut memo = shared.assignments.lock().expect("assignment memo poisoned");
    Arc::clone(memo.entry(key).or_insert_with(|| {
        let mut rng = Rng::new(d.assign_seed);
        Arc::new(d.scheme.build(d.k, d.n, d.s).assignment(&mut rng))
    }))
}

/// Run a decode request's rounds. Round t forks stream t off the
/// request seed, so the reply is a pure function of the request — the
/// determinism `repro load`'s byte-reproducible replay relies on.
///
/// Full (non-prefix) requests with at least `panel.width()` rounds run
/// through the batched panel kernels: rounds are chunked into panels
/// at base `t0`, and lane `l` of a panel replays exactly the scalar
/// loop's `root.fork(t0 + l)` round, so the `errs` array — and the
/// reply — is bit-equal to the scalar path at every width (the final
/// ragged chunk just runs a narrower panel).
fn run_decode(
    d: &DecodeRequest,
    shared: &Shared,
    ws: &mut DecodeWorkspace,
    mirrored: &mut Option<AssignKey>,
    panel: &mut PanelWorkspace,
    panel_mirrored: &mut Option<AssignKey>,
) -> Result<Json> {
    let g = standing_assignment(shared, d);
    let rho = OneStepDecoder::canonical(d.k, d.r, d.s).rho;
    let root = Rng::new(d.seed);
    let width = panel.width();
    let mut errs = vec![0.0; d.rounds];
    match (d.decoder, d.prefix) {
        (DecoderKind::OneStep, None) if d.rounds >= width => {
            // Panel fast path over the panel workspace's own CSR
            // mirror (the same bit-identical streamed kernel, W lanes
            // at a time); re-mirror only on assignment switch.
            let key: AssignKey = (d.scheme.name(), d.k, d.n, d.s, d.assign_seed);
            if *panel_mirrored != Some(key) {
                panel.mirror_csr(&g);
                *panel_mirrored = Some(key);
            }
            let mut t0 = 0;
            while t0 < d.rounds {
                let lanes = width.min(d.rounds - t0);
                panel.onestep_panel(&g, d.r, rho, &root, t0 as u64, lanes, &mut errs[t0..t0 + lanes]);
                t0 += lanes;
            }
        }
        (DecoderKind::OneStep, None) => {
            // One-step rounds stream over the CSR mirror (bit-identical
            // to the CSC path); re-mirror only on assignment switch.
            let key: AssignKey = (d.scheme.name(), d.k, d.n, d.s, d.assign_seed);
            if *mirrored != Some(key) {
                ws.mirror_csr(&g);
                *mirrored = Some(key);
            }
            for (t, e) in errs.iter_mut().enumerate() {
                let mut rng = root.fork(t as u64);
                *e = ws.onestep_trial_streamed(d.r, rho, &mut rng);
            }
        }
        (DecoderKind::OneStep, Some(p)) => {
            // Anytime route: draw the same r survivors as the full
            // path (same RNG stream), decode the first p arrivals
            // through the incremental state. p == r is bit-identical
            // to the full one-step round. Stays scalar: the prefix
            // arm's incremental state has no panel kernel.
            for (t, e) in errs.iter_mut().enumerate() {
                let mut rng = root.fork(t as u64);
                *e = ws.onestep_prefix_trial(&g, d.r, p, rho, &mut rng);
            }
        }
        (DecoderKind::Optimal, None) if d.rounds >= width => {
            // Panel fast path: one lockstep multi-RHS LSQR per panel,
            // warm-started at ρ·1 like the scalar arm below.
            let opts = LsqrOptions::default();
            let mut t0 = 0;
            while t0 < d.rounds {
                let lanes = width.min(d.rounds - t0);
                panel.optimal_panel(
                    &g,
                    d.r,
                    &opts,
                    Some(rho),
                    &root,
                    t0 as u64,
                    lanes,
                    &mut errs[t0..t0 + lanes],
                );
                t0 += lanes;
            }
        }
        (DecoderKind::Optimal, prefix) => {
            let opts = LsqrOptions::default();
            for (t, e) in errs.iter_mut().enumerate() {
                let mut rng = root.fork(t as u64);
                *e = match prefix {
                    None => ws.optimal_trial(&g, d.r, &opts, Some(rho), &mut rng),
                    Some(p) => ws.optimal_prefix_trial(&g, d.r, p, &opts, Some(rho), &mut rng),
                };
            }
        }
    }
    Ok(ok_response(vec![
        ("rounds", Json::Num(d.rounds as f64)),
        ("errs", Json::Arr(errs.into_iter().map(Json::Num).collect())),
    ]))
}

/// Minimal HTTP/1.0 for the `/metrics` endpoint. The `"GET "` bytes
/// were already consumed as a would-be frame prefix; read the rest of
/// the request line for the path, drain the headers, answer, close.
fn serve_http(
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    shared: &Shared,
) -> std::io::Result<()> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let path = line.split_whitespace().next().unwrap_or("").to_string();
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 || header.trim().is_empty() {
            break;
        }
    }
    let (status, body) = if path == "/metrics" {
        ("200 OK", shared.metrics.render())
    } else {
        ("404 Not Found", "only /metrics is served\n".to_string())
    };
    write!(
        writer,
        "HTTP/1.0 {status}\r\nContent-Type: text/plain; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    writer.flush()
}
