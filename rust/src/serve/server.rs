//! The `repro serve` daemon: TCP listener, readiness-driven sessions,
//! shared assignment memo, metrics, shutdown.
//!
//! Two session loops share one wire protocol and one request handler:
//!
//! * **Reactor** (default): a single epoll thread
//!   ([`super::reactor::Poller`]) owns the listener and every
//!   connection. Sockets are nonblocking; each connection carries a
//!   [`FrameDecoder`] that reassembles length-prefixed frames from
//!   whatever chunks the kernel delivers, an outbox of encoded reply
//!   frames, and its hot workspaces behind a mutex so they survive the
//!   nonblocking boundary. Cheap requests (`ping`, `metrics`,
//!   `shutdown`) are answered inline on the reactor thread;
//!   `decode`/`job` work is dispatched to a bounded worker pool, so
//!   one slow `job` cannot stall a thousand `ping`s. Replies are
//!   written in completion order, tagged with the request's echoed
//!   `id`. Backpressure is interest re-registration, never blocking:
//!   EPOLLOUT is added only while an outbox has bytes, and EPOLLIN is
//!   dropped while a connection is over its in-flight or outbox caps.
//! * **Legacy** (`--serve-threads legacy`): the original
//!   thread-per-connection blocking loop, kept so tests can pin that
//!   both loops produce bit-identical replies.
//!
//! Each connection owns a hot [`DecodeWorkspace`] reused across every
//! request on that connection (steady-state decode rounds allocate
//! nothing), plus the CSR mirror of whichever standing assignment it
//! decoded last — switching assignments re-mirrors, staying on one
//! does not. The standing assignments themselves are memoized
//! process-wide behind a mutex keyed by `(scheme, k, n, s,
//! assign_seed)`, so concurrent clients decoding the same
//! configuration share one `Arc<CscMatrix>` instead of redrawing G per
//! request.
//!
//! Connections also own a [`PanelWorkspace`]: full (non-prefix) decode
//! requests with at least `--panel-width` rounds run their rounds
//! through the batched panel kernels instead of the round-at-a-time
//! scalar loop. Round `t` forks stream `t` off the request seed in
//! both paths, and panel lane `l` at base `t0` replays exactly the
//! fork `t0 + l`, so the reply is **bit-equal** to the scalar path —
//! the fast path changes wall-clock only, never bytes (pinned by
//! `tests/serve_load.rs`). Prefix (anytime) requests and short
//! requests stay on the scalar loop.
//!
//! The same port speaks two protocols, disambiguated by the first four
//! bytes: a legal frame prefix is at most [`frame::MAX_FRAME`]
//! (16 MiB), while ASCII `"GET "` reads as ~1.2e9, so an HTTP request
//! can never be mistaken for a frame. HTTP gets the plain-text
//! `/metrics` counters ([`ServeMetrics::render`]) and the connection
//! closes; everything else is length-prefixed JSON frames
//! ([`super::protocol`]).
//!
//! **Shutdown drains.** A `shutdown` request stops the accept loop and
//! all further reads, but every request accepted before it — on any
//! connection — still runs to completion and has its reply flushed
//! before the daemon exits (the legacy loop gets the same guarantee
//! per connection from its strict in-order handling). Only clients
//! that stop reading their replies are abandoned, after a grace
//! period.
//!
//! A request that panics (a parameter combination an assignment
//! builder asserts on) kills only its session — the client sees a
//! dropped connection, the daemon keeps serving.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, BufWriter, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{DecoderKind, ServeMetrics};
use crate::decode::{DecodeWorkspace, OneStepDecoder, PanelWorkspace, DEFAULT_PANEL_WIDTH};
use crate::linalg::{CscMatrix, LsqrOptions};
use crate::util::{Json, Rng};

use super::frame::{self, Decoded, FrameDecoder, FrameError};
use super::protocol::{error_response, ok_response, request_id, with_id, DecodeRequest, Request};
use super::reactor::{Poller, Waker, EPOLLIN, EPOLLOUT};
use super::scheduler::{run_fanout, ArtifactDir, FanoutPlan};

/// Which session loop the daemon runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionLoop {
    /// Readiness-driven epoll loop with a bounded worker pool
    /// (default).
    Reactor,
    /// Thread-per-connection blocking loop (the pre-reactor model,
    /// kept for bit-parity pins).
    Legacy,
}

impl SessionLoop {
    pub fn parse(s: &str) -> Option<SessionLoop> {
        match s {
            "reactor" => Some(SessionLoop::Reactor),
            "legacy" => Some(SessionLoop::Legacy),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SessionLoop::Reactor => "reactor",
            SessionLoop::Legacy => "legacy",
        }
    }
}

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7117`; port 0 picks an ephemeral
    /// port (the bound address is printed as `listening on ADDR`).
    pub addr: String,
    /// Path of the `repro` binary to spawn for fan-out `job` requests
    /// (the daemon schedules them through `scheduler::run_fanout`).
    pub exe: PathBuf,
    /// `--panel-width`: lanes per batched decode panel on the serve
    /// fast path (`None` = [`DEFAULT_PANEL_WIDTH`]). Execution hint
    /// only: replies are bit-identical at every width.
    pub panel_width: Option<usize>,
    /// `--serve-threads`: which session loop runs the sockets.
    /// Execution hint only: replies are bit-identical across loops.
    pub session_loop: SessionLoop,
}

/// Memo key of a standing assignment. `Scheme::name()` is a unique
/// `&'static str` per variant, which keeps the key `Hash + Eq` without
/// demanding those derives of `Scheme` itself.
type AssignKey = (&'static str, usize, usize, usize, u64);

struct Shared {
    metrics: ServeMetrics,
    assignments: Mutex<HashMap<AssignKey, Arc<CscMatrix>>>,
    shutdown: AtomicBool,
    listen_addr: SocketAddr,
    exe: PathBuf,
    /// Resolved panel width every session's fast path uses (>= 1).
    panel_width: usize,
}

/// Per-connection hot state: the workspaces survive across requests,
/// and each `*mirrored` names the standing assignment whose CSR
/// mirror its workspace currently holds (one-step decodes re-mirror
/// only on switch). The panel workspace drives the batched fast path
/// for full decode requests of >= panel_width rounds.
struct SessionWorkspaces {
    ws: DecodeWorkspace,
    mirrored: Option<AssignKey>,
    panel: PanelWorkspace,
    panel_mirrored: Option<AssignKey>,
}

impl SessionWorkspaces {
    fn new(panel_width: usize) -> Self {
        SessionWorkspaces {
            ws: DecodeWorkspace::new(),
            mirrored: None,
            panel: PanelWorkspace::new(panel_width),
            panel_mirrored: None,
        }
    }
}

/// Run the daemon until a `shutdown` request arrives. Blocks the
/// calling thread; prints `listening on ADDR` to stdout once the
/// socket is bound (stdout is line-buffered, so supervisors and tests
/// can wait for that line even through a pipe).
pub fn serve(cfg: &ServeConfig) -> Result<()> {
    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let listen_addr = listener.local_addr().context("reading the bound address")?;
    println!("listening on {listen_addr}");
    eprintln!(
        "repro serve: length-prefixed JSON frames on {listen_addr} ({} loop; HTTP GET \
         /metrics on the same port); send {{\"cmd\": \"shutdown\"}} to stop",
        cfg.session_loop.name()
    );
    let shared = Arc::new(Shared {
        metrics: ServeMetrics::new(),
        assignments: Mutex::new(HashMap::new()),
        shutdown: AtomicBool::new(false),
        listen_addr,
        exe: cfg.exe.clone(),
        panel_width: cfg.panel_width.unwrap_or(DEFAULT_PANEL_WIDTH).max(1),
    });
    match cfg.session_loop {
        SessionLoop::Legacy => serve_legacy(listener, &shared)?,
        SessionLoop::Reactor => Reactor::run(listener, &shared)?,
    }
    eprintln!(
        "repro serve: shutting down after {} request(s) on {} connection(s)",
        shared.metrics.requests.load(Ordering::Relaxed),
        shared.metrics.connections.load(Ordering::Relaxed),
    );
    Ok(())
}

// ========================================================== the handler
// (shared verbatim by both loops, so replies cannot drift apart)

/// What handling one request produced.
struct Handled {
    reply: Json,
    is_error: bool,
    /// Decode rounds executed (for the rounds counter).
    rounds: u64,
    shutdown: bool,
}

impl Handled {
    fn ok(reply: Json) -> Handled {
        Handled { reply, is_error: false, rounds: 0, shutdown: false }
    }

    fn err(msg: &str) -> Handled {
        Handled { reply: error_response(msg), is_error: true, rounds: 0, shutdown: false }
    }
}

/// Split a frame body into its pipelining id and the parsed request.
/// The id survives a request-level parse failure (so an error reply
/// still echoes it and a pipelined client stays in sync), but not a
/// body-level one (nothing to echo if the JSON itself is garbage).
fn parse_request(body: &str) -> (Option<u64>, Result<Request>) {
    match Json::parse(body) {
        Err(e) => (None, Err(e)),
        Ok(j) => match request_id(&j) {
            Err(e) => (None, Err(e)),
            Ok(id) => (id, Request::from_json(&j)),
        },
    }
}

/// Answer the requests that never touch a workspace and never block:
/// the reactor runs these inline on the event thread.
fn respond_light(req: &Request, shared: &Shared) -> Option<Handled> {
    match req {
        Request::Ping => Some(Handled::ok(ok_response(vec![("pong", Json::Bool(true))]))),
        Request::Metrics => Some(Handled::ok(ok_response(vec![(
            "metrics",
            Json::Str(shared.metrics.render()),
        )]))),
        Request::Shutdown => Some(Handled {
            reply: ok_response(vec![("shutdown", Json::Bool(true))]),
            is_error: false,
            rounds: 0,
            shutdown: true,
        }),
        _ => None,
    }
}

/// Answer a `decode` or `job` request against the session's hot
/// workspaces: the reactor runs these on its worker pool.
fn respond_heavy(req: Request, shared: &Shared, wss: &mut SessionWorkspaces) -> Handled {
    match req {
        Request::Decode(d) => match run_decode(&d, shared, wss) {
            Ok(reply) => {
                Handled { reply, is_error: false, rounds: d.rounds as u64, shutdown: false }
            }
            Err(e) => Handled::err(&format!("{e:#}")),
        },
        Request::Job { job, fanout } => {
            shared.metrics.observe_job();
            let plan = FanoutPlan {
                job,
                fanout,
                dir: ArtifactDir::Temp,
                threads: None,
                panel_width: None,
            };
            match run_fanout(&shared.exe, &plan) {
                Ok(merged) => Handled::ok(ok_response(vec![("csv", Json::Str(merged.to_csv()))])),
                Err(e) => Handled::err(&format!("{e:#}")),
            }
        }
        light => respond_light(&light, shared).expect("light request routed to heavy path"),
    }
}

/// Full request handling for the blocking loop: parse, dispatch, echo
/// the id.
fn handle(body: &str, shared: &Shared, wss: &mut SessionWorkspaces) -> Handled {
    let (id, parsed) = parse_request(body);
    let mut handled = match parsed {
        Err(e) => Handled::err(&format!("{e:#}")),
        Ok(req) => match respond_light(&req, shared) {
            Some(h) => h,
            None => respond_heavy(req, shared, wss),
        },
    };
    handled.reply = with_id(handled.reply, id);
    handled
}

/// The memoized standing assignment for a decode request; first use
/// draws it from `assign_seed` (inside the lock: concurrent first
/// requests serialize briefly, but G is built exactly once).
fn standing_assignment(shared: &Shared, d: &DecodeRequest) -> Arc<CscMatrix> {
    let key: AssignKey = (d.scheme.name(), d.k, d.n, d.s, d.assign_seed);
    let mut memo = shared.assignments.lock().expect("assignment memo poisoned");
    Arc::clone(memo.entry(key).or_insert_with(|| {
        let mut rng = Rng::new(d.assign_seed);
        Arc::new(d.scheme.build(d.k, d.n, d.s).assignment(&mut rng))
    }))
}

/// Run a decode request's rounds. Round t forks stream t off the
/// request seed, so the reply is a pure function of the request — the
/// determinism `repro load`'s byte-reproducible replay relies on (and
/// what lets the reactor write replies in completion order without
/// changing any bytes).
///
/// Full (non-prefix) requests with at least `panel.width()` rounds run
/// through the batched panel kernels: rounds are chunked into panels
/// at base `t0`, and lane `l` of a panel replays exactly the scalar
/// loop's `root.fork(t0 + l)` round, so the `errs` array — and the
/// reply — is bit-equal to the scalar path at every width (the final
/// ragged chunk just runs a narrower panel).
fn run_decode(d: &DecodeRequest, shared: &Shared, wss: &mut SessionWorkspaces) -> Result<Json> {
    let g = standing_assignment(shared, d);
    let rho = OneStepDecoder::canonical(d.k, d.r, d.s).rho;
    let root = Rng::new(d.seed);
    let width = wss.panel.width();
    let mut errs = vec![0.0; d.rounds];
    match (d.decoder, d.prefix) {
        (DecoderKind::OneStep, None) if d.rounds >= width => {
            // Panel fast path over the panel workspace's own CSR
            // mirror (the same bit-identical streamed kernel, W lanes
            // at a time); re-mirror only on assignment switch.
            let key: AssignKey = (d.scheme.name(), d.k, d.n, d.s, d.assign_seed);
            if wss.panel_mirrored != Some(key) {
                wss.panel.mirror_csr(&g);
                wss.panel_mirrored = Some(key);
            }
            let mut t0 = 0;
            while t0 < d.rounds {
                let lanes = width.min(d.rounds - t0);
                wss.panel.onestep_panel(
                    &g,
                    d.r,
                    rho,
                    &root,
                    t0 as u64,
                    lanes,
                    &mut errs[t0..t0 + lanes],
                );
                t0 += lanes;
            }
        }
        (DecoderKind::OneStep, None) => {
            // One-step rounds stream over the CSR mirror (bit-identical
            // to the CSC path); re-mirror only on assignment switch.
            let key: AssignKey = (d.scheme.name(), d.k, d.n, d.s, d.assign_seed);
            if wss.mirrored != Some(key) {
                wss.ws.mirror_csr(&g);
                wss.mirrored = Some(key);
            }
            for (t, e) in errs.iter_mut().enumerate() {
                let mut rng = root.fork(t as u64);
                *e = wss.ws.onestep_trial_streamed(d.r, rho, &mut rng);
            }
        }
        (DecoderKind::OneStep, Some(p)) => {
            // Anytime route: draw the same r survivors as the full
            // path (same RNG stream), decode the first p arrivals
            // through the incremental state. p == r is bit-identical
            // to the full one-step round. Stays scalar: the prefix
            // arm's incremental state has no panel kernel.
            for (t, e) in errs.iter_mut().enumerate() {
                let mut rng = root.fork(t as u64);
                *e = wss.ws.onestep_prefix_trial(&g, d.r, p, rho, &mut rng);
            }
        }
        (DecoderKind::Optimal, None) if d.rounds >= width => {
            // Panel fast path: one lockstep multi-RHS LSQR per panel,
            // warm-started at ρ·1 like the scalar arm below.
            let opts = LsqrOptions::default();
            let mut t0 = 0;
            while t0 < d.rounds {
                let lanes = width.min(d.rounds - t0);
                wss.panel.optimal_panel(
                    &g,
                    d.r,
                    &opts,
                    Some(rho),
                    &root,
                    t0 as u64,
                    lanes,
                    &mut errs[t0..t0 + lanes],
                );
                t0 += lanes;
            }
        }
        (DecoderKind::Optimal, prefix) => {
            let opts = LsqrOptions::default();
            for (t, e) in errs.iter_mut().enumerate() {
                let mut rng = root.fork(t as u64);
                *e = match prefix {
                    None => wss.ws.optimal_trial(&g, d.r, &opts, Some(rho), &mut rng),
                    Some(p) => wss.ws.optimal_prefix_trial(&g, d.r, p, &opts, Some(rho), &mut rng),
                };
            }
        }
    }
    Ok(ok_response(vec![
        ("rounds", Json::Num(d.rounds as f64)),
        ("errs", Json::Arr(errs.into_iter().map(Json::Num).collect())),
    ]))
}

// ========================================================= legacy loop

fn serve_legacy(listener: TcpListener, shared: &Arc<Shared>) -> Result<()> {
    for conn in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                let shared = Arc::clone(shared);
                std::thread::spawn(move || session(stream, shared));
            }
            Err(e) => eprintln!("repro serve: accept failed: {e}"),
        }
    }
    Ok(())
}

fn session(stream: TcpStream, shared: Arc<Shared>) {
    shared.metrics.observe_connection();
    let _ = stream.set_nodelay(true);
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(e) => {
            eprintln!("repro serve: cloning connection: {e}");
            return;
        }
    };
    let mut writer = BufWriter::new(stream);
    let mut wss = SessionWorkspaces::new(shared.panel_width);
    loop {
        let prefix = match frame::read_prefix(&mut reader) {
            Ok(p) => p,
            Err(FrameError::Closed) => return,
            Err(_) => {
                // EOF mid-prefix or a socket error: dropped client.
                shared.metrics.observe_error();
                return;
            }
        };
        if &prefix == b"GET " {
            let _ = serve_http(&mut reader, &mut writer, &shared);
            return;
        }
        let body = match frame::read_body(&mut reader, u32::from_be_bytes(prefix)) {
            Ok(b) => b,
            Err(e @ (FrameError::Oversized { .. } | FrameError::BadUtf8)) => {
                // The frame boundary is lost (Oversized never consumed
                // the body), so reply with an error frame and close.
                shared.metrics.observe_error();
                let _ = frame::write_frame(&mut writer, &error_response(&e.to_string()).write());
                return;
            }
            Err(_) => {
                // Truncated mid-body or socket error: dropped client.
                shared.metrics.observe_error();
                return;
            }
        };
        let start = Instant::now();
        shared.metrics.inflight_inc();
        let handled = handle(&body, &shared, &mut wss);
        // Record metrics before replying, so a client that has seen its
        // reply also sees itself in a subsequent /metrics scrape.
        shared.metrics.observe_request(start.elapsed().as_nanos() as u64);
        if handled.is_error {
            shared.metrics.observe_error();
        }
        if handled.rounds > 0 {
            shared.metrics.add_rounds(handled.rounds);
        }
        shared.metrics.inflight_dec();
        if frame::write_frame(&mut writer, &handled.reply.write()).is_err() {
            return;
        }
        if handled.shutdown {
            shared.shutdown.store(true, Ordering::SeqCst);
            // Wake the acceptor loop so it observes the flag. (The
            // reactor loop drains instead; this self-connect wake is
            // the legacy mechanism, kept with the legacy loop. Strict
            // in-order handling means every request this connection
            // pipelined before the shutdown was already answered.)
            let _ = TcpStream::connect(shared.listen_addr);
            return;
        }
    }
}

/// Minimal HTTP/1.0 for the `/metrics` endpoint. The `"GET "` bytes
/// were already consumed as a would-be frame prefix; read the rest of
/// the request line for the path, drain the headers, answer, close.
fn serve_http(
    reader: &mut impl BufRead,
    writer: &mut impl Write,
    shared: &Shared,
) -> std::io::Result<()> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let path = line.split_whitespace().next().unwrap_or("").to_string();
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 || header.trim().is_empty() {
            break;
        }
    }
    let response = http_response(&path, shared);
    writer.write_all(&response)?;
    writer.flush()
}

fn http_response(path: &str, shared: &Shared) -> Vec<u8> {
    let (status, body) = if path == "/metrics" {
        ("200 OK", shared.metrics.render())
    } else {
        ("404 Not Found", "only /metrics is served\n".to_string())
    };
    format!(
        "HTTP/1.0 {status}\r\nContent-Type: text/plain; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

// ======================================================== reactor loop

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;
const READ_CHUNK: usize = 16 * 1024;
/// Per-connection cap on dispatched-but-unanswered requests: above it
/// the reactor stops reading that socket until replies drain.
const MAX_CONN_INFLIGHT: usize = 128;
/// Outbox bytes above which reads pause — a client that pipelines but
/// never reads cannot balloon the reply queue.
const MAX_OUTBOX_BYTES: usize = 4 * 1024 * 1024;
/// After the worker pool drains on shutdown, how long to wait for
/// clients to read their flushed replies before abandoning them.
const DRAIN_FLUSH_DEADLINE: Duration = Duration::from_secs(10);

/// The part of a connection the worker pool sees: its token (to route
/// the completion) and the hot workspaces. Decodes on the same
/// connection serialize on the mutex; pings never touch it.
struct ConnWork {
    token: u64,
    wss: Mutex<SessionWorkspaces>,
}

/// One request dispatched to the worker pool.
struct Job {
    work: Arc<ConnWork>,
    req: Request,
    id: Option<u64>,
    /// When the frame was parsed — queue wait counts toward the
    /// request latency histogram, which is what a pipelined client
    /// actually experiences.
    accepted: Instant,
}

/// One completed pool request, routed back to the reactor thread.
struct Done {
    token: u64,
    /// Encoded reply frame; `None` if the handler panicked (the
    /// connection is dropped, like a legacy session thread dying).
    frame: Option<Vec<u8>>,
}

enum ConnMode {
    Frames,
    /// The peer sent `"GET "`: buffer the rest of the HTTP request.
    Http(Vec<u8>),
}

struct Conn {
    stream: TcpStream,
    decoder: FrameDecoder,
    mode: ConnMode,
    /// Encoded reply frames not yet fully written, front first;
    /// `outbox_pos` is the write offset into the front frame.
    outbox: VecDeque<Vec<u8>>,
    outbox_pos: usize,
    outbox_bytes: usize,
    /// Requests dispatched to the pool whose replies are not yet
    /// queued on the outbox.
    inflight: usize,
    /// Interest mask currently registered with the poller.
    interest: u32,
    /// No more reads; close once the outbox drains and in-flight
    /// replies are delivered (error frame sent, HTTP response queued,
    /// or a draining shutdown).
    closing: bool,
    /// Peer sent EOF; pending replies still flush (half-close).
    read_eof: bool,
    work: Arc<ConnWork>,
}

impl Conn {
    fn new(stream: TcpStream, token: u64, panel_width: usize) -> Conn {
        Conn {
            stream,
            decoder: FrameDecoder::new(),
            mode: ConnMode::Frames,
            outbox: VecDeque::new(),
            outbox_pos: 0,
            outbox_bytes: 0,
            inflight: 0,
            interest: 0,
            closing: false,
            read_eof: false,
            work: Arc::new(ConnWork { token, wss: Mutex::new(SessionWorkspaces::new(panel_width)) }),
        }
    }

    fn wants_read(&self, draining: bool) -> bool {
        !self.closing
            && !self.read_eof
            && !draining
            && self.inflight < MAX_CONN_INFLIGHT
            && self.outbox_bytes <= MAX_OUTBOX_BYTES
    }

    fn desired_interest(&self, draining: bool) -> u32 {
        let mut interest = 0;
        if self.wants_read(draining) {
            interest |= EPOLLIN;
        }
        if !self.outbox.is_empty() {
            interest |= EPOLLOUT;
        }
        interest
    }

    fn push_reply(&mut self, frame_bytes: Vec<u8>) {
        self.outbox_bytes += frame_bytes.len();
        self.outbox.push_back(frame_bytes);
    }

    /// Nothing left to do for this connection?
    fn finished(&self) -> bool {
        (self.closing || self.read_eof) && self.outbox.is_empty() && self.inflight == 0
    }
}

struct Reactor {
    shared: Arc<Shared>,
    poller: Poller,
    listener: TcpListener,
    waker: Arc<Waker>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    tx: Option<Sender<Job>>,
    done: Arc<Mutex<Vec<Done>>>,
    workers: Vec<JoinHandle<()>>,
    /// Pool requests dispatched but not yet completed, across all
    /// connections (the shutdown drain waits on this).
    pool_inflight: usize,
    draining: bool,
    /// Set once draining *and* the pool is empty: the flush grace
    /// period for clients that have not read their replies yet.
    drain_flush_since: Option<Instant>,
}

impl Reactor {
    fn run(listener: TcpListener, shared: &Arc<Shared>) -> Result<()> {
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let poller = Poller::new().context("epoll_create1")?;
        let waker = Arc::new(Waker::new().context("eventfd")?);
        poller
            .add(listener.as_raw_fd(), TOKEN_LISTENER, EPOLLIN)
            .context("registering the listener")?;
        poller.add(waker.fd(), TOKEN_WAKER, EPOLLIN).context("registering the waker")?;

        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let done = Arc::new(Mutex::new(Vec::new()));
        let pool = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 8);
        let workers = (0..pool)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let shared = Arc::clone(shared);
                let done = Arc::clone(&done);
                let waker = Arc::clone(&waker);
                std::thread::spawn(move || worker_loop(rx, shared, done, waker))
            })
            .collect();

        let mut reactor = Reactor {
            shared: Arc::clone(shared),
            poller,
            listener,
            waker,
            conns: HashMap::new(),
            next_token: FIRST_CONN_TOKEN,
            tx: Some(tx),
            done,
            workers,
            pool_inflight: 0,
            draining: false,
            drain_flush_since: None,
        };
        let result = reactor.event_loop();
        // Closing the job channel makes idle workers exit; the drain
        // guaranteed none are mid-request.
        drop(reactor.tx.take());
        reactor.waker.wake();
        for w in reactor.workers.drain(..) {
            let _ = w.join();
        }
        result
    }

    fn event_loop(&mut self) -> Result<()> {
        let mut events = Vec::new();
        loop {
            let timeout = if self.draining { 50 } else { -1 };
            self.poller.wait(&mut events, timeout).context("epoll_wait")?;
            self.shared.metrics.observe_wakeup();
            for ev in events.clone() {
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKER => self.waker.drain(),
                    token => {
                        if ev.writable() {
                            self.flush_conn(token);
                        }
                        if ev.readable() {
                            self.read_conn(token);
                        }
                    }
                }
            }
            self.pump_done();
            if self.draining {
                if self.pool_inflight == 0 {
                    let since = *self.drain_flush_since.get_or_insert_with(Instant::now);
                    let all_flushed = self.conns.values().all(|c| c.outbox.is_empty());
                    if all_flushed || since.elapsed() > DRAIN_FLUSH_DEADLINE {
                        return Ok(());
                    }
                } else {
                    self.drain_flush_since = None;
                }
            }
        }
    }

    fn accept_ready(&mut self) {
        if self.draining {
            return;
        }
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    self.shared.metrics.observe_connection();
                    let _ = stream.set_nodelay(true);
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let token = self.next_token;
                    self.next_token += 1;
                    let mut conn = Conn::new(stream, token, self.shared.panel_width);
                    if self.poller.add(conn.stream.as_raw_fd(), token, EPOLLIN).is_err() {
                        continue;
                    }
                    conn.interest = EPOLLIN;
                    self.conns.insert(token, conn);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    eprintln!("repro serve: accept failed: {e}");
                    break;
                }
            }
        }
    }

    /// Level-triggered read: drain the socket until WouldBlock (or a
    /// backpressure cap pauses this connection — the unread bytes wait
    /// in the kernel buffer, which is the backpressure signal TCP
    /// propagates to the peer).
    fn read_conn(&mut self, token: u64) {
        let mut chunk = [0u8; READ_CHUNK];
        loop {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if conn.read_eof || conn.closing {
                break;
            }
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    conn.read_eof = true;
                    if matches!(conn.mode, ConnMode::Frames) && conn.decoder.buffered() > 0 {
                        // EOF mid-frame: dropped client.
                        self.shared.metrics.observe_error();
                    }
                    break;
                }
                Ok(n) => {
                    let is_frames = match &mut conn.mode {
                        ConnMode::Frames => {
                            conn.decoder.extend(&chunk[..n]);
                            true
                        }
                        ConnMode::Http(buf) => {
                            buf.extend_from_slice(&chunk[..n]);
                            false
                        }
                    };
                    if is_frames {
                        if !self.pump_frames(token) {
                            return;
                        }
                    } else {
                        self.try_http(token);
                    }
                    let Some(conn) = self.conns.get(&token) else { return };
                    if !conn.wants_read(self.draining) {
                        break;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close_conn(token);
                    return;
                }
            }
        }
        // EOF on an HTTP connection answers with whatever arrived.
        if self.conns.get(&token).is_some_and(|c| c.read_eof && matches!(c.mode, ConnMode::Http(_)))
        {
            self.try_http(token);
        }
        self.settle(token);
    }

    /// Decode and dispatch every complete frame buffered on `token`.
    /// Returns false if the connection was closed.
    fn pump_frames(&mut self, token: u64) -> bool {
        loop {
            let step = {
                let Some(conn) = self.conns.get_mut(&token) else { return false };
                if conn.closing
                    || self.draining
                    || !matches!(conn.mode, ConnMode::Frames)
                    || conn.inflight >= MAX_CONN_INFLIGHT
                    || conn.outbox_bytes > MAX_OUTBOX_BYTES
                {
                    return true;
                }
                conn.decoder.next()
            };
            match step {
                Ok(None) => return true,
                Ok(Some(Decoded::HttpGet)) => {
                    let Some(conn) = self.conns.get_mut(&token) else { return false };
                    let tail = conn.decoder.take_buffered();
                    conn.mode = ConnMode::Http(tail);
                    self.try_http(token);
                    return true;
                }
                Ok(Some(Decoded::Frame(body))) => {
                    if !self.accept_frame(token, body) {
                        return false;
                    }
                }
                Err(e) => {
                    // Oversized prefix or non-UTF-8 body: the frame
                    // boundary is lost, so reply with an error frame
                    // and close once it flushes.
                    self.shared.metrics.observe_error();
                    let Some(conn) = self.conns.get_mut(&token) else { return false };
                    conn.push_reply(frame::encode_frame(&error_response(&e.to_string()).write()));
                    conn.closing = true;
                    self.flush_conn(token);
                    return self.conns.contains_key(&token);
                }
            }
        }
    }

    /// One parsed frame: answer light requests inline, dispatch heavy
    /// ones to the pool. Returns false if the connection was closed.
    fn accept_frame(&mut self, token: u64, body: String) -> bool {
        let accepted = Instant::now();
        let (id, parsed) = parse_request(&body);
        self.shared.metrics.inflight_inc();
        let inline = match parsed {
            Err(e) => Handled::err(&format!("{e:#}")),
            Ok(req) => match respond_light(&req, &self.shared) {
                Some(h) => h,
                None => {
                    let Some(conn) = self.conns.get_mut(&token) else {
                        self.shared.metrics.inflight_dec();
                        return false;
                    };
                    conn.inflight += 1;
                    self.pool_inflight += 1;
                    let job = Job { work: Arc::clone(&conn.work), req, id, accepted };
                    if let Some(tx) = &self.tx {
                        tx.send(job).expect("worker pool outlives the reactor");
                    }
                    return true;
                }
            },
        };
        // Inline reply: metrics before the reply bytes, like the pool
        // path and the legacy loop.
        self.shared.metrics.observe_request(accepted.elapsed().as_nanos() as u64);
        if inline.is_error {
            self.shared.metrics.observe_error();
        }
        self.shared.metrics.inflight_dec();
        let reply = frame::encode_frame(&with_id(inline.reply, id).write());
        let Some(conn) = self.conns.get_mut(&token) else { return false };
        conn.push_reply(reply);
        if inline.shutdown {
            self.begin_drain();
        }
        self.flush_conn(token);
        self.conns.contains_key(&token)
    }

    /// A `shutdown` request was accepted: stop accepting connections
    /// and reading requests, let the pool finish everything already
    /// accepted, flush every outbox, then exit.
    fn begin_drain(&mut self) {
        if self.draining {
            return;
        }
        self.draining = true;
        let _ = self.poller.delete(self.listener.as_raw_fd());
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for token in tokens {
            if let Some(conn) = self.conns.get_mut(&token) {
                conn.closing = true;
            }
            self.settle(token);
        }
    }

    /// Reply completions from the worker pool, routed by token. Stale
    /// tokens (connection died while its request ran) just miss the
    /// map — the request still counts as drained.
    fn pump_done(&mut self) {
        let done: Vec<Done> = std::mem::take(&mut *self.done.lock().expect("completions poisoned"));
        for d in done {
            self.pool_inflight -= 1;
            self.shared.metrics.inflight_dec();
            let Some(conn) = self.conns.get_mut(&d.token) else { continue };
            conn.inflight -= 1;
            match d.frame {
                Some(f) => conn.push_reply(f),
                None => {
                    // Handler panicked: drop the connection, keep the
                    // daemon (legacy sessions die the same way).
                    self.close_conn(d.token);
                    continue;
                }
            }
            self.flush_conn(d.token);
            // Replies draining may lift the read backpressure; frames
            // may already be buffered, so pump before trusting epoll.
            if self.pump_frames(d.token) {
                self.read_conn(d.token);
            }
        }
    }

    /// Write the outbox until empty or WouldBlock; EPOLLOUT interest
    /// exists only while bytes remain (write-side backpressure).
    fn flush_conn(&mut self, token: u64) {
        let mut failed = false;
        {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            'outer: while let Some(front) = conn.outbox.front() {
                match conn.stream.write(&front[conn.outbox_pos..]) {
                    Ok(0) => {
                        failed = true;
                        break;
                    }
                    Ok(n) => {
                        conn.outbox_pos += n;
                        conn.outbox_bytes -= n;
                        if conn.outbox_pos == front.len() {
                            conn.outbox.pop_front();
                            conn.outbox_pos = 0;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break 'outer,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        failed = true;
                        break;
                    }
                }
            }
        }
        if failed {
            self.close_conn(token);
        } else {
            self.settle(token);
        }
    }

    /// Close a finished connection, or re-register its interest mask
    /// if it changed (how both backpressure directions are applied and
    /// lifted).
    fn settle(&mut self, token: u64) {
        let Some(conn) = self.conns.get(&token) else { return };
        if conn.finished() {
            self.close_conn(token);
            return;
        }
        let desired = conn.desired_interest(self.draining);
        if desired != conn.interest {
            let fd = conn.stream.as_raw_fd();
            if self.poller.modify(fd, token, desired).is_err() {
                self.close_conn(token);
                return;
            }
            self.conns.get_mut(&token).expect("conn vanished").interest = desired;
        }
    }

    fn close_conn(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            let _ = self.poller.delete(conn.stream.as_raw_fd());
        }
    }

    /// Answer HTTP once the header terminator (or EOF) arrives. The
    /// request line's first token after the sniffed `"GET "` is the
    /// path, exactly like the legacy parser.
    fn try_http(&mut self, token: u64) {
        let path = {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            let ConnMode::Http(buf) = &conn.mode else { return };
            if conn.closing {
                return; // already answered
            }
            if !conn.read_eof && !buf.windows(4).any(|w| w == b"\r\n\r\n") {
                return; // headers still arriving
            }
            let text = String::from_utf8_lossy(buf);
            let line = text.lines().next().unwrap_or("");
            line.split_whitespace().next().unwrap_or("").to_string()
        };
        let response = http_response(&path, &self.shared);
        let Some(conn) = self.conns.get_mut(&token) else { return };
        conn.push_reply(response);
        conn.closing = true;
        self.flush_conn(token);
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Job>>>,
    shared: Arc<Shared>,
    done: Arc<Mutex<Vec<Done>>>,
    waker: Arc<Waker>,
) {
    loop {
        let job = match rx.lock().expect("job queue poisoned").recv() {
            Ok(j) => j,
            Err(_) => return, // reactor dropped the sender: shut down
        };
        let Job { work, req, id, accepted } = job;
        let token = work.token;
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut wss = work.wss.lock().expect("session workspaces poisoned");
            respond_heavy(req, &shared, &mut wss)
        }));
        let d = match result {
            Ok(handled) => {
                // Metrics before the reply is queued, so a client that
                // has seen its reply sees itself in a later scrape.
                shared.metrics.observe_request(accepted.elapsed().as_nanos() as u64);
                if handled.is_error {
                    shared.metrics.observe_error();
                }
                if handled.rounds > 0 {
                    shared.metrics.add_rounds(handled.rounds);
                }
                let body = with_id(handled.reply, id).write();
                Done { token, frame: Some(frame::encode_frame(&body)) }
            }
            Err(_) => Done { token, frame: None },
        };
        done.lock().expect("completions poisoned").push(d);
        waker.wake();
    }
}
