//! A minimal epoll wrapper — the readiness layer under the serve
//! daemon's event loop.
//!
//! The offline vendor set has no `tokio`, `mio`, or even `libc`, so
//! this module declares the four syscall entry points it needs
//! (`epoll_create1` / `epoll_ctl` / `epoll_wait` / `eventfd`) as
//! `extern "C"` functions against the glibc the standard library
//! already links. Everything else stays in std: sockets come in as
//! [`RawFd`]s via `AsRawFd`, nonblocking mode is
//! `TcpStream::set_nonblocking`, and fd lifetimes are owned by the
//! std types — the [`Poller`] never closes a socket it did not open.
//!
//! Design points:
//!
//! * **Level-triggered.** Interest fires as long as the condition
//!   holds, so a handler that drains "as much as is there" can never
//!   strand buffered bytes; the loop cannot busy-spin because interest
//!   is deregistered (EPOLLOUT dropped once an outbox drains) rather
//!   than polled.
//! * **Tokens, not pointers.** `epoll_event.data` carries a plain
//!   `u64` connection token; the server maps tokens to state. Stale
//!   events for a closed connection just miss the map.
//! * **[`Waker`]** is an `eventfd` registered like any other readable
//!   fd — worker threads finish a decode, push the reply on a
//!   completion queue, and `wake()`; the reactor drains the eventfd
//!   and the queue on its next wakeup.
//!
//! The `epoll_event` struct is `repr(packed)` only on x86-64 — the
//! one ABI quirk in the interface (the kernel packs the 12-byte struct
//! there; other architectures use natural alignment).

use std::io;
use std::os::fd::RawFd;

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;
const EPOLL_CLOEXEC: i32 = 0x80000;
const EFD_CLOEXEC: i32 = 0x80000;
const EFD_NONBLOCK: i32 = 0x800;

#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn close(fd: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
}

fn check(ret: i32) -> io::Result<i32> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// One readiness event: which registration fired, and how.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: u64,
    events: u32,
}

impl Event {
    pub fn readable(&self) -> bool {
        self.events & (EPOLLIN | EPOLLHUP | EPOLLRDHUP | EPOLLERR) != 0
    }

    pub fn writable(&self) -> bool {
        self.events & (EPOLLOUT | EPOLLHUP | EPOLLERR) != 0
    }
}

/// An epoll instance. Registrations are `(fd, token, interest)`;
/// [`wait`](Self::wait) blocks until at least one fires.
#[derive(Debug)]
pub struct Poller {
    epfd: RawFd,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        // SAFETY: plain syscall, no pointers.
        let epfd = check(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Poller { epfd })
    }

    fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        let mut ev = EpollEvent { events: interest, data: token };
        // SAFETY: `ev` outlives the call; the kernel copies it.
        check(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) })?;
        Ok(())
    }

    /// Register `fd` with the given interest mask (plus EPOLLRDHUP so
    /// peer half-close surfaces as readable).
    pub fn add(&self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, token, interest | EPOLLRDHUP)
    }

    /// Re-register `fd` with a new interest mask — how the server
    /// implements backpressure (drop EPOLLIN above the in-flight cap,
    /// add EPOLLOUT while an outbox has bytes).
    pub fn modify(&self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, token, interest | EPOLLRDHUP)
    }

    /// Deregister `fd`. Must happen before the fd is closed (a closed
    /// fd is removed by the kernel, but only once all duplicates are
    /// gone).
    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        let mut ev = EpollEvent { events: 0, data: 0 };
        // SAFETY: the event pointer is ignored for DEL on modern
        // kernels but must be non-null for pre-2.6.9 compatibility.
        check(unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) })?;
        Ok(())
    }

    /// Block until readiness (or `timeout_ms`; -1 blocks forever).
    /// Returns the fired events.
    pub fn wait(&self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
        const MAX_EVENTS: usize = 256;
        let mut raw = [EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
        out.clear();
        let n = loop {
            // SAFETY: `raw` is a stack buffer of MAX_EVENTS entries.
            let r = unsafe { epoll_wait(self.epfd, raw.as_mut_ptr(), MAX_EVENTS as i32, timeout_ms) };
            if r >= 0 {
                break r as usize;
            }
            let e = io::Error::last_os_error();
            if e.kind() != io::ErrorKind::Interrupted {
                return Err(e);
            }
        };
        for ev in &raw[..n] {
            // Copy out of the possibly-packed struct field by field.
            let (events, data) = (ev.events, ev.data);
            out.push(Event { token: data, events });
        }
        Ok(())
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: we own epfd and close it exactly once.
        unsafe { close(self.epfd) };
    }
}

/// A cross-thread wakeup handle: an `eventfd` the reactor registers
/// for EPOLLIN. Worker threads [`wake`](Self::wake) after pushing onto
/// the completion queue; the reactor [`drain`](Self::drain)s the
/// counter before popping, so a wake can never be lost (wake-then-pop
/// vs push-then-wake ordering).
#[derive(Debug)]
pub struct Waker {
    fd: RawFd,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        // SAFETY: plain syscall, no pointers.
        let fd = check(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        Ok(Waker { fd })
    }

    pub fn fd(&self) -> RawFd {
        self.fd
    }

    /// Nudge the reactor. Callable from any thread; an eventfd write
    /// is async-signal-safe and never blocks below u64::MAX - 1 pending
    /// wakes.
    pub fn wake(&self) {
        let one: u64 = 1;
        // SAFETY: 8 bytes from a live stack value; eventfd writes of
        // size 8 are atomic.
        unsafe { write(self.fd, (&one as *const u64).cast(), 8) };
    }

    /// Reset the counter after a readable event. Nonblocking: if
    /// another thread's wake races in after this, the eventfd simply
    /// reads ready again on the next `epoll_wait`.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        // SAFETY: 8-byte stack buffer; EFD_NONBLOCK means this returns
        // EAGAIN instead of blocking when the counter is zero.
        unsafe { read(self.fd, buf.as_mut_ptr(), 8) };
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        // SAFETY: we own fd and close it exactly once.
        unsafe { close(self.fd) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;

    #[test]
    fn poller_reports_readability_by_token() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let poller = Poller::new().unwrap();
        poller.add(listener.as_raw_fd(), 7, EPOLLIN).unwrap();

        let mut events = Vec::new();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "nothing pending yet");

        let mut client = TcpStream::connect(addr).unwrap();
        poller.wait(&mut events, 2000).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.readable()), "accept readiness");

        // Accepted stream: writable immediately, readable only after
        // the peer sends.
        let (stream, _) = listener.accept().unwrap();
        stream.set_nonblocking(true).unwrap();
        poller.add(stream.as_raw_fd(), 8, EPOLLIN | EPOLLOUT).unwrap();
        poller.wait(&mut events, 2000).unwrap();
        let ev = events.iter().find(|e| e.token == 8).expect("stream event");
        assert!(ev.writable() && !ev.readable());

        client.write_all(b"hi").unwrap();
        // Interest re-registration: drop EPOLLOUT, wait for the bytes.
        poller.modify(stream.as_raw_fd(), 8, EPOLLIN).unwrap();
        poller.wait(&mut events, 2000).unwrap();
        let ev = events.iter().find(|e| e.token == 8).expect("stream event");
        assert!(ev.readable() && !ev.writable());

        poller.delete(stream.as_raw_fd()).unwrap();
        poller.delete(listener.as_raw_fd()).unwrap();
    }

    #[test]
    fn waker_crosses_threads_and_drains() {
        let poller = Poller::new().unwrap();
        let waker = std::sync::Arc::new(Waker::new().unwrap());
        poller.add(waker.fd(), 1, EPOLLIN).unwrap();

        let w = waker.clone();
        let t = std::thread::spawn(move || w.wake());
        let mut events = Vec::new();
        poller.wait(&mut events, 2000).unwrap();
        assert!(events.iter().any(|e| e.token == 1 && e.readable()));
        t.join().unwrap();

        waker.drain();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "drained waker is quiet");
    }
}
