//! Length-prefixed JSON framing for the serve socket.
//!
//! A frame is a 4-byte big-endian length prefix followed by that many
//! bytes of UTF-8 JSON. The prefix is capped at [`MAX_FRAME`] so a
//! corrupt or hostile length cannot make the server allocate
//! gigabytes; everything past the prefix is plain `util::json` text,
//! so the wire format is debuggable with `xxd` and a JSON
//! pretty-printer.
//!
//! Errors distinguish the cases the session loop treats differently:
//! a clean close at a frame boundary ([`FrameError::Closed`]) is a
//! normal disconnect, a mid-frame EOF ([`FrameError::Truncated`]) is a
//! dropped client, and an oversized prefix ([`FrameError::Oversized`])
//! gets an error frame back before the connection is abandoned (the
//! body was never consumed, so the stream cannot be re-synchronized).

use std::fmt;
use std::io::{self, Read, Write};

/// Hard cap on a frame body: 16 MiB. Far above any legal request or
/// response (a 1000-round decode reply is a few tens of KiB) while
/// keeping a garbage prefix from looking like a huge allocation.
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

/// Why reading a frame stopped.
#[derive(Debug)]
pub enum FrameError {
    /// Clean end of stream at a frame boundary (normal disconnect).
    Closed,
    /// EOF in the middle of a length prefix or body.
    Truncated { got: usize, wanted: usize },
    /// Length prefix beyond [`MAX_FRAME`].
    Oversized { len: u32 },
    /// Frame body is not UTF-8.
    BadUtf8,
    Io(io::Error),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::Truncated { got, wanted } => {
                write!(f, "truncated frame: got {got} of {wanted} bytes before EOF")
            }
            FrameError::Oversized { len } => {
                write!(f, "frame length {len} exceeds the {MAX_FRAME}-byte cap")
            }
            FrameError::BadUtf8 => write!(f, "frame body is not UTF-8"),
            FrameError::Io(e) => write!(f, "frame io: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

fn read_exact_or(
    r: &mut impl Read,
    buf: &mut [u8],
    clean_eof_is_close: bool,
) -> Result<(), FrameError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if clean_eof_is_close && filled == 0 {
                    return Err(FrameError::Closed);
                }
                return Err(FrameError::Truncated { got: filled, wanted: buf.len() });
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(())
}

/// Read the 4-byte length prefix. A clean EOF before any byte is
/// [`FrameError::Closed`]; an EOF after 1-3 bytes is a truncation.
/// (The server peeks these bytes itself to sniff HTTP `GET `
/// requests for the `/metrics` endpoint.)
pub fn read_prefix(r: &mut impl Read) -> Result<[u8; 4], FrameError> {
    let mut prefix = [0u8; 4];
    read_exact_or(r, &mut prefix, true)?;
    Ok(prefix)
}

/// Read a frame body of `len` bytes (validated against [`MAX_FRAME`]).
pub fn read_body(r: &mut impl Read, len: u32) -> Result<String, FrameError> {
    if len > MAX_FRAME {
        return Err(FrameError::Oversized { len });
    }
    let mut body = vec![0u8; len as usize];
    read_exact_or(r, &mut body, false)?;
    String::from_utf8(body).map_err(|_| FrameError::BadUtf8)
}

/// Read one whole frame: prefix, cap check, body.
pub fn read_frame(r: &mut impl Read) -> Result<String, FrameError> {
    let prefix = read_prefix(r)?;
    read_body(r, u32::from_be_bytes(prefix))
}

/// Write one frame and flush (requests and replies are both
/// single-frame, so the peer can always make progress after a flush).
pub fn write_frame(w: &mut impl Write, body: &str) -> io::Result<()> {
    debug_assert!(body.len() as u64 <= MAX_FRAME as u64, "oversized outgoing frame");
    w.write_all(&(body.len() as u32).to_be_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

/// Encode one frame into a byte vector (the reactor queues these on a
/// connection outbox instead of writing to a blocking stream).
pub fn encode_frame(body: &str) -> Vec<u8> {
    debug_assert!(body.len() as u64 <= MAX_FRAME as u64, "oversized outgoing frame");
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(body.as_bytes());
    out
}

/// What [`FrameDecoder::next`] produced from the buffered bytes.
#[derive(Debug, PartialEq, Eq)]
pub enum Decoded {
    /// One complete frame body.
    Frame(String),
    /// The first four bytes were `"GET "`: the peer is speaking HTTP
    /// (the `/metrics` endpoint). The sniffed bytes are consumed; the
    /// rest of the request line is still buffered — take it with
    /// [`FrameDecoder::take_buffered`] and switch to HTTP parsing.
    HttpGet,
}

/// Incremental frame reassembly for nonblocking sockets.
///
/// The blocking readers above pull exact byte counts from a stream;
/// a readiness-driven session instead receives whatever chunk the
/// kernel has — possibly one byte, possibly three frames and a half.
/// `FrameDecoder` buffers those chunks ([`extend`](Self::extend)) and
/// yields complete frames ([`next`](Self::next)) without ever blocking:
/// `Ok(None)` means "need more bytes", never "wait".
///
/// Errors mirror the blocking path: an oversized prefix or a non-UTF-8
/// body poisons the stream (the caller replies with an error frame and
/// abandons the connection; re-synchronization is impossible). EOF
/// handling stays with the caller: a socket close with
/// [`buffered`](Self::buffered)` > 0` is the nonblocking analogue of
/// [`FrameError::Truncated`].
#[derive(Debug, Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Bytes of `buf` already consumed by yielded frames. Compacted
    /// lazily so a byte-at-a-time dribbler costs O(1) amortized.
    pos: usize,
}

impl FrameDecoder {
    pub fn new() -> Self {
        FrameDecoder::default()
    }

    /// Buffer freshly received bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos >= 64 * 1024 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet yielded (a partial frame if > 0).
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Drain the unconsumed buffer (used when switching to HTTP mode:
    /// the bytes after the sniffed `"GET "` belong to the request line).
    pub fn take_buffered(&mut self) -> Vec<u8> {
        let rest = self.buf[self.pos..].to_vec();
        self.buf.clear();
        self.pos = 0;
        rest
    }

    /// Yield the next complete frame, or `Ok(None)` if more bytes are
    /// needed. Call in a loop after each [`extend`](Self::extend): one
    /// chunk may complete several pipelined frames.
    pub fn next(&mut self) -> Result<Option<Decoded>, FrameError> {
        let avail = &self.buf[self.pos..];
        if avail.len() < 4 {
            return Ok(None);
        }
        let prefix: [u8; 4] = avail[..4].try_into().unwrap();
        if &prefix == b"GET " {
            self.pos += 4;
            return Ok(Some(Decoded::HttpGet));
        }
        let len = u32::from_be_bytes(prefix);
        if len > MAX_FRAME {
            return Err(FrameError::Oversized { len });
        }
        let len = len as usize;
        if avail.len() < 4 + len {
            return Ok(None);
        }
        let body = avail[4..4 + len].to_vec();
        self.pos += 4 + len;
        match String::from_utf8(body) {
            Ok(s) => Ok(Some(Decoded::Frame(s))),
            Err(_) => Err(FrameError::BadUtf8),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_round_trip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, "{\"cmd\":\"ping\"}").unwrap();
        write_frame(&mut buf, "").unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), "{\"cmd\":\"ping\"}");
        assert_eq!(read_frame(&mut r).unwrap(), "");
        assert!(matches!(read_frame(&mut r), Err(FrameError::Closed)));
    }

    #[test]
    fn clean_eof_is_closed_but_partial_prefix_is_truncated() {
        let mut empty = Cursor::new(Vec::new());
        assert!(matches!(read_frame(&mut empty), Err(FrameError::Closed)));
        let mut partial = Cursor::new(vec![0u8, 0]);
        assert!(matches!(
            read_frame(&mut partial),
            Err(FrameError::Truncated { got: 2, wanted: 4 })
        ));
    }

    #[test]
    fn truncated_body_reports_progress() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&64u32.to_be_bytes());
        buf.extend_from_slice(b"abc");
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(FrameError::Truncated { got: 3, wanted: 64 })));
    }

    #[test]
    fn oversized_prefix_is_rejected_without_allocating() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_be_bytes());
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(FrameError::Oversized { len: u32::MAX })));
    }

    #[test]
    fn non_utf8_body_is_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&2u32.to_be_bytes());
        buf.extend_from_slice(&[0xff, 0xfe]);
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(FrameError::BadUtf8)));
    }

    #[test]
    fn decoder_reassembles_a_byte_at_a_time() {
        let wire = encode_frame("{\"cmd\":\"ping\"}");
        let mut dec = FrameDecoder::new();
        for (i, b) in wire.iter().enumerate() {
            assert!(dec.next().unwrap().is_none(), "byte {i} of {}", wire.len());
            dec.extend(std::slice::from_ref(b));
        }
        assert_eq!(dec.next().unwrap(), Some(Decoded::Frame("{\"cmd\":\"ping\"}".into())));
        assert!(dec.next().unwrap().is_none());
        assert_eq!(dec.buffered(), 0);
    }

    #[test]
    fn decoder_yields_every_frame_in_one_chunk() {
        let mut wire = encode_frame("{\"a\":1}");
        wire.extend_from_slice(&encode_frame(""));
        wire.extend_from_slice(&encode_frame("{\"b\":2}"));
        // Trailing partial frame: prefix + half a body.
        wire.extend_from_slice(&8u32.to_be_bytes());
        wire.extend_from_slice(b"half");
        let mut dec = FrameDecoder::new();
        dec.extend(&wire);
        assert_eq!(dec.next().unwrap(), Some(Decoded::Frame("{\"a\":1}".into())));
        assert_eq!(dec.next().unwrap(), Some(Decoded::Frame("".into())));
        assert_eq!(dec.next().unwrap(), Some(Decoded::Frame("{\"b\":2}".into())));
        assert!(dec.next().unwrap().is_none());
        assert_eq!(dec.buffered(), 8, "partial frame stays buffered");
        dec.extend(b"body");
        assert_eq!(dec.next().unwrap(), Some(Decoded::Frame("halfbody".into())));
    }

    #[test]
    fn decoder_rejects_oversized_and_non_utf8() {
        let mut dec = FrameDecoder::new();
        dec.extend(&u32::MAX.to_be_bytes());
        assert!(matches!(dec.next(), Err(FrameError::Oversized { len: u32::MAX })));

        let mut dec = FrameDecoder::new();
        dec.extend(&2u32.to_be_bytes());
        dec.extend(&[0xff, 0xfe]);
        assert!(matches!(dec.next(), Err(FrameError::BadUtf8)));
    }

    #[test]
    fn decoder_sniffs_http_and_hands_back_the_tail() {
        let mut dec = FrameDecoder::new();
        dec.extend(b"GET /metrics HTTP/1.0\r\n");
        assert_eq!(dec.next().unwrap(), Some(Decoded::HttpGet));
        assert_eq!(dec.take_buffered(), b"/metrics HTTP/1.0\r\n");
        assert_eq!(dec.buffered(), 0);
    }

    #[test]
    fn decoder_compacts_without_losing_the_partial_tail() {
        // Force the lazy-compaction path: consume > 64 KiB, leaving a
        // partial frame straddling the compaction boundary.
        let big = "x".repeat(40 * 1024);
        let mut dec = FrameDecoder::new();
        let mut wire = encode_frame(&big);
        wire.extend_from_slice(&encode_frame(&big));
        wire.extend_from_slice(&5u32.to_be_bytes());
        wire.extend_from_slice(b"he");
        dec.extend(&wire);
        assert!(matches!(dec.next().unwrap(), Some(Decoded::Frame(_))));
        assert!(matches!(dec.next().unwrap(), Some(Decoded::Frame(_))));
        assert!(dec.next().unwrap().is_none());
        dec.extend(b"llo"); // triggers drain-compaction (pos > 64 KiB)
        assert_eq!(dec.next().unwrap(), Some(Decoded::Frame("hello".into())));
    }
}
