//! Length-prefixed JSON framing for the serve socket.
//!
//! A frame is a 4-byte big-endian length prefix followed by that many
//! bytes of UTF-8 JSON. The prefix is capped at [`MAX_FRAME`] so a
//! corrupt or hostile length cannot make the server allocate
//! gigabytes; everything past the prefix is plain `util::json` text,
//! so the wire format is debuggable with `xxd` and a JSON
//! pretty-printer.
//!
//! Errors distinguish the cases the session loop treats differently:
//! a clean close at a frame boundary ([`FrameError::Closed`]) is a
//! normal disconnect, a mid-frame EOF ([`FrameError::Truncated`]) is a
//! dropped client, and an oversized prefix ([`FrameError::Oversized`])
//! gets an error frame back before the connection is abandoned (the
//! body was never consumed, so the stream cannot be re-synchronized).

use std::fmt;
use std::io::{self, Read, Write};

/// Hard cap on a frame body: 16 MiB. Far above any legal request or
/// response (a 1000-round decode reply is a few tens of KiB) while
/// keeping a garbage prefix from looking like a huge allocation.
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

/// Why reading a frame stopped.
#[derive(Debug)]
pub enum FrameError {
    /// Clean end of stream at a frame boundary (normal disconnect).
    Closed,
    /// EOF in the middle of a length prefix or body.
    Truncated { got: usize, wanted: usize },
    /// Length prefix beyond [`MAX_FRAME`].
    Oversized { len: u32 },
    /// Frame body is not UTF-8.
    BadUtf8,
    Io(io::Error),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::Truncated { got, wanted } => {
                write!(f, "truncated frame: got {got} of {wanted} bytes before EOF")
            }
            FrameError::Oversized { len } => {
                write!(f, "frame length {len} exceeds the {MAX_FRAME}-byte cap")
            }
            FrameError::BadUtf8 => write!(f, "frame body is not UTF-8"),
            FrameError::Io(e) => write!(f, "frame io: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

fn read_exact_or(
    r: &mut impl Read,
    buf: &mut [u8],
    clean_eof_is_close: bool,
) -> Result<(), FrameError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if clean_eof_is_close && filled == 0 {
                    return Err(FrameError::Closed);
                }
                return Err(FrameError::Truncated { got: filled, wanted: buf.len() });
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(())
}

/// Read the 4-byte length prefix. A clean EOF before any byte is
/// [`FrameError::Closed`]; an EOF after 1-3 bytes is a truncation.
/// (The server peeks these bytes itself to sniff HTTP `GET `
/// requests for the `/metrics` endpoint.)
pub fn read_prefix(r: &mut impl Read) -> Result<[u8; 4], FrameError> {
    let mut prefix = [0u8; 4];
    read_exact_or(r, &mut prefix, true)?;
    Ok(prefix)
}

/// Read a frame body of `len` bytes (validated against [`MAX_FRAME`]).
pub fn read_body(r: &mut impl Read, len: u32) -> Result<String, FrameError> {
    if len > MAX_FRAME {
        return Err(FrameError::Oversized { len });
    }
    let mut body = vec![0u8; len as usize];
    read_exact_or(r, &mut body, false)?;
    String::from_utf8(body).map_err(|_| FrameError::BadUtf8)
}

/// Read one whole frame: prefix, cap check, body.
pub fn read_frame(r: &mut impl Read) -> Result<String, FrameError> {
    let prefix = read_prefix(r)?;
    read_body(r, u32::from_be_bytes(prefix))
}

/// Write one frame and flush (requests and replies are both
/// single-frame, so the peer can always make progress after a flush).
pub fn write_frame(w: &mut impl Write, body: &str) -> io::Result<()> {
    debug_assert!(body.len() as u64 <= MAX_FRAME as u64, "oversized outgoing frame");
    w.write_all(&(body.len() as u32).to_be_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_round_trip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, "{\"cmd\":\"ping\"}").unwrap();
        write_frame(&mut buf, "").unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), "{\"cmd\":\"ping\"}");
        assert_eq!(read_frame(&mut r).unwrap(), "");
        assert!(matches!(read_frame(&mut r), Err(FrameError::Closed)));
    }

    #[test]
    fn clean_eof_is_closed_but_partial_prefix_is_truncated() {
        let mut empty = Cursor::new(Vec::new());
        assert!(matches!(read_frame(&mut empty), Err(FrameError::Closed)));
        let mut partial = Cursor::new(vec![0u8, 0]);
        assert!(matches!(
            read_frame(&mut partial),
            Err(FrameError::Truncated { got: 2, wanted: 4 })
        ));
    }

    #[test]
    fn truncated_body_reports_progress() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&64u32.to_be_bytes());
        buf.extend_from_slice(b"abc");
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(FrameError::Truncated { got: 3, wanted: 64 })));
    }

    #[test]
    fn oversized_prefix_is_rejected_without_allocating() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_be_bytes());
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(FrameError::Oversized { len: u32::MAX })));
    }

    #[test]
    fn non_utf8_body_is_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&2u32.to_be_bytes());
        buf.extend_from_slice(&[0xff, 0xfe]);
        let mut r = Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(FrameError::BadUtf8)));
    }
}
