//! The fan-out job scheduler: spawn `repro shard` children, wait,
//! verify, merge.
//!
//! This is `repro run --fanout N`'s driver, extracted from the CLI so
//! the serve daemon can schedule `job` requests through the exact same
//! machinery (`main.rs`'s `cmd_run` is now a thin flag-parsing shim
//! over [`run_fanout`]). Progress goes to stderr, results come back as
//! a [`MergedRun`], and failures are `anyhow` errors — usage-level
//! validation (exit-2 discipline) stays in the CLI.
//!
//! Artifact-directory policy ([`ArtifactDir`]):
//!
//! * `Temp` — a pid-named directory under the OS temp dir, **cleared
//!   if it already exists** (a leftover from a crashed run whose pid
//!   got recycled would otherwise mix stale shard artifacts into this
//!   run's verify/merge set), and removed again on exit, success or
//!   failure.
//! * `Keep` — an explicit `--artifacts-dir`: created if absent, but a
//!   directory that already holds shard artifacts is **refused** (the
//!   same stale-mixing hazard; pass `Resume` to reuse them
//!   deliberately, or point at a clean directory).
//! * `Resume` — reuse every artifact in the directory that parses
//!   (checksum-verified) and matches this exact job and shard count;
//!   respawn only the missing or corrupt shards.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::sim::shard::TABLES_WITH_S;
use crate::sim::{JobKind, JobSpec, MergedRun, ShardArtifact};

/// Where a fan-out run keeps its shard artifacts.
#[derive(Clone, Debug)]
pub enum ArtifactDir {
    /// Fresh pid-named temp dir, removed after the run.
    Temp,
    /// Explicit directory, kept after the run; must not already hold
    /// artifacts.
    Keep(PathBuf),
    /// Explicit directory whose valid artifacts are reused; only
    /// missing/corrupt shards are respawned. Kept after the run.
    Resume(PathBuf),
}

/// One fan-out run, fully specified.
#[derive(Clone, Debug)]
pub struct FanoutPlan {
    pub job: JobSpec,
    /// Number of shard processes (>= 1; the CLI validates before
    /// building a plan, the library re-checks).
    pub fanout: usize,
    pub dir: ArtifactDir,
    /// Explicit per-child `--threads`; `None` splits the machine's
    /// worker budget across the children that actually spawn.
    pub threads: Option<usize>,
    /// Explicit per-child `--panel-width`; `None` keeps the children on
    /// their default. Execution hint only — never part of the job
    /// identity, and the merged bits are invariant in it.
    pub panel_width: Option<usize>,
}

/// The artifacts (`*.json` files) already present in `dir`, sorted.
fn existing_artifacts(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut found = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(found),
        Err(e) => return Err(e).with_context(|| format!("reading {}", dir.display())),
    };
    for entry in entries {
        let path = entry.with_context(|| format!("reading {}", dir.display()))?.path();
        if path.extension().is_some_and(|e| e == "json") {
            found.push(path);
        }
    }
    found.sort();
    Ok(found)
}

/// Resolve the plan's directory policy: returns `(dir, keep)` with the
/// directory existing and safe to write shard artifacts into.
fn prepare_dir(plan: &FanoutPlan) -> Result<(PathBuf, bool)> {
    match &plan.dir {
        ArtifactDir::Temp => {
            let d = std::env::temp_dir().join(format!(
                "gradcode-fanout-{}-{}-{}",
                std::process::id(),
                plan.job.kind.name(),
                plan.job.id
            ));
            // The name embeds this process's pid, so anything already
            // there is a leftover from a crashed run whose pid got
            // recycled — clear it rather than merge its stale shards.
            match std::fs::remove_dir_all(&d) {
                Ok(()) => eprintln!(
                    "clearing stale temp artifacts dir {} (crashed run with a recycled pid)",
                    d.display()
                ),
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => {
                    return Err(e).with_context(|| format!("clearing stale {}", d.display()))
                }
            }
            std::fs::create_dir_all(&d).with_context(|| format!("creating {}", d.display()))?;
            Ok((d, false))
        }
        ArtifactDir::Keep(d) => {
            let stale = existing_artifacts(d)?;
            if let Some(first) = stale.first() {
                bail!(
                    "artifacts dir {} already holds {} shard artifact(s) (e.g. {}); a \
                     non-resume run would mix them into a fresh verify/merge set — pass \
                     --resume to reuse them, or choose a clean directory",
                    d.display(),
                    stale.len(),
                    first.display()
                );
            }
            std::fs::create_dir_all(d).with_context(|| format!("creating {}", d.display()))?;
            Ok((d.clone(), true))
        }
        ArtifactDir::Resume(d) => {
            std::fs::create_dir_all(d).with_context(|| format!("creating {}", d.display()))?;
            Ok((d.clone(), true))
        }
    }
}

/// The argv a fan-out child gets: the job reconstructed flag by flag
/// (so the child's JobSpec is identical to the parent's and the
/// artifacts merge), plus the shard header and output path.
fn shard_child_args(
    job: &JobSpec,
    shard_id: usize,
    num_shards: usize,
    out: &Path,
    threads: Option<usize>,
    panel_width: Option<usize>,
) -> Vec<String> {
    let mut v: Vec<String> = vec!["shard".into()];
    match job.kind {
        JobKind::Figure => {
            v.push("--fig".into());
            v.push(job.id.clone());
            if job.id == "5" {
                v.push("--tmax".into());
                v.push(job.tmax.to_string());
            }
        }
        JobKind::Table => {
            v.push("--table".into());
            v.push(job.id.clone());
            // Derived-s tables reject --s; their JobSpec carries the
            // default, which the child reproduces by omission.
            if TABLES_WITH_S.contains(&job.id.as_str()) {
                v.push("--s".into());
                v.push(job.s.to_string());
            }
        }
        JobKind::Ablation => {
            v.push("--ablation".into());
            v.push(job.id.clone());
            v.push("--s".into());
            v.push(job.s.to_string());
        }
        JobKind::Scenario => {
            v.push("--scenario".into());
            v.push(job.id.clone());
            v.push("--s".into());
            v.push(job.s.to_string());
        }
    }
    for (flag, val) in [
        ("--trials", job.trials.to_string()),
        ("--seed", job.seed.to_string()),
        ("--k", job.k.to_string()),
        // Canonical scenario string: the child's parse reproduces the
        // parent's Scenario exactly (the parent cross-checks anyway).
        ("--stragglers", job.scenario.to_string()),
        ("--shard-id", shard_id.to_string()),
        ("--num-shards", num_shards.to_string()),
    ] {
        v.push(flag.into());
        v.push(val);
    }
    v.push("--out".into());
    v.push(out.to_string_lossy().into_owned());
    if let Some(t) = threads {
        v.push("--threads".into());
        v.push(t.to_string());
    }
    if let Some(w) = panel_width {
        v.push("--panel-width".into());
        v.push(w.to_string());
    }
    v
}

/// The collection half: wait for all shard children, parse their
/// artifacts, verify the set against the **parent's** job (the
/// children reconstruct it from `shard_child_args`' flags, so a missed
/// flag would otherwise make every child consistently wrong and sail
/// through the mutual-consistency checks), and merge.
fn wait_verify_merge(
    job: &JobSpec,
    children: Vec<(usize, PathBuf, std::process::Child)>,
    mut failures: Vec<String>,
    reused: Vec<ShardArtifact>,
) -> Result<MergedRun> {
    let mut artifacts = reused;
    for (sid, out, mut child) in children {
        let status = match child.wait() {
            Ok(status) => status,
            Err(e) => {
                failures.push(format!("waiting for shard {sid}: {e}"));
                continue;
            }
        };
        if !status.success() {
            failures.push(format!("shard {sid} exited with {status}"));
            continue;
        }
        match std::fs::read_to_string(&out) {
            Ok(text) => match ShardArtifact::parse(&text) {
                Ok(a) if a.job != *job => failures.push(format!(
                    "shard {sid} computed a different job than requested: {:?} vs {:?} \
                     (shard_child_args out of step with a job flag?)",
                    a.job, job
                )),
                Ok(a) => artifacts.push(a),
                Err(e) => failures.push(format!("shard {sid}: {e:#}")),
            },
            Err(e) => failures.push(format!("shard {sid}: reading {}: {e}", out.display())),
        }
    }
    if !failures.is_empty() {
        bail!("fan-out failed: {}", failures.join("; "));
    }
    ShardArtifact::verify_set(&artifacts)?;
    Ok(ShardArtifact::merge(artifacts)?)
}

/// Run the whole fan-out cycle: prepare the artifacts dir, reuse valid
/// artifacts when resuming, spawn `exe shard ...` children for the
/// missing shards, wait, verify, merge. `exe` is the `repro` binary to
/// spawn (the CLI and the serve daemon both pass
/// `std::env::current_exe()`).
pub fn run_fanout(exe: &Path, plan: &FanoutPlan) -> Result<MergedRun> {
    let job = &plan.job;
    let fanout = plan.fanout;
    if fanout == 0 {
        bail!("fanout must be at least 1");
    }
    let resuming = matches!(plan.dir, ArtifactDir::Resume(_));
    let (dir, keep) = prepare_dir(plan)?;

    // Resume: reuse every artifact in the directory that parses
    // (checksum-verified) and belongs to this exact job and shard
    // count; everything else — absent, corrupt, or foreign — leaves
    // its shard ids in the respawn set.
    let mut reused: Vec<ShardArtifact> = Vec::new();
    let mut covered: Vec<usize> = Vec::new();
    if resuming {
        for path in existing_artifacts(&dir)? {
            let text = match std::fs::read_to_string(&path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("resume: skipping unreadable {} ({e})", path.display());
                    continue;
                }
            };
            match ShardArtifact::parse(&text) {
                Ok(a) if a.job == *job && a.num_shards == fanout => {
                    covered.extend(a.shard_ids.iter().copied());
                    reused.push(a);
                }
                Ok(a) => eprintln!(
                    "resume: skipping {} (different job or shard count: {} {} x{})",
                    path.display(),
                    a.job.kind.name(),
                    a.job.id,
                    a.num_shards
                ),
                Err(e) => eprintln!(
                    "resume: discarding corrupt {} ({e:#}); its shard will be recomputed",
                    path.display()
                ),
            }
        }
        covered.sort_unstable();
        if let Some(w) = covered.windows(2).find(|w| w[0] == w[1]) {
            bail!(
                "resume dir {} covers shard id {} more than once (overlapping artifacts); \
                 remove the extras before resuming",
                dir.display(),
                w[0]
            );
        }
    }
    let missing: Vec<usize> = (0..fanout).filter(|i| !covered.contains(i)).collect();
    // Without an explicit thread count, split the machine's worker
    // budget across the children that actually spawn — the respawn
    // set, not the nominal fanout, so a resume of one missing shard
    // still gets the whole machine. Results are thread-count
    // invariant; this only affects wall-clock.
    let threads = match plan.threads {
        Some(t) => Some(t),
        None => {
            Some((crate::util::parallel::default_threads() / missing.len().max(1)).max(1))
        }
    };
    if resuming {
        eprintln!(
            "resuming {} {}: {}/{fanout} shard(s) present in {}, respawning {:?}",
            job.kind.name(),
            job.id,
            covered.len(),
            dir.display(),
            missing
        );
    } else {
        eprintln!(
            "fanning {} {} out across {fanout} shard processes (artifacts in {})",
            job.kind.name(),
            job.id,
            dir.display()
        );
    }
    let mut children = Vec::new();
    let mut spawn_errors: Vec<String> = Vec::new();
    for &sid in &missing {
        let out =
            dir.join(format!("{}_{}_shard_{sid}_of_{fanout}.json", job.kind.name(), job.id));
        match std::process::Command::new(exe)
            .args(shard_child_args(job, sid, fanout, &out, threads, plan.panel_width))
            .spawn()
        {
            Ok(child) => children.push((sid, out, child)),
            Err(e) => spawn_errors.push(format!("spawning shard {sid}: {e}")),
        }
    }
    // Wait for every spawned child (even after a spawn failure, so none
    // are left running), then verify + merge. The temp artifacts dir is
    // removed on success AND failure — temporary artifacts never
    // outlive the run; use Keep or Resume to retain them for debugging
    // or resumption.
    let outcome = wait_verify_merge(job, children, spawn_errors, reused);
    if !keep {
        let _ = std::fs::remove_dir_all(&dir);
    }
    outcome
}
