//! Serving layer: the `repro serve` daemon and its job scheduler.
//!
//! Four pieces, bottom-up:
//!
//! - [`frame`] — length-prefixed JSON framing (4-byte big-endian
//!   prefix, 16 MiB cap, UTF-8 body) with error cases the session loop
//!   can tell apart: clean close, truncation, oversized prefix.
//! - [`protocol`] — the request/response schema. Requests are JSON
//!   objects with a `"cmd"` key (`ping`, `decode`, `job`, `metrics`,
//!   `shutdown`); `job` embeds a [`crate::sim::JobSpec`] via its own
//!   `to_json`/`from_json`, so the wire format reuses the
//!   shard-artifact format instead of inventing a second one.
//! - [`scheduler`] — the fan-out/resume/verify machinery that
//!   `repro run --fanout` uses, extracted so the daemon schedules
//!   `job` requests through the identical code path.
//! - [`server`] — the accept loop, per-connection sessions with hot
//!   [`crate::decode::DecodeWorkspace`]s, the process-wide standing-
//!   assignment memo, and the HTTP `/metrics` counter endpoint.
//!
//! The client side lives in [`crate::load`]: a seeded deterministic
//! traffic generator whose replay output is byte-reproducible.

pub mod frame;
pub mod protocol;
pub mod scheduler;
pub mod server;

pub use protocol::{DecodeRequest, Request};
pub use scheduler::{run_fanout, ArtifactDir, FanoutPlan};
pub use server::{serve, ServeConfig};
