//! Serving layer: the `repro serve` daemon and its job scheduler.
//!
//! Five pieces, bottom-up:
//!
//! - [`frame`] — length-prefixed JSON framing (4-byte big-endian
//!   prefix, 16 MiB cap, UTF-8 body) with error cases the session loop
//!   can tell apart: clean close, truncation, oversized prefix. Two
//!   readers: blocking (`read_frame`) for clients and the legacy loop,
//!   and the resumable [`frame::FrameDecoder`] that reassembles frames
//!   from arbitrary chunks for the nonblocking reactor.
//! - [`protocol`] — the request/response schema. Requests are JSON
//!   objects with a `"cmd"` key (`ping`, `decode`, `job`, `metrics`,
//!   `shutdown`); `job` embeds a [`crate::sim::JobSpec`] via its own
//!   `to_json`/`from_json`, so the wire format reuses the
//!   shard-artifact format instead of inventing a second one. An
//!   optional `"id"` (echoed in the reply) lets pipelined clients
//!   match replies written in completion order.
//! - [`reactor`] — a minimal epoll wrapper (raw glibc syscalls; the
//!   offline vendor set has no tokio/mio/libc) plus an eventfd
//!   [`reactor::Waker`] for worker-to-reactor completion signaling.
//! - [`scheduler`] — the fan-out/resume/verify machinery that
//!   `repro run --fanout` uses, extracted so the daemon schedules
//!   `job` requests through the identical code path.
//! - [`server`] — the session loops: the default readiness-driven
//!   reactor (nonblocking sockets, per-connection frame reassembly,
//!   bounded worker pool, completion-order replies, draining
//!   shutdown) and the legacy thread-per-connection loop
//!   (`--serve-threads legacy`), both over the same handler, hot
//!   per-connection [`crate::decode::DecodeWorkspace`]s, the
//!   process-wide standing-assignment memo, and the HTTP `/metrics`
//!   counter endpoint.
//!
//! The client side lives in [`crate::load`]: a seeded deterministic
//! traffic generator whose replay output is byte-reproducible at any
//! concurrency, arrival process, and pipeline depth.

pub mod frame;
pub mod protocol;
pub mod reactor;
pub mod scheduler;
pub mod server;

pub use protocol::{DecodeRequest, Request};
pub use scheduler::{run_fanout, ArtifactDir, FanoutPlan};
pub use server::{serve, ServeConfig, SessionLoop};
