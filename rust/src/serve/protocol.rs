//! Request/response schema of the serve socket.
//!
//! Every frame body is one JSON object. Requests carry a `"cmd"` key:
//!
//! ```text
//! {"cmd": "ping"}
//! {"cmd": "decode", "scheme": "frc", "k": 1000, "n": 1000, "s": 10,
//!  "r": 800, "rounds": 32, "decoder": "onestep",
//!  "assign_seed": "11", "seed": "42"}
//! {"cmd": "job", "fanout": 4, "job": {<JobSpec::to_json form>}}
//! {"cmd": "metrics"}
//! {"cmd": "shutdown"}
//! ```
//!
//! Replies are `{"ok": true, ...}` or `{"ok": false, "error": "..."}`.
//! Seeds travel as decimal strings (the `JobSpec` artifact convention:
//! u64 exceeds f64's exact-integer range), and the embedded job uses
//! [`JobSpec::to_json`] verbatim, so the wire format and the
//! shard-artifact format cannot drift apart.
//!
//! Responses are deterministic functions of the request: the
//! standing assignment G is drawn from `assign_seed` (memoized
//! server-side) and round `t` of a `decode` request forks stream `t`
//! off `seed`, so the same request always yields the same error
//! sequence — the property `repro load`'s byte-reproducible replay is
//! built on.

use anyhow::{anyhow, bail, Context, Result};

use crate::codes::Scheme;
use crate::coordinator::DecoderKind;
use crate::sim::JobSpec;
use crate::util::Json;

/// Upper bounds a single request may ask for — generous for real use,
/// tight enough that a malicious frame cannot turn into an
/// hours-long solve or a huge allocation.
pub const MAX_DIM: usize = 1_000_000;
pub const MAX_ROUNDS: usize = 1_000_000;
pub const MAX_FANOUT: usize = 256;

/// A standing-assignment decode request: run `rounds` straggler-draw +
/// decode rounds against the (memoized) assignment G drawn from
/// `(scheme, k, n, s, assign_seed)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DecodeRequest {
    pub scheme: Scheme,
    pub k: usize,
    pub n: usize,
    pub s: usize,
    /// Survivors per round (fastest-r uniform straggler draw).
    pub r: usize,
    pub rounds: usize,
    pub decoder: DecoderKind,
    /// Seed of the standing assignment (part of the server's memo key).
    pub assign_seed: u64,
    /// Root seed of the per-round straggler draws; round t forks
    /// stream t, so rounds are independent of request batching.
    pub seed: u64,
    /// Anytime prefix: decode only the first `prefix` arrivals of each
    /// round's survivor draw (1 ≤ prefix ≤ r). `None` decodes the full
    /// survivor set — the wire bytes of prefix-free requests are
    /// unchanged, so existing `repro load` replays stay byte-identical.
    pub prefix: Option<usize>,
}

/// A parsed request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Ping,
    Decode(DecodeRequest),
    Job { job: JobSpec, fanout: usize },
    Metrics,
    Shutdown,
}

fn seed_field(j: &Json, key: &str) -> Result<u64> {
    // Decimal-string seeds, like the shard artifacts.
    j.get(key)?.as_str()?.parse::<u64>().with_context(|| format!("field {key:?}"))
}

fn bounded(j: &Json, key: &str, lo: usize, hi: usize) -> Result<usize> {
    let v = j.get(key)?.as_usize().with_context(|| format!("field {key:?}"))?;
    if !(lo..=hi).contains(&v) {
        bail!("field {key:?} = {v} out of range [{lo}, {hi}]");
    }
    Ok(v)
}

impl Request {
    pub fn from_json(j: &Json) -> Result<Request> {
        match j.get("cmd")?.as_str()? {
            "ping" => Ok(Request::Ping),
            "metrics" => Ok(Request::Metrics),
            "shutdown" => Ok(Request::Shutdown),
            "decode" => {
                let scheme_name = j.get("scheme")?.as_str()?;
                let scheme = Scheme::parse(scheme_name)
                    .ok_or_else(|| anyhow!("unknown scheme {scheme_name:?}"))?;
                let k = bounded(j, "k", 1, MAX_DIM)?;
                let n = match j.opt("n") {
                    Some(v) => v.as_usize().context("field \"n\"")?,
                    None => k,
                };
                if !(1..=MAX_DIM).contains(&n) {
                    bail!("field \"n\" = {n} out of range [1, {MAX_DIM}]");
                }
                let s = bounded(j, "s", 1, k)?;
                let r = bounded(j, "r", 1, n)?;
                let rounds = bounded(j, "rounds", 1, MAX_ROUNDS)?;
                let decoder = match j.opt("decoder") {
                    None => DecoderKind::OneStep,
                    Some(v) => {
                        let name = v.as_str()?;
                        DecoderKind::parse(name)
                            .ok_or_else(|| anyhow!("unknown decoder {name:?}"))?
                    }
                };
                let prefix = match j.opt("prefix") {
                    None => None,
                    Some(_) => Some(bounded(j, "prefix", 1, r)?),
                };
                Ok(Request::Decode(DecodeRequest {
                    scheme,
                    k,
                    n,
                    s,
                    r,
                    rounds,
                    decoder,
                    assign_seed: seed_field(j, "assign_seed")?,
                    seed: seed_field(j, "seed")?,
                    prefix,
                }))
            }
            "job" => {
                let job = JobSpec::from_json(j.get("job")?).context("field \"job\"")?;
                let fanout = match j.opt("fanout") {
                    None => 2,
                    Some(v) => {
                        let f = v.as_usize().context("field \"fanout\"")?;
                        if !(1..=MAX_FANOUT).contains(&f) {
                            bail!("field \"fanout\" = {f} out of range [1, {MAX_FANOUT}]");
                        }
                        f
                    }
                };
                Ok(Request::Job { job, fanout })
            }
            other => bail!("unknown cmd {other:?} (ping|decode|job|metrics|shutdown)"),
        }
    }

    /// Serialize for the client side (`repro load` and tests).
    /// `Request::from_json(&req.to_json())` reproduces `req` exactly.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        match self {
            Request::Ping => {
                m.insert("cmd".into(), Json::Str("ping".into()));
            }
            Request::Metrics => {
                m.insert("cmd".into(), Json::Str("metrics".into()));
            }
            Request::Shutdown => {
                m.insert("cmd".into(), Json::Str("shutdown".into()));
            }
            Request::Decode(d) => {
                m.insert("cmd".into(), Json::Str("decode".into()));
                m.insert("scheme".into(), Json::Str(d.scheme.name().into()));
                m.insert("k".into(), Json::Num(d.k as f64));
                m.insert("n".into(), Json::Num(d.n as f64));
                m.insert("s".into(), Json::Num(d.s as f64));
                m.insert("r".into(), Json::Num(d.r as f64));
                m.insert("rounds".into(), Json::Num(d.rounds as f64));
                m.insert("decoder".into(), Json::Str(d.decoder.name().into()));
                m.insert("assign_seed".into(), Json::Str(d.assign_seed.to_string()));
                m.insert("seed".into(), Json::Str(d.seed.to_string()));
                if let Some(p) = d.prefix {
                    m.insert("prefix".into(), Json::Num(p as f64));
                }
            }
            Request::Job { job, fanout } => {
                m.insert("cmd".into(), Json::Str("job".into()));
                m.insert("job".into(), job.to_json());
                m.insert("fanout".into(), Json::Num(*fanout as f64));
            }
        }
        Json::Obj(m)
    }
}

/// Parse the optional pipelining id: `"id"` as a decimal string (the
/// seed convention — u64 exceeds f64's exact-integer range). Absent
/// means the client is running strict request/reply turn-taking;
/// requests without an id serialize identically to the pre-pipelining
/// wire format, so old replays stay byte-identical.
pub fn request_id(j: &Json) -> Result<Option<u64>> {
    match j.opt("id") {
        None => Ok(None),
        Some(v) => {
            Ok(Some(v.as_str()?.parse::<u64>().context("field \"id\"")?))
        }
    }
}

/// Attach the request's id to a request or reply object. The server
/// echoes the id so a pipelined client can match replies written in
/// completion order; an id-free request gets an id-free reply, byte
/// for byte as before.
pub fn with_id(j: Json, id: Option<u64>) -> Json {
    match (j, id) {
        (Json::Obj(mut m), Some(i)) => {
            m.insert("id".to_string(), Json::Str(i.to_string()));
            Json::Obj(m)
        }
        (j, _) => j,
    }
}

/// Build an `{"ok": true, ...}` reply.
pub fn ok_response(fields: Vec<(&str, Json)>) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("ok".to_string(), Json::Bool(true));
    for (k, v) in fields {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Build an `{"ok": false, "error": ...}` reply.
pub fn error_response(msg: &str) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("ok".to_string(), Json::Bool(false));
    m.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::JobKind;
    use crate::stragglers::Scenario;

    fn sample_decode() -> DecodeRequest {
        DecodeRequest {
            scheme: Scheme::Rbgc,
            k: 100,
            n: 120,
            s: 10,
            r: 96,
            rounds: 8,
            decoder: DecoderKind::Optimal,
            assign_seed: u64::MAX,
            seed: 42,
            prefix: None,
        }
    }

    #[test]
    fn requests_round_trip_through_json() {
        let job = JobSpec {
            kind: JobKind::Table,
            id: "thm5".into(),
            trials: 2000,
            seed: u64::MAX - 1,
            k: 100,
            s: 10,
            tmax: 0,
            scenario: Scenario::default(),
        };
        for req in [
            Request::Ping,
            Request::Metrics,
            Request::Shutdown,
            Request::Decode(sample_decode()),
            Request::Decode(DecodeRequest { prefix: Some(17), ..sample_decode() }),
            Request::Job { job, fanout: 4 },
        ] {
            let text = req.to_json().write();
            let back = Request::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, req, "{text}");
        }
    }

    #[test]
    fn decode_defaults_and_bounds() {
        let j = Json::parse(
            r#"{"cmd": "decode", "scheme": "frc", "k": 50, "s": 5, "r": 40,
                "rounds": 2, "assign_seed": "1", "seed": "2"}"#,
        )
        .unwrap();
        let Request::Decode(d) = Request::from_json(&j).unwrap() else { panic!("decode") };
        assert_eq!(d.n, 50, "n defaults to k");
        assert_eq!(d.decoder, DecoderKind::OneStep, "decoder defaults to one-step");
        assert_eq!(d.prefix, None, "prefix defaults to full survivor set");
        assert!(
            !Request::Decode(d).to_json().write().contains("prefix"),
            "prefix-free requests serialize without the key (replay byte parity)"
        );

        for bad in [
            r#"{"cmd": "decode", "scheme": "nope", "k": 50, "s": 5, "r": 40, "rounds": 2, "assign_seed": "1", "seed": "2"}"#,
            r#"{"cmd": "decode", "scheme": "frc", "k": 0, "s": 5, "r": 40, "rounds": 2, "assign_seed": "1", "seed": "2"}"#,
            r#"{"cmd": "decode", "scheme": "frc", "k": 50, "s": 51, "r": 40, "rounds": 2, "assign_seed": "1", "seed": "2"}"#,
            r#"{"cmd": "decode", "scheme": "frc", "k": 50, "s": 5, "r": 51, "rounds": 2, "assign_seed": "1", "seed": "2"}"#,
            r#"{"cmd": "decode", "scheme": "frc", "k": 50, "s": 5, "r": 40, "rounds": 0, "assign_seed": "1", "seed": "2"}"#,
            r#"{"cmd": "decode", "scheme": "frc", "k": 50, "s": 5, "r": 40, "rounds": 2, "assign_seed": "-1", "seed": "2"}"#,
            r#"{"cmd": "decode", "scheme": "frc", "k": 50, "s": 5, "r": 40, "rounds": 2, "assign_seed": "1", "seed": "2", "prefix": 0}"#,
            r#"{"cmd": "decode", "scheme": "frc", "k": 50, "s": 5, "r": 40, "rounds": 2, "assign_seed": "1", "seed": "2", "prefix": 41}"#,
            r#"{"cmd": "frobnicate"}"#,
        ] {
            assert!(Request::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn ids_parse_echo_and_stay_off_the_wire_when_absent() {
        let bare = Json::parse(r#"{"cmd": "ping"}"#).unwrap();
        assert_eq!(request_id(&bare).unwrap(), None);

        let tagged = Json::parse(r#"{"cmd": "ping", "id": "18446744073709551615"}"#).unwrap();
        assert_eq!(request_id(&tagged).unwrap(), Some(u64::MAX));
        // Unknown keys are ignored by from_json, so tagging is
        // parse-compatible with the original protocol.
        assert_eq!(Request::from_json(&tagged).unwrap(), Request::Ping);

        for bad in [r#"{"cmd": "ping", "id": 7}"#, r#"{"cmd": "ping", "id": "-1"}"#] {
            assert!(request_id(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }

        let reply = ok_response(vec![("pong", Json::Bool(true))]);
        let untagged = with_id(reply.clone(), None).write();
        assert!(!untagged.contains("\"id\""), "id-free stays id-free: {untagged}");
        assert_eq!(untagged, reply.write(), "with_id(None) is byte-identity");
        let tagged = with_id(reply, Some(42)).write();
        assert!(tagged.contains("\"id\":\"42\""), "{tagged}");
    }

    #[test]
    fn response_helpers_have_the_ok_discriminant() {
        let ok = ok_response(vec![("pong", Json::Bool(true))]).write();
        assert!(ok.contains("\"ok\":true"), "{ok}");
        let err = error_response("boom").write();
        assert!(err.contains("\"ok\":false"), "{err}");
        assert!(err.contains("\"error\":\"boom\""), "{err}");
    }
}
