//! Coordinator configuration.

use crate::codes::Scheme;
use crate::stragglers::{DeadlinePolicy, LatencyModel};

/// Which decoder the master runs on the survivor matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecoderKind {
    /// Algorithm 1 with ρ = k/(rs): O(nnz), streamable.
    OneStep,
    /// Algorithm 2 via LSQR: minimizes ||A x - 1_k||².
    Optimal,
}

impl DecoderKind {
    pub fn parse(s: &str) -> Option<DecoderKind> {
        match s.to_ascii_lowercase().as_str() {
            "onestep" | "one-step" | "1step" => Some(DecoderKind::OneStep),
            "optimal" | "lsqr" => Some(DecoderKind::Optimal),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DecoderKind::OneStep => "one-step",
            DecoderKind::Optimal => "optimal",
        }
    }
}

/// Anytime stopping rule applied to the master's arrival stream: with
/// decoding incremental, the master can act *during* the gather instead
/// of waiting the deadline out. See
/// [`crate::coordinator::master::gather_and_decode_anytime`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AnytimePolicy {
    /// Gather the configured policy's full survivor set (the default).
    None,
    /// Cancel-on-target: stop at the first arrival whose exact
    /// incremental err₁ satisfies err₁/k ≤ target.
    TargetErr1(f64),
    /// Mid-round deadline revision: at wall-clock `at`, revise the
    /// gather cutoff to `to`. Messages already in hand can't be
    /// un-received, so the effective cutoff is `max(at, to)`, clamped
    /// to the original gather (revision only shortens). Ignored for
    /// straggler draws with no time axis.
    ReviseDeadline { at: f64, to: f64 },
}

impl Default for AnytimePolicy {
    fn default() -> Self {
        AnytimePolicy::None
    }
}

/// Full coordinator setup for a training run.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub scheme: Scheme,
    /// Tasks (= data shards) k; also n (workers) for the paper's codes.
    pub k: usize,
    /// Tasks per worker s.
    pub s: usize,
    pub decoder: DecoderKind,
    pub latency: LatencyModel,
    pub deadline: DeadlinePolicy,
    pub seed: u64,
    /// Worker-compute parallelism (OS threads submitting to the pool).
    pub threads: usize,
}

impl CoordinatorConfig {
    pub fn new(scheme: Scheme, k: usize, s: usize) -> Self {
        CoordinatorConfig {
            scheme,
            k,
            s,
            decoder: DecoderKind::OneStep,
            latency: LatencyModel::ShiftedExp { base: 0.05, rate: 10.0 },
            deadline: DeadlinePolicy::FastestR((k * 4) / 5),
            seed: 0,
            threads: crate::util::parallel::default_threads(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decoder_parse() {
        assert_eq!(DecoderKind::parse("onestep"), Some(DecoderKind::OneStep));
        assert_eq!(DecoderKind::parse("LSQR"), Some(DecoderKind::Optimal));
        assert_eq!(DecoderKind::parse("x"), None);
    }

    #[test]
    fn default_config_sane() {
        let c = CoordinatorConfig::new(Scheme::Frc, 100, 10);
        assert_eq!(c.k, 100);
        assert!(matches!(c.deadline, crate::stragglers::DeadlinePolicy::FastestR(80)));
    }
}
