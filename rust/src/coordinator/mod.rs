//! L3 coordinator — the paper's system layer.
//!
//! The master assigns tasks via a gradient code G, broadcasts the model,
//! gathers coded messages until the deadline policy fires, decodes the
//! surviving columns (one-step or optimal), and emits the gradient-sum
//! estimate plus round metrics. Workers are logical entities whose
//! compute runs on the PJRT engine pool ([`crate::runtime`]) and whose
//! completion times come from a latency model ([`crate::stragglers`]).

pub mod config;
pub mod master;
pub mod metrics;
pub mod worker;

pub use config::{AnytimePolicy, CoordinatorConfig, DecoderKind};
pub use master::{gather_and_decode, gather_and_decode_anytime, Round};
pub use metrics::{LatencyHistogram, RoundMetrics, ServeMetrics, TrainingHistory};
pub use worker::{
    compute_message, compute_message_via, specs_from_assignment, Message, MessagePath,
    ModelKind, WorkerSpec,
};
