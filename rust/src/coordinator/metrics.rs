//! Per-round metrics and training history (CSV-dumpable), plus the
//! serving-layer observability types: a log-bucketed
//! [`LatencyHistogram`] and the [`ServeMetrics`] counters behind
//! `repro serve`'s `/metrics` endpoint and `repro load`'s report.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One training round's observability record.
#[derive(Clone, Debug, Default)]
pub struct RoundMetrics {
    pub round: usize,
    /// Training loss (model-dependent: exact for linear, mean worker
    /// loss for MLP).
    pub loss: f64,
    /// Decoding error ||A x - 1_k||² of the round's survivor matrix.
    pub decode_err: f64,
    /// Survivor count r.
    pub survivors: usize,
    /// Virtual gather time (when the deadline fired), seconds.
    pub gather_time: f64,
    /// Wall-clock compute+coordination time, seconds.
    pub wall_time: f64,
}

impl RoundMetrics {
    pub fn csv_header() -> &'static str {
        "round,loss,decode_err,survivors,gather_time,wall_time"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{:.6e},{:.6e},{},{:.6},{:.6}",
            self.round, self.loss, self.decode_err, self.survivors, self.gather_time, self.wall_time
        )
    }
}

/// A whole run's history plus summary statistics.
#[derive(Clone, Debug, Default)]
pub struct TrainingHistory {
    pub rounds: Vec<RoundMetrics>,
}

impl TrainingHistory {
    pub fn push(&mut self, m: RoundMetrics) {
        self.rounds.push(m);
    }

    pub fn final_loss(&self) -> f64 {
        self.rounds.last().map(|m| m.loss).unwrap_or(f64::NAN)
    }

    pub fn mean_decode_err(&self) -> f64 {
        if self.rounds.is_empty() {
            return f64::NAN;
        }
        self.rounds.iter().map(|m| m.decode_err).sum::<f64>() / self.rounds.len() as f64
    }

    pub fn total_gather_time(&self) -> f64 {
        self.rounds.iter().map(|m| m.gather_time).sum()
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(RoundMetrics::csv_header());
        out.push('\n');
        for m in &self.rounds {
            out.push_str(&m.to_csv());
            out.push('\n');
        }
        out
    }
}

// ---------------------------------------------------- LatencyHistogram

/// Sub-buckets per power-of-two octave: 16 gives ~6.7% worst-case
/// relative bucket width — HDR-histogram resolution without its
/// configurability.
const SUB_BUCKETS: usize = 16;

/// Values `0..16` get exact unit buckets; every later octave
/// `[2^e, 2^(e+1))` for `e in 4..=63` splits into 16 linear sub-buckets.
const NUM_BUCKETS: usize = SUB_BUCKETS + (63 - 4) * SUB_BUCKETS + SUB_BUCKETS;

/// Log-bucketed histogram of nanosecond latencies.
///
/// Recording is O(1) (a leading-zeros count), memory is one fixed
/// 976-slot table covering the full u64 range, and quantiles are a pure
/// function of the recorded multiset — independent of record order — so
/// two runs that observe the same set of values render identical
/// summaries. Mergeable: worker threads each fill their own and fold
/// them together afterwards.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    max_ns: u64,
    sum_ns: u128,
}

fn bucket_of(ns: u64) -> usize {
    if ns < SUB_BUCKETS as u64 {
        return ns as usize;
    }
    let exp = 63 - ns.leading_zeros() as usize; // floor(log2 ns), >= 4 here
    let sub = ((ns >> (exp - 4)) & 15) as usize;
    (exp - 3) * SUB_BUCKETS + sub
}

/// Largest value a bucket covers (quantiles report this conservative
/// upper edge, clamped to the true observed max).
fn bucket_upper_ns(b: usize) -> u64 {
    if b < SUB_BUCKETS {
        return b as u64;
    }
    // Bucket (exp-3)*16 + sub covers [(16+sub) << (exp-4), ..) with
    // width 2^(exp-4); `shift` is that exp-4.
    let shift = (b / SUB_BUCKETS) as u32 - 1;
    let sub = (b % SUB_BUCKETS) as u64;
    let lower = (SUB_BUCKETS as u64 + sub) << shift;
    lower + (1u64 << shift) - 1
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { counts: vec![0; NUM_BUCKETS], total: 0, max_ns: 0, sum_ns: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_ns(&mut self, ns: u64) {
        self.counts[bucket_of(ns)] += 1;
        self.total += 1;
        self.max_ns = self.max_ns.max(ns);
        self.sum_ns += ns as u128;
    }

    /// Fold another histogram in (disjoint worker shards of one run).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.total += other.total;
        self.max_ns = self.max_ns.max(other.max_ns);
        self.sum_ns += other.sum_ns;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / self.total as f64
    }

    /// The q-quantile (q in [0, 1]) as a bucket upper edge — within
    /// ~6.7% of the true order statistic, exact for values < 16 ns and
    /// for the maximum. 0 when nothing was recorded.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper_ns(b).min(self.max_ns);
            }
        }
        self.max_ns
    }
}

// -------------------------------------------------------- ServeMetrics

/// Shared counters behind `repro serve`'s `/metrics` endpoint.
///
/// Counter bumps are lock-free atomics; the latency histogram takes one
/// short mutex hold per completed request. Counters are recorded
/// *before* the response frame is written, so a client that has seen
/// its reply is guaranteed to see itself in a subsequent scrape.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Framed requests handled (including ones answered with an error).
    pub requests: AtomicU64,
    /// Requests answered with an `ok: false` frame plus framing-level
    /// failures (oversized prefix, truncated frame).
    pub errors: AtomicU64,
    /// Decode rounds executed across all `decode` requests.
    pub rounds: AtomicU64,
    /// Fan-out jobs scheduled via `job` requests.
    pub jobs: AtomicU64,
    /// Connections accepted.
    pub connections: AtomicU64,
    /// Requests accepted but not yet answered — a gauge, not a
    /// counter: incremented when a frame parses as a request,
    /// decremented once its reply is queued for the socket. Under
    /// pipelining this is the aggregate in-flight depth.
    pub inflight: AtomicU64,
    /// Reactor `epoll_wait` returns. Stays near zero while the daemon
    /// is idle (level-triggered interest is deregistered when there is
    /// nothing to do), so a busy-spinning reactor shows up as this
    /// counter running away between scrapes.
    pub wakeups: AtomicU64,
    latency: Mutex<LatencyHistogram>,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one handled request and its wall-clock latency.
    pub fn observe_request(&self, latency_ns: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latency.lock().expect("latency histogram poisoned").record_ns(latency_ns);
    }

    pub fn observe_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_rounds(&self, n: u64) {
        self.rounds.fetch_add(n, Ordering::Relaxed);
    }

    pub fn observe_job(&self) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn observe_connection(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was accepted (parsed off the wire) and is now in
    /// flight.
    pub fn inflight_inc(&self) {
        self.inflight.fetch_add(1, Ordering::Relaxed);
    }

    /// The in-flight request's reply has been queued for its socket.
    pub fn inflight_dec(&self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }

    /// One reactor `epoll_wait` return.
    pub fn observe_wakeup(&self) {
        self.wakeups.fetch_add(1, Ordering::Relaxed);
    }

    /// A point-in-time copy of the latency histogram.
    pub fn latency_snapshot(&self) -> LatencyHistogram {
        self.latency.lock().expect("latency histogram poisoned").clone()
    }

    /// Text exposition (one `name value` pair per line) — what the
    /// HTTP `/metrics` endpoint serves.
    pub fn render(&self) -> String {
        let lat = self.latency_snapshot();
        let mut out = String::new();
        for (name, value) in [
            ("gradcode_connections_total", self.connections.load(Ordering::Relaxed)),
            ("gradcode_requests_total", self.requests.load(Ordering::Relaxed)),
            ("gradcode_errors_total", self.errors.load(Ordering::Relaxed)),
            ("gradcode_rounds_total", self.rounds.load(Ordering::Relaxed)),
            ("gradcode_jobs_total", self.jobs.load(Ordering::Relaxed)),
            ("gradcode_inflight_requests", self.inflight.load(Ordering::Relaxed)),
            ("gradcode_reactor_wakeups_total", self.wakeups.load(Ordering::Relaxed)),
            ("gradcode_request_latency_count", lat.count()),
            ("gradcode_request_latency_p50_us", lat.quantile_ns(0.50) / 1_000),
            ("gradcode_request_latency_p99_us", lat.quantile_ns(0.99) / 1_000),
            ("gradcode_request_latency_max_us", lat.quantile_ns(1.0) / 1_000),
        ] {
            out.push_str(name);
            out.push(' ');
            out.push_str(&value.to_string());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_summaries() {
        let mut h = TrainingHistory::default();
        for i in 0..3 {
            h.push(RoundMetrics {
                round: i,
                loss: 10.0 - i as f64,
                decode_err: i as f64,
                survivors: 8,
                gather_time: 0.5,
                wall_time: 0.1,
            });
        }
        assert_eq!(h.final_loss(), 8.0);
        assert_eq!(h.mean_decode_err(), 1.0);
        assert!((h.total_gather_time() - 1.5).abs() < 1e-12);
        let csv = h.to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("round,"));
    }

    #[test]
    fn empty_history_is_nan() {
        let h = TrainingHistory::default();
        assert!(h.final_loss().is_nan());
        assert!(h.mean_decode_err().is_nan());
    }

    #[test]
    fn histogram_buckets_partition_the_u64_range() {
        // Every bucket's upper edge maps back to itself, edges are
        // strictly increasing, and the next value starts the next
        // bucket — i.e. the buckets tile u64 with no gaps or overlaps.
        let mut prev_upper: Option<u64> = None;
        for b in 0..NUM_BUCKETS {
            let upper = bucket_upper_ns(b);
            assert_eq!(bucket_of(upper), b, "upper edge of bucket {b}");
            if let Some(p) = prev_upper {
                assert!(upper > p, "bucket {b} edges not increasing");
                assert_eq!(bucket_of(p + 1), b, "gap before bucket {b}");
            }
            prev_upper = Some(upper);
        }
        assert_eq!(prev_upper, Some(u64::MAX));
        assert_eq!(bucket_of(u64::MAX), NUM_BUCKETS - 1);
    }

    #[test]
    fn histogram_quantiles_are_order_independent_and_tight() {
        let values: Vec<u64> = (1..=1000).map(|i| i * 1_000).collect(); // 1..=1000 us
        let mut forward = LatencyHistogram::new();
        let mut backward = LatencyHistogram::new();
        for &v in &values {
            forward.record_ns(v);
        }
        for &v in values.iter().rev() {
            backward.record_ns(v);
        }
        for q in [0.0, 0.5, 0.99, 0.999, 1.0] {
            assert_eq!(forward.quantile_ns(q), backward.quantile_ns(q), "q={q}");
        }
        // Upper-edge quantiles overshoot by at most one bucket width
        // (~6.7%) and never undershoot the true order statistic.
        let p50 = forward.quantile_ns(0.5);
        assert!((500_000..=540_000).contains(&p50), "p50 {p50}");
        let p99 = forward.quantile_ns(0.99);
        assert!((990_000..=1_060_000).contains(&p99), "p99 {p99}");
        assert_eq!(forward.quantile_ns(1.0), 1_000_000); // max is exact
        assert_eq!(forward.count(), 1000);
        assert!((forward.mean_ns() - 500_500.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_merge_equals_combined_recording() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut whole = LatencyHistogram::new();
        for i in 0..500u64 {
            let v = i * i + 17;
            if i % 2 == 0 { a.record_ns(v) } else { b.record_ns(v) }
            whole.record_ns(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.counts, whole.counts);
        for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile_ns(q), whole.quantile_ns(q), "q={q}");
        }
    }

    #[test]
    fn serve_metrics_render_contains_every_counter() {
        let m = ServeMetrics::new();
        m.observe_connection();
        m.observe_request(1_500_000);
        m.observe_request(2_500_000);
        m.observe_error();
        m.add_rounds(32);
        m.observe_job();
        m.inflight_inc();
        m.inflight_inc();
        m.inflight_dec();
        m.observe_wakeup();
        m.observe_wakeup();
        m.observe_wakeup();
        let text = m.render();
        assert!(text.contains("gradcode_connections_total 1\n"), "{text}");
        assert!(text.contains("gradcode_requests_total 2\n"), "{text}");
        assert!(text.contains("gradcode_errors_total 1\n"), "{text}");
        assert!(text.contains("gradcode_rounds_total 32\n"), "{text}");
        assert!(text.contains("gradcode_jobs_total 1\n"), "{text}");
        assert!(text.contains("gradcode_inflight_requests 1\n"), "{text}");
        assert!(text.contains("gradcode_reactor_wakeups_total 3\n"), "{text}");
        assert!(text.contains("gradcode_request_latency_count 2\n"), "{text}");
    }
}
