//! Per-round metrics and training history (CSV-dumpable).

/// One training round's observability record.
#[derive(Clone, Debug, Default)]
pub struct RoundMetrics {
    pub round: usize,
    /// Training loss (model-dependent: exact for linear, mean worker
    /// loss for MLP).
    pub loss: f64,
    /// Decoding error ||A x - 1_k||² of the round's survivor matrix.
    pub decode_err: f64,
    /// Survivor count r.
    pub survivors: usize,
    /// Virtual gather time (when the deadline fired), seconds.
    pub gather_time: f64,
    /// Wall-clock compute+coordination time, seconds.
    pub wall_time: f64,
}

impl RoundMetrics {
    pub fn csv_header() -> &'static str {
        "round,loss,decode_err,survivors,gather_time,wall_time"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{:.6e},{:.6e},{},{:.6},{:.6}",
            self.round, self.loss, self.decode_err, self.survivors, self.gather_time, self.wall_time
        )
    }
}

/// A whole run's history plus summary statistics.
#[derive(Clone, Debug, Default)]
pub struct TrainingHistory {
    pub rounds: Vec<RoundMetrics>,
}

impl TrainingHistory {
    pub fn push(&mut self, m: RoundMetrics) {
        self.rounds.push(m);
    }

    pub fn final_loss(&self) -> f64 {
        self.rounds.last().map(|m| m.loss).unwrap_or(f64::NAN)
    }

    pub fn mean_decode_err(&self) -> f64 {
        if self.rounds.is_empty() {
            return f64::NAN;
        }
        self.rounds.iter().map(|m| m.decode_err).sum::<f64>() / self.rounds.len() as f64
    }

    pub fn total_gather_time(&self) -> f64 {
        self.rounds.iter().map(|m| m.gather_time).sum()
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(RoundMetrics::csv_header());
        out.push('\n');
        for m in &self.rounds {
            out.push_str(&m.to_csv());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_summaries() {
        let mut h = TrainingHistory::default();
        for i in 0..3 {
            h.push(RoundMetrics {
                round: i,
                loss: 10.0 - i as f64,
                decode_err: i as f64,
                survivors: 8,
                gather_time: 0.5,
                wall_time: 0.1,
            });
        }
        assert_eq!(h.final_loss(), 8.0);
        assert_eq!(h.mean_decode_err(), 1.0);
        assert!((h.total_gather_time() - 1.5).abs() < 1e-12);
        let csv = h.to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("round,"));
    }

    #[test]
    fn empty_history_is_nan() {
        let h = TrainingHistory::default();
        assert!(h.final_loss().is_nan());
        assert!(h.mean_decode_err().is_nan());
    }
}
