//! Master-side gather + decode.
//!
//! Given the full message set, draw worker latencies, apply the deadline
//! policy, select the survivor matrix A = G[:, non-stragglers], decode
//! (one-step or optimal), and aggregate ĝ = Σ_j x_j · msg_j — the
//! estimate of the gradient sum Σ_i f_i.

use anyhow::{bail, Result};

use super::config::DecoderKind;
use super::worker::Message;
use crate::decode::{Decoder, OneStepDecoder, OptimalDecoder};
use crate::linalg::CscMatrix;
use crate::stragglers::{sample_round, DeadlinePolicy, LatencyModel};
use crate::util::Rng;

/// Outcome of one coordination round.
#[derive(Clone, Debug)]
pub struct Round {
    pub non_stragglers: Vec<usize>,
    /// When the master stopped waiting (virtual seconds).
    pub gather_time: f64,
    /// Decoding weights over the survivors (same order).
    pub weights: Vec<f64>,
    /// Achieved decoding error ||A x - 1_k||² for the weights used.
    pub decode_err: f64,
    /// ĝ — the estimate of Σ_{i=1}^k f_i.
    pub estimate: Vec<f32>,
    /// Mean per-task loss over surviving workers (MLP rounds).
    pub mean_loss: f64,
}

/// Run the gather + decode for one round.
///
/// `messages` must hold all n workers' outputs (indexed by worker id);
/// stragglers are decided here by the latency model, mirroring a real
/// deployment where every worker computes but only the fast ones count.
pub fn gather_and_decode(
    g: &CscMatrix,
    s: usize,
    messages: &[Message],
    decoder: DecoderKind,
    latency: &LatencyModel,
    deadline: &DeadlinePolicy,
    rng: &mut Rng,
) -> Result<Round> {
    let n = g.cols;
    if messages.len() != n {
        bail!("expected {n} messages, got {}", messages.len());
    }
    let sample = sample_round(latency, deadline, n, rng);
    let survivors = sample.non_stragglers;
    if survivors.is_empty() {
        bail!("all workers straggled: raise the deadline");
    }
    let a = g.select_columns(&survivors);
    let k = g.rows;
    let r = survivors.len();

    let weights = match decoder {
        DecoderKind::OneStep => OneStepDecoder::canonical(k, r, s).weights(&a),
        DecoderKind::Optimal => OptimalDecoder::new().weights(&a),
    };
    let decode_err = crate::decode::decode_error(&a, &weights);

    // ĝ = Σ_j x_j msg_j over survivors.
    let dim = messages[survivors[0]].payload.len();
    let mut estimate = vec![0.0f32; dim];
    let mut loss_sum = 0.0f64;
    let mut tasks = 0usize;
    for (pos, &j) in survivors.iter().enumerate() {
        let msg = &messages[j];
        if msg.payload.len() != dim {
            bail!("message {j} has wrong payload length");
        }
        let w = weights[pos] as f32;
        if w != 0.0 {
            for (e, p) in estimate.iter_mut().zip(&msg.payload) {
                *e += w * p;
            }
        }
        loss_sum += msg.loss_sum;
        tasks += msg.tasks_done;
    }
    let mean_loss = if tasks > 0 { loss_sum / tasks as f64 } else { 0.0 };

    Ok(Round {
        non_stragglers: survivors,
        gather_time: sample.gather_time,
        weights,
        decode_err,
        estimate,
        mean_loss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{FractionalRepetitionCode, GradientCode};
    use crate::stragglers::{DeadlinePolicy, LatencyModel};

    /// Synthetic messages where task i's "gradient" is e_i scaled by
    /// (i+1): the true sum over tasks is [1, 2, ..., k].
    fn synthetic_messages(g: &CscMatrix) -> Vec<Message> {
        let k = g.rows;
        (0..g.cols)
            .map(|j| {
                let mut payload = vec![0.0f32; k];
                for (i, c) in g.col(j) {
                    payload[i] += (c as f32) * (i as f32 + 1.0);
                }
                Message { worker: j, payload, loss_sum: 1.0, tasks_done: g.col_nnz(j) }
            })
            .collect()
    }

    #[test]
    fn zero_decode_error_recovers_exact_gradient_sum() {
        // FRC with no stragglers: optimal decode is exact, so the
        // estimate equals the true sum [1..k].
        let (k, s) = (12usize, 3usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(0));
        let msgs = synthetic_messages(&g);
        let round = gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::Optimal,
            &LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 },
            &DeadlinePolicy::FastestR(k),
            &mut Rng::new(1),
        )
        .unwrap();
        assert!(round.decode_err < 1e-12, "err {}", round.decode_err);
        for i in 0..k {
            assert!(
                (round.estimate[i] - (i as f32 + 1.0)).abs() < 1e-4,
                "coord {i}: {}",
                round.estimate[i]
            );
        }
    }

    #[test]
    fn estimate_error_bounded_by_decode_error() {
        // |f^T A x - f^T 1|^2 <= ||f||^2 err(A)  (paper eq. 2.3).
        let (k, s) = (20usize, 5usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(2));
        let msgs = synthetic_messages(&g);
        let round = gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::OneStep,
            &LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 },
            &DeadlinePolicy::FastestR(15),
            &mut Rng::new(3),
        )
        .unwrap();
        let f_norm_sq: f64 = (1..=k).map(|i| (i * i) as f64).sum();
        let true_sum: f64 = (1..=k).map(|i| i as f64).sum();
        let est_sum: f64 = round.estimate.iter().map(|&v| v as f64).sum();
        // The component-wise estimate error is f-weighted; check the
        // aggregate inequality with f = identity basis reading.
        let err = (est_sum - true_sum).powi(2);
        assert!(
            err <= f_norm_sq * round.decode_err + 1e-6,
            "estimate err {err} > bound {}",
            f_norm_sq * round.decode_err
        );
    }

    #[test]
    fn survivor_count_respects_policy() {
        let (k, s) = (10usize, 2usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(4));
        let msgs = synthetic_messages(&g);
        let round = gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::OneStep,
            &LatencyModel::Pareto { scale: 0.1, shape: 1.5 },
            &DeadlinePolicy::FastestR(6),
            &mut Rng::new(5),
        )
        .unwrap();
        assert_eq!(round.non_stragglers.len(), 6);
        assert_eq!(round.weights.len(), 6);
    }

    #[test]
    fn message_count_mismatch_errors() {
        let (k, s) = (10usize, 2usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(6));
        let msgs = synthetic_messages(&g)[..5].to_vec();
        assert!(gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::OneStep,
            &LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 },
            &DeadlinePolicy::FastestR(5),
            &mut Rng::new(7),
        )
        .is_err());
    }
}
