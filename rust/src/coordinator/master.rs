//! Master-side gather + decode.
//!
//! Given the full message set, draw worker latencies, apply the deadline
//! policy, select the survivor matrix A = G[:, non-stragglers], decode
//! (one-step or optimal), and aggregate ĝ = Σ_j x_j · msg_j — the
//! estimate of the gradient sum Σ_i f_i.
//!
//! The round runs on the `DecodeWorkspace` spine: straggler draws land
//! in the workspace's `StragglerScratch` (`LatencyStragglers` is pinned
//! draw-for-draw identical to the historical `sample_round`), A is
//! materialized into the workspace submatrix, and both decode arms
//! solve into workspace buffers — so a training loop's steady state
//! allocates only what the returned [`Round`] itself owns.

use anyhow::{bail, Result};

use super::config::{AnytimePolicy, DecoderKind};
use super::worker::Message;
use crate::decode::{DecodeWorkspace, OneStepDecoder};
use crate::linalg::{CscMatrix, LsqrOptions};
use crate::stragglers::{DeadlinePolicy, LatencyModel, LatencyStragglers};
use crate::util::Rng;

/// Outcome of one coordination round.
#[derive(Clone, Debug)]
pub struct Round {
    pub non_stragglers: Vec<usize>,
    /// When the master stopped waiting (virtual seconds).
    pub gather_time: f64,
    /// Decoding weights over the survivors (same order).
    pub weights: Vec<f64>,
    /// Achieved decoding error ||A x - 1_k||² for the weights used.
    pub decode_err: f64,
    /// ĝ — the estimate of Σ_{i=1}^k f_i.
    pub estimate: Vec<f32>,
    /// Mean per-task loss over surviving workers (MLP rounds).
    pub mean_loss: f64,
    /// The survivors in message-arrival order (ascending completion
    /// time; draw order for models with no time axis) — the order the
    /// incremental decoder consumed them in.
    pub arrivals: Vec<usize>,
    /// Exact incremental err₁ after each arrival: `err1_trace[i]` is
    /// bit-identical to a batch decode on the first i+1 arrivals
    /// (prefix-parity contract), at the round's planned step size
    /// ρ = k/(r_planned·s). Truncated at the stopping arrival when an
    /// anytime policy fires.
    pub err1_trace: Vec<f64>,
    /// `Some(count)` when an [`AnytimePolicy`] fired: the number of
    /// arrivals actually consumed (the decode, weights, and estimate
    /// all reflect exactly that prefix). `None` when the round ran the
    /// deadline policy to completion.
    pub stopped_at: Option<usize>,
}

/// Run the gather + decode for one round.
///
/// `messages` must hold all n workers' outputs (indexed by worker id);
/// stragglers are decided here by the latency model, mirroring a real
/// deployment where every worker computes but only the fast ones count.
///
/// `ws` supplies every scratch buffer (straggler draw, selected A,
/// LSQR); build it once per training run and reuse it across rounds.
/// Outputs are bit-identical to the historical allocating path
/// (`sample_round` + `select_columns` + `Decoder::weights` +
/// `decode_error`) — the straggler draw consumes the same RNG stream,
/// and both decode arms replicate the same arithmetic.
pub fn gather_and_decode(
    g: &CscMatrix,
    s: usize,
    messages: &[Message],
    decoder: DecoderKind,
    latency: &LatencyModel,
    deadline: &DeadlinePolicy,
    rng: &mut Rng,
    ws: &mut DecodeWorkspace,
) -> Result<Round> {
    gather_and_decode_anytime(
        g,
        s,
        messages,
        decoder,
        latency,
        deadline,
        AnytimePolicy::None,
        rng,
        ws,
    )
}

/// [`gather_and_decode`] with an anytime stopping rule: decoding runs
/// *as the messages arrive* (the workspace's incremental decoder
/// replays the draw in arrival order, recording the exact err₁ after
/// every arrival), so the master can cancel on a target error or
/// revise its deadline mid-round and commit the decode for exactly the
/// prefix in hand. With [`AnytimePolicy::None`] every published output
/// is bit-identical to the historical gather-then-decode path — the
/// trace rides along without touching the decode.
#[allow(clippy::too_many_arguments)]
pub fn gather_and_decode_anytime(
    g: &CscMatrix,
    s: usize,
    messages: &[Message],
    decoder: DecoderKind,
    latency: &LatencyModel,
    deadline: &DeadlinePolicy,
    anytime: AnytimePolicy,
    rng: &mut Rng,
    ws: &mut DecodeWorkspace,
) -> Result<Round> {
    let n = g.cols;
    if messages.len() != n {
        bail!("expected {n} messages, got {}", messages.len());
    }
    // Validate every payload dimension up front, against one expected
    // value, before the straggler draw or any decode work: a single
    // malformed payload is blamed by its own index (instead of making
    // every *other* worker look wrong once the survivor anchor happens
    // to be the bad one), and a bad batch fails before an LSQR solve is
    // paid for. The check consumes no RNG, so the draw stream for valid
    // inputs is untouched.
    let dim = messages.first().map_or(0, |m| m.payload.len());
    if let Some(bad) = messages.iter().position(|m| m.payload.len() != dim) {
        bail!(
            "message {bad} has payload length {}, expected {dim} (dimension of message 0)",
            messages[bad].payload.len()
        );
    }
    let model = LatencyStragglers { model: *latency, policy: *deadline };
    ws.select_submatrix_with(g, &model, rng);
    if ws.last_non_stragglers().is_empty() {
        bail!("all workers straggled: raise the deadline");
    }
    let k = g.rows;
    let r_full = ws.last_non_stragglers().len();

    // Decode-as-messages-arrive: replay the draw through the
    // incremental decoder in arrival order, recording the exact err₁
    // after every arrival. The step size uses the *planned* survivor
    // count (a streaming master cannot know the realized r mid-gather).
    let rho_planned = OneStepDecoder::canonical(k, r_full, s).rho;
    let mut err1_trace = Vec::with_capacity(r_full);
    ws.incremental_trace_selected(g, rho_planned, &mut err1_trace);
    let mut arrivals = ws.last_arrival_order().to_vec();

    let mut stopped_at = None;
    match anytime {
        AnytimePolicy::None => {}
        AnytimePolicy::TargetErr1(t) => {
            let target = t * k as f64;
            if let Some(i) = err1_trace.iter().position(|&e| e <= target) {
                let stop = i + 1;
                let gather = if ws.last_gather_time().is_nan() {
                    f64::NAN
                } else {
                    // The master cancels the moment the target-hitting
                    // message lands.
                    ws.last_latencies()[arrivals[i]]
                };
                ws.adopt_arrival_prefix(g, stop, gather);
                stopped_at = Some(stop);
                err1_trace.truncate(stop);
                arrivals.truncate(stop);
            }
        }
        AnytimePolicy::ReviseDeadline { at, to } => {
            let gather0 = ws.last_gather_time();
            if !gather0.is_nan() {
                let eff = gather0.min(at.max(to));
                if eff < gather0 {
                    let stop = {
                        let lat = ws.last_latencies();
                        arrivals.iter().take_while(|&&j| lat[j] <= eff).count()
                    };
                    if stop == 0 {
                        bail!(
                            "the revised deadline ({eff}) cut every survivor: revise later or higher"
                        );
                    }
                    ws.adopt_arrival_prefix(g, stop, eff);
                    stopped_at = Some(stop);
                    err1_trace.truncate(stop);
                    arrivals.truncate(stop);
                }
            }
        }
    }
    let r = ws.last_non_stragglers().len();

    let weights = match decoder {
        // One-step weights are the constant ρ·1_r — no solve needed.
        DecoderKind::OneStep => vec![OneStepDecoder::canonical(k, r, s).rho; r],
        DecoderKind::Optimal => ws.optimal_weights_selected(&LsqrOptions::default()).to_vec(),
    };
    let decode_err = ws.decode_error_selected(&weights);
    let survivors = ws.last_non_stragglers();

    // ĝ = Σ_j x_j msg_j over survivors (dimensions validated above).
    let mut estimate = vec![0.0f32; dim];
    let mut loss_sum = 0.0f64;
    let mut tasks = 0usize;
    for (pos, &j) in survivors.iter().enumerate() {
        let msg = &messages[j];
        let w = weights[pos] as f32;
        if w != 0.0 {
            for (e, p) in estimate.iter_mut().zip(&msg.payload) {
                *e += w * p;
            }
        }
        loss_sum += msg.loss_sum;
        tasks += msg.tasks_done;
    }
    let mean_loss = if tasks > 0 { loss_sum / tasks as f64 } else { 0.0 };

    Ok(Round {
        non_stragglers: survivors.to_vec(),
        gather_time: ws.last_gather_time(),
        weights,
        decode_err,
        estimate,
        mean_loss,
        arrivals,
        err1_trace,
        stopped_at,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{FractionalRepetitionCode, GradientCode};
    use crate::stragglers::{DeadlinePolicy, LatencyModel};

    /// Synthetic messages where task i's "gradient" is e_i scaled by
    /// (i+1): the true sum over tasks is [1, 2, ..., k].
    fn synthetic_messages(g: &CscMatrix) -> Vec<Message> {
        let k = g.rows;
        (0..g.cols)
            .map(|j| {
                let mut payload = vec![0.0f32; k];
                for (i, c) in g.col(j) {
                    payload[i] += (c as f32) * (i as f32 + 1.0);
                }
                Message { worker: j, payload, loss_sum: 1.0, tasks_done: g.col_nnz(j) }
            })
            .collect()
    }

    #[test]
    fn zero_decode_error_recovers_exact_gradient_sum() {
        // FRC with no stragglers: optimal decode is exact, so the
        // estimate equals the true sum [1..k].
        let (k, s) = (12usize, 3usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(0));
        let msgs = synthetic_messages(&g);
        let round = gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::Optimal,
            &LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 },
            &DeadlinePolicy::FastestR(k),
            &mut Rng::new(1),
            &mut DecodeWorkspace::new(),
        )
        .unwrap();
        assert!(round.decode_err < 1e-12, "err {}", round.decode_err);
        for i in 0..k {
            assert!(
                (round.estimate[i] - (i as f32 + 1.0)).abs() < 1e-4,
                "coord {i}: {}",
                round.estimate[i]
            );
        }
    }

    #[test]
    fn estimate_error_bounded_by_decode_error() {
        // |f^T A x - f^T 1|^2 <= ||f||^2 err(A)  (paper eq. 2.3).
        let (k, s) = (20usize, 5usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(2));
        let msgs = synthetic_messages(&g);
        let round = gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::OneStep,
            &LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 },
            &DeadlinePolicy::FastestR(15),
            &mut Rng::new(3),
            &mut DecodeWorkspace::new(),
        )
        .unwrap();
        let f_norm_sq: f64 = (1..=k).map(|i| (i * i) as f64).sum();
        let true_sum: f64 = (1..=k).map(|i| i as f64).sum();
        let est_sum: f64 = round.estimate.iter().map(|&v| v as f64).sum();
        // The component-wise estimate error is f-weighted; check the
        // aggregate inequality with f = identity basis reading.
        let err = (est_sum - true_sum).powi(2);
        assert!(
            err <= f_norm_sq * round.decode_err + 1e-6,
            "estimate err {err} > bound {}",
            f_norm_sq * round.decode_err
        );
    }

    #[test]
    fn survivor_count_respects_policy() {
        let (k, s) = (10usize, 2usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(4));
        let msgs = synthetic_messages(&g);
        let round = gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::OneStep,
            &LatencyModel::Pareto { scale: 0.1, shape: 1.5 },
            &DeadlinePolicy::FastestR(6),
            &mut Rng::new(5),
            &mut DecodeWorkspace::new(),
        )
        .unwrap();
        assert_eq!(round.non_stragglers.len(), 6);
        assert_eq!(round.weights.len(), 6);
    }

    #[test]
    fn message_count_mismatch_errors() {
        let (k, s) = (10usize, 2usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(6));
        let msgs = synthetic_messages(&g)[..5].to_vec();
        assert!(gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::OneStep,
            &LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 },
            &DeadlinePolicy::FastestR(5),
            &mut Rng::new(7),
            &mut DecodeWorkspace::new(),
        )
        .is_err());
    }

    #[test]
    fn malformed_payload_is_blamed_by_index_before_any_decode_work() {
        let (k, s) = (12usize, 3usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(10));
        let mut msgs = synthetic_messages(&g);
        msgs[7].payload.pop(); // worker 7 ships a short gradient
        let mut rng = Rng::new(11);
        let err = gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::Optimal,
            &LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 },
            &DeadlinePolicy::FastestR(k),
            &mut rng,
            &mut DecodeWorkspace::new(),
        )
        .unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("message 7"), "the malformed worker must be named: {text}");
        // The validation fired before the straggler draw: the caller's
        // rng stream is untouched (still equal to a fresh one).
        assert_eq!(rng.next_u64(), Rng::new(11).next_u64());
    }

    #[test]
    fn workspace_round_matches_historical_allocating_path_bitwise() {
        // The pre-port sequence: sample_round -> select_columns ->
        // Decoder::weights -> decode_error. The workspace round must
        // reproduce every output bit for bit, RNG stream included.
        use crate::decode::{Decoder, OptimalDecoder};
        use crate::stragglers::sample_round;
        let (k, s) = (18usize, 3usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(8));
        let msgs = synthetic_messages(&g);
        let latency = LatencyModel::ShiftedExp { base: 0.01, rate: 5.0 };
        let deadline = DeadlinePolicy::FastestR(13);
        for decoder in [DecoderKind::OneStep, DecoderKind::Optimal] {
            let mut rng_ref = Rng::new(9);
            let sample = sample_round(&latency, &deadline, g.cols, &mut rng_ref);
            let a = g.select_columns(&sample.non_stragglers);
            let r = sample.non_stragglers.len();
            let weights_ref = match decoder {
                DecoderKind::OneStep => OneStepDecoder::canonical(k, r, s).weights(&a),
                DecoderKind::Optimal => OptimalDecoder::new().weights(&a),
            };
            let err_ref = crate::decode::decode_error(&a, &weights_ref);

            let mut rng = Rng::new(9);
            let round = gather_and_decode(
                &g,
                s,
                &msgs,
                decoder,
                &latency,
                &deadline,
                &mut rng,
                &mut DecodeWorkspace::new(),
            )
            .unwrap();
            assert_eq!(round.non_stragglers, sample.non_stragglers, "{decoder:?}");
            assert_eq!(round.gather_time.to_bits(), sample.gather_time.to_bits());
            assert_eq!(round.weights.len(), weights_ref.len(), "{decoder:?}");
            for (w, w_ref) in round.weights.iter().zip(&weights_ref) {
                assert_eq!(w.to_bits(), w_ref.to_bits(), "{decoder:?}");
            }
            assert_eq!(round.decode_err.to_bits(), err_ref.to_bits(), "{decoder:?}");
            // The two rngs must have consumed the same stream.
            assert_eq!(rng.f64().to_bits(), rng_ref.f64().to_bits());
        }
    }

    #[test]
    fn err1_trace_is_prefix_parity_with_batch_decode() {
        let (k, s) = (18usize, 3usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(20));
        let msgs = synthetic_messages(&g);
        let round = gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::OneStep,
            &LatencyModel::Pareto { scale: 0.1, shape: 1.5 },
            &DeadlinePolicy::FastestR(13),
            &mut Rng::new(21),
            &mut DecodeWorkspace::new(),
        )
        .unwrap();
        assert_eq!(round.err1_trace.len(), round.arrivals.len());
        assert!(round.stopped_at.is_none());
        // Arrivals are a permutation of the survivor set.
        let mut sorted = round.arrivals.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, round.non_stragglers);
        // Every trace entry is bit-identical to a batch decode on
        // exactly that arrival prefix.
        let rho = OneStepDecoder::canonical(k, round.non_stragglers.len(), s).rho;
        let mut batch_ws = DecodeWorkspace::new();
        for i in 0..round.arrivals.len() {
            let batch = batch_ws.err1_fused(&g, &round.arrivals[..i + 1], rho);
            assert_eq!(round.err1_trace[i].to_bits(), batch.to_bits(), "prefix {}", i + 1);
        }
    }

    #[test]
    fn anytime_policy_none_round_is_bit_identical_to_plain_round() {
        let (k, s) = (18usize, 3usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(22));
        let msgs = synthetic_messages(&g);
        let latency = LatencyModel::ShiftedExp { base: 0.01, rate: 5.0 };
        let deadline = DeadlinePolicy::FastestR(13);
        let plain = gather_and_decode(
            &g, s, &msgs, DecoderKind::Optimal, &latency, &deadline,
            &mut Rng::new(23), &mut DecodeWorkspace::new(),
        )
        .unwrap();
        let anytime = gather_and_decode_anytime(
            &g, s, &msgs, DecoderKind::Optimal, &latency, &deadline,
            AnytimePolicy::None, &mut Rng::new(23), &mut DecodeWorkspace::new(),
        )
        .unwrap();
        assert_eq!(plain.non_stragglers, anytime.non_stragglers);
        assert_eq!(plain.gather_time.to_bits(), anytime.gather_time.to_bits());
        assert_eq!(plain.decode_err.to_bits(), anytime.decode_err.to_bits());
        for (a, b) in plain.weights.iter().zip(&anytime.weights) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn cancel_on_target_commits_the_decode_for_the_stopped_prefix() {
        let (k, s) = (18usize, 3usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(24));
        let msgs = synthetic_messages(&g);
        let latency = LatencyModel::Pareto { scale: 0.1, shape: 1.5 };
        let deadline = DeadlinePolicy::FastestR(16);
        // FRC reaches low err1 well before all 16 arrivals; a loose
        // target must fire before the full gather.
        let round = gather_and_decode_anytime(
            &g, s, &msgs, DecoderKind::OneStep, &latency, &deadline,
            AnytimePolicy::TargetErr1(0.9), &mut Rng::new(25), &mut DecodeWorkspace::new(),
        )
        .unwrap();
        let stop = round.stopped_at.expect("target must fire below err1 = k");
        assert_eq!(round.arrivals.len(), stop);
        assert_eq!(round.err1_trace.len(), stop);
        assert_eq!(round.non_stragglers.len(), stop);
        assert!(*round.err1_trace.last().unwrap() <= 0.9 * k as f64);
        // The committed survivor set is the sorted arrival prefix, the
        // gather clock is the stopping arrival's completion time, and
        // the weights cover exactly the prefix.
        let mut sorted = round.arrivals.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, round.non_stragglers);
        assert_eq!(round.weights.len(), stop);
        assert!(round.gather_time.is_finite());
        // Decode error matches a from-scratch decode on the committed set.
        let a = g.select_columns(&round.non_stragglers);
        let reference = crate::decode::decode_error(&a, &round.weights);
        assert_eq!(round.decode_err.to_bits(), reference.to_bits());
    }

    #[test]
    fn deadline_revision_shortens_the_gather_and_respects_arrival_times() {
        let (k, s) = (18usize, 3usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(26));
        let msgs = synthetic_messages(&g);
        let latency = LatencyModel::Pareto { scale: 0.1, shape: 1.2 };
        let deadline = DeadlinePolicy::Fixed(10.0);
        let full = gather_and_decode(
            &g, s, &msgs, DecoderKind::OneStep, &latency, &deadline,
            &mut Rng::new(27), &mut DecodeWorkspace::new(),
        )
        .unwrap();
        let revised = gather_and_decode_anytime(
            &g, s, &msgs, DecoderKind::OneStep, &latency, &deadline,
            AnytimePolicy::ReviseDeadline { at: 0.15, to: 0.4 },
            &mut Rng::new(27), &mut DecodeWorkspace::new(),
        )
        .unwrap();
        assert_eq!(full.gather_time, 10.0);
        assert_eq!(revised.gather_time, 0.4);
        let stop = revised.stopped_at.expect("revision fired");
        assert!(stop <= full.non_stragglers.len());
        // Every committed survivor beat the revised cutoff; the set is
        // a subset of the full round's survivors.
        assert!(revised.non_stragglers.iter().all(|j| full.non_stragglers.contains(j)));
        // Revision that never binds leaves the round bit-identical.
        let noop = gather_and_decode_anytime(
            &g, s, &msgs, DecoderKind::OneStep, &latency, &deadline,
            AnytimePolicy::ReviseDeadline { at: 11.0, to: 12.0 },
            &mut Rng::new(27), &mut DecodeWorkspace::new(),
        )
        .unwrap();
        assert!(noop.stopped_at.is_none());
        assert_eq!(noop.decode_err.to_bits(), full.decode_err.to_bits());
    }
}
