//! Master-side gather + decode.
//!
//! Given the full message set, draw worker latencies, apply the deadline
//! policy, select the survivor matrix A = G[:, non-stragglers], decode
//! (one-step or optimal), and aggregate ĝ = Σ_j x_j · msg_j — the
//! estimate of the gradient sum Σ_i f_i.
//!
//! The round runs on the `DecodeWorkspace` spine: straggler draws land
//! in the workspace's `StragglerScratch` (`LatencyStragglers` is pinned
//! draw-for-draw identical to the historical `sample_round`), A is
//! materialized into the workspace submatrix, and both decode arms
//! solve into workspace buffers — so a training loop's steady state
//! allocates only what the returned [`Round`] itself owns.

use anyhow::{bail, Result};

use super::config::DecoderKind;
use super::worker::Message;
use crate::decode::{DecodeWorkspace, OneStepDecoder};
use crate::linalg::{CscMatrix, LsqrOptions};
use crate::stragglers::{DeadlinePolicy, LatencyModel, LatencyStragglers};
use crate::util::Rng;

/// Outcome of one coordination round.
#[derive(Clone, Debug)]
pub struct Round {
    pub non_stragglers: Vec<usize>,
    /// When the master stopped waiting (virtual seconds).
    pub gather_time: f64,
    /// Decoding weights over the survivors (same order).
    pub weights: Vec<f64>,
    /// Achieved decoding error ||A x - 1_k||² for the weights used.
    pub decode_err: f64,
    /// ĝ — the estimate of Σ_{i=1}^k f_i.
    pub estimate: Vec<f32>,
    /// Mean per-task loss over surviving workers (MLP rounds).
    pub mean_loss: f64,
}

/// Run the gather + decode for one round.
///
/// `messages` must hold all n workers' outputs (indexed by worker id);
/// stragglers are decided here by the latency model, mirroring a real
/// deployment where every worker computes but only the fast ones count.
///
/// `ws` supplies every scratch buffer (straggler draw, selected A,
/// LSQR); build it once per training run and reuse it across rounds.
/// Outputs are bit-identical to the historical allocating path
/// (`sample_round` + `select_columns` + `Decoder::weights` +
/// `decode_error`) — the straggler draw consumes the same RNG stream,
/// and both decode arms replicate the same arithmetic.
pub fn gather_and_decode(
    g: &CscMatrix,
    s: usize,
    messages: &[Message],
    decoder: DecoderKind,
    latency: &LatencyModel,
    deadline: &DeadlinePolicy,
    rng: &mut Rng,
    ws: &mut DecodeWorkspace,
) -> Result<Round> {
    let n = g.cols;
    if messages.len() != n {
        bail!("expected {n} messages, got {}", messages.len());
    }
    // Validate every payload dimension up front, against one expected
    // value, before the straggler draw or any decode work: a single
    // malformed payload is blamed by its own index (instead of making
    // every *other* worker look wrong once the survivor anchor happens
    // to be the bad one), and a bad batch fails before an LSQR solve is
    // paid for. The check consumes no RNG, so the draw stream for valid
    // inputs is untouched.
    let dim = messages.first().map_or(0, |m| m.payload.len());
    if let Some(bad) = messages.iter().position(|m| m.payload.len() != dim) {
        bail!(
            "message {bad} has payload length {}, expected {dim} (dimension of message 0)",
            messages[bad].payload.len()
        );
    }
    let model = LatencyStragglers { model: *latency, policy: *deadline };
    ws.select_submatrix_with(g, &model, rng);
    if ws.last_non_stragglers().is_empty() {
        bail!("all workers straggled: raise the deadline");
    }
    let k = g.rows;
    let r = ws.last_non_stragglers().len();

    let weights = match decoder {
        // One-step weights are the constant ρ·1_r — no solve needed.
        DecoderKind::OneStep => vec![OneStepDecoder::canonical(k, r, s).rho; r],
        DecoderKind::Optimal => ws.optimal_weights_selected(&LsqrOptions::default()).to_vec(),
    };
    let decode_err = ws.decode_error_selected(&weights);
    let survivors = ws.last_non_stragglers();

    // ĝ = Σ_j x_j msg_j over survivors (dimensions validated above).
    let mut estimate = vec![0.0f32; dim];
    let mut loss_sum = 0.0f64;
    let mut tasks = 0usize;
    for (pos, &j) in survivors.iter().enumerate() {
        let msg = &messages[j];
        let w = weights[pos] as f32;
        if w != 0.0 {
            for (e, p) in estimate.iter_mut().zip(&msg.payload) {
                *e += w * p;
            }
        }
        loss_sum += msg.loss_sum;
        tasks += msg.tasks_done;
    }
    let mean_loss = if tasks > 0 { loss_sum / tasks as f64 } else { 0.0 };

    Ok(Round {
        non_stragglers: survivors.to_vec(),
        gather_time: ws.last_gather_time(),
        weights,
        decode_err,
        estimate,
        mean_loss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{FractionalRepetitionCode, GradientCode};
    use crate::stragglers::{DeadlinePolicy, LatencyModel};

    /// Synthetic messages where task i's "gradient" is e_i scaled by
    /// (i+1): the true sum over tasks is [1, 2, ..., k].
    fn synthetic_messages(g: &CscMatrix) -> Vec<Message> {
        let k = g.rows;
        (0..g.cols)
            .map(|j| {
                let mut payload = vec![0.0f32; k];
                for (i, c) in g.col(j) {
                    payload[i] += (c as f32) * (i as f32 + 1.0);
                }
                Message { worker: j, payload, loss_sum: 1.0, tasks_done: g.col_nnz(j) }
            })
            .collect()
    }

    #[test]
    fn zero_decode_error_recovers_exact_gradient_sum() {
        // FRC with no stragglers: optimal decode is exact, so the
        // estimate equals the true sum [1..k].
        let (k, s) = (12usize, 3usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(0));
        let msgs = synthetic_messages(&g);
        let round = gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::Optimal,
            &LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 },
            &DeadlinePolicy::FastestR(k),
            &mut Rng::new(1),
            &mut DecodeWorkspace::new(),
        )
        .unwrap();
        assert!(round.decode_err < 1e-12, "err {}", round.decode_err);
        for i in 0..k {
            assert!(
                (round.estimate[i] - (i as f32 + 1.0)).abs() < 1e-4,
                "coord {i}: {}",
                round.estimate[i]
            );
        }
    }

    #[test]
    fn estimate_error_bounded_by_decode_error() {
        // |f^T A x - f^T 1|^2 <= ||f||^2 err(A)  (paper eq. 2.3).
        let (k, s) = (20usize, 5usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(2));
        let msgs = synthetic_messages(&g);
        let round = gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::OneStep,
            &LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 },
            &DeadlinePolicy::FastestR(15),
            &mut Rng::new(3),
            &mut DecodeWorkspace::new(),
        )
        .unwrap();
        let f_norm_sq: f64 = (1..=k).map(|i| (i * i) as f64).sum();
        let true_sum: f64 = (1..=k).map(|i| i as f64).sum();
        let est_sum: f64 = round.estimate.iter().map(|&v| v as f64).sum();
        // The component-wise estimate error is f-weighted; check the
        // aggregate inequality with f = identity basis reading.
        let err = (est_sum - true_sum).powi(2);
        assert!(
            err <= f_norm_sq * round.decode_err + 1e-6,
            "estimate err {err} > bound {}",
            f_norm_sq * round.decode_err
        );
    }

    #[test]
    fn survivor_count_respects_policy() {
        let (k, s) = (10usize, 2usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(4));
        let msgs = synthetic_messages(&g);
        let round = gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::OneStep,
            &LatencyModel::Pareto { scale: 0.1, shape: 1.5 },
            &DeadlinePolicy::FastestR(6),
            &mut Rng::new(5),
            &mut DecodeWorkspace::new(),
        )
        .unwrap();
        assert_eq!(round.non_stragglers.len(), 6);
        assert_eq!(round.weights.len(), 6);
    }

    #[test]
    fn message_count_mismatch_errors() {
        let (k, s) = (10usize, 2usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(6));
        let msgs = synthetic_messages(&g)[..5].to_vec();
        assert!(gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::OneStep,
            &LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 },
            &DeadlinePolicy::FastestR(5),
            &mut Rng::new(7),
            &mut DecodeWorkspace::new(),
        )
        .is_err());
    }

    #[test]
    fn malformed_payload_is_blamed_by_index_before_any_decode_work() {
        let (k, s) = (12usize, 3usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(10));
        let mut msgs = synthetic_messages(&g);
        msgs[7].payload.pop(); // worker 7 ships a short gradient
        let mut rng = Rng::new(11);
        let err = gather_and_decode(
            &g,
            s,
            &msgs,
            DecoderKind::Optimal,
            &LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 },
            &DeadlinePolicy::FastestR(k),
            &mut rng,
            &mut DecodeWorkspace::new(),
        )
        .unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("message 7"), "the malformed worker must be named: {text}");
        // The validation fired before the straggler draw: the caller's
        // rng stream is untouched (still equal to a fresh one).
        assert_eq!(rng.next_u64(), Rng::new(11).next_u64());
    }

    #[test]
    fn workspace_round_matches_historical_allocating_path_bitwise() {
        // The pre-port sequence: sample_round -> select_columns ->
        // Decoder::weights -> decode_error. The workspace round must
        // reproduce every output bit for bit, RNG stream included.
        use crate::decode::{Decoder, OptimalDecoder};
        use crate::stragglers::sample_round;
        let (k, s) = (18usize, 3usize);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(8));
        let msgs = synthetic_messages(&g);
        let latency = LatencyModel::ShiftedExp { base: 0.01, rate: 5.0 };
        let deadline = DeadlinePolicy::FastestR(13);
        for decoder in [DecoderKind::OneStep, DecoderKind::Optimal] {
            let mut rng_ref = Rng::new(9);
            let sample = sample_round(&latency, &deadline, g.cols, &mut rng_ref);
            let a = g.select_columns(&sample.non_stragglers);
            let r = sample.non_stragglers.len();
            let weights_ref = match decoder {
                DecoderKind::OneStep => OneStepDecoder::canonical(k, r, s).weights(&a),
                DecoderKind::Optimal => OptimalDecoder::new().weights(&a),
            };
            let err_ref = crate::decode::decode_error(&a, &weights_ref);

            let mut rng = Rng::new(9);
            let round = gather_and_decode(
                &g,
                s,
                &msgs,
                decoder,
                &latency,
                &deadline,
                &mut rng,
                &mut DecodeWorkspace::new(),
            )
            .unwrap();
            assert_eq!(round.non_stragglers, sample.non_stragglers, "{decoder:?}");
            assert_eq!(round.gather_time.to_bits(), sample.gather_time.to_bits());
            assert_eq!(round.weights.len(), weights_ref.len(), "{decoder:?}");
            for (w, w_ref) in round.weights.iter().zip(&weights_ref) {
                assert_eq!(w.to_bits(), w_ref.to_bits(), "{decoder:?}");
            }
            assert_eq!(round.decode_err.to_bits(), err_ref.to_bits(), "{decoder:?}");
            // The two rngs must have consumed the same stream.
            assert_eq!(rng.f64().to_bits(), rng_ref.f64().to_bits());
        }
    }
}
