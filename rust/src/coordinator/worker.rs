//! Worker-side logic: task lists from G columns and coded messages.
//!
//! Worker j computes the gradients of the tasks in column j of G and
//! sends back ONE vector — the linear combination with its column's
//! coefficients (computed by the AOT `combine_*` artifact, so the
//! message construction itself exercises the L1 kernel).

use anyhow::{bail, Result};

use crate::linalg::CscMatrix;
use crate::runtime::{Backend, CombineKind};
use crate::training::data::Shard;

/// Worker j's standing assignment (column j of G).
#[derive(Clone, Debug, Default)]
pub struct WorkerSpec {
    pub id: usize,
    pub tasks: Vec<usize>,
    pub coeffs: Vec<f64>,
}

/// Decompose an assignment matrix into per-worker specs.
pub fn specs_from_assignment(g: &CscMatrix) -> Vec<WorkerSpec> {
    (0..g.cols)
        .map(|j| {
            let (tasks, coeffs): (Vec<usize>, Vec<f64>) = g.col(j).unzip();
            WorkerSpec { id: j, tasks, coeffs }
        })
        .collect()
}

/// One worker's round output.
#[derive(Clone, Debug, Default)]
pub struct Message {
    pub worker: usize,
    /// The coded linear combination of its task gradients.
    pub payload: Vec<f32>,
    /// Sum of per-task losses (MLP model; 0 for linear).
    pub loss_sum: f64,
    /// Number of tasks this worker computed.
    pub tasks_done: usize,
}

/// Which model the workers are differentiating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Linear,
    Mlp,
}

/// How the worker round is dispatched to the backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MessagePath {
    /// One fused dispatch per worker (msg_* artifacts) — the §Perf
    /// optimized path; falls back to PerTask if artifacts lack it.
    Fused,
    /// s + 1 dispatches per worker (grad_* per task + combine_*).
    PerTask,
}

/// Compute worker `spec`'s coded message for the current params.
///
/// Stacks the task gradients into (s_max, d) buffers (zero-padded, zero
/// coefficients for unused rows) and runs the combine artifact. Workers
/// with more than s_max tasks (possible for BGC, whose column degrees
/// are Binomial with tail above the mean s) process their task list in
/// s_max-sized chunks and sum the partial combines — the message is
/// identical, only the kernel is invoked ⌈tasks/s_max⌉ times.
pub fn compute_message(
    backend: &Backend,
    model: ModelKind,
    params: &[f32],
    shards: &[Shard],
    spec: &WorkerSpec,
) -> Result<Message> {
    compute_message_via(backend, model, params, shards, spec, MessagePath::Fused)
}

/// `compute_message` with an explicit dispatch path (benchmarks compare
/// the two; production uses Fused when available).
pub fn compute_message_via(
    backend: &Backend,
    model: ModelKind,
    params: &[f32],
    shards: &[Shard],
    spec: &WorkerSpec,
    path: MessagePath,
) -> Result<Message> {
    if path == MessagePath::Fused && backend.has_fused_message() {
        return compute_message_fused(backend, model, params, shards, spec);
    }
    compute_message_pertask(backend, model, params, shards, spec)
}

/// Fused path: chunk the task list into s_max groups, one backend
/// dispatch per chunk (typically exactly one).
fn compute_message_fused(
    backend: &Backend,
    model: ModelKind,
    params: &[f32],
    shards: &[Shard],
    spec: &WorkerSpec,
) -> Result<Message> {
    let s_max = backend.s_max();
    let (xdim, ydim, d) = match model {
        ModelKind::Linear => {
            let l = backend.linear_dims();
            (l.m * l.d, l.m, l.d)
        }
        ModelKind::Mlp => {
            let m = backend.mlp_dims();
            (m.m * m.d_in, m.m * m.d_out, m.flat_dim)
        }
    };

    let mut payload = vec![0.0f32; d];
    let mut loss_sum = 0.0f64;
    let positions: Vec<usize> = (0..spec.tasks.len()).collect();
    for chunk in positions.chunks(s_max.max(1)) {
        let mut xs = vec![0.0f32; s_max * xdim];
        let mut ys = vec![0.0f32; s_max * ydim];
        let mut coeffs = vec![0.0f32; s_max];
        for (slot, &pos) in chunk.iter().enumerate() {
            let (task, coeff) = (spec.tasks[pos], spec.coeffs[pos]);
            if task >= shards.len() {
                bail!("worker {}: task {task} out of range", spec.id);
            }
            let shard = &shards[task];
            xs[slot * xdim..(slot + 1) * xdim].copy_from_slice(&shard.x);
            ys[slot * ydim..(slot + 1) * ydim].copy_from_slice(&shard.y);
            coeffs[slot] = coeff as f32;
        }
        match model {
            ModelKind::Linear => {
                let partial = backend.linear_message(params, &xs, &ys, &coeffs)?;
                for (p, v) in payload.iter_mut().zip(&partial) {
                    *p += v;
                }
            }
            ModelKind::Mlp => {
                let (losses, partial) = backend.mlp_message(params, &xs, &ys, &coeffs)?;
                for slot in 0..chunk.len() {
                    loss_sum += losses[slot] as f64;
                }
                for (p, v) in payload.iter_mut().zip(&partial) {
                    *p += v;
                }
            }
        }
    }
    Ok(Message { worker: spec.id, payload, loss_sum, tasks_done: spec.tasks.len() })
}

/// Per-task path (s + 1 dispatches): kept for benchmarking and as the
/// fallback when artifacts predate the fused modules.
fn compute_message_pertask(
    backend: &Backend,
    model: ModelKind,
    params: &[f32],
    shards: &[Shard],
    spec: &WorkerSpec,
) -> Result<Message> {
    let s_max = backend.s_max();
    let (d, kind) = match model {
        ModelKind::Linear => (backend.linear_dims().d, CombineKind::Linear),
        ModelKind::Mlp => (backend.mlp_dims().flat_dim, CombineKind::Mlp),
    };

    let mut payload = vec![0.0f32; d];
    let mut loss_sum = 0.0f64;
    let chunks: Vec<usize> = (0..spec.tasks.len()).collect();
    for chunk in chunks.chunks(s_max.max(1)) {
        let mut stacked = vec![0.0f32; s_max * d];
        let mut coeffs = vec![0.0f32; s_max];
        for (slot, &pos) in chunk.iter().enumerate() {
            let (task, coeff) = (spec.tasks[pos], spec.coeffs[pos]);
            if task >= shards.len() {
                bail!("worker {}: task {task} out of range", spec.id);
            }
            let shard = &shards[task];
            let grad = match model {
                ModelKind::Linear => backend.linear_grad(&shard.x, params, &shard.y)?,
                ModelKind::Mlp => {
                    let (loss, grad) = backend.mlp_grad(params, &shard.x, &shard.y)?;
                    loss_sum += loss as f64;
                    grad
                }
            };
            stacked[slot * d..(slot + 1) * d].copy_from_slice(&grad);
            coeffs[slot] = coeff as f32;
        }
        let partial = backend.combine(kind, &stacked, &coeffs)?;
        for (p, v) in payload.iter_mut().zip(&partial) {
            *p += v;
        }
    }

    Ok(Message {
        worker: spec.id,
        payload,
        loss_sum,
        tasks_done: spec.tasks.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{FractionalRepetitionCode, GradientCode};
    use crate::runtime::{LinearDims, MlpDims};
    use crate::training::data::LinearDataset;
    use crate::util::Rng;

    fn backend() -> Backend {
        Backend::Native {
            linear: LinearDims { m: 8, d: 4 },
            mlp: MlpDims { m: 4, d_in: 3, d_hidden: 4, d_out: 2, flat_dim: 3 * 4 + 4 + 4 * 2 + 2 },
            s_max: 4,
        }
    }

    #[test]
    fn specs_mirror_columns() {
        let g = FractionalRepetitionCode::new(8, 8, 2).assignment(&mut Rng::new(0));
        let specs = specs_from_assignment(&g);
        assert_eq!(specs.len(), 8);
        assert_eq!(specs[0].tasks, vec![0, 1]);
        assert_eq!(specs[3].tasks, vec![2, 3]);
        assert!(specs.iter().all(|s| s.coeffs.iter().all(|&c| c == 1.0)));
    }

    #[test]
    fn message_is_sum_of_task_gradients_for_boolean_code() {
        let b = backend();
        let dims = b.linear_dims();
        let mut rng = Rng::new(1);
        let ds = LinearDataset::generate(dims, 4, 0.1, &mut rng);
        let params = vec![0.1f32; dims.d];
        let spec = WorkerSpec { id: 0, tasks: vec![1, 3], coeffs: vec![1.0, 1.0] };
        let msg =
            compute_message(&b, ModelKind::Linear, &params, &ds.shards, &spec).unwrap();
        let g1 = b.linear_grad(&ds.shards[1].x, &params, &ds.shards[1].y).unwrap();
        let g3 = b.linear_grad(&ds.shards[3].x, &params, &ds.shards[3].y).unwrap();
        for i in 0..dims.d {
            assert!((msg.payload[i] - (g1[i] + g3[i])).abs() < 1e-5);
        }
        assert_eq!(msg.tasks_done, 2);
    }

    #[test]
    fn mlp_message_accumulates_loss() {
        let b = backend();
        let dims = b.mlp_dims();
        let mut rng = Rng::new(2);
        let ds = crate::training::data::MlpDataset::generate(dims, 3, &mut rng);
        let params = vec![0.05f32; dims.flat_dim];
        let spec = WorkerSpec { id: 1, tasks: vec![0, 2], coeffs: vec![1.0, 1.0] };
        let msg = compute_message(&b, ModelKind::Mlp, &params, &ds.shards, &spec).unwrap();
        assert!(msg.loss_sum > 0.0);
        assert_eq!(msg.payload.len(), dims.flat_dim);
    }

    #[test]
    fn more_tasks_than_s_max_chunks_correctly() {
        // 6 tasks with s_max = 4: two combine chunks, same message as
        // summing all task gradients directly.
        let b = backend();
        let dims = b.linear_dims();
        let ds = LinearDataset::generate(dims, 6, 0.1, &mut Rng::new(3));
        let params = vec![0.2f32; dims.d];
        let spec = WorkerSpec { id: 0, tasks: (0..6).collect(), coeffs: vec![1.0; 6] };
        let msg =
            compute_message(&b, ModelKind::Linear, &params, &ds.shards, &spec).unwrap();
        let mut want = vec![0.0f32; dims.d];
        for t in 0..6 {
            let g = b.linear_grad(&ds.shards[t].x, &params, &ds.shards[t].y).unwrap();
            for (w, v) in want.iter_mut().zip(&g) {
                *w += v;
            }
        }
        for (a, w) in msg.payload.iter().zip(&want) {
            assert!((a - w).abs() < 1e-5);
        }
        assert_eq!(msg.tasks_done, 6);
    }

    #[test]
    fn out_of_range_task_errors() {
        let b = backend();
        let ds = LinearDataset::generate(b.linear_dims(), 2, 0.0, &mut Rng::new(4));
        let spec = WorkerSpec { id: 0, tasks: vec![5], coeffs: vec![1.0] };
        assert!(compute_message(&b, ModelKind::Linear, &[0.0; 4], &ds.shards, &spec).is_err());
    }
}
