//! Latency-based stragglers: workers draw completion times from a
//! latency distribution; the master's deadline policy decides who counts
//! as a non-straggler. This is the mechanism behind the paper's
//! abstract straggler model (see DESIGN.md §Hardware-Adaptation); the
//! e2e coordinator uses it round by round, and the scenario spine
//! ([`super::scenario`]) threads it through the Monte-Carlo decode
//! pipeline and the `repro scenario` time-to-accuracy sweeps.

use super::{StragglerModel, StragglerScratch};
use crate::util::Rng;

/// Worker completion-time distributions (seconds).
#[derive(Clone, Copy, Debug)]
pub enum LatencyModel {
    /// base + Exp(rate): light-tailed service times.
    ShiftedExp { base: f64, rate: f64 },
    /// Pareto(x_m, alpha): heavy-tailed — the classic straggler regime.
    Pareto { scale: f64, shape: f64 },
    /// Bimodal: fast with prob 1-p, slow (straggler) with prob p —
    /// models the "attack of the clones" scenario [1].
    Bimodal { fast: f64, slow: f64, p_slow: f64 },
}

impl LatencyModel {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            LatencyModel::ShiftedExp { base, rate } => base + rng.exp(rate),
            LatencyModel::Pareto { scale, shape } => rng.pareto(scale, shape),
            LatencyModel::Bimodal { fast, slow, p_slow } => {
                if rng.bernoulli(p_slow) {
                    slow
                } else {
                    fast
                }
            }
        }
    }

    /// Closed-form quantile (inverse CDF) at probability `p` in [0, 1):
    /// the deadline that admits a fraction `p` of workers in
    /// expectation. Deterministic — the `repro scenario` deadline sweep
    /// derives its grid from it, so the sweep is part of the run
    /// identity rather than an empirical estimate.
    pub fn quantile(&self, p: f64) -> f64 {
        assert!((0.0..1.0).contains(&p), "quantile needs p in [0, 1), got {p}");
        match *self {
            LatencyModel::ShiftedExp { base, rate } => base - (1.0 - p).ln() / rate,
            LatencyModel::Pareto { scale, shape } => scale / (1.0 - p).powf(1.0 / shape),
            LatencyModel::Bimodal { fast, slow, p_slow } => {
                if p < 1.0 - p_slow {
                    fast
                } else {
                    slow
                }
            }
        }
    }

    /// Closed-form CDF: the probability a worker finishes by time `t`
    /// — equivalently, the expected fraction of non-stragglers under a
    /// fixed deadline `t`. Inverse of [`quantile`](Self::quantile) on
    /// the continuous families; the `latparam` study uses it to map a
    /// swept latency model to the expected survivor count at a fixed
    /// deadline.
    pub fn cdf(&self, t: f64) -> f64 {
        match *self {
            LatencyModel::ShiftedExp { base, rate } => {
                if t < base {
                    0.0
                } else {
                    1.0 - (-rate * (t - base)).exp()
                }
            }
            LatencyModel::Pareto { scale, shape } => {
                if t < scale {
                    0.0
                } else {
                    1.0 - (scale / t).powf(shape)
                }
            }
            LatencyModel::Bimodal { fast, slow, p_slow } => {
                let mut p = 0.0;
                if t >= fast {
                    p += 1.0 - p_slow;
                }
                if t >= slow {
                    p += p_slow;
                }
                p
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LatencyModel::ShiftedExp { .. } => "shifted-exp",
            LatencyModel::Pareto { .. } => "pareto",
            LatencyModel::Bimodal { .. } => "bimodal",
        }
    }
}

/// When does the master stop waiting?
#[derive(Clone, Copy, Debug)]
pub enum DeadlinePolicy {
    /// Fixed wall-clock deadline.
    Fixed(f64),
    /// Wait for the fastest r workers (order-statistic gather).
    FastestR(usize),
}

/// Latencies + the induced non-straggler set for one round.
#[derive(Clone, Debug)]
pub struct LatencySample {
    pub latencies: Vec<f64>,
    pub non_stragglers: Vec<usize>,
    /// The effective gather time (when the master stopped waiting).
    pub gather_time: f64,
}

/// Draw one round of latencies and apply the deadline policy.
pub fn sample_round(
    model: &LatencyModel,
    policy: &DeadlinePolicy,
    n: usize,
    rng: &mut Rng,
) -> LatencySample {
    let latencies: Vec<f64> = (0..n).map(|_| model.sample(rng)).collect();
    let (non_stragglers, gather_time) = match *policy {
        DeadlinePolicy::Fixed(deadline) => {
            let ns: Vec<usize> =
                (0..n).filter(|&i| latencies[i] <= deadline).collect();
            (ns, deadline)
        }
        DeadlinePolicy::FastestR(r) => {
            let r = r.clamp(1, n);
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| latencies[a].partial_cmp(&latencies[b]).unwrap());
            let mut ns = order[..r].to_vec();
            let gather = latencies[order[r - 1]];
            ns.sort_unstable();
            (ns, gather)
        }
    };
    LatencySample { latencies, non_stragglers, gather_time }
}

/// A latency-driven straggler model (adapts `sample_round` to the
/// `StragglerModel` trait for the Monte-Carlo harness).
#[derive(Clone, Copy, Debug)]
pub struct LatencyStragglers {
    pub model: LatencyModel,
    pub policy: DeadlinePolicy,
}

impl StragglerModel for LatencyStragglers {
    fn non_stragglers(&self, n: usize, rng: &mut Rng) -> Vec<usize> {
        sample_round(&self.model, &self.policy, n, rng).non_stragglers
    }

    /// Allocation-free [`sample_round`]: identical RNG stream (n model
    /// draws) and identical survivor set + gather time, draw for draw.
    /// Ties in the fastest-r order statistic break by worker index —
    /// exactly what `sample_round`'s stable sort does — so the two
    /// paths agree even for the tie-heavy Bimodal model (pinned below).
    fn non_stragglers_into(&self, n: usize, rng: &mut Rng, ws: &mut StragglerScratch) {
        ws.latencies.clear();
        for _ in 0..n {
            ws.latencies.push(self.model.sample(rng));
        }
        let StragglerScratch { idx, latencies, order, gather_time, .. } = ws;
        match self.policy {
            DeadlinePolicy::Fixed(deadline) => {
                idx.clear();
                idx.extend((0..n).filter(|&i| latencies[i] <= deadline));
                *gather_time = deadline;
            }
            DeadlinePolicy::FastestR(r) => {
                let r = r.clamp(1, n);
                order.clear();
                order.extend(0..n);
                // Unstable in-place sort (no merge-sort scratch buffer);
                // the (latency, index) key makes it deterministic and
                // equal to sample_round's stable latency-only sort.
                order.sort_unstable_by(|&a, &b| {
                    latencies[a]
                        .partial_cmp(&latencies[b])
                        .expect("latency draws are finite")
                        .then(a.cmp(&b))
                });
                *gather_time = latencies[order[r - 1]];
                idx.clear();
                idx.extend_from_slice(&order[..r]);
                idx.sort_unstable();
            }
        }
    }

    fn name(&self) -> &'static str {
        "latency"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_inverts_quantile_on_the_continuous_families() {
        for m in [
            LatencyModel::ShiftedExp { base: 0.02, rate: 5.0 },
            LatencyModel::Pareto { scale: 0.02, shape: 1.5 },
        ] {
            for p in [0.0, 0.1, 0.5, 0.8, 0.99] {
                let t = m.quantile(p);
                assert!(
                    (m.cdf(t) - p).abs() < 1e-12,
                    "{}: cdf(quantile({p})) = {}",
                    m.name(),
                    m.cdf(t)
                );
            }
            assert_eq!(m.cdf(0.0), 0.0, "{}: nothing finishes at t=0", m.name());
        }
        let b = LatencyModel::Bimodal { fast: 0.1, slow: 10.0, p_slow: 0.3 };
        assert_eq!(b.cdf(0.05), 0.0);
        assert!((b.cdf(1.0) - 0.7).abs() < 1e-12);
        assert!((b.cdf(20.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fastest_r_returns_exactly_r() {
        let m = LatencyModel::ShiftedExp { base: 0.1, rate: 2.0 };
        let mut rng = Rng::new(1);
        let s = sample_round(&m, &DeadlinePolicy::FastestR(30), 100, &mut rng);
        assert_eq!(s.non_stragglers.len(), 30);
        // Gather time = r-th order statistic; all non-stragglers <= it.
        for &i in &s.non_stragglers {
            assert!(s.latencies[i] <= s.gather_time);
        }
    }

    #[test]
    fn fixed_deadline_filters() {
        let m = LatencyModel::Bimodal { fast: 0.1, slow: 10.0, p_slow: 0.3 };
        let mut rng = Rng::new(2);
        let s = sample_round(&m, &DeadlinePolicy::Fixed(1.0), 200, &mut rng);
        // All fast workers respond, all slow ones straggle.
        for i in 0..200 {
            let is_ns = s.non_stragglers.binary_search(&i).is_ok();
            assert_eq!(is_ns, s.latencies[i] <= 1.0);
        }
        // ~70% fast
        let frac = s.non_stragglers.len() as f64 / 200.0;
        assert!((frac - 0.7).abs() < 0.12, "{frac}");
    }

    #[test]
    fn pareto_produces_heavy_tail() {
        let m = LatencyModel::Pareto { scale: 1.0, shape: 1.2 };
        let mut rng = Rng::new(3);
        let lats: Vec<f64> = (0..10_000).map(|_| m.sample(&mut rng)).collect();
        let max = lats.iter().cloned().fold(0.0, f64::max);
        let med = {
            let mut v = lats.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[5000]
        };
        assert!(max / med > 50.0, "tail ratio {}", max / med);
    }

    #[test]
    fn fastest_r_clamps() {
        let m = LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 };
        let mut rng = Rng::new(4);
        let s = sample_round(&m, &DeadlinePolicy::FastestR(500), 10, &mut rng);
        assert_eq!(s.non_stragglers.len(), 10);
    }

    /// Seeded empirical quantiles vs the closed-form inverse CDF, for
    /// all three models (the distribution sanity check behind the
    /// `repro scenario` deadline grid).
    #[test]
    fn sampled_quantiles_match_closed_form() {
        let models = [
            LatencyModel::ShiftedExp { base: 0.1, rate: 2.0 },
            LatencyModel::Pareto { scale: 0.5, shape: 2.5 },
        ];
        let trials = 40_000usize;
        for (mi, m) in models.iter().enumerate() {
            let mut rng = Rng::new(100 + mi as u64);
            let mut lats: Vec<f64> = (0..trials).map(|_| m.sample(&mut rng)).collect();
            lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for &q in &[0.1, 0.25, 0.5, 0.75, 0.9] {
                let expected = m.quantile(q);
                let got = lats[(q * trials as f64) as usize];
                assert!(
                    (got - expected).abs() <= 0.05 * expected.abs().max(0.05),
                    "{} q={q}: sampled {got} vs quantile {expected}",
                    m.name()
                );
            }
        }
        // Bimodal: quantile is a step function; check both branches and
        // the empirical mass below the step.
        let m = LatencyModel::Bimodal { fast: 0.1, slow: 5.0, p_slow: 0.3 };
        assert_eq!(m.quantile(0.5), 0.1);
        assert_eq!(m.quantile(0.8), 5.0);
        let mut rng = Rng::new(200);
        let fast_frac = (0..trials)
            .filter(|_| m.sample(&mut rng) <= 0.1)
            .count() as f64
            / trials as f64;
        assert!((fast_frac - 0.7).abs() < 0.02, "{fast_frac}");
    }

    /// Monotonicity + support sanity of the quantile functions.
    #[test]
    fn quantiles_are_monotone_and_respect_support() {
        let m = LatencyModel::ShiftedExp { base: 0.2, rate: 3.0 };
        assert_eq!(m.quantile(0.0), 0.2);
        let p = LatencyModel::Pareto { scale: 1.5, shape: 1.1 };
        assert_eq!(p.quantile(0.0), 1.5);
        for model in [m, p] {
            let mut prev = f64::NEG_INFINITY;
            for i in 0..18 {
                let q = model.quantile(i as f64 * 0.05);
                assert!(q >= prev, "{}: not monotone at {i}", model.name());
                prev = q;
            }
        }
    }

    /// The scratch draw is sample_round, draw for draw: same RNG
    /// consumption, same survivors, same gather time — including the
    /// tie-heavy Bimodal × fastest-r case where only the stable tie
    /// order keeps the two paths aligned.
    #[test]
    fn scratch_draw_matches_sample_round_exactly() {
        use crate::stragglers::StragglerScratch;
        let models = [
            LatencyModel::ShiftedExp { base: 0.1, rate: 2.0 },
            LatencyModel::Pareto { scale: 0.5, shape: 1.5 },
            LatencyModel::Bimodal { fast: 0.1, slow: 10.0, p_slow: 0.4 },
        ];
        let policies =
            [DeadlinePolicy::Fixed(0.6), DeadlinePolicy::FastestR(13), DeadlinePolicy::FastestR(99)];
        let mut ws = StragglerScratch::new();
        for (mi, &model) in models.iter().enumerate() {
            for (pi, &policy) in policies.iter().enumerate() {
                let m = LatencyStragglers { model, policy };
                let mut rng_a = Rng::new(300 + (mi * 7 + pi) as u64);
                let mut rng_b = rng_a.clone();
                for _ in 0..10 {
                    let sample = sample_round(&model, &policy, 40, &mut rng_a);
                    m.non_stragglers_into(40, &mut rng_b, &mut ws);
                    assert_eq!(ws.idx, sample.non_stragglers, "{} policy {pi}", model.name());
                    assert_eq!(
                        ws.gather_time.to_bits(),
                        sample.gather_time.to_bits(),
                        "{} policy {pi}",
                        model.name()
                    );
                }
                assert_eq!(rng_a.next_u64(), rng_b.next_u64());
            }
        }
    }
}
