//! Latency-based stragglers: workers draw completion times from a
//! latency distribution; the master's deadline policy decides who counts
//! as a non-straggler. This is the mechanism behind the paper's
//! abstract straggler model (see DESIGN.md §Hardware-Adaptation) and is
//! what the e2e coordinator uses.

use super::StragglerModel;
use crate::util::Rng;

/// Worker completion-time distributions (seconds).
#[derive(Clone, Copy, Debug)]
pub enum LatencyModel {
    /// base + Exp(rate): light-tailed service times.
    ShiftedExp { base: f64, rate: f64 },
    /// Pareto(x_m, alpha): heavy-tailed — the classic straggler regime.
    Pareto { scale: f64, shape: f64 },
    /// Bimodal: fast with prob 1-p, slow (straggler) with prob p —
    /// models the "attack of the clones" scenario [1].
    Bimodal { fast: f64, slow: f64, p_slow: f64 },
}

impl LatencyModel {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            LatencyModel::ShiftedExp { base, rate } => base + rng.exp(rate),
            LatencyModel::Pareto { scale, shape } => rng.pareto(scale, shape),
            LatencyModel::Bimodal { fast, slow, p_slow } => {
                if rng.bernoulli(p_slow) {
                    slow
                } else {
                    fast
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LatencyModel::ShiftedExp { .. } => "shifted-exp",
            LatencyModel::Pareto { .. } => "pareto",
            LatencyModel::Bimodal { .. } => "bimodal",
        }
    }
}

/// When does the master stop waiting?
#[derive(Clone, Copy, Debug)]
pub enum DeadlinePolicy {
    /// Fixed wall-clock deadline.
    Fixed(f64),
    /// Wait for the fastest r workers (order-statistic gather).
    FastestR(usize),
}

/// Latencies + the induced non-straggler set for one round.
#[derive(Clone, Debug)]
pub struct LatencySample {
    pub latencies: Vec<f64>,
    pub non_stragglers: Vec<usize>,
    /// The effective gather time (when the master stopped waiting).
    pub gather_time: f64,
}

/// Draw one round of latencies and apply the deadline policy.
pub fn sample_round(
    model: &LatencyModel,
    policy: &DeadlinePolicy,
    n: usize,
    rng: &mut Rng,
) -> LatencySample {
    let latencies: Vec<f64> = (0..n).map(|_| model.sample(rng)).collect();
    let (non_stragglers, gather_time) = match *policy {
        DeadlinePolicy::Fixed(deadline) => {
            let ns: Vec<usize> =
                (0..n).filter(|&i| latencies[i] <= deadline).collect();
            (ns, deadline)
        }
        DeadlinePolicy::FastestR(r) => {
            let r = r.clamp(1, n);
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| latencies[a].partial_cmp(&latencies[b]).unwrap());
            let mut ns = order[..r].to_vec();
            let gather = latencies[order[r - 1]];
            ns.sort_unstable();
            (ns, gather)
        }
    };
    LatencySample { latencies, non_stragglers, gather_time }
}

/// A latency-driven straggler model (adapts `sample_round` to the
/// `StragglerModel` trait for the Monte-Carlo harness).
#[derive(Clone, Copy, Debug)]
pub struct LatencyStragglers {
    pub model: LatencyModel,
    pub policy: DeadlinePolicy,
}

impl StragglerModel for LatencyStragglers {
    fn non_stragglers(&self, n: usize, rng: &mut Rng) -> Vec<usize> {
        sample_round(&self.model, &self.policy, n, rng).non_stragglers
    }

    fn name(&self) -> &'static str {
        "latency"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fastest_r_returns_exactly_r() {
        let m = LatencyModel::ShiftedExp { base: 0.1, rate: 2.0 };
        let mut rng = Rng::new(1);
        let s = sample_round(&m, &DeadlinePolicy::FastestR(30), 100, &mut rng);
        assert_eq!(s.non_stragglers.len(), 30);
        // Gather time = r-th order statistic; all non-stragglers <= it.
        for &i in &s.non_stragglers {
            assert!(s.latencies[i] <= s.gather_time);
        }
    }

    #[test]
    fn fixed_deadline_filters() {
        let m = LatencyModel::Bimodal { fast: 0.1, slow: 10.0, p_slow: 0.3 };
        let mut rng = Rng::new(2);
        let s = sample_round(&m, &DeadlinePolicy::Fixed(1.0), 200, &mut rng);
        // All fast workers respond, all slow ones straggle.
        for i in 0..200 {
            let is_ns = s.non_stragglers.binary_search(&i).is_ok();
            assert_eq!(is_ns, s.latencies[i] <= 1.0);
        }
        // ~70% fast
        let frac = s.non_stragglers.len() as f64 / 200.0;
        assert!((frac - 0.7).abs() < 0.12, "{frac}");
    }

    #[test]
    fn pareto_produces_heavy_tail() {
        let m = LatencyModel::Pareto { scale: 1.0, shape: 1.2 };
        let mut rng = Rng::new(3);
        let lats: Vec<f64> = (0..10_000).map(|_| m.sample(&mut rng)).collect();
        let max = lats.iter().cloned().fold(0.0, f64::max);
        let med = {
            let mut v = lats.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[5000]
        };
        assert!(max / med > 50.0, "tail ratio {}", max / med);
    }

    #[test]
    fn fastest_r_clamps() {
        let m = LatencyModel::ShiftedExp { base: 0.0, rate: 1.0 };
        let mut rng = Rng::new(4);
        let s = sample_round(&m, &DeadlinePolicy::FastestR(500), 10, &mut rng);
        assert_eq!(s.non_stragglers.len(), 10);
    }
}
