//! Adversarial straggler model: an attacker controls who straggles.
//!
//! Bridges `crate::adversary` into the coordinator, modelling the §4
//! threat: a scheduler-level adversary (or a worst-case network) that
//! delays exactly the workers whose loss hurts decoding most. The
//! worst-case set is computed ONCE against G (the adversary knows the
//! code, not the data) and replayed every round — matching the paper's
//! standing-assignment setting.

use super::{StragglerModel, StragglerScratch};
use crate::adversary::{frc_worst_stragglers, greedy_stragglers, local_search_stragglers};
use crate::linalg::CscMatrix;
use crate::util::Rng;

/// Which attack the adversary mounts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttackKind {
    /// Thm-10 block attack (linear time; devastating on FRC).
    BlockAttack,
    /// Greedy column removal on the one-step objective.
    Greedy,
    /// Greedy + 1-swap local search.
    LocalSearch,
}

impl AttackKind {
    /// The CLI/scenario token (`--stragglers adversarial:<token>`);
    /// round-trips through [`AttackKind::parse`].
    pub fn token(&self) -> &'static str {
        match self {
            AttackKind::BlockAttack => "block",
            AttackKind::Greedy => "greedy",
            AttackKind::LocalSearch => "local-search",
        }
    }

    pub fn parse(s: &str) -> Option<AttackKind> {
        match s {
            "block" => Some(AttackKind::BlockAttack),
            "greedy" => Some(AttackKind::Greedy),
            "local-search" => Some(AttackKind::LocalSearch),
            _ => None,
        }
    }
}

/// A straggler model that always returns the adversary's survivor set.
#[derive(Clone, Debug)]
pub struct AdversarialStragglers {
    survivors: Vec<usize>,
    kind: AttackKind,
}

impl AdversarialStragglers {
    /// Mount `kind` against assignment matrix `g`, keeping r survivors
    /// (i.e. the adversary delays the other n - r workers).
    pub fn plan(g: &CscMatrix, r: usize, s: usize, kind: AttackKind) -> Self {
        let rho = g.rows as f64 / (r as f64 * s as f64);
        let survivors = match kind {
            AttackKind::BlockAttack => frc_worst_stragglers(g, r),
            AttackKind::Greedy => greedy_stragglers(g, r, rho),
            AttackKind::LocalSearch => local_search_stragglers(g, r, rho, 3),
        };
        AdversarialStragglers { survivors, kind }
    }

    pub fn survivors(&self) -> &[usize] {
        &self.survivors
    }

    pub fn kind(&self) -> AttackKind {
        self.kind
    }
}

impl StragglerModel for AdversarialStragglers {
    fn non_stragglers(&self, n: usize, _rng: &mut Rng) -> Vec<usize> {
        assert!(self.survivors.iter().all(|&j| j < n), "attack planned for a different n");
        self.survivors.clone()
    }

    /// Replays the planned survivor set (in its planned order) without
    /// touching the RNG — the standing-assignment attack is the same
    /// every round.
    fn non_stragglers_into(&self, n: usize, _rng: &mut Rng, ws: &mut StragglerScratch) {
        assert!(self.survivors.iter().all(|&j| j < n), "attack planned for a different n");
        ws.idx.clear();
        ws.idx.extend_from_slice(&self.survivors);
        ws.gather_time = f64::NAN;
    }

    fn name(&self) -> &'static str {
        match self.kind {
            AttackKind::BlockAttack => "adversarial-block",
            AttackKind::Greedy => "adversarial-greedy",
            AttackKind::LocalSearch => "adversarial-local-search",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{GradientCode, Scheme};
    use crate::decode::OptimalDecoder;
    use crate::stragglers::UniformStragglers;

    #[test]
    fn block_attack_on_frc_forces_k_minus_r() {
        let (k, s, r) = (40usize, 5usize, 30usize);
        let g = Scheme::Frc.build(k, k, s).assignment(&mut Rng::new(1));
        let adv = AdversarialStragglers::plan(&g, r, s, AttackKind::BlockAttack);
        let mut rng = Rng::new(2);
        let ns = adv.non_stragglers(k, &mut rng);
        assert_eq!(ns.len(), r);
        let err = OptimalDecoder::new().err(&g.select_columns(&ns));
        assert!((err - (k - r) as f64).abs() < 1e-8, "{err}");
    }

    #[test]
    fn adversary_beats_random_on_every_code() {
        let (k, s, r) = (40usize, 5usize, 30usize);
        let mut rng = Rng::new(3);
        for scheme in [Scheme::Frc, Scheme::Bgc, Scheme::Cyclic] {
            let g = scheme.build(k, k, s).assignment(&mut rng);
            let adv = AdversarialStragglers::plan(&g, r, s, AttackKind::Greedy);
            let adv_err = OptimalDecoder::new()
                .err(&g.select_columns(&adv.non_stragglers(k, &mut rng)));
            let uni = UniformStragglers::new(0.25);
            let mut rand_err = 0.0;
            for _ in 0..30 {
                rand_err += OptimalDecoder::new()
                    .err(&g.select_columns(&uni.non_stragglers(k, &mut rng)));
            }
            rand_err /= 30.0;
            assert!(
                adv_err >= rand_err - 1e-9,
                "{}: adversarial {adv_err} < random {rand_err}",
                scheme.name()
            );
        }
    }

    #[test]
    fn replay_is_deterministic() {
        let (k, s, r) = (20usize, 4usize, 15usize);
        let g = Scheme::Bgc.build(k, k, s).assignment(&mut Rng::new(4));
        let adv = AdversarialStragglers::plan(&g, r, s, AttackKind::LocalSearch);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(99);
        assert_eq!(adv.non_stragglers(k, &mut r1), adv.non_stragglers(k, &mut r2));
    }

    #[test]
    fn scratch_replay_matches_planned_survivors() {
        use crate::stragglers::StragglerScratch;
        let g = Scheme::Bgc.build(16, 16, 3).assignment(&mut Rng::new(8));
        let adv = AdversarialStragglers::plan(&g, 12, 3, AttackKind::Greedy);
        let mut ws = StragglerScratch::new();
        let mut rng = Rng::new(9);
        let before = rng.clone().next_u64();
        adv.non_stragglers_into(16, &mut rng, &mut ws);
        assert_eq!(ws.idx, adv.survivors());
        assert!(ws.gather_time.is_nan());
        // The replay consumes no RNG.
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn attack_kind_tokens_round_trip() {
        for kind in [AttackKind::BlockAttack, AttackKind::Greedy, AttackKind::LocalSearch] {
            assert_eq!(AttackKind::parse(kind.token()), Some(kind));
        }
        assert_eq!(AttackKind::parse("nope"), None);
    }

    #[test]
    #[should_panic(expected = "different n")]
    fn wrong_n_panics() {
        let g = Scheme::Bgc.build(10, 10, 2).assignment(&mut Rng::new(6));
        let adv = AdversarialStragglers::plan(&g, 8, 2, AttackKind::Greedy);
        adv.non_stragglers(5, &mut Rng::new(7));
    }
}
