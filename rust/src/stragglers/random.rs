//! Uniformly random stragglers — the paper's average-case model:
//! the r = ceil((1-δ) n) non-stragglers are a uniform subset.

use super::{StragglerModel, StragglerScratch};
use crate::util::Rng;

#[derive(Clone, Copy, Debug)]
pub struct UniformStragglers {
    /// Straggler fraction δ in [0, 1).
    pub delta: f64,
}

impl UniformStragglers {
    pub fn new(delta: f64) -> Self {
        assert!((0.0..1.0).contains(&delta), "delta must be in [0, 1)");
        UniformStragglers { delta }
    }

    /// r = round((1-δ) n), clamped to [1, n].
    pub fn r(&self, n: usize) -> usize {
        (((1.0 - self.delta) * n as f64).round() as usize).clamp(1, n)
    }
}

impl StragglerModel for UniformStragglers {
    fn non_stragglers(&self, n: usize, rng: &mut Rng) -> Vec<usize> {
        let r = self.r(n);
        let mut idx = rng.sample_indices(n, r);
        idx.sort_unstable();
        idx
    }

    /// Exactly `Rng::sample_indices_into(n, r, ..)` — the identical RNG
    /// stream *and* output order as the pre-spine hard-coded sampling
    /// in `decode::DecodeWorkspace`, so the default uniform scenario
    /// reproduces every historical figure/table CSV byte-for-byte
    /// (pinned by `tests/decode_parity.rs`). Unsorted by contract; the
    /// decode pipeline's accumulation order is the draw order.
    fn non_stragglers_into(&self, n: usize, rng: &mut Rng, ws: &mut StragglerScratch) {
        let r = self.r(n);
        rng.sample_indices_into(n, r, &mut ws.pool, &mut ws.idx);
        ws.gather_time = f64::NAN;
    }

    fn name(&self) -> &'static str {
        "uniform-random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r_computation() {
        assert_eq!(UniformStragglers::new(0.0).r(100), 100);
        assert_eq!(UniformStragglers::new(0.25).r(100), 75);
        assert_eq!(UniformStragglers::new(0.99).r(100), 1);
    }

    #[test]
    fn subsets_are_uniformish() {
        // Each worker should be a non-straggler ~r/n of the time.
        let m = UniformStragglers::new(0.5);
        let mut rng = Rng::new(2);
        let mut counts = vec![0usize; 20];
        let trials = 20_000;
        for _ in 0..trials {
            for i in m.non_stragglers(20, &mut rng) {
                counts[i] += 1;
            }
        }
        for &c in &counts {
            let p = c as f64 / trials as f64;
            assert!((p - 0.5).abs() < 0.03, "inclusion prob {p}");
        }
    }

    #[test]
    #[should_panic(expected = "delta")]
    fn delta_one_rejected() {
        UniformStragglers::new(1.0);
    }

    #[test]
    fn scratch_draw_is_bitwise_sample_indices_into() {
        // The load-bearing pin of the scenario spine: the uniform
        // scratch draw IS the historical workspace sampling — same RNG
        // stream, same (unsorted) order.
        use crate::stragglers::StragglerScratch;
        let m = UniformStragglers::new(0.25);
        let mut ws = StragglerScratch::new();
        let (mut pool, mut out) = (Vec::new(), Vec::new());
        let mut rng_a = Rng::new(5);
        let mut rng_b = Rng::new(5);
        for _ in 0..20 {
            rng_a.sample_indices_into(40, m.r(40), &mut pool, &mut out);
            m.non_stragglers_into(40, &mut rng_b, &mut ws);
            assert_eq!(ws.idx, out);
        }
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }
}
