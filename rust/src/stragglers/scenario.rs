//! The straggler *scenario*: which [`StragglerModel`] family a whole
//! run uses, as part of the run identity.
//!
//! A [`Scenario`] is what the CLI's `--stragglers` flag names, what
//! `sim::shard::JobSpec` carries (and serializes into shard artifacts,
//! format v3 — older artifacts parse as the uniform default), and what
//! every figure/table/ablation sweep resolves into a concrete model per
//! sweep point via [`Scenario::resolve`]. The canonical string form
//! round-trips: `Scenario::parse(&s.to_string())` reproduces `s`
//! exactly (f64 parameters use Rust's shortest round-trip formatting).
//!
//! Grammar (the `--stragglers` flag):
//!
//! ```text
//! uniform                      the paper default: r = (1-δ)k uniform
//!                              survivors, δ from the sweep point
//! uniform:D                    fixed straggler fraction D (models a
//!                              misestimated δ: selection uses D, the
//!                              decoder stays configured for the sweep)
//! shifted-exp:BASE,RATE[,P]    latency draws base + Exp(rate)
//! pareto:SCALE,SHAPE[,P]       heavy-tailed Pareto latencies
//! bimodal:FAST,SLOW,PSLOW[,P]  two-mode latencies (clone stragglers)
//! adversarial:block|greedy|local-search
//!                              §4 adversary, standing assignment
//! P = fastest-r                wait for the point's r fastest (default)
//!   | deadline:T               fixed wall-clock deadline T
//! ```

use std::fmt;

use anyhow::{bail, Result};

use super::{
    AdversarialStragglers, AttackKind, DeadlinePolicy, LatencyModel, LatencyStragglers,
    StragglerModel, UniformStragglers,
};
use crate::codes::GradientCode;
use crate::linalg::CscMatrix;
use crate::util::Rng;

/// The deadline policy as specified in a scenario — `FastestR` is
/// parameterized by the sweep point's r at [`Scenario::resolve`] time
/// (a figure sweeps δ, so r varies per point), while `Deadline` carries
/// its wall-clock bound directly.
#[derive(Clone, Copy, Debug)]
pub enum PolicySpec {
    FastestR,
    Deadline(f64),
}

/// A straggler scenario: the model family one run draws its
/// non-straggler sets from. Part of the shard-run identity — two
/// artifacts merge only if their scenarios are identical (bitwise on
/// f64 parameters).
#[derive(Clone, Debug)]
pub enum Scenario {
    /// The paper's average-case model (and the default): r uniform
    /// survivors. `delta: None` takes δ from each sweep point —
    /// byte-identical to the pre-spine hard-coded sampling; `Some(d)`
    /// fixes the selection fraction at d regardless of the sweep.
    Uniform { delta: Option<f64> },
    /// Latency draws + deadline policy (the coordinator's mechanism,
    /// now available to every figure/table/ablation sweep).
    Latency { model: LatencyModel, policy: PolicySpec },
    /// The §4 adversary in the standing-assignment setting: G is drawn
    /// once per sweep point (seeded), the attack planned once against
    /// it, and every trial replays the planned survivor set.
    Adversarial { attack: AttackKind },
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario::Uniform { delta: None }
    }
}

impl PartialEq for Scenario {
    fn eq(&self, other: &Self) -> bool {
        use Scenario::*;
        match (self, other) {
            (Uniform { delta: a }, Uniform { delta: b }) => {
                a.map(f64::to_bits) == b.map(f64::to_bits)
            }
            (Latency { model: m1, policy: p1 }, Latency { model: m2, policy: p2 }) => {
                latency_model_bits(m1) == latency_model_bits(m2) && policy_bits(p1) == policy_bits(p2)
            }
            (Adversarial { attack: a }, Adversarial { attack: b }) => a == b,
            _ => false,
        }
    }
}

impl Eq for Scenario {}

fn latency_model_bits(m: &LatencyModel) -> (u8, u64, u64, u64) {
    match *m {
        LatencyModel::ShiftedExp { base, rate } => (0, base.to_bits(), rate.to_bits(), 0),
        LatencyModel::Pareto { scale, shape } => (1, scale.to_bits(), shape.to_bits(), 0),
        LatencyModel::Bimodal { fast, slow, p_slow } => {
            (2, fast.to_bits(), slow.to_bits(), p_slow.to_bits())
        }
    }
}

fn policy_bits(p: &PolicySpec) -> (u8, u64) {
    match *p {
        PolicySpec::FastestR => (0, 0),
        PolicySpec::Deadline(t) => (1, t.to_bits()),
    }
}

impl fmt::Display for Scenario {
    /// The canonical string form (what artifacts store and
    /// `shard_child_args` forwards). `fastest-r` is the policy default
    /// and is omitted, so the canonical form is a parse fixed point.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Scenario::Uniform { delta: None } => write!(f, "uniform"),
            Scenario::Uniform { delta: Some(d) } => write!(f, "uniform:{d}"),
            Scenario::Latency { model, policy } => {
                match *model {
                    LatencyModel::ShiftedExp { base, rate } => {
                        write!(f, "shifted-exp:{base},{rate}")?
                    }
                    LatencyModel::Pareto { scale, shape } => write!(f, "pareto:{scale},{shape}")?,
                    LatencyModel::Bimodal { fast, slow, p_slow } => {
                        write!(f, "bimodal:{fast},{slow},{p_slow}")?
                    }
                }
                match *policy {
                    PolicySpec::FastestR => Ok(()),
                    PolicySpec::Deadline(t) => write!(f, ",deadline:{t}"),
                }
            }
            Scenario::Adversarial { attack } => write!(f, "adversarial:{}", attack.token()),
        }
    }
}

impl Scenario {
    /// The default scenario — today's hard-coded uniform sampling.
    pub fn is_default(&self) -> bool {
        matches!(self, Scenario::Uniform { delta: None })
    }

    /// The latency model, when this scenario has one (the `repro
    /// scenario` time-to-accuracy sweeps require it: uniform and
    /// adversarial scenarios have no wall-clock axis).
    pub fn latency_model(&self) -> Option<&LatencyModel> {
        match self {
            Scenario::Latency { model, .. } => Some(model),
            _ => None,
        }
    }

    /// Parse the `--stragglers` grammar (see the module docs). Errors
    /// name the offending piece; the canonical [`fmt::Display`] form
    /// always parses back to an equal scenario.
    pub fn parse(text: &str) -> Result<Scenario> {
        let (head, rest) = match text.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (text, None),
        };
        match head {
            "uniform" => match rest {
                None => Ok(Scenario::Uniform { delta: None }),
                Some(d) => {
                    let delta = parse_f64(d, "uniform delta")?;
                    if !(0.0..1.0).contains(&delta) {
                        bail!("uniform delta must be in [0, 1), got {delta}");
                    }
                    Ok(Scenario::Uniform { delta: Some(delta) })
                }
            },
            "shifted-exp" => {
                let (params, policy) = split_policy(rest, "shifted-exp")?;
                let [base, rate] = parse_params(&params, "shifted-exp", ["base", "rate"])?;
                if rate <= 0.0 {
                    bail!("shifted-exp rate must be > 0, got {rate}");
                }
                if base < 0.0 {
                    bail!("shifted-exp base must be >= 0 (latencies are wall-clock), got {base}");
                }
                Ok(Scenario::Latency { model: LatencyModel::ShiftedExp { base, rate }, policy })
            }
            "pareto" => {
                let (params, policy) = split_policy(rest, "pareto")?;
                let [scale, shape] = parse_params(&params, "pareto", ["scale", "shape"])?;
                if scale <= 0.0 || shape <= 0.0 {
                    bail!("pareto scale and shape must be > 0, got {scale},{shape}");
                }
                Ok(Scenario::Latency { model: LatencyModel::Pareto { scale, shape }, policy })
            }
            "bimodal" => {
                let (params, policy) = split_policy(rest, "bimodal")?;
                let [fast, slow, p_slow] =
                    parse_params(&params, "bimodal", ["fast", "slow", "p_slow"])?;
                if !(0.0..=1.0).contains(&p_slow) {
                    bail!("bimodal p_slow must be in [0, 1], got {p_slow}");
                }
                // fast > slow would make the quantile function
                // non-monotone and silently invert the tta deadline
                // sweep; negative latencies have no wall-clock meaning.
                if fast < 0.0 || slow < fast {
                    bail!("bimodal needs 0 <= fast <= slow, got fast={fast} slow={slow}");
                }
                Ok(Scenario::Latency {
                    model: LatencyModel::Bimodal { fast, slow, p_slow },
                    policy,
                })
            }
            "adversarial" => {
                let Some(tok) = rest else {
                    bail!("adversarial scenario needs an attack: adversarial:block|greedy|local-search");
                };
                let Some(attack) = AttackKind::parse(tok) else {
                    bail!("unknown attack {tok:?} (block|greedy|local-search)");
                };
                Ok(Scenario::Adversarial { attack })
            }
            other => bail!(
                "unknown straggler scenario {other:?} \
                 (uniform|shifted-exp|pareto|bimodal|adversarial)"
            ),
        }
    }

    /// Resolve this scenario into the concrete model one sweep point's
    /// trials draw from. `delta` and `r` are the point's straggler
    /// fraction and survivor count (r = round((1-δ)k) clamped, the
    /// formula every sweep uses); `plan_seed` seeds the adversarial
    /// standing assignment (shared by all shards of a job, so planning
    /// is shard- and thread-invariant).
    pub fn resolve(
        &self,
        code: &dyn GradientCode,
        delta: f64,
        r: usize,
        plan_seed: u64,
    ) -> ResolvedScenario {
        match self {
            Scenario::Uniform { delta: fixed } => ResolvedScenario {
                model: Box::new(UniformStragglers::new(fixed.unwrap_or(delta))),
                standing_g: None,
            },
            Scenario::Latency { model, policy } => {
                let policy = match *policy {
                    PolicySpec::FastestR => DeadlinePolicy::FastestR(r),
                    PolicySpec::Deadline(t) => DeadlinePolicy::Fixed(t),
                };
                ResolvedScenario {
                    model: Box::new(LatencyStragglers { model: *model, policy }),
                    standing_g: None,
                }
            }
            Scenario::Adversarial { attack } => {
                let g = code.assignment(&mut Rng::new(plan_seed));
                let model = AdversarialStragglers::plan(&g, r, code.s(), *attack);
                ResolvedScenario { model: Box::new(model), standing_g: Some(g) }
            }
        }
    }
}

fn parse_f64(s: &str, what: &str) -> Result<f64> {
    match s.trim().parse::<f64>() {
        Ok(x) if x.is_finite() => Ok(x),
        _ => bail!("{what}: expected a finite number, got {s:?}"),
    }
}

/// Split a latency spec's comma list into its numeric params and the
/// optional trailing policy (`fastest-r` or `deadline:T`).
fn split_policy(rest: Option<&str>, family: &str) -> Result<(Vec<String>, PolicySpec)> {
    let Some(rest) = rest else {
        bail!("{family} scenario needs parameters, e.g. {family}:<a>,<b>");
    };
    let mut parts: Vec<String> = rest.split(',').map(str::to_string).collect();
    let policy = match parts.last().map(String::as_str) {
        Some("fastest-r") => {
            parts.pop();
            PolicySpec::FastestR
        }
        Some(p) if p.starts_with("deadline:") => {
            let t = parse_f64(&p["deadline:".len()..], "deadline")?;
            if t <= 0.0 {
                bail!("deadline must be > 0, got {t}");
            }
            parts.pop();
            PolicySpec::Deadline(t)
        }
        _ => PolicySpec::FastestR,
    };
    Ok((parts, policy))
}

fn parse_params<const N: usize>(
    parts: &[String],
    family: &str,
    names: [&str; N],
) -> Result<[f64; N]> {
    if parts.len() != N {
        bail!(
            "{family} scenario needs {N} parameters ({}), got {} in {parts:?}",
            names.join(","),
            parts.len()
        );
    }
    let mut out = [0.0f64; N];
    for (i, (part, name)) in parts.iter().zip(names).enumerate() {
        out[i] = parse_f64(part, &format!("{family} {name}"))?;
    }
    Ok(out)
}

/// A scenario resolved at one sweep point: the concrete model plus,
/// for adversarial scenarios, the standing assignment matrix the attack
/// was planned against (trials decode on it instead of re-drawing G).
///
/// Invariant: `standing_g` is `Some` only for models whose draw is
/// **deterministic** (a replayed survivor set consuming no RNG) — the
/// sweeps rely on it to collapse standing points to a single exact
/// decode (`sim::scenario::scalar_partial_under`).
pub struct ResolvedScenario {
    pub model: Box<dyn StragglerModel>,
    pub standing_g: Option<CscMatrix>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::Scheme;
    use crate::stragglers::StragglerScratch;

    #[test]
    fn canonical_form_is_a_parse_fixed_point() {
        let cases = [
            "uniform",
            "uniform:0.2",
            "shifted-exp:0.1,2",
            "pareto:0.02,1.5",
            "pareto:0.02,1.5,deadline:0.5",
            "bimodal:0.1,10,0.25",
            "adversarial:greedy",
            "adversarial:block",
            "adversarial:local-search",
        ];
        for text in cases {
            let s = Scenario::parse(text).unwrap();
            assert_eq!(s.to_string(), text, "canonical form drifted");
            assert_eq!(Scenario::parse(&s.to_string()).unwrap(), s);
        }
        // fastest-r is the default policy and canonicalizes away.
        let s = Scenario::parse("pareto:1,1.5,fastest-r").unwrap();
        assert_eq!(s.to_string(), "pareto:1,1.5");
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "frobnicate",
            "uniform:1.0",
            "uniform:x",
            "pareto",
            "pareto:1",
            "pareto:1,2,3",
            "pareto:0,1",
            "shifted-exp:0.1,0",
            "shifted-exp:-1,2",
            "bimodal:1,2,1.5",
            "bimodal:5,0.1,0.3",
            "bimodal:-1,2,0.3",
            "pareto:1,2,deadline:0",
            "adversarial",
            "adversarial:alien",
        ] {
            assert!(Scenario::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn equality_is_bitwise_on_parameters() {
        assert_eq!(Scenario::default(), Scenario::Uniform { delta: None });
        assert!(Scenario::default().is_default());
        assert_ne!(
            Scenario::parse("pareto:1,1.5").unwrap(),
            Scenario::parse("pareto:1,1.6").unwrap()
        );
        assert_ne!(
            Scenario::parse("pareto:1,1.5").unwrap(),
            Scenario::parse("pareto:1,1.5,deadline:2").unwrap()
        );
        assert_ne!(Scenario::parse("uniform").unwrap(), Scenario::parse("uniform:0.2").unwrap());
    }

    #[test]
    fn uniform_resolution_uses_point_delta_unless_overridden() {
        let code = Scheme::Bgc.build(20, 20, 4);
        let mut ws = StragglerScratch::new();
        let mut rng = Rng::new(3);
        let resolved = Scenario::default().resolve(code.as_ref(), 0.25, 15, 0);
        assert!(resolved.standing_g.is_none());
        resolved.model.non_stragglers_into(20, &mut rng, &mut ws);
        assert_eq!(ws.idx.len(), 15);
        // Override: selection fraction fixed at 0.5 regardless of the
        // point's δ = 0.25.
        let over = Scenario::parse("uniform:0.5").unwrap().resolve(code.as_ref(), 0.25, 15, 0);
        over.model.non_stragglers_into(20, &mut rng, &mut ws);
        assert_eq!(ws.idx.len(), 10);
    }

    #[test]
    fn latency_resolution_parameterizes_fastest_r_with_point_r() {
        let code = Scheme::Bgc.build(30, 30, 4);
        let s = Scenario::parse("pareto:0.1,1.5").unwrap();
        let resolved = s.resolve(code.as_ref(), 0.4, 18, 0);
        let mut ws = StragglerScratch::new();
        let mut rng = Rng::new(4);
        resolved.model.non_stragglers_into(30, &mut rng, &mut ws);
        assert_eq!(ws.idx.len(), 18);
        assert!(ws.gather_time.is_finite());
    }

    #[test]
    fn adversarial_resolution_plans_a_standing_assignment() {
        let code = Scheme::Frc.build(20, 20, 5);
        let s = Scenario::parse("adversarial:block").unwrap();
        let resolved = s.resolve(code.as_ref(), 0.25, 15, 99);
        let g = resolved.standing_g.as_ref().expect("standing G");
        // The standing G is the seeded draw the attack was planned on.
        assert_eq!(*g, code.assignment(&mut Rng::new(99)));
        let mut ws = StragglerScratch::new();
        let mut rng = Rng::new(5);
        resolved.model.non_stragglers_into(20, &mut rng, &mut ws);
        assert_eq!(ws.idx.len(), 15);
        // Replay: a second draw returns the same set.
        let first = ws.idx.clone();
        resolved.model.non_stragglers_into(20, &mut rng, &mut ws);
        assert_eq!(ws.idx, first);
    }
}
