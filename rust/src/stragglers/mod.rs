//! Straggler models: who fails to respond by the deadline.
//!
//! The paper's analysis assumes the r = (1-δ)k non-stragglers are chosen
//! uniformly at random (§3, §5) or adversarially (§4). The coordinator
//! additionally supports latency-based models where stragglers emerge
//! from heavy-tailed worker completion times and a gather deadline —
//! the mechanism that produces "random" straggler sets in real clusters.

pub mod adversarial;
pub mod latency;
pub mod random;

pub use latency::{sample_round, DeadlinePolicy, LatencyModel, LatencySample, LatencyStragglers};
pub use adversarial::{AdversarialStragglers, AttackKind};
pub use random::UniformStragglers;

use crate::util::Rng;

/// A straggler model selects the non-straggler (responding) worker set.
pub trait StragglerModel {
    /// Return the sorted indices of the non-straggler workers out of n.
    fn non_stragglers(&self, n: usize, rng: &mut Rng) -> Vec<usize>;
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_model_respects_r() {
        let m = UniformStragglers::new(0.3);
        let mut rng = Rng::new(1);
        let ns = m.non_stragglers(100, &mut rng);
        assert_eq!(ns.len(), 70);
        assert!(ns.windows(2).all(|w| w[0] < w[1]));
    }
}
