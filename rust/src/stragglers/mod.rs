//! Straggler models: who fails to respond by the deadline.
//!
//! The paper's analysis assumes the r = (1-δ)k non-stragglers are chosen
//! uniformly at random (§3, §5) or adversarially (§4). The coordinator
//! additionally supports latency-based models where stragglers emerge
//! from heavy-tailed worker completion times and a gather deadline —
//! the mechanism that produces "random" straggler sets in real clusters.
//!
//! Since the scenario-spine refactor, every layer of the repo selects
//! stragglers through this one trait: the e2e coordinator uses the
//! allocating [`StragglerModel::non_stragglers`], and the Monte-Carlo
//! decode pipeline uses the allocation-free
//! [`StragglerModel::non_stragglers_into`] with a per-workspace
//! [`StragglerScratch`]. The [`scenario::Scenario`] enum names a model
//! family on the CLI (`--stragglers ...`), carries it inside the shard
//! run identity, and resolves it to a concrete model per sweep point.

pub mod adversarial;
pub mod latency;
pub mod random;
pub mod scenario;

pub use adversarial::{AdversarialStragglers, AttackKind};
pub use latency::{sample_round, DeadlinePolicy, LatencyModel, LatencySample, LatencyStragglers};
pub use random::UniformStragglers;
pub use scenario::{PolicySpec, ResolvedScenario, Scenario};

use crate::util::Rng;

/// Reusable scratch for [`StragglerModel::non_stragglers_into`]: every
/// buffer a straggler draw needs, owned by the caller (one per
/// `decode::DecodeWorkspace`) so the steady-state trial loop performs
/// zero heap allocations. Each model uses the subset it needs.
#[derive(Clone, Debug, Default)]
pub struct StragglerScratch {
    /// Fisher-Yates pool for uniform sampling (length n).
    pub pool: Vec<usize>,
    /// The selected non-straggler index set — the draw's output.
    pub idx: Vec<usize>,
    /// Per-worker latency draws (latency models only; length n).
    pub latencies: Vec<f64>,
    /// Order-statistic scratch for the fastest-r policy (length n).
    pub order: Vec<usize>,
    /// The selected set in message-arrival order (filled on demand by
    /// [`StragglerScratch::compute_arrivals`]).
    pub arrivals: Vec<usize>,
    /// Gather wall-clock of the most recent draw: when the master
    /// stopped waiting. Latency models set it (fixed deadline: the
    /// deadline; fastest-r: the r-th order statistic); models with no
    /// time axis (uniform, adversarial) set NaN.
    pub gather_time: f64,
}

impl StragglerScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size every buffer for draws over n workers (optional — the
    /// buffers grow on demand; after this call the draw loop performs
    /// zero allocations from the very first trial).
    pub fn reserve(&mut self, n: usize) {
        self.pool.reserve(n);
        self.idx.reserve(n);
        self.latencies.reserve(n);
        self.order.reserve(n);
        self.arrivals.reserve(n);
    }

    /// Derive the message-arrival order of the most recent draw into
    /// `arrivals` — **arrival order is contract** for the incremental
    /// decode paths:
    ///
    /// * draws with a time axis (`gather_time` finite): ascending
    ///   (latency, worker index) over the selected set — the order the
    ///   coded messages actually reach the master;
    /// * draws with no time axis (uniform, adversarial,
    ///   `gather_time` NaN): the draw order of `idx` itself, matching
    ///   the [`StragglerModel::non_stragglers_into`] order contract.
    ///
    /// Allocation-free at steady state (one `extend_from_slice` into a
    /// reserved buffer plus an in-place sort).
    pub fn compute_arrivals(&mut self) {
        self.arrivals.clear();
        self.arrivals.extend_from_slice(&self.idx);
        if !self.gather_time.is_nan() {
            let latencies = &self.latencies;
            self.arrivals.sort_unstable_by(|&a, &b| {
                latencies[a]
                    .partial_cmp(&latencies[b])
                    .expect("latency draws are finite")
                    .then(a.cmp(&b))
            });
        }
    }
}

/// A straggler model selects the non-straggler (responding) worker set.
///
/// `Send + Sync` is a supertrait: the Monte-Carlo engine shares one
/// resolved model across its worker threads by reference (models are
/// immutable per sweep point; all per-draw state lives in the RNG and
/// the [`StragglerScratch`]).
pub trait StragglerModel: Send + Sync {
    /// Return the sorted indices of the non-straggler workers out of n.
    fn non_stragglers(&self, n: usize, rng: &mut Rng) -> Vec<usize>;

    /// Allocation-free draw into caller-owned scratch: `ws.idx` receives
    /// the non-straggler set and `ws.gather_time` the gather wall-clock
    /// (NaN for models with no time axis).
    ///
    /// Unlike [`StragglerModel::non_stragglers`], the output **order is
    /// part of the contract** — the decode pipeline accumulates
    /// coverage in `ws.idx` order, so the order determines output bits:
    ///
    /// * uniform: Fisher-Yates draw order, RNG-stream- and
    ///   order-identical to `Rng::sample_indices_into` — which is what
    ///   keeps every pre-spine figure/table CSV byte-identical under
    ///   the default scenario;
    /// * latency and adversarial models: ascending worker index
    ///   (matching their sorted `non_stragglers` output).
    fn non_stragglers_into(&self, n: usize, rng: &mut Rng, ws: &mut StragglerScratch);

    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_model_respects_r() {
        let m = UniformStragglers::new(0.3);
        let mut rng = Rng::new(1);
        let ns = m.non_stragglers(100, &mut rng);
        assert_eq!(ns.len(), 70);
        assert!(ns.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn scratch_draw_matches_allocating_draw_as_a_set() {
        // Same RNG stream -> the scratch draw selects the same worker
        // set as the allocating draw (the scratch output is unsorted by
        // contract; compare as sorted sets).
        let m = UniformStragglers::new(0.4);
        let mut ws = StragglerScratch::new();
        let mut rng_a = Rng::new(7);
        let mut rng_b = Rng::new(7);
        for _ in 0..10 {
            let sorted = m.non_stragglers(50, &mut rng_a);
            m.non_stragglers_into(50, &mut rng_b, &mut ws);
            let mut got = ws.idx.clone();
            got.sort_unstable();
            assert_eq!(got, sorted);
            assert!(ws.gather_time.is_nan());
        }
        // Streams stayed in lockstep.
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }

    #[test]
    fn arrivals_without_time_axis_are_draw_order() {
        let m = UniformStragglers::new(0.4);
        let mut ws = StragglerScratch::new();
        let mut rng = Rng::new(9);
        m.non_stragglers_into(50, &mut rng, &mut ws);
        ws.compute_arrivals();
        assert_eq!(ws.arrivals, ws.idx);
    }

    #[test]
    fn arrivals_under_latency_draw_are_sorted_by_latency_then_index() {
        let model = LatencyStragglers {
            model: LatencyModel::Pareto { scale: 0.1, shape: 1.5 },
            policy: DeadlinePolicy::FastestR(12),
        };
        let mut ws = StragglerScratch::new();
        let mut rng = Rng::new(10);
        for _ in 0..5 {
            model.non_stragglers_into(40, &mut rng, &mut ws);
            ws.compute_arrivals();
            assert_eq!(ws.arrivals.len(), ws.idx.len());
            // Same set as idx, ordered by ascending completion time.
            let mut sorted = ws.arrivals.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, ws.idx);
            assert!(ws
                .arrivals
                .windows(2)
                .all(|w| ws.latencies[w[0]] <= ws.latencies[w[1]]));
            // The fastest-r order buffer IS the arrival order.
            assert_eq!(ws.arrivals, ws.order[..ws.idx.len()]);
        }
    }
}
