//! `repro load`: a seeded, deterministic traffic generator for the
//! `repro serve` daemon.
//!
//! The generator fires `requests` decode requests at the daemon over
//! `concurrency` persistent connections, keeping up to `pipeline`
//! requests in flight per connection, and produces two artifacts:
//!
//! - a **replay** (stdout): one CSV row per request with its derived
//!   seed and error-sequence summary, plus a log2 histogram of the
//!   per-request mean errors. Request `i` always carries seed
//!   `root.fork(i).next_u64()` and the server decodes round `t` of
//!   seed `w` from `Rng::new(w).fork(t)`, so the replay is a pure
//!   function of `(seed, workload)` — byte-identical across runs,
//!   concurrency levels, arrival processes, and pipeline depths.
//!   Diffing two replays is the end-to-end regression check for the
//!   whole serve path.
//! - a **report** (stderr): latency quantiles (p50/p99/p999/max) from
//!   a [`LatencyHistogram`], throughput in requests/s and decode
//!   rounds/s, and a PASS/FAIL verdict against an optional p99 SLO.
//!   This half is timing and *not* reproducible — which is exactly why
//!   it is kept out of the replay bytes.
//!
//! **Pipelining.** Every request carries an `"id"` (its request index,
//! as a decimal string); the daemon echoes the id in the reply, so a
//! worker can keep `pipeline` requests outstanding and match replies
//! in whatever order the server completes them. Depth 1 degenerates to
//! the classic lockstep request/reply loop. Because replies are pure
//! functions of their requests, the replay bytes cannot depend on
//! completion order.
//!
//! **Workloads.** The default workload fires one fixed template per
//! request. `--workload latparam` instead cycles request `i` through
//! the `latparam` study's template grid
//! ([`crate::sim::scenario::latparam_models`]): one decode template
//! per (sweep arm, scheme, parameter point), with each template's `r`
//! set to the survivor count the swept latency model is expected to
//! deliver by the fixed deadline. The grid is a deterministic function
//! of the base latency model, so the workload is as reproducible as
//! the fixed template.
//!
//! Arrival processes: `closed` (fire as fast as replies come back),
//! `uniform:GAP_MS` (fixed think time per worker), `poisson:RATE`
//! (exponential gaps; `RATE` is the *aggregate* target req/s, split
//! evenly across workers). Gap draws come from per-worker forks
//! disjoint from the per-request seed streams, so the arrival process
//! never perturbs the replay.
//!
//! Connections are dialed lazily — a worker opens its socket when its
//! first request is ready to leave, with a bounded exponential-backoff
//! retry window — so a daemon that is still binding its listener (or
//! briefly over its accept backlog) delays the run instead of failing
//! it on one `ECONNREFUSED`.

use std::collections::HashMap;
use std::io::BufWriter;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::LatencyHistogram;
use crate::serve::frame;
use crate::serve::protocol;
use crate::serve::{DecodeRequest, Request};
use crate::sim::figures::FIG_SCHEMES;
use crate::sim::scenario::{
    latparam_deadline, latparam_expected_r, latparam_models, LATPARAM_ARMS,
};
use crate::stragglers::LatencyModel;
use crate::util::{Json, Rng};

/// When the next request leaves a worker.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Closed loop: next request leaves as soon as the reply lands.
    Closed,
    /// Fixed gap of `gap_ms` milliseconds before each request.
    Uniform { gap_ms: u64 },
    /// Poisson arrivals at `rate` requests/second aggregate.
    Poisson { rate: f64 },
}

impl Arrival {
    /// Parse `closed`, `uniform:GAP_MS`, or `poisson:RATE`.
    pub fn parse(text: &str) -> Result<Arrival> {
        if text == "closed" {
            return Ok(Arrival::Closed);
        }
        if let Some(ms) = text.strip_prefix("uniform:") {
            let gap_ms = ms.parse::<u64>().with_context(|| format!("gap in {text:?}"))?;
            return Ok(Arrival::Uniform { gap_ms });
        }
        if let Some(r) = text.strip_prefix("poisson:") {
            let rate = r.parse::<f64>().with_context(|| format!("rate in {text:?}"))?;
            if !(rate > 0.0 && rate.is_finite()) {
                bail!("poisson rate must be finite and positive, got {rate}");
            }
            return Ok(Arrival::Poisson { rate });
        }
        bail!("unknown arrival process {text:?} (closed | uniform:GAP_MS | poisson:RATE)");
    }
}

/// Which decode template each request carries.
#[derive(Clone, Debug)]
pub enum Workload {
    /// Every request fires the one configured template.
    Fixed,
    /// The standing latency-parameter workload: request `i` cycles
    /// through the `latparam` study's template grid built from `base`
    /// (one template per sweep arm x scheme x parameter point, `r` set
    /// from the swept model's expected survivors at the fixed
    /// deadline).
    Latparam { base: LatencyModel },
}

/// One load run's shape.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    /// Daemon address, e.g. `127.0.0.1:7117`.
    pub addr: String,
    pub requests: usize,
    pub concurrency: usize,
    /// Max requests in flight per connection (1 = lockstep).
    pub pipeline: usize,
    pub arrival: Arrival,
    /// Root seed: derives every per-request seed and every arrival gap.
    pub seed: u64,
    /// p99 SLO in milliseconds; 0 disables the verdict line.
    pub slo_p99_ms: f64,
    /// The decode request fired on every arrival (its `seed` field is
    /// overwritten per request; `assign_seed` stays fixed, so all
    /// requests share one memoized standing assignment server-side).
    /// Under [`Workload::Latparam`] this is the grid's base template:
    /// its `k`, `s`, `rounds`, `decoder`, and `assign_seed` carry over
    /// to every grid point, while `scheme`, `n`, and `r` vary.
    pub template: DecodeRequest,
    pub workload: Workload,
}

/// What a load run produced.
#[derive(Clone, Debug)]
pub struct LoadOutcome {
    /// Byte-reproducible replay CSV (print to stdout).
    pub replay: String,
    /// Human latency/throughput report (print to stderr).
    pub report: String,
    /// True iff `slo_p99_ms == 0` or the measured p99 met it.
    pub slo_ok: bool,
    pub total_rounds: u64,
    pub elapsed: f64,
    pub rounds_per_sec: f64,
    pub requests_per_sec: f64,
}

/// Error summary of one request's reply.
struct RequestResult {
    index: usize,
    seed: u64,
    errs: Vec<f64>,
}

struct WorkerOutput {
    results: Vec<RequestResult>,
    latency: LatencyHistogram,
}

/// The per-request decode templates of a workload. Request `i` fires
/// template `i % len`, so the mapping is independent of concurrency
/// and pipeline depth.
fn request_templates(cfg: &LoadConfig) -> Vec<DecodeRequest> {
    match &cfg.workload {
        Workload::Fixed => vec![cfg.template.clone()],
        Workload::Latparam { base } => {
            let deadline = latparam_deadline(base);
            let k = cfg.template.k;
            let mut out = Vec::new();
            for &arm in &LATPARAM_ARMS {
                for &scheme in &FIG_SCHEMES {
                    for (_param, swept) in latparam_models(arm, base) {
                        let mut t = cfg.template.clone();
                        t.scheme = scheme;
                        // The study's geometry: square code, survivors
                        // from the swept model's CDF at the deadline.
                        t.n = k;
                        t.r = latparam_expected_r(&swept, deadline, k);
                        t.prefix = None;
                        out.push(t);
                    }
                }
            }
            out
        }
    }
}

/// Bounded-retry dial. Workers connect lazily (first send, not worker
/// start), and a listener that is not accepting yet gets an
/// exponential-backoff window of `patience` before the run fails.
fn connect_with_retry(addr: &str, t: usize, patience: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + patience;
    let mut delay = Duration::from_millis(5);
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                stream.set_nodelay(true).ok();
                return Ok(stream);
            }
            Err(e) => {
                if Instant::now() + delay >= deadline {
                    return Err(e).with_context(|| format!("worker {t}: connecting to {addr}"));
                }
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_millis(250));
            }
        }
    }
}

/// How long a worker keeps retrying its initial dial.
const CONNECT_PATIENCE: Duration = Duration::from_secs(5);

/// One request awaiting its reply.
struct Pending {
    index: usize,
    seed: u64,
    start: Instant,
}

fn worker(
    cfg: &LoadConfig,
    templates: &[DecodeRequest],
    t: usize,
    c: usize,
    root: &Rng,
) -> Result<WorkerOutput> {
    let depth = cfg.pipeline.max(1);
    // Gap stream disjoint from per-request seed forks (those use
    // indices 0..requests; requests is bounded far below u64::MAX - c).
    let mut gaps = root.fork(u64::MAX - t as u64);
    let mut results = Vec::new();
    let mut latency = LatencyHistogram::new();
    // Lazily dialed: no socket until the first request is ready.
    let mut stream: Option<TcpStream> = None;
    let mut outstanding: HashMap<u64, Pending> = HashMap::new();
    let mut next = t;
    while next < cfg.requests || !outstanding.is_empty() {
        // Fill the pipeline window, then block on one reply.
        while next < cfg.requests && outstanding.len() < depth {
            match cfg.arrival {
                Arrival::Closed => {}
                Arrival::Uniform { gap_ms } => std::thread::sleep(Duration::from_millis(gap_ms)),
                Arrival::Poisson { rate } => {
                    let gap_s = gaps.exp(rate / c as f64);
                    std::thread::sleep(Duration::from_secs_f64(gap_s.min(60.0)));
                }
            }
            let i = next;
            next += c;
            let seed = root.fork(i as u64).next_u64();
            let mut req = templates[i % templates.len()].clone();
            req.seed = seed;
            if stream.is_none() {
                stream = Some(connect_with_retry(&cfg.addr, t, CONNECT_PATIENCE)?);
            }
            let conn = stream.as_mut().expect("just connected");
            let body = protocol::with_id(Request::Decode(req).to_json(), Some(i as u64)).write();
            {
                let mut w = BufWriter::new(&mut *conn);
                frame::write_frame(&mut w, &body)
                    .with_context(|| format!("sending request {i}"))?;
            }
            outstanding.insert(i as u64, Pending { index: i, seed, start: Instant::now() });
        }
        let conn = stream.as_mut().expect("in-flight requests imply a connection");
        let body = frame::read_frame(conn)
            .map_err(|e| anyhow::anyhow!("reading reply frame: {e}"))?;
        let reply = Json::parse(&body).context("parsing reply frame")?;
        let id = protocol::request_id(&reply)
            .context("reply id")?
            .ok_or_else(|| anyhow::anyhow!("reply carries no id: {}", reply.write()))?;
        let Some(p) = outstanding.remove(&id) else {
            bail!("unsolicited reply id {id} (never sent or already answered)");
        };
        latency.record_ns(p.start.elapsed().as_nanos() as u64);
        let i = p.index;
        let ok = matches!(reply.get("ok"), Ok(Json::Bool(true)));
        if !ok {
            let msg = reply
                .get("error")
                .and_then(|e| e.as_str().map(str::to_string))
                .unwrap_or_else(|_| reply.write());
            bail!("request {i}: server error: {msg}");
        }
        let errs: Vec<f64> = reply
            .get("errs")?
            .as_arr()?
            .iter()
            .map(Json::as_f64)
            .collect::<Result<_>>()
            .with_context(|| format!("request {i}: errs"))?;
        if errs.len() != cfg.template.rounds {
            bail!(
                "request {i}: reply has {} errors, expected {} rounds",
                errs.len(),
                cfg.template.rounds
            );
        }
        results.push(RequestResult { index: i, seed: p.seed, errs });
    }
    Ok(WorkerOutput { results, latency })
}

/// Log2 bucket of a positive error: the unbiased f64 exponent, read
/// straight from the bit pattern so bucketing is deterministic across
/// platforms (no libm `log2` variance). Zero maps to the subnormal
/// floor bucket -1023.
fn log2_bucket(x: f64) -> i64 {
    ((x.to_bits() >> 52) & 0x7ff) as i64 - 1023
}

fn render_replay(cfg: &LoadConfig, results: &[RequestResult]) -> String {
    use std::fmt::Write as _;
    let t = &cfg.template;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# repro load replay: seed={} requests={} scheme={} k={} n={} s={} r={} rounds={} decoder={}",
        cfg.seed, cfg.requests, t.scheme.name(), t.k, t.n, t.s, t.r, t.rounds,
        t.decoder.name(),
    );
    if let Some(p) = t.prefix {
        // Only prefixed templates emit this line, so prefix-free
        // replays stay byte-identical to pre-prefix builds.
        let _ = writeln!(out, "# anytime prefix={p} (first {p} arrivals of each round's draw)");
    }
    if let Workload::Latparam { base } = &cfg.workload {
        // Likewise latparam-only, so default-workload replays keep
        // their exact historical bytes.
        let _ = writeln!(
            out,
            "# workload latparam: base={base:?} deadline={:.6e} templates={}",
            latparam_deadline(base),
            2 * FIG_SCHEMES.len() * 18,
        );
    }
    out.push_str("request,seed,mean_err,min_err,max_err,first_err,last_err\n");
    let mut hist = std::collections::BTreeMap::new();
    for r in results {
        let mean = r.errs.iter().sum::<f64>() / r.errs.len() as f64;
        let min = r.errs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = r.errs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let _ = writeln!(
            out,
            "{},{},{:e},{:e},{:e},{:e},{:e}",
            r.index,
            r.seed,
            mean,
            min,
            max,
            r.errs[0],
            r.errs[r.errs.len() - 1],
        );
        *hist.entry(log2_bucket(mean)).or_insert(0u64) += 1;
    }
    out.push_str("bucket,count\n");
    for (b, c) in &hist {
        let _ = writeln!(out, "{b},{c}");
    }
    out
}

/// Fire the load and collect both artifacts. Fails if any request
/// errors or any index is missing — a partial replay would diff clean
/// against another partial replay with the same holes.
pub fn run_load(cfg: &LoadConfig) -> Result<LoadOutcome> {
    if cfg.requests == 0 {
        bail!("--requests must be at least 1");
    }
    if cfg.pipeline == 0 {
        bail!("--pipeline must be at least 1");
    }
    let c = cfg.concurrency.clamp(1, cfg.requests);
    let templates = request_templates(cfg);
    let root = Rng::new(cfg.seed);
    let start = Instant::now();
    let outputs: Vec<Result<WorkerOutput>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..c)
            .map(|t| {
                let root = root.clone();
                let templates = &templates;
                scope.spawn(move || worker(cfg, templates, t, c, &root))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("load worker panicked")).collect()
    });
    let elapsed = start.elapsed().as_secs_f64().max(1e-9);

    let mut results = Vec::with_capacity(cfg.requests);
    let mut latency = LatencyHistogram::new();
    for out in outputs {
        let out = out?;
        latency.merge(&out.latency);
        results.extend(out.results);
    }
    results.sort_by_key(|r| r.index);
    for (want, r) in results.iter().enumerate() {
        if r.index != want {
            bail!("request {want} missing from results (got index {})", r.index);
        }
    }
    if results.len() != cfg.requests {
        bail!("collected {} results, expected {}", results.len(), cfg.requests);
    }

    let total_rounds = (cfg.requests * cfg.template.rounds) as u64;
    let requests_per_sec = cfg.requests as f64 / elapsed;
    let rounds_per_sec = total_rounds as f64 / elapsed;
    let p50 = latency.quantile_ns(0.50) as f64 / 1e6;
    let p99 = latency.quantile_ns(0.99) as f64 / 1e6;
    let p999 = latency.quantile_ns(0.999) as f64 / 1e6;
    let maxl = latency.quantile_ns(1.0) as f64 / 1e6;
    let slo_ok = cfg.slo_p99_ms <= 0.0 || p99 <= cfg.slo_p99_ms;

    use std::fmt::Write as _;
    let mut report = String::new();
    let _ = writeln!(
        report,
        "load: {} requests x {} rounds over {} connection(s), pipeline {}, arrival {:?}, seed {}",
        cfg.requests,
        cfg.template.rounds,
        c,
        cfg.pipeline.max(1),
        cfg.arrival,
        cfg.seed
    );
    let _ = writeln!(
        report,
        "latency: p50 {p50:.3} ms, p99 {p99:.3} ms, p999 {p999:.3} ms, max {maxl:.3} ms, \
         mean {:.3} ms",
        latency.mean_ns() / 1e6
    );
    let _ = writeln!(
        report,
        "throughput: {requests_per_sec:.1} req/s, {rounds_per_sec:.1} decode rounds/s \
         over {elapsed:.3} s"
    );
    if cfg.slo_p99_ms > 0.0 {
        let _ = writeln!(
            report,
            "slo: p99 {p99:.3} ms vs target {:.3} ms -> {}",
            cfg.slo_p99_ms,
            if slo_ok { "PASS" } else { "FAIL" }
        );
    }

    Ok(LoadOutcome {
        replay: render_replay(cfg, &results),
        report,
        slo_ok,
        total_rounds,
        elapsed,
        rounds_per_sec,
        requests_per_sec,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::Scheme;
    use crate::coordinator::DecoderKind;

    #[test]
    fn arrival_parse_accepts_the_three_processes() {
        assert_eq!(Arrival::parse("closed").unwrap(), Arrival::Closed);
        assert_eq!(Arrival::parse("uniform:5").unwrap(), Arrival::Uniform { gap_ms: 5 });
        assert_eq!(Arrival::parse("poisson:200").unwrap(), Arrival::Poisson { rate: 200.0 });
        for bad in ["open", "uniform:", "uniform:x", "poisson:0", "poisson:-1", "poisson:inf"] {
            assert!(Arrival::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn log2_bucket_matches_the_exponent_field() {
        assert_eq!(log2_bucket(1.0), 0);
        assert_eq!(log2_bucket(2.0), 1);
        assert_eq!(log2_bucket(0.5), -1);
        assert_eq!(log2_bucket(3.9), 1);
        assert_eq!(log2_bucket(0.0), -1023);
        assert_eq!(log2_bucket(1e-3), -10);
    }

    fn test_cfg(workload: Workload) -> LoadConfig {
        LoadConfig {
            addr: "127.0.0.1:0".into(),
            requests: 8,
            concurrency: 2,
            pipeline: 1,
            arrival: Arrival::Closed,
            seed: 11,
            slo_p99_ms: 0.0,
            template: DecodeRequest {
                scheme: Scheme::Frc,
                k: 20,
                n: 20,
                s: 4,
                r: 16,
                rounds: 3,
                decoder: DecoderKind::OneStep,
                assign_seed: 11,
                seed: 0,
                prefix: None,
            },
            workload,
        }
    }

    #[test]
    fn latparam_workload_builds_the_full_template_grid() {
        let base = LatencyModel::Pareto { scale: 0.05, shape: 1.5 };
        let cfg = test_cfg(Workload::Latparam { base });
        let templates = request_templates(&cfg);
        // 2 arms x 3 schemes x 18 parameter points, cycling.
        assert_eq!(templates.len(), 2 * 3 * 18);
        let deadline = latparam_deadline(&base);
        for t in &templates {
            assert_eq!(t.k, 20);
            assert_eq!(t.n, 20);
            assert_eq!(t.s, 4);
            assert_eq!(t.rounds, 3);
            assert!((1..=t.n).contains(&t.r));
            assert!(t.prefix.is_none());
        }
        // Templates vary along the sweep: the heavy-tail end of the
        // pareto-shape arm admits fewer survivors than the light end.
        let models = latparam_models("pareto-shape", &base);
        assert_eq!(templates[0].r, latparam_expected_r(&models[0].1, deadline, 20));
        assert!(templates[0].r < templates[17].r);
        // The fixed workload is a single template, unchanged.
        assert_eq!(request_templates(&test_cfg(Workload::Fixed)).len(), 1);
    }

    #[test]
    fn connect_retries_until_a_late_listener_binds() {
        // Reserve an ephemeral port, release it, and bind it again
        // from another thread only after a delay — the shape of the
        // `repro load`-beats-the-daemon race this retry loop absorbs.
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let binder = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            let listener = std::net::TcpListener::bind(addr).unwrap();
            listener.accept().unwrap();
        });
        let start = Instant::now();
        let stream = connect_with_retry(&addr.to_string(), 0, Duration::from_secs(10)).unwrap();
        assert!(start.elapsed() >= Duration::from_millis(100), "must actually have waited");
        drop(stream);
        binder.join().unwrap();

        // A listener that never shows up fails within the patience
        // bound instead of hanging.
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let dead = probe.local_addr().unwrap();
        drop(probe);
        let start = Instant::now();
        assert!(connect_with_retry(&dead.to_string(), 0, Duration::from_millis(200)).is_err());
        assert!(start.elapsed() < Duration::from_secs(5));
    }
}
