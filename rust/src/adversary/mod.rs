//! Adversarial straggler selection (paper §4).
//!
//! §4.1: against FRC an adversary kills whole task-blocks and forces
//! err(A) = k - r in linear time (Thm 10). §4.2: for general codes the
//! problem (r-ASP, Definition 4) is NP-hard via reduction from densest
//! k-subgraph (Thm 11), so polynomial adversaries must use heuristics —
//! we implement greedy removal, local search, and (for tiny n) an
//! exhaustive oracle to measure how far the heuristics fall short.

pub mod exhaustive;
pub mod frc_attack;
pub mod greedy;
pub mod local_search;
pub mod reduction;

pub use exhaustive::exhaustive_worst_case;
pub use frc_attack::frc_worst_stragglers;
pub use greedy::greedy_stragglers;
pub use local_search::local_search_stragglers;
pub use reduction::{dks_to_asp, greedy_dks, objective_identity_gap, AspInstance};

use crate::linalg::CscMatrix;

/// The r-ASP objective (Definition 4): the one-step decoding error of
/// the column submatrix selected by `non_stragglers`, computed through
/// the fused no-materialize path (row coverage accumulated straight
/// from G — bit-identical to selecting A and summing its rows).
pub fn asp_objective(g: &CscMatrix, non_stragglers: &[usize], rho: f64) -> f64 {
    let mut row_acc = Vec::new();
    crate::decode::err1_from_supports(g, non_stragglers, rho, &mut row_acc)
}

/// [`asp_objective`] with a caller-reused accumulator. The exhaustive
/// adversary evaluates C(n, r) candidate sets through this variant with
/// one shared buffer; greedy and local search don't need it — they
/// maintain row sums incrementally and never re-evaluate from scratch.
pub fn asp_objective_with(
    g: &CscMatrix,
    non_stragglers: &[usize],
    rho: f64,
    row_acc: &mut Vec<f64>,
) -> f64 {
    crate::decode::err1_from_supports(g, non_stragglers, rho, row_acc)
}

/// An adversary proposes the non-straggler set that *maximizes* the
/// decoding error (i.e. picks the worst r columns to survive).
pub trait Adversary {
    fn worst_non_stragglers(&self, g: &CscMatrix, r: usize) -> Vec<usize>;
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asp_objective_matches_onestep_error() {
        use crate::decode::OneStepDecoder;
        let g = CscMatrix::from_supports(4, vec![vec![0, 1], vec![2], vec![3], vec![0]]);
        let ns = vec![0, 2];
        let rho = 0.5;
        let direct = asp_objective(&g, &ns, rho);
        let via_decoder = OneStepDecoder::new(rho).err1(&g.select_columns(&ns));
        assert!((direct - via_decoder).abs() < 1e-12);
    }
}
