//! Adversarial straggler selection (paper §4).
//!
//! §4.1: against FRC an adversary kills whole task-blocks and forces
//! err(A) = k - r in linear time (Thm 10). §4.2: for general codes the
//! problem (r-ASP, Definition 4) is NP-hard via reduction from densest
//! k-subgraph (Thm 11), so polynomial adversaries must use heuristics —
//! we implement greedy removal, local search, and (for tiny n) an
//! exhaustive oracle to measure how far the heuristics fall short.

pub mod exhaustive;
pub mod frc_attack;
pub mod greedy;
pub mod local_search;
pub mod reduction;

pub use exhaustive::exhaustive_worst_case;
pub use frc_attack::frc_worst_stragglers;
pub use greedy::greedy_stragglers;
pub use local_search::local_search_stragglers;
pub use reduction::{dks_to_asp, greedy_dks, objective_identity_gap, AspInstance};

use crate::linalg::CscMatrix;

/// The r-ASP objective (Definition 4): the one-step decoding error of
/// the column submatrix selected by `non_stragglers`.
pub fn asp_objective(g: &CscMatrix, non_stragglers: &[usize], rho: f64) -> f64 {
    let a = g.select_columns(non_stragglers);
    let sums = a.row_sums();
    sums.iter().map(|&v| (rho * v - 1.0).powi(2)).sum()
}

/// An adversary proposes the non-straggler set that *maximizes* the
/// decoding error (i.e. picks the worst r columns to survive).
pub trait Adversary {
    fn worst_non_stragglers(&self, g: &CscMatrix, r: usize) -> Vec<usize>;
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asp_objective_matches_onestep_error() {
        use crate::decode::OneStepDecoder;
        let g = CscMatrix::from_supports(4, vec![vec![0, 1], vec![2], vec![3], vec![0]]);
        let ns = vec![0, 2];
        let rho = 0.5;
        let direct = asp_objective(&g, &ns, rho);
        let via_decoder = OneStepDecoder::new(rho).err1(&g.select_columns(&ns));
        assert!((direct - via_decoder).abs() < 1e-12);
    }
}
