//! The DkS → r-ASP reduction (paper Thm 11): adversarial straggler
//! selection is NP-hard.
//!
//! Given a d-regular graph (V, E), build C = [B | 0] where B is the
//! |E| x |V| unsigned incidence matrix padded with |E| - |V| zero
//! columns (C is square |E| x |E|, boolean, ≤ d nonzeros per column —
//! note |E| = nd/2 for a simple d-regular graph; the paper's |E| = nd
//! double-counts, the construction is otherwise unchanged). For
//! ρ ∈ (0, 2/3) the r-ASP optimum on C with r = t + (|E| - n) selects
//! exactly t incidence columns whose vertex set is the densest
//! t-subgraph, because (eq. 4.2/4.3)
//!
//!   ||ρ C x - 1||^2 = 2ρ² e(S) + dρ² |S| - 2ρ d |S| + |E|.
//!
//! `objective_identity_gap` verifies that algebra numerically; the
//! thm11 table + tests use it as the NP-hardness witness, and compare
//! greedy-ASP against greedy-DkS on reduction instances.

use crate::graph::Graph;
use crate::linalg::CscMatrix;

/// A reduction instance: the ASP matrix C plus provenance.
#[derive(Clone, Debug)]
pub struct AspInstance {
    /// |E| x |E| boolean matrix [B | 0].
    pub c: CscMatrix,
    pub n_vertices: usize,
    pub degree: usize,
    pub num_edges: usize,
}

impl AspInstance {
    /// The survivor budget r that makes t incidence columns optimal.
    pub fn r_for_subset_size(&self, t: usize) -> usize {
        t + (self.num_edges - self.n_vertices)
    }

    /// The survivor set encoding vertex subset S: S's incidence columns
    /// plus all zero columns.
    pub fn survivors_for_subset(&self, subset: &[usize]) -> Vec<usize> {
        let mut cols: Vec<usize> = subset.to_vec();
        cols.extend(self.n_vertices..self.num_edges);
        cols.sort_unstable();
        cols
    }
}

/// Build the Thm-11 instance from a d-regular graph.
pub fn dks_to_asp(g: &Graph, d: usize) -> AspInstance {
    assert!(g.is_regular(d), "reduction requires a d-regular graph");
    let n = g.n;
    let m = n * d / 2; // |E|
    assert!(m >= n, "need |E| >= |V| (d >= 2) to pad C square");

    // Edge enumeration: (u, v) with u < v, in adjacency order.
    let mut edge_id = std::collections::HashMap::new();
    let mut next = 0usize;
    for u in 0..n {
        for &v in &g.adj[u] {
            if u < v {
                edge_id.insert((u, v), next);
                next += 1;
            }
        }
    }
    assert_eq!(next, m);

    // Column j < n: incidence of vertex j (rows = edges touching j).
    // Column j >= n: zero.
    let mut supports: Vec<Vec<usize>> = Vec::with_capacity(m);
    for v in 0..n {
        let rows: Vec<usize> = g.adj[v]
            .iter()
            .map(|&u| {
                let key = (v.min(u), v.max(u));
                edge_id[&key]
            })
            .collect();
        supports.push(rows);
    }
    supports.resize(m, Vec::new());

    AspInstance { c: CscMatrix::from_supports(m, supports), n_vertices: n, degree: d, num_edges: m }
}

/// | lhs - rhs | of eq. 4.2/4.3 for a given vertex subset:
/// lhs = the actual one-step objective on the survivors encoding S,
/// rhs = 2ρ² e(S) + dρ² |S| - 2ρ d |S| + |E|.
pub fn objective_identity_gap(inst: &AspInstance, g: &Graph, subset: &[usize], rho: f64) -> f64 {
    let survivors = inst.survivors_for_subset(subset);
    let lhs = super::asp_objective(&inst.c, &survivors, rho);
    let e_s = g.edges_within(subset) as f64;
    let t = subset.len() as f64;
    let d = inst.degree as f64;
    let rhs = 2.0 * rho * rho * e_s + d * rho * rho * t - 2.0 * rho * d * t
        + inst.num_edges as f64;
    (lhs - rhs).abs()
}

/// Greedy densest-t-subgraph by min-degree peeling (the classic charikar
/// style heuristic): repeatedly delete the vertex with the fewest edges
/// into the surviving set until t vertices remain.
pub fn greedy_dks(g: &Graph, t: usize) -> Vec<usize> {
    assert!(t <= g.n && t >= 1);
    let mut alive = vec![true; g.n];
    let mut deg: Vec<usize> = (0..g.n).map(|v| g.degree(v)).collect();
    let mut remaining = g.n;
    while remaining > t {
        let v = (0..g.n)
            .filter(|&v| alive[v])
            .min_by_key(|&v| deg[v])
            .unwrap();
        alive[v] = false;
        remaining -= 1;
        for &u in &g.adj[v] {
            if alive[u] {
                deg[u] -= 1;
            }
        }
    }
    (0..g.n).filter(|&v| alive[v]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::random_regular_graph;
    use crate::util::Rng;

    #[test]
    fn instance_shape_and_sparsity() {
        let g = Graph::ring_lattice(10, 4);
        let inst = dks_to_asp(&g, 4);
        assert_eq!(inst.num_edges, 20);
        assert_eq!(inst.c.rows, 20);
        assert_eq!(inst.c.cols, 20);
        // Incidence columns have exactly d entries; padding columns zero.
        for v in 0..10 {
            assert_eq!(inst.c.col_nnz(v), 4);
        }
        for j in 10..20 {
            assert_eq!(inst.c.col_nnz(j), 0);
        }
        // Every edge row has exactly 2 incidences.
        assert!(inst.c.row_degrees().iter().all(|&d| d == 2));
    }

    #[test]
    fn objective_identity_holds_exactly() {
        let mut rng = Rng::new(1);
        let g = random_regular_graph(12, 4, &mut rng);
        let inst = dks_to_asp(&g, 4);
        for rho in [0.1, 0.3, 0.5, 0.65] {
            for _ in 0..10 {
                let t = 1 + rng.usize(12);
                let subset = rng.sample_indices(12, t);
                let gap = objective_identity_gap(&inst, &g, &subset, rho);
                assert!(gap < 1e-9, "identity gap {gap} at rho={rho}, t={t}");
            }
        }
    }

    #[test]
    fn denser_subsets_give_larger_objective() {
        // At fixed |S|, the identity says the objective is increasing in
        // e(S): the ASP adversary is hunting dense subgraphs.
        let g = Graph::ring_lattice(12, 4);
        let inst = dks_to_asp(&g, 4);
        let rho = 0.5;
        // Contiguous run on the ring (dense) vs spread-out (sparse).
        let dense: Vec<usize> = (0..4).collect();
        let sparse = vec![0, 3, 6, 9];
        let dense_obj =
            super::super::asp_objective(&inst.c, &inst.survivors_for_subset(&dense), rho);
        let sparse_obj =
            super::super::asp_objective(&inst.c, &inst.survivors_for_subset(&sparse), rho);
        assert!(g.edges_within(&dense) > g.edges_within(&sparse));
        assert!(dense_obj > sparse_obj, "{dense_obj} <= {sparse_obj}");
    }

    #[test]
    fn greedy_dks_returns_t_vertices_preferring_density() {
        let mut rng = Rng::new(2);
        let g = random_regular_graph(20, 4, &mut rng);
        let s = greedy_dks(&g, 8);
        assert_eq!(s.len(), 8);
        // Compare with mean density of random subsets.
        let mut rand_edges = 0.0;
        for _ in 0..50 {
            rand_edges += g.edges_within(&rng.sample_indices(20, 8)) as f64;
        }
        rand_edges /= 50.0;
        assert!(
            g.edges_within(&s) as f64 >= rand_edges,
            "greedy {} < random mean {rand_edges}",
            g.edges_within(&s)
        );
    }

    #[test]
    #[should_panic(expected = "d-regular")]
    fn rejects_irregular_graph() {
        let mut g = Graph::ring_lattice(8, 2);
        g.adj[0].push(4);
        g.adj[4].push(0);
        for a in g.adj.iter_mut() {
            a.sort_unstable();
        }
        dks_to_asp(&g, 2);
    }
}
