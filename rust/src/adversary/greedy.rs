//! Greedy polynomial-time adversary for general codes.
//!
//! Since r-ASP is NP-hard (Thm 11), a realistic adversary is a greedy
//! heuristic: start from all n workers surviving and repeatedly kill the
//! worker whose removal most increases the one-step decoding error of
//! the survivors. Incremental row-sum maintenance makes each sweep
//! O(n · nnz/n) = O(nnz), total O((n-r) · n · s̄).

use super::Adversary;
use crate::linalg::CscMatrix;

/// Greedily pick r survivors that (locally) maximize err_1.
pub fn greedy_stragglers(g: &CscMatrix, r: usize, rho: f64) -> Vec<usize> {
    assert!(r <= g.cols && r >= 1);
    let k = g.rows;
    let mut alive: Vec<bool> = vec![true; g.cols];
    let mut alive_count = g.cols;

    // row_sums of the surviving submatrix.
    let mut sums = g.row_sums();
    // Current objective: sum_i (rho * sums[i] - 1)^2 — maintained lazily
    // per candidate via the delta of its column.
    while alive_count > r {
        let mut best_j = usize::MAX;
        let mut best_delta = f64::NEG_INFINITY;
        for j in 0..g.cols {
            if !alive[j] {
                continue;
            }
            // Removing column j changes rows in its support:
            // delta = sum_{(i,v) in col j} [ (rho(sums_i - v) - 1)^2
            //                                - (rho sums_i - 1)^2 ]
            let mut delta = 0.0;
            for (i, v) in g.col(j) {
                let before = rho * sums[i] - 1.0;
                let after = rho * (sums[i] - v) - 1.0;
                delta += after * after - before * before;
            }
            if delta > best_delta {
                best_delta = delta;
                best_j = j;
            }
        }
        debug_assert!(best_j != usize::MAX);
        alive[best_j] = false;
        alive_count -= 1;
        for (i, v) in g.col(best_j) {
            sums[i] -= v;
        }
        debug_assert!(sums.len() == k);
    }
    (0..g.cols).filter(|&j| alive[j]).collect()
}

#[derive(Clone, Copy, Debug)]
pub struct GreedyAdversary {
    pub rho: f64,
}

impl Adversary for GreedyAdversary {
    fn worst_non_stragglers(&self, g: &CscMatrix, r: usize) -> Vec<usize> {
        greedy_stragglers(g, r, self.rho)
    }

    fn name(&self) -> &'static str {
        "greedy"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adversary::asp_objective;
    use crate::codes::{BernoulliCode, FractionalRepetitionCode, GradientCode};
    use crate::stragglers::{StragglerModel, UniformStragglers};
    use crate::util::Rng;

    #[test]
    fn returns_exactly_r_sorted_survivors() {
        let g = BernoulliCode::new(30, 30, 4).assignment(&mut Rng::new(1));
        let ns = greedy_stragglers(&g, 18, 30.0 / (18.0 * 4.0));
        assert_eq!(ns.len(), 18);
        assert!(ns.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn beats_random_stragglers_on_average() {
        let (k, s, r) = (40usize, 5usize, 28usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let g = BernoulliCode::new(k, k, s).assignment(&mut Rng::new(2));
        let greedy_obj = asp_objective(&g, &greedy_stragglers(&g, r, rho), rho);
        let mut rng = Rng::new(3);
        let model = UniformStragglers::new(1.0 - r as f64 / k as f64);
        let mut rand_obj = 0.0;
        let trials = 50;
        for _ in 0..trials {
            rand_obj += asp_objective(&g, &model.non_stragglers(k, &mut rng), rho);
        }
        rand_obj /= trials as f64;
        assert!(
            greedy_obj > rand_obj,
            "greedy {greedy_obj} should beat random {rand_obj}"
        );
    }

    #[test]
    fn recovers_block_attack_on_frc() {
        // On FRC the greedy adversary should find (close to) the block
        // attack's objective: killing whole blocks.
        let (k, s, r) = (20usize, 4usize, 12usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(4));
        let greedy_obj = asp_objective(&g, &greedy_stragglers(&g, r, rho), rho);
        let block_obj = asp_objective(
            &g,
            &crate::adversary::frc_worst_stragglers(&g, r),
            rho,
        );
        assert!(
            greedy_obj >= 0.8 * block_obj,
            "greedy {greedy_obj} far below block attack {block_obj}"
        );
    }

    #[test]
    fn r_equals_n_removes_nothing() {
        let g = BernoulliCode::new(10, 10, 2).assignment(&mut Rng::new(5));
        let ns = greedy_stragglers(&g, 10, 1.0);
        assert_eq!(ns, (0..10).collect::<Vec<_>>());
    }
}
