//! The FRC block attack (paper §4.1, Thm 10).
//!
//! FRC columns come in k/s groups of identical columns. Killing all s
//! copies of a group zeroes those s coordinates of the decode, adding s
//! to err(A). The attack greedily kills ⌊(k-r)/s⌋ whole groups (plus a
//! partial group with the leftover budget, which contributes nothing —
//! it only wastes budget, which is why the adversary kills whole groups
//! first). Works on any column-permuted FRC: groups are recovered by
//! hashing column supports, O(k) expected time — matching the paper's
//! "quadratic time with access to G only" bound with room to spare.

use super::Adversary;
use crate::linalg::CscMatrix;
use std::collections::HashMap;

/// Choose the r non-stragglers that maximize FRC decoding error:
/// keep workers covering as few distinct blocks as possible.
pub fn frc_worst_stragglers(g: &CscMatrix, r: usize) -> Vec<usize> {
    assert!(r <= g.cols);
    // Group columns by identical support (the FRC blocks).
    let mut groups: HashMap<&[usize], Vec<usize>> = HashMap::new();
    for j in 0..g.cols {
        groups.entry(g.col_support(j)).or_default().push(j);
    }
    // Keep whole groups while budget lasts: every fully-kept group leaves
    // err unchanged; every fully-killed group adds its block size.
    let mut groups: Vec<Vec<usize>> = groups.into_values().collect();
    // Deterministic order: by first column index.
    groups.sort_by_key(|cols| cols[0]);

    let mut survivors = Vec::with_capacity(r);
    // Prefer to *fill* the survivor set with as few groups as possible,
    // so the killed budget wipes out whole groups. Taking the largest
    // groups first minimizes the number of partially-surviving groups.
    groups.sort_by_key(|cols| std::cmp::Reverse(cols.len()));
    for group in &groups {
        if survivors.len() == r {
            break;
        }
        let take = group.len().min(r - survivors.len());
        survivors.extend_from_slice(&group[..take]);
    }
    survivors.sort_unstable();
    survivors
}

/// Trait adapter.
#[derive(Clone, Copy, Debug, Default)]
pub struct FrcAttack;

impl Adversary for FrcAttack {
    fn worst_non_stragglers(&self, g: &CscMatrix, r: usize) -> Vec<usize> {
        frc_worst_stragglers(g, r)
    }

    fn name(&self) -> &'static str {
        "frc-block-attack"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{FractionalRepetitionCode, GradientCode};
    use crate::decode::OptimalDecoder;
    use crate::util::Rng;

    #[test]
    fn forces_err_equal_k_minus_r() {
        // Thm 10: err(A) = k - r when s | (k - r).
        let (k, s) = (20, 5);
        let code = FractionalRepetitionCode::new(k, k, s);
        let g = code.assignment(&mut Rng::new(1));
        for r in [5, 10, 15] {
            let ns = frc_worst_stragglers(&g, r);
            assert_eq!(ns.len(), r);
            let a = g.select_columns(&ns);
            let err = OptimalDecoder::new().err(&a);
            assert!(
                (err - (k - r) as f64).abs() < 1e-8,
                "r={r}: err {err} != {}",
                k - r
            );
        }
    }

    #[test]
    fn partial_budget_wastes_nothing_extra() {
        // k=20, s=5, r=12: survivors fill 2 groups fully + 2 of a third;
        // 1 group fully killed -> err = 5 = floor((k-r)/s)*s.
        let (k, s, r) = (20usize, 5usize, 12usize);
        let code = FractionalRepetitionCode::new(k, k, s);
        let g = code.assignment(&mut Rng::new(2));
        let ns = frc_worst_stragglers(&g, r);
        let err = OptimalDecoder::new().err(&g.select_columns(&ns));
        let expect = ((k - r) / s * s) as f64;
        assert!((err - expect).abs() < 1e-8, "err {err} != {expect}");
    }

    #[test]
    fn attack_survives_column_permutation() {
        let (k, s, r) = (24usize, 4usize, 12usize);
        let code = FractionalRepetitionCode::new(k, k, s);
        let g = code.assignment(&mut Rng::new(3));
        // Permute columns.
        let mut rng = Rng::new(4);
        let mut perm: Vec<usize> = (0..k).collect();
        rng.shuffle(&mut perm);
        let gp = g.select_columns(&perm);
        let ns = frc_worst_stragglers(&gp, r);
        let err = OptimalDecoder::new().err(&gp.select_columns(&ns));
        assert!((err - (k - r) as f64).abs() < 1e-8, "err {err}");
    }

    #[test]
    fn adversarial_much_worse_than_random_average() {
        let (k, s, r) = (100usize, 10usize, 80usize);
        let code = FractionalRepetitionCode::new(k, k, s);
        let g = code.assignment(&mut Rng::new(5));
        let adv_err = OptimalDecoder::new().err(&g.select_columns(&frc_worst_stragglers(&g, r)));
        // Random straggler average (Thm 6): k * C(k-s, r-s)/C(k, r) ≈ tiny.
        let mut rng = Rng::new(6);
        let mut rand_err = 0.0;
        for _ in 0..20 {
            let idx = rng.sample_indices(k, r);
            rand_err += OptimalDecoder::new().err(&g.select_columns(&idx));
        }
        rand_err /= 20.0;
        assert!(adv_err >= 20.0 - 1e-9, "adv {adv_err}");
        assert!(adv_err > 5.0 * (rand_err + 1e-12), "adv {adv_err} vs random {rand_err}");
    }
}
