//! Exhaustive adversary — the ground-truth worst case for tiny n.
//!
//! Enumerates all C(n, r) survivor sets. Exponential, so it is gated to
//! n <= 24; used in tests and the thm11 table to measure the optimality
//! gap of the polynomial heuristics (greedy / local search).

use super::asp_objective_with;
use crate::linalg::CscMatrix;

/// Max n for which exhaustive enumeration is permitted.
pub const MAX_N: usize = 24;

/// The true worst-case survivor set and its objective value.
pub fn exhaustive_worst_case(g: &CscMatrix, r: usize, rho: f64) -> (Vec<usize>, f64) {
    let n = g.cols;
    assert!(n <= MAX_N, "exhaustive adversary capped at n <= {MAX_N}");
    assert!(r <= n && r >= 1);

    let mut best_obj = f64::NEG_INFINITY;
    let mut best: Vec<usize> = Vec::new();
    // One coverage accumulator reused across all C(n, r) evaluations.
    let mut row_acc = Vec::new();
    // Iterate over all r-subsets via the "revolving door" of bitmasks.
    let mut comb: Vec<usize> = (0..r).collect();
    loop {
        let obj = asp_objective_with(g, &comb, rho, &mut row_acc);
        if obj > best_obj {
            best_obj = obj;
            best = comb.clone();
        }
        // Next combination in lexicographic order.
        let mut i = r;
        loop {
            if i == 0 {
                return (best, best_obj);
            }
            i -= 1;
            if comb[i] != i + n - r {
                break;
            }
        }
        comb[i] += 1;
        for j in i + 1..r {
            comb[j] = comb[j - 1] + 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adversary::{asp_objective, greedy_stragglers, local_search_stragglers};
    use crate::codes::{BernoulliCode, FractionalRepetitionCode, GradientCode};
    use crate::util::Rng;

    #[test]
    fn finds_block_kill_on_tiny_frc() {
        let (k, s, r) = (8usize, 2usize, 6usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let g = FractionalRepetitionCode::new(k, k, s).assignment(&mut Rng::new(1));
        let (_best, obj) = exhaustive_worst_case(&g, r, rho);
        // Killing one whole block leaves 2 tasks uncovered; each
        // uncovered row contributes 1. Plus the kept-rows deviation from
        // rho-scaling. Sanity: objective >= 2 (the uncovered rows).
        assert!(obj >= 2.0 - 1e-9, "{obj}");
    }

    #[test]
    fn upper_bounds_heuristics() {
        let (k, s, r) = (12usize, 3usize, 8usize);
        let rho = k as f64 / (r as f64 * s as f64);
        for seed in 0..4 {
            let g = BernoulliCode::new(k, k, s).assignment(&mut Rng::new(seed));
            let (_, exact) = exhaustive_worst_case(&g, r, rho);
            let greedy = asp_objective(&g, &greedy_stragglers(&g, r, rho), rho);
            let ls = asp_objective(&g, &local_search_stragglers(&g, r, rho, 10), rho);
            assert!(exact >= greedy - 1e-9, "exact {exact} < greedy {greedy}");
            assert!(exact >= ls - 1e-9, "exact {exact} < local search {ls}");
        }
    }

    #[test]
    fn enumerates_all_subsets_r_equals_n() {
        let g = BernoulliCode::new(6, 6, 2).assignment(&mut Rng::new(5));
        let (best, obj) = exhaustive_worst_case(&g, 6, 0.5);
        assert_eq!(best, (0..6).collect::<Vec<_>>());
        assert!((obj - asp_objective(&g, &best, 0.5)).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "capped")]
    fn rejects_large_n() {
        let g = BernoulliCode::new(30, 30, 2).assignment(&mut Rng::new(6));
        exhaustive_worst_case(&g, 10, 1.0);
    }
}
