//! Local-search adversary: refine a straggler set by 1-swaps.
//!
//! Starts from a seed solution (greedy or random) and repeatedly swaps
//! one survivor with one straggler when the swap increases the one-step
//! objective, until a local optimum or the sweep budget is exhausted.
//! This is the strongest polynomial adversary in the suite and the one
//! the thm11 table uses to show heuristics stall on BGCs.

use super::{greedy_stragglers, Adversary};
#[cfg(test)]
use super::asp_objective;
use crate::linalg::CscMatrix;

/// Improve `survivors` by 1-swaps. Returns the locally-optimal set.
pub fn local_search_stragglers(
    g: &CscMatrix,
    r: usize,
    rho: f64,
    max_sweeps: usize,
) -> Vec<usize> {
    let mut survivors = greedy_stragglers(g, r, rho);
    let mut in_set = vec![false; g.cols];
    for &j in &survivors {
        in_set[j] = true;
    }
    // Maintain row sums of the survivor submatrix.
    let mut sums = vec![0.0; g.rows];
    for &j in &survivors {
        for (i, v) in g.col(j) {
            sums[i] += v;
        }
    }
    let term = |x: f64| (rho * x - 1.0).powi(2);

    for _ in 0..max_sweeps {
        let mut improved = false;
        for out_pos in 0..survivors.len() {
            let out_j = survivors[out_pos];
            // Delta of removing out_j.
            let mut remove_delta = 0.0;
            for (i, v) in g.col(out_j) {
                remove_delta += term(sums[i] - v) - term(sums[i]);
            }
            // Try every straggler as a replacement.
            let mut best_in = usize::MAX;
            let mut best_total = 0.0f64;
            for in_j in 0..g.cols {
                if in_set[in_j] || in_j == out_j {
                    continue;
                }
                // Delta of adding in_j after removing out_j. Supports may
                // overlap, so compute on the updated sums lazily.
                let mut add_delta = 0.0;
                // sums' = sums - col(out_j); evaluate add on sums'.
                // Build overlap-aware: for rows in in_j's support,
                // subtract out_j's value if shared.
                for (i, v_in) in g.col(in_j) {
                    let v_out = g
                        .col(out_j)
                        .find(|&(io, _)| io == i)
                        .map(|(_, v)| v)
                        .unwrap_or(0.0);
                    let base = sums[i] - v_out;
                    add_delta += term(base + v_in) - term(base);
                }
                let total = remove_delta + add_delta;
                if total > best_total + 1e-12 {
                    best_total = total;
                    best_in = in_j;
                }
            }
            if best_in != usize::MAX {
                // Apply the swap.
                for (i, v) in g.col(out_j) {
                    sums[i] -= v;
                }
                for (i, v) in g.col(best_in) {
                    sums[i] += v;
                }
                in_set[out_j] = false;
                in_set[best_in] = true;
                survivors[out_pos] = best_in;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    survivors.sort_unstable();
    survivors
}

#[derive(Clone, Copy, Debug)]
pub struct LocalSearchAdversary {
    pub rho: f64,
    pub max_sweeps: usize,
}

impl Adversary for LocalSearchAdversary {
    fn worst_non_stragglers(&self, g: &CscMatrix, r: usize) -> Vec<usize> {
        local_search_stragglers(g, r, self.rho, self.max_sweeps)
    }

    fn name(&self) -> &'static str {
        "local-search"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{BernoulliCode, GradientCode};
    use crate::util::Rng;

    #[test]
    fn never_worse_than_greedy_seed() {
        let (k, s, r) = (30usize, 4usize, 20usize);
        let rho = k as f64 / (r as f64 * s as f64);
        for seed in 0..5 {
            let g = BernoulliCode::new(k, k, s).assignment(&mut Rng::new(seed));
            let greedy_obj = asp_objective(&g, &greedy_stragglers(&g, r, rho), rho);
            let ls = local_search_stragglers(&g, r, rho, 10);
            let ls_obj = asp_objective(&g, &ls, rho);
            assert!(
                ls_obj >= greedy_obj - 1e-9,
                "seed {seed}: local search {ls_obj} < greedy {greedy_obj}"
            );
        }
    }

    #[test]
    fn returns_valid_survivor_set() {
        let g = BernoulliCode::new(20, 20, 3).assignment(&mut Rng::new(9));
        let ls = local_search_stragglers(&g, 12, 20.0 / 36.0, 5);
        assert_eq!(ls.len(), 12);
        assert!(ls.windows(2).all(|w| w[0] < w[1]));
        assert!(ls.iter().all(|&j| j < 20));
    }

    #[test]
    fn zero_sweeps_equals_greedy() {
        let (k, s, r) = (25usize, 3usize, 15usize);
        let rho = k as f64 / (r as f64 * s as f64);
        let g = BernoulliCode::new(k, k, s).assignment(&mut Rng::new(10));
        let mut greedy = greedy_stragglers(&g, r, rho);
        greedy.sort_unstable();
        assert_eq!(local_search_stragglers(&g, r, rho, 0), greedy);
    }
}
