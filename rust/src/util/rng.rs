//! Deterministic, seedable PRNG (xoshiro256++ seeded via SplitMix64).
//!
//! The whole simulation stack is seeded so every figure/table in
//! EXPERIMENTS.md is exactly reproducible. `fork(i)` derives independent
//! per-trial / per-thread streams, which is what the Monte-Carlo engine
//! uses to parallelize trials without sharing state.

/// SplitMix64 step — used for seeding and stream derivation.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box-Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream for trial/thread `i`.
    pub fn fork(&self, i: u64) -> Rng {
        // Mix the current state with the stream index through SplitMix64.
        let mut sm = self.s[0] ^ self.s[2].rotate_left(17) ^ i.wrapping_mul(0xA24B_AED4_963E_E407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased (rejection on the tail).
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "usize(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) uniformly without
    /// replacement (partial Fisher-Yates, O(n) memory, O(m) swaps).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "sample_indices: m={m} > n={n}");
        let mut pool: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.usize(n - i);
            pool.swap(i, j);
        }
        pool.truncate(m);
        pool
    }

    /// [`Rng::sample_indices`] into caller-owned buffers: `pool` is the
    /// Fisher-Yates scratch (resized to n, capacity kept) and `out`
    /// receives the m sampled indices. Draws the identical RNG stream
    /// as the allocating variant, so seeded simulations are unchanged;
    /// zero heap allocation once both buffers have warmed up.
    pub fn sample_indices_into(
        &mut self,
        n: usize,
        m: usize,
        pool: &mut Vec<usize>,
        out: &mut Vec<usize>,
    ) {
        assert!(m <= n, "sample_indices_into: m={m} > n={n}");
        pool.clear();
        pool.extend(0..n);
        for i in 0..m {
            let j = i + self.usize(n - i);
            pool.swap(i, j);
        }
        out.clear();
        out.extend_from_slice(&pool[..m]);
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Exponential with rate `lambda` (mean 1/lambda), via inverse CDF.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Pareto with scale `x_m` and shape `alpha` (heavy-tailed straggler
    /// latencies), via inverse CDF.
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        assert!(x_m > 0.0 && alpha > 0.0);
        x_m / (1.0 - self.f64()).powf(1.0 / alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_streams_are_independent_and_deterministic() {
        let root = Rng::new(7);
        let mut a1 = root.fork(0);
        let mut a2 = root.fork(0);
        let mut b = root.fork(1);
        assert_eq!(a1.next_u64(), a2.next_u64());
        assert_ne!(a1.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_uniformish() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.usize(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c} out of range");
        }
    }

    #[test]
    fn bernoulli_mean() {
        let mut r = Rng::new(5);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        let mean = hits as f64 / 100_000.0;
        assert!((mean - 0.3).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn sample_indices_into_matches_allocating_variant() {
        let mut a = Rng::new(6);
        let mut b = Rng::new(6);
        let (mut pool, mut out) = (Vec::new(), Vec::new());
        for trial in 0..50 {
            let m = trial % 21;
            let reference = a.sample_indices(50, m);
            b.sample_indices_into(50, m, &mut pool, &mut out);
            assert_eq!(out, reference, "trial {trial}");
        }
        // Streams stayed in lockstep throughout.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(6);
        for _ in 0..100 {
            let s = r.sample_indices(50, 20);
            assert_eq!(s.len(), 20);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 20);
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn sample_indices_full_is_permutation() {
        let mut r = Rng::new(7);
        let mut s = r.sample_indices(10, 10);
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(8);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn pareto_lower_bound() {
        let mut r = Rng::new(10);
        for _ in 0..10_000 {
            assert!(r.pareto(1.5, 2.0) >= 1.5);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
