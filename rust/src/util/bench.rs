//! Micro-benchmark harness for the `harness = false` bench targets.
//!
//! Criterion is not in the offline vendor set, so this provides the same
//! workflow: warmup, timed iterations, median/p10/p90 reporting, and a
//! `black_box` to defeat const-folding. Output is one line per benchmark,
//! machine-grepable for EXPERIMENTS.md.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark runner. Prints `bench <name> ... median=<t> p10=<t> p90=<t>`.
pub struct Bencher {
    /// Minimum wall-clock time to spend measuring each benchmark.
    pub measure_time: Duration,
    /// Warmup time before measurement.
    pub warmup_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure_time: Duration::from_millis(700),
            warmup_time: Duration::from_millis(200),
        }
    }
}

/// Format a duration with appropriate unit.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.3}s", ns / 1_000_000_000.0)
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            measure_time: Duration::from_millis(250),
            warmup_time: Duration::from_millis(50),
        }
    }

    /// Benchmark `f`, returning the median per-iteration time.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Duration {
        // Warmup + estimate per-iter cost.
        let warm_start = Instant::now();
        let mut iters_done = 0u64;
        while warm_start.elapsed() < self.warmup_time || iters_done == 0 {
            std_black_box(f());
            iters_done += 1;
            if iters_done > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / iters_done as f64;

        // Choose a batch size so each sample takes ~1/50 of measure_time.
        let target_sample = self.measure_time.as_secs_f64() / 50.0;
        let batch = ((target_sample / per_iter.max(1e-12)).ceil() as u64).max(1);

        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure_time || samples.len() < 10 {
            let t0 = Instant::now();
            for _ in 0..batch {
                std_black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
            if samples.len() > 10_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> Duration {
            let idx = ((samples.len() - 1) as f64 * p).round() as usize;
            Duration::from_secs_f64(samples[idx])
        };
        let (p10, med, p90) = (q(0.10), q(0.50), q(0.90));
        println!(
            "bench {name:<48} median={:<10} p10={:<10} p90={:<10} samples={}",
            fmt_duration(med),
            fmt_duration(p10),
            fmt_duration(p90),
            samples.len()
        );
        med
    }

    /// Benchmark and report a derived throughput (items/sec).
    pub fn bench_throughput<T>(&self, name: &str, items: u64, f: impl FnMut() -> T) -> f64 {
        let med = self.bench(name, f);
        let thr = items as f64 / med.as_secs_f64();
        println!("bench {name:<48} throughput={thr:.3e} items/s");
        thr
    }
}

/// True when benches should run in quick mode (CI / `make test`).
pub fn quick_mode() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v != "0").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_positive_time() {
        let b = Bencher {
            measure_time: Duration::from_millis(30),
            warmup_time: Duration::from_millis(5),
        };
        let med = b.bench("noop-ish", || black_box(3u64).wrapping_mul(7));
        assert!(med.as_nanos() > 0);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("us"));
        assert!(fmt_duration(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }
}
