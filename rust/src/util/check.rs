//! Tiny property-testing harness (proptest is not in the vendor set).
//!
//! `property(cases, seed, |rng| ...)` runs a closure over `cases` forked
//! RNG streams; on failure it reports the failing case index + seed so
//! the exact case can be replayed with `Rng::new(seed).fork(i)`.

use super::rng::Rng;

/// Run `f` over `cases` independent RNG streams; panic with a replayable
/// (seed, case) pair on the first failure.
pub fn property(cases: usize, seed: u64, f: impl Fn(&mut Rng) -> Result<(), String>) {
    let root = Rng::new(seed);
    for i in 0..cases {
        let mut rng = root.fork(i as u64);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed at case {i} (seed {seed}): {msg}");
        }
    }
}

/// Assert two floats are within absolute + relative tolerance.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol})"))
    }
}

/// Assert a predicate with a formatted message.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_passes_trivially() {
        property(50, 1, |rng| {
            let x = rng.f64();
            ensure((0.0..1.0).contains(&x), format!("{x} out of range"))
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn property_reports_failure() {
        property(10, 2, |rng| ensure(rng.f64() < 0.5, "too big"));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(close(1e6, 1e6 + 1.0, 1e-9).is_err());
        assert!(close(1e6, 1e6 + 1.0, 1e-5).is_ok());
    }
}
