//! Minimal JSON parser *and writer* — reads `artifacts/manifest.json`
//! and round-trips the Monte-Carlo shard artifacts (`sim::shard`).
//!
//! Hand-rolled because the offline vendor set has no serde_json; this is
//! a strict RFC-8259 subset parser (no comments, no trailing commas)
//! plus a writer whose output the parser accepts verbatim:
//! `Json::parse(&j.write())` reproduces `j` for any value the shard
//! pipeline produces. Numbers are emitted via Rust's shortest
//! round-tripping float formatting; exact f64 interchange (bit
//! patterns) is layered above this module by `sim::shard`, which
//! encodes payload floats as hex strings.
//!
//! The writer is generic over [`fmt::Write`]: the `String`-returning
//! entry points ([`Json::write`], [`Json::write_pretty`],
//! [`Json::write_excluding`]) and the streaming ones
//! ([`Json::write_compact_to`], [`Json::write_excluding_to`]) share one
//! kernel, so a sink that folds a checksum (`sim::shard`'s FNV-1a
//! state) sees byte-for-byte the same serialization without the body
//! `String` ever being materialized.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (wanted key {key:?})"),
        }
    }

    /// Optional object field lookup: `None` when the key is absent (or
    /// when `self` is not an object). Callers that treat absence as an
    /// error use [`Json::get`]; this is for schema fields that older
    /// artifact versions legitimately omit (e.g. the shard artifact's
    /// `checksum`, absent in the v1 format).
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if !(x >= 0.0 && x < 18_446_744_073_709_551_616.0) || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        // Past 2^53 (and past usize::MAX on 32-bit targets) `x as usize`
        // saturates or lands on a value the document never contained, so
        // a corrupted trial count or shard id would parse to a silently
        // wrong number. Accept only values that survive the
        // usize -> f64 -> usize round trip exactly.
        let u = x as usize;
        if u as f64 != x {
            bail!("integer {x} does not round-trip through f64 exactly (precision lost)");
        }
        Ok(u)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Serialize compactly (no whitespace). The output parses back to
    /// an equal value: strings are escaped per RFC 8259 and numbers use
    /// Rust's shortest round-tripping formatting. Non-finite numbers
    /// have no JSON representation and are written as `null` (callers
    /// that need exact f64 interchange — the shard artifacts — encode
    /// bit patterns as strings instead of relying on `Json::Num`).
    pub fn write(&self) -> String {
        let mut out = String::new();
        self.write_to(&mut out, None, 0).expect("String sink never fails");
        out
    }

    /// Serialize with 2-space indentation (readable artifact diffs).
    /// Parses back identically to [`Json::write`]'s output.
    pub fn write_pretty(&self) -> String {
        let mut out = String::new();
        self.write_to(&mut out, Some(2), 0).expect("String sink never fails");
        out.push('\n');
        out
    }

    /// Stream the compact serialization into any [`fmt::Write`] sink —
    /// byte-identical to [`Json::write`] without materializing the
    /// `String`. This is what lets the shard-artifact checksum fold a
    /// hash over multi-megabyte bodies allocation-free (the sink is
    /// the hash state).
    pub fn write_compact_to<W: fmt::Write>(&self, w: &mut W) -> fmt::Result {
        self.write_to(w, None, 0)
    }

    /// Compact serialization of an object with one **top-level** key
    /// omitted — byte-identical to removing the key from a clone and
    /// calling [`Json::write`], but without deep-cloning the value tree.
    /// Kept for tests and small bodies; the shard-artifact checksum
    /// streams through [`Json::write_excluding_to`] instead.
    pub fn write_excluding(&self, skip_key: &str) -> String {
        let mut out = String::new();
        self.write_excluding_to(skip_key, &mut out).expect("String sink never fails");
        out
    }

    /// Streaming form of [`Json::write_excluding`]: serialize into any
    /// [`fmt::Write`] sink with one top-level key omitted, never
    /// materializing the body. Byte-identical to `write_excluding`
    /// (pinned by tests here and by the shard checksum pin).
    pub fn write_excluding_to<W: fmt::Write>(&self, skip_key: &str, w: &mut W) -> fmt::Result {
        match self {
            Json::Obj(map) => {
                w.write_char('{')?;
                let mut first = true;
                for (key, val) in map {
                    if key == skip_key {
                        continue;
                    }
                    if !first {
                        w.write_char(',')?;
                    }
                    first = false;
                    write_escaped(key, w)?;
                    w.write_char(':')?;
                    val.write_to(w, None, 0)?;
                }
                w.write_char('}')
            }
            other => other.write_to(w, None, 0),
        }
    }

    fn write_to<W: fmt::Write>(&self, out: &mut W, indent: Option<usize>, level: usize) -> fmt::Result {
        match self {
            Json::Null => out.write_str("null"),
            Json::Bool(true) => out.write_str("true"),
            Json::Bool(false) => out.write_str("false"),
            Json::Num(x) => {
                if x.is_finite() {
                    write!(out, "{x}")
                } else {
                    debug_assert!(false, "non-finite number {x} has no JSON form");
                    out.write_str("null")
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                if items.is_empty() {
                    return out.write_str("[]");
                }
                out.write_char('[')?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.write_char(',')?;
                    }
                    newline_indent(out, indent, level + 1)?;
                    item.write_to(out, indent, level + 1)?;
                }
                newline_indent(out, indent, level)?;
                out.write_char(']')
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    return out.write_str("{}");
                }
                out.write_char('{')?;
                for (i, (key, val)) in map.iter().enumerate() {
                    if i > 0 {
                        out.write_char(',')?;
                    }
                    newline_indent(out, indent, level + 1)?;
                    write_escaped(key, out)?;
                    out.write_char(':')?;
                    if indent.is_some() {
                        out.write_char(' ')?;
                    }
                    val.write_to(out, indent, level + 1)?;
                }
                newline_indent(out, indent, level)?;
                out.write_char('}')
            }
        }
    }
}

fn newline_indent<W: fmt::Write>(out: &mut W, indent: Option<usize>, level: usize) -> fmt::Result {
    if let Some(width) = indent {
        out.write_char('\n')?;
        for _ in 0..width * level {
            out.write_char(' ')?;
        }
    }
    Ok(())
}

fn write_escaped<W: fmt::Write>(s: &str, out: &mut W) -> fmt::Result {
    out.write_char('"')?;
    for c in s.chars() {
        match c {
            '"' => out.write_str("\\\"")?,
            '\\' => out.write_str("\\\\")?,
            '\n' => out.write_str("\\n")?,
            '\r' => out.write_str("\\r")?,
            '\t' => out.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(out, "\\u{:04x}", c as u32)?,
            c => out.write_char(c)?,
        }
    }
    out.write_char('"')
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.i),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        // Accumulate raw bytes and validate UTF-8 once at the end, so
        // multi-byte characters in the input pass through intact
        // (escape sequences are appended in their UTF-8 encoding).
        let mut out: Vec<u8> = Vec::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(String::from_utf8(out).context("invalid UTF-8 in string")?),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    let decoded = match e {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'b' => '\u{8}',
                        b'f' => '\u{c}',
                        b'n' => '\n',
                        b'r' => '\r',
                        b't' => '\t',
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // No surrogate-pair support (BMP only).
                            char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    };
                    let mut buf = [0u8; 4];
                    out.extend_from_slice(decoded.encode_utf8(&mut buf).as_bytes());
                }
                _ => out.push(c),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "c");
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"linear": {"m": 32, "d": 64},
                "artifacts": {"grad_linear": {"file": "grad_linear.hlo.txt",
                                              "inputs": [[32, 64], [64], [32]]}}}"#,
        )
        .unwrap();
        assert_eq!(j.get("linear").unwrap().get("m").unwrap().as_usize().unwrap(), 32);
        let inputs = j
            .get("artifacts")
            .unwrap()
            .get("grad_linear")
            .unwrap()
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(inputs[0].as_arr().unwrap()[1].as_usize().unwrap(), 64);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn opt_is_none_for_missing_keys_and_non_objects() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(j.opt("a").is_some());
        assert!(j.opt("b").is_none());
        assert!(Json::Num(1.0).opt("a").is_none());
    }

    #[test]
    fn as_usize_rejects_fractional_and_negative() {
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Num(-1.0).as_usize().is_err());
        assert_eq!(Json::Num(7.0).as_usize().unwrap(), 7);
    }

    #[test]
    fn as_usize_rejects_values_that_lost_integer_precision() {
        // 1e300 has a zero fraction but `as usize` would saturate to
        // usize::MAX; the old accessor accepted it silently.
        assert!(Json::parse("1e300").unwrap().as_usize().is_err());
        // 2^64 saturates too — and deceptively compares equal after the
        // saturating cast, so the range check must fire first.
        assert!(Json::parse("18446744073709551616").unwrap().as_usize().is_err());
        assert!(Json::Num(f64::INFINITY).as_usize().is_err());
        assert!(Json::Num(f64::NAN).as_usize().is_err());
        // 2^53 is the last f64 with unit spacing; it round-trips.
        assert_eq!(Json::parse("9007199254740992").unwrap().as_usize().unwrap(), 1usize << 53);
        // Exactly-representable values above 2^53 still round-trip and
        // stay accepted (u64 seeds travel as strings, but large exact
        // counts are legitimate).
        assert_eq!(
            Json::parse("1152921504606846976").unwrap().as_usize().unwrap(),
            1usize << 60
        );
    }

    #[test]
    fn write_roundtrips_nested_values() {
        let j = Json::parse(
            r#"{"a": [1, 2.5, {"b": "c"}], "d": {}, "e": [], "f": null, "g": true, "h": -0.125}"#,
        )
        .unwrap();
        assert_eq!(Json::parse(&j.write()).unwrap(), j);
        assert_eq!(Json::parse(&j.write_pretty()).unwrap(), j);
    }

    #[test]
    fn write_escapes_strings() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let text = j.write();
        assert_eq!(text, "\"a\\\"b\\\\c\\nd\\te\\u0001\"");
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn write_numbers_roundtrip_exactly() {
        for x in [0.0, -0.0, 1.0, 0.1, 2e-7, 123456789.25, 5000.0] {
            let text = Json::Num(x).write();
            match Json::parse(&text).unwrap() {
                Json::Num(y) => assert_eq!(y.to_bits(), x.to_bits(), "{text}"),
                other => panic!("parsed {other:?}"),
            }
        }
    }

    #[test]
    fn write_compact_has_no_whitespace() {
        let j = Json::parse(r#"{"a": [1, 2], "b": "x"}"#).unwrap();
        assert_eq!(j.write(), r#"{"a":[1,2],"b":"x"}"#);
    }

    #[test]
    fn write_excluding_matches_remove_then_write() {
        let j = Json::parse(r#"{"a": [1, 2], "checksum": "xx", "z": {"c": 3}}"#).unwrap();
        let Json::Obj(mut m) = j.clone() else { panic!("object") };
        m.remove("checksum");
        assert_eq!(j.write_excluding("checksum"), Json::Obj(m).write());
        // Absent key: identical to a plain write. Only top-level keys
        // are skipped (nested "c" survives).
        assert_eq!(j.write_excluding("nope"), j.write());
        assert_eq!(j.write_excluding("c"), j.write());
        // Excluding the only key leaves the empty object.
        let solo = Json::parse(r#"{"only": 1}"#).unwrap();
        assert_eq!(solo.write_excluding("only"), "{}");
        // Non-objects pass through.
        assert_eq!(Json::Num(1.0).write_excluding("x"), "1");
    }

    #[test]
    fn streaming_writers_match_materializing_writers_byte_for_byte() {
        let j = Json::parse(
            r#"{"a": [1, 2.5, {"b": "c\"d\\e\nf"}], "checksum": "xx", "d": {}, "e": [],
                "f": null, "g": true, "h": -0.125, "i": "δ"}"#,
        )
        .unwrap();
        let mut streamed = String::new();
        j.write_compact_to(&mut streamed).unwrap();
        assert_eq!(streamed, j.write());
        let mut streamed = String::new();
        j.write_excluding_to("checksum", &mut streamed).unwrap();
        assert_eq!(streamed, j.write_excluding("checksum"));
        // Non-objects pass through both paths identically too.
        let n = Json::Num(2e-7);
        let mut streamed = String::new();
        n.write_excluding_to("x", &mut streamed).unwrap();
        assert_eq!(streamed, n.write());
    }

    #[test]
    fn non_ascii_strings_roundtrip() {
        // Raw multi-byte UTF-8 survives parse and write->parse.
        let j = Json::parse("\"\u{03b4}=0.25 \u{2192} ok\"").unwrap();
        assert_eq!(j, Json::Str("\u{03b4}=0.25 \u{2192} ok".into()));
        assert_eq!(Json::parse(&j.write()).unwrap(), j);
        // \u escapes still decode and re-encode as raw UTF-8.
        assert_eq!(Json::parse("\"\\u03b4\"").unwrap(), Json::Str("\u{03b4}".into()));
    }
}
