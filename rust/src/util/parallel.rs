//! Scoped-thread parallel map — the Monte-Carlo engine's backbone.
//!
//! Hand-rolled (no rayon in the offline vendor set): chunks the index
//! space across `threads` OS threads via `std::thread::scope`, preserving
//! output order. Each worker gets its own forked RNG stream upstream, so
//! results are independent of the thread count.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (capped so the figure
/// harness stays polite on shared machines).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Parallel `(0..n).map(f)` with order-preserving output.
///
/// Work is distributed dynamically (atomic counter), so skewed per-item
/// cost (e.g. LSQR on ill-conditioned draws) does not idle threads.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out = vec![T::default(); n];
    let next = AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<Option<T>>> = (0..n).map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                *slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    for (i, slot) in slots.into_iter().enumerate() {
        out[i] = slot.into_inner().unwrap().expect("worker missed slot");
    }
    out
}

/// Parallel mean of `n` trial values (the Monte-Carlo primitive).
pub fn parallel_mean<F>(n: usize, threads: usize, f: F) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    let vals = parallel_map(n, threads, f);
    vals.iter().sum::<f64>() / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map() {
        let f = |i: usize| (i * i) as f64;
        let par = parallel_map(1000, 8, f);
        let ser: Vec<f64> = (0..1000).map(f).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(parallel_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn mean_of_constant() {
        assert!((parallel_mean(100, 4, |_| 2.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let f = |i: usize| (i as f64).sqrt();
        let a = parallel_map(512, 2, f);
        let b = parallel_map(512, 7, f);
        assert_eq!(a, b);
    }
}
