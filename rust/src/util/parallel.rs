//! Scoped-thread parallel map — the Monte-Carlo engine's backbone.
//!
//! Hand-rolled (no rayon in the offline vendor set): workers claim
//! contiguous index chunks off an atomic counter and write results
//! straight into their output slots — no per-item locks, no `Default +
//! Clone` bounds, no post-pass collection. Each worker can build a
//! per-thread workspace via [`parallel_map_with`]'s init hook, which is
//! how the simulation layer reuses decode scratch across trials.
//!
//! Results are position-addressed, so the output is order-preserving
//! and — as long as `f(i)` is a pure function of `i` (each trial forks
//! its own RNG stream upstream) — bit-identical for every thread count.
//!
//! This module is the *intra-process* level of the fan-out hierarchy:
//!
//! 1. **threads within a process** — here, chunked work stealing over
//!    one trial range;
//! 2. **processes/machines** — `sim::shard` slices the trial range into
//!    disjoint shards and merges exact partial aggregates, so the two
//!    levels compose without affecting a single output bit.
//!
//! Both levels lean on the same invariant: trial `i` is a pure function
//! of the trial index (per-trial forked RNG streams), so *where* it
//! runs — which thread, which chunk, which shard, which machine — is
//! unobservable in the results.

use std::mem::{ManuallyDrop, MaybeUninit};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (capped so the figure
/// harness stays polite on shared machines).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Parallel `(0..n).map(f)` with order-preserving output.
///
/// Work is distributed dynamically in chunks (atomic counter), so
/// skewed per-item cost (e.g. LSQR on ill-conditioned draws) does not
/// idle threads, while cheap items don't thrash the counter.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_with(n, threads, || (), move |_ws, i| f(i))
}

/// [`parallel_map`] with a per-thread workspace: every worker thread
/// calls `init()` once and hands the workspace to each `f(&mut ws, i)`
/// it runs. The workspace is scratch only — `f` must fully overwrite
/// whatever state it reads, so results stay independent of which thread
/// (and in which order) ran each item.
pub fn parallel_map_with<W, T, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> W + Sync,
    F: Fn(&mut W, usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n <= 1 {
        let mut ws = init();
        return (0..n).map(|i| f(&mut ws, i)).collect();
    }

    // Chunk size: enough chunks per thread for load balancing (~8×),
    // large enough that the atomic is off the hot path for cheap items.
    let chunk = (n / (threads * 8)).max(1);

    let mut out: Vec<MaybeUninit<T>> = Vec::with_capacity(n);
    // SAFETY: MaybeUninit<T> requires no initialization.
    unsafe { out.set_len(n) };

    /// Shareable pointer to the output slots. Writes are raced-free
    /// because the atomic counter hands every index to exactly one
    /// worker, and the scope join synchronizes them with the reader.
    struct OutPtr<T>(*mut MaybeUninit<T>);
    unsafe impl<T: Send> Sync for OutPtr<T> {}

    let out_ptr = OutPtr(out.as_mut_ptr());
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let out_ptr = &out_ptr;
            let next = &next;
            let init = &init;
            let f = &f;
            scope.spawn(move || {
                let mut ws = init();
                loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        let v = f(&mut ws, i);
                        // SAFETY: index i was claimed by exactly this
                        // worker; slots are disjoint.
                        unsafe { (*out_ptr.0.add(i)).write(v) };
                    }
                }
            });
        }
    });

    // SAFETY: the scope joined every worker, and together they claimed
    // and wrote each index in 0..n exactly once, so all n slots are
    // initialized. Transmute Vec<MaybeUninit<T>> -> Vec<T> in place.
    unsafe {
        let mut out = ManuallyDrop::new(out);
        Vec::from_raw_parts(out.as_mut_ptr() as *mut T, n, out.capacity())
    }
}

/// Panel-granularity [`parallel_map_with`]: `n` trial values produced
/// in panels of `width`, so each worker amortizes its kernel calls over
/// W trials (the multi-RHS decode path). Workers claim whole panels off
/// the atomic counter and `f(&mut ws, panel, out)` writes the panel's
/// values directly into its disjoint output window
/// `out[panel*width .. min((panel+1)*width, n)]` — the final panel may
/// be ragged (fewer than `width` slots). Output is position-addressed,
/// so as long as panel `p` is a pure function of its trial indices the
/// results are bit-identical for every thread count — and, when `f`'s
/// lanes reproduce the scalar per-trial computation, for every width.
pub fn parallel_map_panels_with<W, I, F>(
    n: usize,
    width: usize,
    threads: usize,
    init: I,
    f: F,
) -> Vec<f64>
where
    I: Fn() -> W + Sync,
    F: Fn(&mut W, usize, &mut [f64]) + Sync,
{
    assert!(width >= 1, "panel width must be >= 1");
    let panels = n.div_ceil(width);
    let threads = threads.max(1).min(panels.max(1));
    let mut out = vec![0.0f64; n];
    if threads == 1 || panels <= 1 {
        let mut ws = init();
        for p in 0..panels {
            let lo = p * width;
            let hi = ((p + 1) * width).min(n);
            f(&mut ws, p, &mut out[lo..hi]);
        }
        return out;
    }

    // Same chunked-counter scheme as parallel_map_with, but the unit of
    // work (and of output ownership) is a whole panel.
    let chunk = (panels / (threads * 8)).max(1);

    /// Shareable base pointer to the output; panel windows are disjoint
    /// because the counter hands each panel to exactly one worker.
    struct OutPtr(*mut f64);
    unsafe impl Sync for OutPtr {}

    let out_ptr = OutPtr(out.as_mut_ptr());
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let out_ptr = &out_ptr;
            let next = &next;
            let init = &init;
            let f = &f;
            scope.spawn(move || {
                let mut ws = init();
                loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= panels {
                        break;
                    }
                    let end = (start + chunk).min(panels);
                    for p in start..end {
                        let lo = p * width;
                        let hi = ((p + 1) * width).min(n);
                        // SAFETY: panel p was claimed by exactly this
                        // worker; panel windows partition 0..n, and the
                        // scope join synchronizes writes with the reader.
                        let window =
                            unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(lo), hi - lo) };
                        f(&mut ws, p, window);
                    }
                }
            });
        }
    });
    out
}

/// Parallel mean of `n` trial values (the Monte-Carlo primitive).
pub fn parallel_mean<F>(n: usize, threads: usize, f: F) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    let vals = parallel_map(n, threads, f);
    vals.iter().sum::<f64>() / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map() {
        let f = |i: usize| (i * i) as f64;
        let par = parallel_map(1000, 8, f);
        let ser: Vec<f64> = (0..1000).map(f).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(parallel_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn mean_of_constant() {
        assert!((parallel_mean(100, 4, |_| 2.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let f = |i: usize| (i as f64).sqrt();
        let a = parallel_map(512, 2, f);
        let b = parallel_map(512, 7, f);
        assert_eq!(a, b);
    }

    #[test]
    fn no_default_or_clone_bound_required() {
        // A type that is Send but neither Default nor Clone.
        struct Opaque(#[allow(dead_code)] Box<u64>);
        let v = parallel_map(64, 4, |i| Opaque(Box::new(i as u64)));
        assert_eq!(v.len(), 64);
        assert_eq!(*v[63].0, 63);
    }

    #[test]
    fn workspace_hook_provides_per_thread_scratch() {
        // The workspace is reused within a thread but never shared
        // across threads; f fully overwrites it per item.
        let out = parallel_map_with(
            200,
            4,
            || Vec::<u64>::new(),
            |ws, i| {
                ws.clear();
                ws.extend((0..(i % 7) as u64).map(|x| x + i as u64));
                ws.iter().sum::<u64>()
            },
        );
        let reference: Vec<u64> = (0..200)
            .map(|i| (0..(i % 7) as u64).map(|x| x + i as u64).sum())
            .collect();
        assert_eq!(out, reference);
    }

    #[test]
    fn workspace_results_identical_across_thread_counts() {
        let run = |threads| {
            parallel_map_with(333, threads, || [0f64; 8], |ws, i| {
                for (j, slot) in ws.iter_mut().enumerate() {
                    *slot = (i * j) as f64;
                }
                ws.iter().sum::<f64>()
            })
        };
        let a = run(1);
        let b = run(3);
        let c = run(16);
        assert_eq!(a, b);
        assert_eq!(b, c);
    }

    #[test]
    fn panel_map_matches_per_item_map_for_all_widths_and_threads() {
        // f's lanes reproduce the scalar per-trial value, so the output
        // must be identical for every (width, threads) combination —
        // including ragged tails (137 % width != 0 for most widths).
        let per_item = |i: usize| (i as f64).sqrt() + i as f64;
        let reference: Vec<f64> = (0..137).map(per_item).collect();
        for width in [1usize, 3, 4, 8, 200] {
            for threads in [1usize, 4, 13] {
                let got = parallel_map_panels_with(137, width, threads, || (), |_, p, out| {
                    for (l, slot) in out.iter_mut().enumerate() {
                        *slot = per_item(p * width + l);
                    }
                });
                assert_eq!(got, reference, "width {width} threads {threads}");
            }
        }
    }

    #[test]
    fn panel_map_passes_ragged_tail_window() {
        // 10 trials, width 4 -> panels of 4, 4, 2.
        let sizes = parallel_map_panels_with(10, 4, 1, || (), |_, _p, out| {
            let w = out.len();
            for slot in out.iter_mut() {
                *slot = w as f64;
            }
        });
        assert_eq!(sizes, vec![4.0, 4.0, 4.0, 4.0, 4.0, 4.0, 4.0, 4.0, 2.0, 2.0]);
    }

    #[test]
    fn panel_map_empty_input() {
        let v = parallel_map_panels_with(0, 8, 4, || (), |_, _, _| panic!("no panels"));
        assert!(v.is_empty());
    }

    #[test]
    fn large_n_with_many_threads_covers_every_slot() {
        // Regression guard for the chunked counter: no index skipped,
        // none written twice (values are position-dependent).
        let n = 10_007; // prime, to exercise ragged final chunks
        let v = parallel_map(n, 13, |i| i);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(i, x);
        }
    }
}
