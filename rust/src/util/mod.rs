//! Cross-cutting substrates: RNG, JSON, parallel map, bench + property
//! harnesses. Hand-rolled because the offline vendor set only ships the
//! `xla` PJRT bindings and `anyhow` (see Cargo.toml note).

pub mod bench;
pub mod check;
pub mod json;
pub mod parallel;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
