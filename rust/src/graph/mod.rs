//! Graph substrate: random s-regular generation, the bipartite view of
//! non-straggler matrices (Lemma 14/15 walk counting), and spectral-gap
//! diagnostics for expander codes.

pub mod bipartite;
pub mod regular;
pub mod spectral;

pub use regular::{random_regular_graph, Graph};
