//! Bipartite-graph view of a non-straggler matrix A (paper §5.1).
//!
//! A is k x r; left vertices are the k tasks, right vertices the r
//! workers, with an edge (i, j) iff A_ij != 0. Lemma 14/15 relate the
//! algorithmic decoding error to weighted closed-walk counts on this
//! graph; `walk_moments` computes 1^T (A A^T)^t 1 for the Lemma-15
//! alternating-sum cross-checks in tests and the thm tables.

use crate::linalg::CscMatrix;

/// Degree statistics of the bipartite view.
#[derive(Clone, Debug, PartialEq)]
pub struct DegreeStats {
    pub min: usize,
    pub max: usize,
    pub mean: f64,
}

fn stats(degrees: &[usize]) -> DegreeStats {
    let min = degrees.iter().copied().min().unwrap_or(0);
    let max = degrees.iter().copied().max().unwrap_or(0);
    let mean = if degrees.is_empty() {
        0.0
    } else {
        degrees.iter().sum::<usize>() as f64 / degrees.len() as f64
    };
    DegreeStats { min, max, mean }
}

/// Left-vertex (task) degrees: how many responding workers cover task i.
pub fn left_degrees(a: &CscMatrix) -> Vec<usize> {
    a.row_degrees()
}

/// Right-vertex (worker) degrees: tasks per responding worker.
pub fn right_degrees(a: &CscMatrix) -> Vec<usize> {
    (0..a.cols).map(|j| a.col_nnz(j)).collect()
}

pub fn left_degree_stats(a: &CscMatrix) -> DegreeStats {
    stats(&left_degrees(a))
}

pub fn right_degree_stats(a: &CscMatrix) -> DegreeStats {
    stats(&right_degrees(a))
}

/// Number of tasks covered by no responding worker. Each such task
/// contributes exactly 1 to err(A) for boolean codes (its coordinate of
/// 1_k is orthogonal to the span of A).
pub fn uncovered_tasks(a: &CscMatrix) -> usize {
    left_degrees(a).iter().filter(|&&d| d == 0).count()
}

/// a_t = 1^T (A A^T)^t 1 for t = 0..=t_max — the weighted closed-walk
/// counts of Lemma 14 (walks of length 2t from a left vertex back to a
/// left vertex). Computed by repeated matvec, O(t_max * nnz).
pub fn walk_moments(a: &CscMatrix, t_max: usize) -> Vec<f64> {
    let mut u = vec![1.0; a.rows];
    let mut moments = Vec::with_capacity(t_max + 1);
    moments.push(a.rows as f64); // t = 0: 1^T 1 = k
    for _ in 1..=t_max {
        let atu = a.t_matvec(&u);
        u = a.matvec(&atu);
        moments.push(u.iter().sum::<f64>());
    }
    moments
}

/// Lemma 15: ||u_t||^2 as the alternating binomial sum of walk moments,
/// sum_{i=0}^{2t} (-1)^i C(2t, i) a_i / nu^i. Numerically fragile for
/// large t (alternating sum) — used as a *test oracle* against the
/// direct iterate computation for small t.
pub fn lemma15_error(a: &CscMatrix, nu: f64, t: usize) -> f64 {
    let moments = walk_moments(a, 2 * t);
    let mut sum = 0.0;
    let mut binom = 1.0; // C(2t, 0)
    let mut nu_pow = 1.0;
    for i in 0..=2 * t {
        let sign = if i % 2 == 0 { 1.0 } else { -1.0 };
        sum += sign * binom * moments[i] / nu_pow;
        // C(2t, i+1) = C(2t, i) * (2t - i) / (i + 1)
        binom = binom * (2 * t - i) as f64 / (i + 1) as f64;
        nu_pow *= nu;
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn bernoulli_matrix(k: usize, r: usize, p: f64, seed: u64) -> CscMatrix {
        let mut rng = Rng::new(seed);
        let cols = (0..r)
            .map(|_| (0..k).filter(|_| rng.bernoulli(p)).collect())
            .collect();
        CscMatrix::from_supports(k, cols)
    }

    #[test]
    fn degrees_sum_to_nnz() {
        let a = bernoulli_matrix(40, 30, 0.2, 1);
        let ld: usize = left_degrees(&a).iter().sum();
        let rd: usize = right_degrees(&a).iter().sum();
        assert_eq!(ld, a.nnz());
        assert_eq!(rd, a.nnz());
    }

    #[test]
    fn uncovered_counts_zero_rows() {
        let a = CscMatrix::from_supports(4, vec![vec![0, 1], vec![1]]);
        assert_eq!(uncovered_tasks(&a), 2); // tasks 2 and 3
    }

    #[test]
    fn walk_moment_t0_is_k() {
        let a = bernoulli_matrix(25, 20, 0.15, 2);
        assert_eq!(walk_moments(&a, 0)[0], 25.0);
    }

    #[test]
    fn walk_moment_t1_counts_paths() {
        // a_1 = 1^T A A^T 1 = ||A^T 1||^2 = sum_j (col degree)^2
        let a = bernoulli_matrix(25, 20, 0.15, 3);
        let expected: f64 = right_degrees(&a).iter().map(|&d| (d * d) as f64).sum();
        let m = walk_moments(&a, 1);
        assert!((m[1] - expected).abs() < 1e-9);
    }

    #[test]
    fn lemma15_matches_direct_iterate_t1() {
        // ||u_1||^2 = ||(I - AA^T/nu) 1||^2 expanded = a0 - 2 a1/nu + a2/nu^2
        let a = bernoulli_matrix(20, 15, 0.2, 4);
        let nu = 30.0;
        let direct = {
            let atu = a.t_matvec(&vec![1.0; a.rows]);
            let aatu = a.matvec(&atu);
            let u1: Vec<f64> = (0..a.rows).map(|i| 1.0 - aatu[i] / nu).collect();
            u1.iter().map(|x| x * x).sum::<f64>()
        };
        let lemma = lemma15_error(&a, nu, 1);
        assert!((direct - lemma).abs() < 1e-8, "{direct} vs {lemma}");
    }

    #[test]
    fn degree_stats() {
        let a = CscMatrix::from_supports(3, vec![vec![0], vec![0, 1, 2]]);
        let rs = right_degree_stats(&a);
        assert_eq!(rs, DegreeStats { min: 1, max: 3, mean: 2.0 });
        let ls = left_degree_stats(&a);
        assert_eq!(ls.min, 1);
        assert_eq!(ls.max, 2);
    }
}
