//! Spectral diagnostics for graph-based codes.
//!
//! The quality of an s-regular expander code is governed by
//! λ(G) = max{|λ2|, |λk|} (Thm 3 / Raviv et al. [20]); Ramanujan graphs
//! achieve λ ≤ 2 sqrt(s-1). These helpers quantify how close a random
//! s-regular draw is to that bound (the paper's argument for using
//! random regular graphs instead of explicit Ramanujan constructions).

use super::regular::Graph;
use crate::linalg::{regular_graph_lambda, CscMatrix};
use crate::util::Rng;

/// Adjacency matrix of a graph as boolean CSC.
pub fn adjacency(g: &Graph) -> CscMatrix {
    CscMatrix::from_supports(g.n, g.adj.clone())
}

/// λ(G) = max{|λ2|, |λk|} for an s-regular graph.
pub fn lambda(g: &Graph, s: usize, rng: &mut Rng) -> f64 {
    debug_assert!(g.is_regular(s));
    regular_graph_lambda(&adjacency(g), s, rng, 500)
}

/// The Ramanujan bound 2 sqrt(s-1).
pub fn ramanujan_bound(s: usize) -> f64 {
    2.0 * ((s - 1) as f64).sqrt()
}

/// λ(G) / (2 sqrt(s-1)): ≈1 means near-Ramanujan (a good expander).
pub fn expansion_quality(g: &Graph, s: usize, rng: &mut Rng) -> f64 {
    lambda(g, s, rng) / ramanujan_bound(s)
}

/// Expander-mixing check: for all sampled vertex pairs (S, T),
/// |e(S,T) - s|S||T|/n| <= λ sqrt(|S||T|). Returns the max violation
/// ratio over `samples` random pairs (<= 1 means the mixing lemma holds
/// with the given λ on every sampled pair).
pub fn mixing_violation(g: &Graph, s: usize, lam: f64, samples: usize, rng: &mut Rng) -> f64 {
    let n = g.n;
    let mut worst: f64 = 0.0;
    for _ in 0..samples {
        let a = 1 + rng.usize(n / 2);
        let b = 1 + rng.usize(n / 2);
        let sv = rng.sample_indices(n, a);
        let tv = rng.sample_indices(n, b);
        let mut in_t = vec![false; n];
        for &v in &tv {
            in_t[v] = true;
        }
        // e(S, T): ordered pairs (u in S, v in T) with an edge.
        let mut e_st = 0usize;
        for &u in &sv {
            for &v in &g.adj[u] {
                if in_t[v] {
                    e_st += 1;
                }
            }
        }
        let expected = s as f64 * a as f64 * b as f64 / n as f64;
        let bound = lam * ((a * b) as f64).sqrt();
        if bound > 0.0 {
            worst = worst.max((e_st as f64 - expected).abs() / bound);
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::regular::random_regular_graph;

    #[test]
    fn complete_graph_lambda_is_one() {
        let g = Graph::complete(8);
        let l = lambda(&g, 7, &mut Rng::new(1));
        assert!((l - 1.0).abs() < 1e-5, "{l}");
    }

    #[test]
    fn random_regular_is_near_ramanujan() {
        // Friedman's theorem: random s-regular graphs have
        // λ ≤ 2 sqrt(s-1) + o(1) w.h.p. Allow 25% slack at k=100.
        let mut rng = Rng::new(2);
        let g = random_regular_graph(100, 10, &mut rng);
        let q = expansion_quality(&g, 10, &mut rng);
        assert!(q < 1.25, "expansion quality {q}");
        assert!(q > 0.5, "suspiciously small λ: quality {q}");
    }

    #[test]
    fn mixing_lemma_holds_on_random_regular() {
        let mut rng = Rng::new(3);
        let g = random_regular_graph(60, 6, &mut rng);
        let lam = lambda(&g, 6, &mut rng);
        // Use a slightly inflated λ to absorb power-iteration tolerance.
        let v = mixing_violation(&g, 6, lam * 1.05, 200, &mut rng);
        assert!(v <= 1.0, "mixing violation {v}");
    }

    #[test]
    fn adjacency_is_symmetric_boolean() {
        let mut rng = Rng::new(4);
        let g = random_regular_graph(20, 4, &mut rng);
        let a = adjacency(&g);
        assert!(a.is_boolean());
        let d = a.to_dense();
        for i in 0..20 {
            for j in 0..20 {
                assert_eq!(d[(i, j)], d[(j, i)]);
            }
        }
    }
}
