//! Random s-regular graph generation — the substrate for the expander
//! baseline of Raviv et al. [20] (paper §6 compares against random
//! s-regular graphs, which are near-Ramanujan expanders w.h.p. [15]).
//!
//! Pairing/configuration model with rejection of self-loops and multi-
//! edges, plus an edge-swap repair pass so generation terminates for all
//! feasible (k, s) instead of resampling forever on unlucky tails.

use crate::util::Rng;

/// A simple undirected graph as sorted adjacency lists.
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    pub adj: Vec<Vec<usize>>,
}

impl Graph {
    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum::<usize>() / 2
    }

    pub fn degree(&self, v: usize) -> usize {
        self.adj[v].len()
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj[u].binary_search(&v).is_ok()
    }

    pub fn is_regular(&self, s: usize) -> bool {
        self.adj.iter().all(|a| a.len() == s)
    }

    /// Simple graph: no self-loops, no duplicate edges.
    pub fn is_simple(&self) -> bool {
        self.adj.iter().enumerate().all(|(v, a)| {
            a.windows(2).all(|w| w[0] < w[1]) && !a.contains(&v)
        })
    }

    /// Number of edges inside the vertex subset (used by DkS heuristics).
    pub fn edges_within(&self, subset: &[usize]) -> usize {
        let mut inset = vec![false; self.n];
        for &v in subset {
            inset[v] = true;
        }
        let mut count = 0;
        for &v in subset {
            for &u in &self.adj[v] {
                if inset[u] && u > v {
                    count += 1;
                }
            }
        }
        count
    }

    /// d-regular ring lattice (each vertex tied to d/2 neighbours each
    /// side) — a deterministic regular graph for tests and reductions.
    pub fn ring_lattice(n: usize, d: usize) -> Graph {
        assert!(d % 2 == 0 && d < n, "ring lattice needs even d < n");
        let mut adj = vec![Vec::new(); n];
        for v in 0..n {
            for step in 1..=d / 2 {
                let u = (v + step) % n;
                adj[v].push(u);
                adj[u].push(v);
            }
        }
        for a in adj.iter_mut() {
            a.sort_unstable();
        }
        Graph { n, adj }
    }

    pub fn complete(n: usize) -> Graph {
        let adj = (0..n).map(|v| (0..n).filter(|&u| u != v).collect()).collect();
        Graph { n, adj }
    }
}

/// Generate a uniform-ish random s-regular simple graph on n vertices.
///
/// Configuration model: put s stubs on each vertex, take a random perfect
/// matching of stubs; retry a bounded number of times, then repair the
/// remaining self-loops/multi-edges with random edge swaps (the standard
/// practical construction; the induced bias is negligible for s ≪ n).
pub fn random_regular_graph(n: usize, s: usize, rng: &mut Rng) -> Graph {
    assert!(s < n, "degree must be < n");
    assert!(n * s % 2 == 0, "n*s must be even");

    for _ in 0..CONFIGURATION_ATTEMPTS {
        if let Some(g) = try_configuration(n, s, rng) {
            return g;
        }
    }
    // Repair path: accept a defective multigraph matching and fix it.
    repair_matching(n, s, rng)
}

/// Configuration-model retries before falling back to edge-swap repair.
/// Shared with `codes::RegularGraphCode::assignment_into` so the two
/// generation paths consume identical RNG streams.
pub const CONFIGURATION_ATTEMPTS: usize = 50;

/// Zero-allocation twin of `try_configuration`: one configuration-model
/// draw into caller-owned flat buffers. On success (`true`) the sorted
/// neighbours of vertex v are `adj_flat[v*s..(v+1)*s]`. Consumes the
/// exact RNG stream of the allocating variant — one full stub shuffle —
/// and applies the identical self-loop/multi-edge rejection, so a
/// retry loop over either variant stays in seeded lockstep (pinned by
/// a test below).
pub fn try_configuration_flat(
    n: usize,
    s: usize,
    rng: &mut Rng,
    stubs: &mut Vec<usize>,
    adj_flat: &mut Vec<usize>,
    deg: &mut Vec<usize>,
) -> bool {
    stubs.clear();
    stubs.extend((0..n * s).map(|i| i / s));
    rng.shuffle(stubs);
    adj_flat.clear();
    adj_flat.resize(n * s, 0);
    deg.clear();
    deg.resize(n, 0);
    for pair in stubs.chunks(2) {
        let (u, v) = (pair[0], pair[1]);
        if u == v || adj_flat[u * s..u * s + deg[u]].contains(&v) {
            return false;
        }
        adj_flat[u * s + deg[u]] = v;
        deg[u] += 1;
        adj_flat[v * s + deg[v]] = u;
        deg[v] += 1;
    }
    for v in 0..n {
        adj_flat[v * s..(v + 1) * s].sort_unstable();
    }
    true
}

/// One configuration-model draw; None if it produced a loop/multi-edge.
fn try_configuration(n: usize, s: usize, rng: &mut Rng) -> Option<Graph> {
    let mut stubs: Vec<usize> = (0..n * s).map(|i| i / s).collect();
    rng.shuffle(&mut stubs);
    let mut adj = vec![Vec::with_capacity(s); n];
    for pair in stubs.chunks(2) {
        let (u, v) = (pair[0], pair[1]);
        if u == v || adj[u].contains(&v) {
            return None;
        }
        adj[u].push(v);
        adj[v].push(u);
    }
    for a in adj.iter_mut() {
        a.sort_unstable();
    }
    Some(Graph { n, adj })
}

/// Zero-allocation twin of [`repair_matching`]: the incremental
/// edge-swap repair run entirely in caller-owned flat buffers. On
/// return the **sorted** neighbours of vertex `v` are
/// `adj_flat[v*s..(v+1)*s]`.
///
/// Consumes the exact RNG stream of the allocating variant — the same
/// stub shuffle, the same defective-edge list order (so the same
/// `rng.usize` draws), the same swap proposals and accept/reject
/// decisions — so a seeded caller can switch between the two without
/// moving a bit (pinned by a test below). The allocating variant's
/// `HashMap<(u,v), count>` is replaced by a multiset adjacency mirror:
/// every vertex always owns exactly `s` stub endpoints, so
/// `adj_flat[u*s..(u+1)*s]` holds `u`'s current neighbours (with
/// multiplicity; self-loops appear as `u` itself) and edge
/// multiplicities are membership counts in that segment.
pub(crate) fn repair_matching_flat(
    n: usize,
    s: usize,
    rng: &mut Rng,
    stubs: &mut Vec<usize>,
    edges: &mut Vec<usize>,
    adj_flat: &mut Vec<usize>,
    deg: &mut Vec<usize>,
    bad: &mut Vec<usize>,
) {
    // Multiplicity of edge (u, v) == occurrences of v in u's segment
    // (u != v; self-loops are caught by the u == v check before any
    // multiplicity lookup, exactly like the allocating variant).
    fn count(adj: &[usize], s: usize, u: usize, v: usize) -> usize {
        adj[u * s..(u + 1) * s].iter().filter(|&&x| x == v).count()
    }
    // Rewrite one occurrence of `old` in u's segment to `new` — the
    // mirror of a counts entry decrement + increment.
    fn replace_one(adj: &mut [usize], s: usize, u: usize, old: usize, new: usize) {
        let seg = &mut adj[u * s..(u + 1) * s];
        let pos = seg.iter().position(|&x| x == old).expect("adjacency mirror out of sync");
        seg[pos] = new;
    }

    stubs.clear();
    stubs.extend((0..n * s).map(|i| i / s));
    rng.shuffle(stubs);
    // Edge e is the endpoint pair (edges[2e], edges[2e+1]).
    edges.clear();
    edges.extend_from_slice(stubs);
    let m = n * s / 2;

    adj_flat.clear();
    adj_flat.resize(n * s, 0);
    deg.clear();
    deg.resize(n, 0);
    for e in 0..m {
        let (u, v) = (edges[2 * e], edges[2 * e + 1]);
        adj_flat[u * s + deg[u]] = v;
        deg[u] += 1;
        adj_flat[v * s + deg[v]] = u;
        deg[v] += 1;
    }

    let mut guard = 0usize;
    loop {
        bad.clear();
        for e in 0..m {
            let (u, v) = (edges[2 * e], edges[2 * e + 1]);
            if u == v || count(adj_flat, s, u, v) > 1 {
                bad.push(e);
            }
        }
        if bad.is_empty() {
            break;
        }
        guard += 1;
        assert!(guard < 1_000_000, "edge-swap repair failed to converge");
        let i = bad[rng.usize(bad.len())];
        let j = rng.usize(m);
        if i == j {
            continue;
        }
        let (a, b) = (edges[2 * i], edges[2 * i + 1]);
        let (c, d) = (edges[2 * j], edges[2 * j + 1]);
        // Propose swap (a,b),(c,d) -> (a,d),(c,b).
        if a == d || c == b {
            continue;
        }
        if count(adj_flat, s, a, d) > 0 || count(adj_flat, s, c, b) > 0 {
            continue;
        }
        // Apply to the edge list and mirror in the adjacency multiset.
        edges[2 * i + 1] = d;
        edges[2 * j + 1] = b;
        replace_one(adj_flat, s, a, b, d);
        replace_one(adj_flat, s, b, a, c);
        replace_one(adj_flat, s, c, d, b);
        replace_one(adj_flat, s, d, c, a);
    }

    for v in 0..n {
        adj_flat[v * s..(v + 1) * s].sort_unstable();
    }
}

/// Take a defective matching and swap edges until simple. Allocates;
/// reached when all [`CONFIGURATION_ATTEMPTS`] rejections fire — rare
/// for s ≤ 3 but the usual outcome for denser degrees, since one
/// configuration is simple with probability ≈ exp(−(s²−1)/4). Kept as
/// the reference implementation for `random_regular_graph`; the
/// re-draw hot path uses the flat-buffer twin
/// [`repair_matching_flat`], which replays this function's RNG walk
/// without allocating.
pub(crate) fn repair_matching(n: usize, s: usize, rng: &mut Rng) -> Graph {
    // Edge list with possible defects.
    let mut stubs: Vec<usize> = (0..n * s).map(|i| i / s).collect();
    rng.shuffle(&mut stubs);
    let mut edges: Vec<(usize, usize)> = stubs.chunks(2).map(|p| (p[0], p[1])).collect();

    let edge_key = |u: usize, v: usize| (u.min(v), u.max(v));
    let mut counts = std::collections::HashMap::new();
    for &(u, v) in &edges {
        *counts.entry(edge_key(u, v)).or_insert(0usize) += 1;
    }
    let is_bad = |u: usize, v: usize, counts: &std::collections::HashMap<(usize, usize), usize>| {
        u == v || counts[&edge_key(u, v)] > 1
    };

    let mut guard = 0usize;
    loop {
        let bad: Vec<usize> = (0..edges.len())
            .filter(|&i| {
                let (u, v) = edges[i];
                is_bad(u, v, &counts)
            })
            .collect();
        if bad.is_empty() {
            break;
        }
        guard += 1;
        assert!(guard < 1_000_000, "edge-swap repair failed to converge");
        let i = bad[rng.usize(bad.len())];
        let j = rng.usize(edges.len());
        if i == j {
            continue;
        }
        let (a, b) = edges[i];
        let (c, d) = edges[j];
        // Propose swap (a,b),(c,d) -> (a,d),(c,b).
        let (n1, n2) = ((a, d), (c, b));
        if n1.0 == n1.1 || n2.0 == n2.1 {
            continue;
        }
        let k1 = edge_key(n1.0, n1.1);
        let k2 = edge_key(n2.0, n2.1);
        if counts.get(&k1).copied().unwrap_or(0) > 0 || counts.get(&k2).copied().unwrap_or(0) > 0 {
            continue;
        }
        // Apply.
        *counts.get_mut(&edge_key(a, b)).unwrap() -= 1;
        *counts.get_mut(&edge_key(c, d)).unwrap() -= 1;
        *counts.entry(k1).or_insert(0) += 1;
        *counts.entry(k2).or_insert(0) += 1;
        edges[i] = n1;
        edges[j] = n2;
    }

    let mut adj = vec![Vec::with_capacity(s); n];
    for (u, v) in edges {
        adj[u].push(v);
        adj[v].push(u);
    }
    for a in adj.iter_mut() {
        a.sort_unstable();
    }
    Graph { n, adj }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_regular_is_simple_and_regular() {
        let mut rng = Rng::new(1);
        for &(n, s) in &[(10, 3), (20, 5), (100, 10), (101, 4)] {
            let g = random_regular_graph(n, s, &mut rng);
            assert!(g.is_regular(s), "not {s}-regular for n={n}");
            assert!(g.is_simple(), "not simple for n={n}, s={s}");
            assert_eq!(g.edge_count(), n * s / 2);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g1 = random_regular_graph(30, 4, &mut Rng::new(9));
        let g2 = random_regular_graph(30, 4, &mut Rng::new(9));
        assert_eq!(g1.adj, g2.adj);
    }

    #[test]
    fn flat_configuration_matches_allocating_variant() {
        // Same seed -> same accept/reject decision and, on accept, the
        // same sorted adjacency; the RNG streams stay in lockstep.
        let (mut stubs, mut adj_flat, mut deg) = (Vec::new(), Vec::new(), Vec::new());
        for seed in 0..40u64 {
            for &(n, s) in &[(12usize, 3usize), (20, 5), (9, 2)] {
                let mut ra = Rng::new(seed);
                let mut rb = Rng::new(seed);
                let reference = try_configuration(n, s, &mut ra);
                let ok = try_configuration_flat(n, s, &mut rb, &mut stubs, &mut adj_flat, &mut deg);
                assert_eq!(ok, reference.is_some(), "n={n} s={s} seed={seed}");
                if let Some(g) = reference {
                    for v in 0..n {
                        assert_eq!(&adj_flat[v * s..(v + 1) * s], &g.adj[v][..], "vertex {v}");
                    }
                }
                assert_eq!(ra.next_u64(), rb.next_u64(), "rng diverged (seed {seed})");
            }
        }
    }

    #[test]
    fn flat_repair_matches_allocating_variant() {
        // Dense degrees land on the repair path essentially always
        // (P(simple config) ≈ exp(−(s²−1)/4)); same seed must give the
        // same repaired graph and leave the RNG streams in lockstep.
        let (mut stubs, mut edges, mut adj_flat, mut deg, mut bad) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new());
        for seed in 0..25u64 {
            for &(n, s) in &[(12usize, 5usize), (10, 6), (16, 4)] {
                let mut ra = Rng::new(seed);
                let mut rb = Rng::new(seed);
                let reference = repair_matching(n, s, &mut ra);
                repair_matching_flat(
                    n, s, &mut rb, &mut stubs, &mut edges, &mut adj_flat, &mut deg, &mut bad,
                );
                for v in 0..n {
                    assert_eq!(
                        &adj_flat[v * s..(v + 1) * s],
                        &reference.adj[v][..],
                        "vertex {v} (n={n} s={s} seed={seed})"
                    );
                }
                assert_eq!(ra.next_u64(), rb.next_u64(), "rng diverged (n={n} s={s} seed={seed})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "even")]
    fn odd_degree_sum_panics() {
        random_regular_graph(5, 3, &mut Rng::new(1));
    }

    #[test]
    fn ring_lattice_structure() {
        let g = Graph::ring_lattice(8, 4);
        assert!(g.is_regular(4));
        assert!(g.is_simple());
        assert!(g.has_edge(0, 1) && g.has_edge(0, 2) && !g.has_edge(0, 3));
    }

    #[test]
    fn complete_graph_edges_within() {
        let g = Graph::complete(6);
        assert_eq!(g.edges_within(&[0, 1, 2]), 3);
        assert_eq!(g.edges_within(&[4]), 0);
        assert_eq!(g.edge_count(), 15);
    }

    #[test]
    fn edges_within_matches_bruteforce() {
        let mut rng = Rng::new(11);
        let g = random_regular_graph(30, 6, &mut rng);
        let subset: Vec<usize> = rng.sample_indices(30, 12);
        let mut brute = 0;
        for i in 0..subset.len() {
            for j in i + 1..subset.len() {
                if g.has_edge(subset[i], subset[j]) {
                    brute += 1;
                }
            }
        }
        assert_eq!(g.edges_within(&subset), brute);
    }
}
