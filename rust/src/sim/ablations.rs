//! Ablation studies for the design choices DESIGN.md calls out.
//!
//! * `rho_sweep` — sensitivity of the one-step decoder to ρ around the
//!   canonical k/(rs) (the paper fixes ρ; how flat is the optimum?).
//! * `rbgc_threshold` — Algorithm 3 regularizes columns above 2s down
//!   to s. What happens with other (trigger, target) pairs?
//! * `lsqr_tolerance` — decode accuracy vs iteration budget for the
//!   optimal decoder (the practical accuracy/latency dial).
//! * `normalization` — boolean vs column-normalized coefficients
//!   (negative result: coverage noise dominates degree noise, so
//!   normalization does not improve BGC one-step error; optimal decode
//!   is scale-invariant anyway).
//!
//! Like the figures and tables, every study is *(per-shard partials) ∘
//! (finalize)*: the `*_partials` variants run any [`Shard`] of the
//! trial range on the [`crate::decode::DecodeWorkspace`]-threaded
//! zero-allocation pipeline and return [`AblationPartialPoint`]s; the
//! classic entry points below are the `num_shards = 1` case. The
//! per-study parameter sweeps live in [`study_partials`], the single
//! dispatch `repro ablation`, `repro shard --ablation`, and
//! `repro run --ablation` all share (via `shard::JobSpec`), so a study
//! cannot be producible-but-unmergeable. Trial values are bit-identical
//! to the historical `mc.mean(|rng| ...)` closures (pinned by the
//! legacy-parity tests below); merged shards reproduce the unsharded
//! CSV byte-for-byte (`tests/shard_parity.rs`).

use anyhow::{bail, Result};

use super::montecarlo::MonteCarlo;
use super::scenario::scalar_partial_under;
use super::shard::{Partial, Shard, ABLATION_IDS};
use crate::codes::{normalized_rho, Scheme, ThresholdedBernoulliCode};
use crate::linalg::LsqrOptions;
use crate::stragglers::Scenario;

/// One ablation data point.
#[derive(Clone, Debug)]
pub struct AblationPoint {
    pub study: &'static str,
    pub setting: String,
    pub value: f64,
}

impl AblationPoint {
    pub fn csv_header() -> &'static str {
        "study,setting,value"
    }

    /// CSV row. `setting` is quoted per RFC 4180 when it contains a
    /// comma, quote, or newline; every built-in study emits plain
    /// settings (pinned by a test), so their bytes are unchanged — the
    /// quoting only guards future studies against emitting rows a CSV
    /// reader would mis-split.
    pub fn to_csv(&self) -> String {
        format!("{},{},{:.6e}", self.study, csv_field(&self.setting), self.value)
    }
}

/// RFC-4180 field escaping: pass clean fields through untouched, wrap
/// hostile ones in quotes with `""` doubling.
fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// One ablation point's *partial* state: the study metadata plus an
/// exact partial aggregate of this shard's trials. Finalizing a
/// fully-merged partial yields the published [`AblationPoint`].
#[derive(Clone, Debug)]
pub struct AblationPartialPoint {
    pub study: &'static str,
    pub setting: String,
    /// The study's k (finalize divides the merged statistic by it).
    pub k: usize,
    pub partial: Partial,
}

impl AblationPartialPoint {
    /// Metadata equality — merge refuses to combine partials from
    /// different sweep points.
    pub fn same_point(&self, other: &AblationPartialPoint) -> bool {
        self.study == other.study
            && self.setting == other.setting
            && self.k == other.k
            && self.partial.kind() == other.partial.kind()
    }

    /// Finalize a (fully-merged) partial into the published point.
    pub fn finalize(&self) -> AblationPoint {
        AblationPoint {
            study: self.study,
            setting: self.setting.clone(),
            value: self.partial.value() / self.k as f64,
        }
    }
}

/// Finalize a slice of fully-merged partial points.
pub fn finalize_ablation_points(points: &[AblationPartialPoint]) -> Vec<AblationPoint> {
    points.iter().map(|p| p.finalize()).collect()
}

// ------------------------------------------------------ study registry

/// The fixed ρ-factor sweep `--ablation rho` runs.
pub const RHO_FACTORS: [f64; 7] = [0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 2.0];
/// The fixed (trigger, target) pairs `--ablation rbgc` runs.
pub const RBGC_PAIRS: [(f64, f64); 5] =
    [(1.0, 1.0), (1.5, 1.0), (2.0, 1.0), (2.0, 1.5), (3.0, 2.0)];
/// The fixed LSQR iteration caps `--ablation lsqr` runs.
pub const LSQR_CAPS: [usize; 6] = [1, 2, 4, 8, 16, 64];
/// The fixed δ sweep `--ablation normalization` runs.
pub const NORMALIZATION_DELTAS: [f64; 3] = [0.1, 0.3, 0.5];

/// One shard of the study registered under the CLI id `study` (one of
/// [`ABLATION_IDS`]) — the dispatch `shard::JobSpec::run` and every
/// ablation CLI path share. Sweep parameters are the fixed constants
/// above; `k`, `s`, and the Monte-Carlo budget come from the job.
pub fn study_partials(
    study: &str,
    k: usize,
    s: usize,
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Result<Vec<AblationPartialPoint>> {
    Ok(match study {
        "rho" => rho_sweep_partials(Scheme::Bgc, k, s, 0.25, &RHO_FACTORS, scenario, mc, shard),
        "rbgc" => rbgc_threshold_partials(k, s, 0.25, &RBGC_PAIRS, scenario, mc, shard),
        "lsqr" => {
            lsqr_tolerance_partials(Scheme::Bgc, k, s, 0.25, &LSQR_CAPS, scenario, mc, shard)
        }
        "normalization" => {
            normalization_partials(Scheme::Bgc, k, s, &NORMALIZATION_DELTAS, scenario, mc, shard)
        }
        other => bail!("unknown ablation study {other:?} (one of {})", ABLATION_IDS.join("|")),
    })
}

fn r_of(k: usize, delta: f64) -> usize {
    (((1.0 - delta) * k as f64).round() as usize).clamp(1, k)
}

// ---------------------------------------------------------- rho_sweep

/// One shard of [`rho_sweep`]: mean err_1 at ρ = factor · k/(rs),
/// through the workspace re-draw pipeline.
pub fn rho_sweep_partials(
    scheme: Scheme,
    k: usize,
    s: usize,
    delta: f64,
    factors: &[f64],
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Vec<AblationPartialPoint> {
    let r = r_of(k, delta);
    let canonical = k as f64 / (r as f64 * s as f64);
    let code = scheme.build(k, k, s);
    let resolved = scenario.resolve(code.as_ref(), delta, r, mc.seed);
    factors
        .iter()
        .map(|&f| {
            let rho = f * canonical;
            let partial = scalar_partial_under(
                &resolved,
                mc,
                shard,
                |ws, model, rng| ws.onestep_redraw_trial_with(code.as_ref(), model, rho, rng),
                |ws, g, model, rng| ws.onestep_trial_with(g, model, rho, rng),
            );
            AblationPartialPoint {
                study: "rho_sweep",
                setting: format!("{} rho={f:.2}x", scheme.name()),
                k,
                partial,
            }
        })
        .collect()
}

/// ρ sensitivity: mean err_1 at ρ = factor · k/(rs).
pub fn rho_sweep(
    scheme: Scheme,
    k: usize,
    s: usize,
    delta: f64,
    factors: &[f64],
    mc: &MonteCarlo,
) -> Vec<AblationPoint> {
    finalize_ablation_points(&rho_sweep_partials(
        scheme,
        k,
        s,
        delta,
        factors,
        &Scenario::default(),
        mc,
        Shard::full(),
    ))
}

// ----------------------------------------------------- rbgc_threshold

/// One shard of [`rbgc_threshold`]. The code family is
/// [`ThresholdedBernoulliCode`] in `codes/rbgc.rs` (the paper's
/// Algorithm 3 generalized to arbitrary (trigger, target); rBGC itself
/// is the (2, 1) instance, so there is exactly one copy of the draw).
/// Its `assignment_into` replicates the pre-PR-4 inline closure draw
/// RNG-for-RNG, so seeded ablation values are unchanged, and the loop
/// is allocation-free at steady state (`tests/zero_alloc.rs`).
pub fn rbgc_threshold_partials(
    k: usize,
    s: usize,
    delta: f64,
    pairs: &[(f64, f64)],
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Vec<AblationPartialPoint> {
    let r = r_of(k, delta);
    let rho = k as f64 / (r as f64 * s as f64); // OneStepDecoder::canonical
    pairs
        .iter()
        .map(|&(trigger, target)| {
            let code = ThresholdedBernoulliCode::new(k, k, s, trigger, target);
            let resolved = scenario.resolve(&code, delta, r, mc.seed);
            let partial = scalar_partial_under(
                &resolved,
                mc,
                shard,
                |ws, model, rng| ws.onestep_redraw_trial_with(&code, model, rho, rng),
                |ws, g, model, rng| ws.onestep_trial_with(g, model, rho, rng),
            );
            AblationPartialPoint {
                study: "rbgc_threshold",
                setting: format!("trigger={trigger}s target={target}s"),
                k,
                partial,
            }
        })
        .collect()
}

/// rBGC-style regularization with arbitrary (trigger, target) columns:
/// thin any column above `trigger`·s down to `target`·s.
pub fn rbgc_threshold(
    k: usize,
    s: usize,
    delta: f64,
    pairs: &[(f64, f64)],
    mc: &MonteCarlo,
) -> Vec<AblationPoint> {
    finalize_ablation_points(&rbgc_threshold_partials(
        k,
        s,
        delta,
        pairs,
        &Scenario::default(),
        mc,
        Shard::full(),
    ))
}

// ----------------------------------------------------- lsqr_tolerance

/// One shard of [`lsqr_tolerance`]: the full-budget reference row plus
/// one row per iteration cap, all on the workspace LSQR re-draw path
/// (`lsqr_with` is bit-identical to the allocating `lsqr`).
pub fn lsqr_tolerance_partials(
    scheme: Scheme,
    k: usize,
    s: usize,
    delta: f64,
    caps: &[usize],
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Vec<AblationPartialPoint> {
    let r = r_of(k, delta);
    let code = scheme.build(k, k, s);
    let resolved = scenario.resolve(code.as_ref(), delta, r, mc.seed);
    let mut out = Vec::new();
    let run_cap = |opts: &LsqrOptions| {
        scalar_partial_under(
            &resolved,
            mc,
            shard,
            |ws, model, rng| ws.optimal_redraw_trial_with(code.as_ref(), model, opts, None, rng),
            |ws, g, model, rng| ws.optimal_trial_with(g, model, opts, None, rng),
        )
    };
    // Reference: full-budget decode.
    let partial = run_cap(&LsqrOptions::default());
    out.push(AblationPartialPoint {
        study: "lsqr_tolerance",
        setting: "cap=default".into(),
        k,
        partial,
    });
    for &cap in caps {
        let capped = LsqrOptions { max_iter: cap, ..LsqrOptions::default() };
        let partial = run_cap(&capped);
        out.push(AblationPartialPoint {
            study: "lsqr_tolerance",
            setting: format!("cap={cap}"),
            k,
            partial,
        });
    }
    out
}

/// Optimal-decoder accuracy vs LSQR iteration cap.
pub fn lsqr_tolerance(
    scheme: Scheme,
    k: usize,
    s: usize,
    delta: f64,
    caps: &[usize],
    mc: &MonteCarlo,
) -> Vec<AblationPoint> {
    finalize_ablation_points(&lsqr_tolerance_partials(
        scheme,
        k,
        s,
        delta,
        caps,
        &Scenario::default(),
        mc,
        Shard::full(),
    ))
}

// ------------------------------------------------------ normalization

/// One shard of [`normalization`]: the boolean arm runs the fused
/// one-step re-draw trial; the normalized arm runs the fused
/// column-normalized variant
/// ([`DecodeWorkspace::onestep_normalized_redraw_trial`]) — both
/// bit-identical to the historical allocating closures.
pub fn normalization_partials(
    scheme: Scheme,
    k: usize,
    s: usize,
    deltas: &[f64],
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Vec<AblationPartialPoint> {
    let code = scheme.build(k, k, s);
    let mut out = Vec::new();
    for &delta in deltas {
        let r = r_of(k, delta);
        let rho_boolean = k as f64 / (r as f64 * s as f64);
        let rho_normalized = normalized_rho(k, r);
        let resolved = scenario.resolve(code.as_ref(), delta, r, mc.seed);
        let partial = scalar_partial_under(
            &resolved,
            mc,
            shard,
            |ws, model, rng| ws.onestep_redraw_trial_with(code.as_ref(), model, rho_boolean, rng),
            |ws, g, model, rng| ws.onestep_trial_with(g, model, rho_boolean, rng),
        );
        out.push(AblationPartialPoint {
            study: "normalization",
            setting: format!("{} delta={delta:.1} boolean", scheme.name()),
            k,
            partial,
        });
        let partial = scalar_partial_under(
            &resolved,
            mc,
            shard,
            |ws, model, rng| {
                ws.onestep_normalized_redraw_trial_with(code.as_ref(), model, rho_normalized, rng)
            },
            |ws, g, model, rng| ws.onestep_normalized_trial_with(g, model, rho_normalized, rng),
        );
        out.push(AblationPartialPoint {
            study: "normalization",
            setting: format!("{} delta={delta:.1} normalized", scheme.name()),
            k,
            partial,
        });
    }
    out
}

/// Boolean vs normalized coefficients under one-step decoding.
pub fn normalization(
    scheme: Scheme,
    k: usize,
    s: usize,
    deltas: &[f64],
    mc: &MonteCarlo,
) -> Vec<AblationPoint> {
    finalize_ablation_points(&normalization_partials(
        scheme,
        k,
        s,
        deltas,
        &Scenario::default(),
        mc,
        Shard::full(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::normalized::normalize_columns;
    use crate::decode::{OneStepDecoder, OptimalDecoder};
    use crate::linalg::{lsqr, CscMatrix};
    use crate::util::Rng;

    fn mc() -> MonteCarlo {
        MonteCarlo::new(120, 7)
    }

    /// The pre-PR-4 per-trial draw: build G, keep r uniform columns.
    fn draw_a(scheme: Scheme, k: usize, s: usize, r: usize, rng: &mut Rng) -> CscMatrix {
        let g = scheme.build(k, k, s).assignment(rng);
        g.select_columns(&rng.sample_indices(k, r))
    }

    #[test]
    fn rho_sweep_optimum_near_canonical() {
        let pts = rho_sweep(Scheme::Bgc, 40, 5, 0.25, &[0.5, 1.0, 2.0], &mc());
        assert_eq!(pts.len(), 3);
        // Canonical (factor 1.0) beats gross misscalings.
        assert!(pts[1].value < pts[0].value, "{pts:?}");
        assert!(pts[1].value < pts[2].value, "{pts:?}");
    }

    #[test]
    fn rbgc_paper_setting_present() {
        let pts = rbgc_threshold(30, 3, 0.3, &[(2.0, 1.0), (3.0, 2.0)], &mc());
        assert_eq!(pts.len(), 2);
        assert!(pts.iter().all(|p| p.value.is_finite() && p.value >= 0.0));
    }

    #[test]
    fn lsqr_error_decreases_with_budget() {
        let pts = lsqr_tolerance(Scheme::Bgc, 30, 5, 0.3, &[1, 4, 64], &mc());
        // More iterations => no worse error (monotone within noise).
        let cap1 = pts.iter().find(|p| p.setting == "cap=1").unwrap().value;
        let cap64 = pts.iter().find(|p| p.setting == "cap=64").unwrap().value;
        assert!(cap64 <= cap1 + 1e-9, "cap64 {cap64} > cap1 {cap1}");
    }

    #[test]
    fn normalization_stays_in_regime() {
        // The ablation's documented (negative) finding: normalization
        // does not rescue BGC's one-step error — coverage randomness,
        // not degree variance, drives it.
        let pts = normalization(Scheme::Bgc, 40, 5, &[0.3], &mc());
        let boolean = pts.iter().find(|p| p.setting.ends_with("boolean")).unwrap().value;
        let norm = pts.iter().find(|p| p.setting.ends_with("normalized")).unwrap().value;
        let ratio = norm / boolean;
        assert!((0.8..2.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn csv_format() {
        let p = AblationPoint { study: "rho_sweep", setting: "x".into(), value: 0.5 };
        assert_eq!(p.to_csv(), "rho_sweep,x,5.000000e-1");
    }

    #[test]
    fn csv_quoting_escapes_hostile_settings() {
        let p = AblationPoint { study: "rho_sweep", setting: "a,b \"c\"".into(), value: 1.0 };
        assert_eq!(p.to_csv(), "rho_sweep,\"a,b \"\"c\"\"\",1.000000e0");
        let p = AblationPoint { study: "rho_sweep", setting: "line\nbreak".into(), value: 1.0 };
        assert_eq!(p.to_csv(), "rho_sweep,\"line\nbreak\",1.000000e0");
    }

    #[test]
    fn built_in_studies_emit_csv_safe_settings() {
        // Guarantee behind the unquoted fast path: no registered study
        // ever emits a comma/quote/newline in `setting`, so the CSV
        // stays machine-parseable with a naive comma split.
        let mc = MonteCarlo::new(2, 1);
        for &id in &ABLATION_IDS {
            let pts = study_partials(id, 12, 2, &Scenario::default(), &mc, Shard::full()).unwrap();
            assert!(!pts.is_empty(), "{id}");
            for p in &pts {
                assert!(
                    !p.setting.contains(',')
                        && !p.setting.contains('"')
                        && !p.setting.contains('\n'),
                    "{id}: hostile setting {:?}",
                    p.setting
                );
                let row = p.finalize().to_csv();
                assert_eq!(row.matches(',').count(), 2, "{id}: {row}");
            }
        }
        assert!(study_partials("nope", 12, 2, &Scenario::default(), &mc, Shard::full()).is_err());
    }

    // ---- legacy-parity pins: the workspace-threaded studies must
    // reproduce the pre-PR-4 `mc.mean(|rng| ...)` closures bit-for-bit.

    #[test]
    fn rho_sweep_matches_legacy_closure_bitwise() {
        let mc = MonteCarlo::new(50, 11);
        let (scheme, k, s, delta) = (Scheme::Bgc, 24usize, 4usize, 0.25);
        let factors = [0.5, 1.0, 2.0];
        let pts = rho_sweep(scheme, k, s, delta, &factors, &mc);
        let r = r_of(k, delta);
        let canonical = k as f64 / (r as f64 * s as f64);
        for (p, &f) in pts.iter().zip(&factors) {
            let rho = f * canonical;
            let legacy = mc.mean(|rng| {
                let a = draw_a(scheme, k, s, r, rng);
                OneStepDecoder::new(rho).err1(&a)
            }) / k as f64;
            assert_eq!(p.value.to_bits(), legacy.to_bits(), "factor {f}");
        }
    }

    #[test]
    fn rbgc_threshold_matches_legacy_closure_bitwise() {
        let mc = MonteCarlo::new(40, 12);
        let (k, s, delta) = (20usize, 3usize, 0.3);
        let pairs = [(2.0, 1.0), (3.0, 2.0)];
        let pts = rbgc_threshold(k, s, delta, &pairs, &mc);
        let r = r_of(k, delta);
        for (p, &(trigger, target)) in pts.iter().zip(&pairs) {
            let legacy = mc.mean(|rng| {
                // The pre-PR-4 inline draw, verbatim.
                let pb = s as f64 / k as f64;
                let supports: Vec<Vec<usize>> = (0..k)
                    .map(|_| {
                        let mut col: Vec<usize> =
                            (0..k).filter(|_| rng.bernoulli(pb)).collect();
                        let trig = (trigger * s as f64).round() as usize;
                        let targ = ((target * s as f64).round() as usize).max(1);
                        if col.len() > trig {
                            while col.len() > targ {
                                let idx = rng.usize(col.len());
                                col.swap_remove(idx);
                            }
                            col.sort_unstable();
                        }
                        col
                    })
                    .collect();
                let g = CscMatrix::from_supports(k, supports);
                let a = g.select_columns(&rng.sample_indices(k, r));
                OneStepDecoder::canonical(k, r, s).err1(&a)
            }) / k as f64;
            assert_eq!(p.value.to_bits(), legacy.to_bits(), "pair ({trigger}, {target})");
        }
    }

    #[test]
    fn lsqr_tolerance_matches_legacy_closure_bitwise() {
        let mc = MonteCarlo::new(30, 13);
        let (scheme, k, s, delta) = (Scheme::Bgc, 20usize, 4usize, 0.3);
        let caps = [1usize, 8];
        let pts = lsqr_tolerance(scheme, k, s, delta, &caps, &mc);
        let r = r_of(k, delta);
        let reference = mc.mean(|rng| {
            let a = draw_a(scheme, k, s, r, rng);
            OptimalDecoder::new().err(&a)
        }) / k as f64;
        assert_eq!(pts[0].value.to_bits(), reference.to_bits(), "cap=default");
        for (p, &cap) in pts[1..].iter().zip(&caps) {
            let legacy = mc.mean(|rng| {
                let a = draw_a(scheme, k, s, r, rng);
                let b = vec![1.0; a.rows];
                let res =
                    lsqr(&a, &b, &LsqrOptions { max_iter: cap, ..LsqrOptions::default() });
                res.residual_norm * res.residual_norm
            }) / k as f64;
            assert_eq!(p.value.to_bits(), legacy.to_bits(), "cap {cap}");
        }
    }

    #[test]
    fn normalization_matches_legacy_closure_bitwise() {
        let mc = MonteCarlo::new(40, 14);
        let (scheme, k, s) = (Scheme::Bgc, 20usize, 4usize);
        let delta = 0.3;
        let pts = normalization(scheme, k, s, &[delta], &mc);
        let r = r_of(k, delta);
        let boolean = mc.mean(|rng| {
            let a = draw_a(scheme, k, s, r, rng);
            OneStepDecoder::canonical(k, r, s).err1(&a)
        }) / k as f64;
        let norm = mc.mean(|rng| {
            let a = normalize_columns(&draw_a(scheme, k, s, r, rng));
            OneStepDecoder::new(k as f64 / r as f64).err1(&a)
        }) / k as f64;
        assert_eq!(pts[0].value.to_bits(), boolean.to_bits(), "boolean arm");
        assert_eq!(pts[1].value.to_bits(), norm.to_bits(), "normalized arm");
    }

    #[test]
    fn sharded_study_partials_merge_to_entry_point_bits() {
        let mc = MonteCarlo::new(45, 9);
        let args = (Scheme::Bgc, 16usize, 3usize, 0.25);
        let factors = [0.5, 1.0];
        let sc = Scenario::default();
        let whole = rho_sweep(args.0, args.1, args.2, args.3, &factors, &mc);
        let mut merged = rho_sweep_partials(
            args.0,
            args.1,
            args.2,
            args.3,
            &factors,
            &sc,
            &mc,
            Shard::new(0, 3).unwrap(),
        );
        for sid in 1..3 {
            let part = rho_sweep_partials(
                args.0,
                args.1,
                args.2,
                args.3,
                &factors,
                &sc,
                &mc,
                Shard::new(sid, 3).unwrap(),
            );
            for (a, b) in merged.iter_mut().zip(&part) {
                assert!(a.same_point(b));
                a.partial.merge(&b.partial).unwrap();
            }
        }
        let merged = finalize_ablation_points(&merged);
        assert_eq!(merged.len(), whole.len());
        for (a, b) in merged.iter().zip(&whole) {
            assert_eq!(a.setting, b.setting);
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "{}", a.setting);
        }
    }
}
