//! Ablation studies for the design choices DESIGN.md calls out.
//!
//! * `rho_sweep` — sensitivity of the one-step decoder to ρ around the
//!   canonical k/(rs) (the paper fixes ρ; how flat is the optimum?).
//! * `rbgc_threshold` — Algorithm 3 regularizes columns above 2s down
//!   to s. What happens with other (trigger, target) pairs?
//! * `lsqr_tolerance` — decode accuracy vs iteration budget for the
//!   optimal decoder (the practical accuracy/latency dial).
//! * `normalization` — boolean vs column-normalized coefficients
//!   (negative result: coverage noise dominates degree noise, so
//!   normalization does not improve BGC one-step error; optimal decode
//!   is scale-invariant anyway).

use super::montecarlo::MonteCarlo;
use crate::codes::{normalized::normalize_columns, GradientCode, Scheme};
use crate::decode::{OneStepDecoder, OptimalDecoder};
use crate::linalg::{lsqr, CscMatrix, LsqrOptions};
use crate::util::Rng;

/// One ablation data point.
#[derive(Clone, Debug)]
pub struct AblationPoint {
    pub study: &'static str,
    pub setting: String,
    pub value: f64,
}

impl AblationPoint {
    pub fn csv_header() -> &'static str {
        "study,setting,value"
    }

    pub fn to_csv(&self) -> String {
        format!("{},{},{:.6e}", self.study, self.setting, self.value)
    }
}

fn draw_a(scheme: Scheme, k: usize, s: usize, r: usize, rng: &mut Rng) -> CscMatrix {
    let g = scheme.build(k, k, s).assignment(rng);
    g.select_columns(&rng.sample_indices(k, r))
}

/// ρ sensitivity: mean err_1 at ρ = factor · k/(rs).
pub fn rho_sweep(
    scheme: Scheme,
    k: usize,
    s: usize,
    delta: f64,
    factors: &[f64],
    mc: &MonteCarlo,
) -> Vec<AblationPoint> {
    let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
    let canonical = k as f64 / (r as f64 * s as f64);
    factors
        .iter()
        .map(|&f| {
            let rho = f * canonical;
            let mean = mc.mean(|rng| {
                let a = draw_a(scheme, k, s, r, rng);
                OneStepDecoder::new(rho).err1(&a)
            });
            AblationPoint {
                study: "rho_sweep",
                setting: format!("{} rho={f:.2}x", scheme.name()),
                value: mean / k as f64,
            }
        })
        .collect()
}

/// rBGC-style regularization with arbitrary (trigger, target) columns:
/// thin any column above `trigger`·s down to `target`·s.
pub fn rbgc_threshold(
    k: usize,
    s: usize,
    delta: f64,
    pairs: &[(f64, f64)],
    mc: &MonteCarlo,
) -> Vec<AblationPoint> {
    let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
    pairs
        .iter()
        .map(|&(trigger, target)| {
            let mean = mc.mean(|rng| {
                // Draw a BGC and regularize with the custom thresholds.
                let p = s as f64 / k as f64;
                let supports: Vec<Vec<usize>> = (0..k)
                    .map(|_| {
                        let mut col: Vec<usize> =
                            (0..k).filter(|_| rng.bernoulli(p)).collect();
                        let trig = (trigger * s as f64).round() as usize;
                        let targ = ((target * s as f64).round() as usize).max(1);
                        if col.len() > trig {
                            while col.len() > targ {
                                let idx = rng.usize(col.len());
                                col.swap_remove(idx);
                            }
                            col.sort_unstable();
                        }
                        col
                    })
                    .collect();
                let g = CscMatrix::from_supports(k, supports);
                let a = g.select_columns(&rng.sample_indices(k, r));
                OneStepDecoder::canonical(k, r, s).err1(&a)
            });
            AblationPoint {
                study: "rbgc_threshold",
                setting: format!("trigger={trigger}s target={target}s"),
                value: mean / k as f64,
            }
        })
        .collect()
}

/// Optimal-decoder accuracy vs LSQR iteration cap.
pub fn lsqr_tolerance(
    scheme: Scheme,
    k: usize,
    s: usize,
    delta: f64,
    caps: &[usize],
    mc: &MonteCarlo,
) -> Vec<AblationPoint> {
    let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
    let mut out = Vec::new();
    // Reference: full-budget decode.
    let reference = mc.mean(|rng| {
        let a = draw_a(scheme, k, s, r, rng);
        OptimalDecoder::new().err(&a)
    });
    out.push(AblationPoint {
        study: "lsqr_tolerance",
        setting: "cap=default".into(),
        value: reference / k as f64,
    });
    for &cap in caps {
        let mean = mc.mean(|rng| {
            let a = draw_a(scheme, k, s, r, rng);
            let b = vec![1.0; a.rows];
            let res = lsqr(&a, &b, &LsqrOptions { max_iter: cap, ..LsqrOptions::default() });
            res.residual_norm * res.residual_norm
        });
        out.push(AblationPoint {
            study: "lsqr_tolerance",
            setting: format!("cap={cap}"),
            value: mean / k as f64,
        });
    }
    out
}

/// Boolean vs normalized coefficients under one-step decoding.
pub fn normalization(
    scheme: Scheme,
    k: usize,
    s: usize,
    deltas: &[f64],
    mc: &MonteCarlo,
) -> Vec<AblationPoint> {
    let mut out = Vec::new();
    for &delta in deltas {
        let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
        let boolean = mc.mean(|rng| {
            let a = draw_a(scheme, k, s, r, rng);
            OneStepDecoder::canonical(k, r, s).err1(&a)
        });
        let norm = mc.mean(|rng| {
            let a = normalize_columns(&draw_a(scheme, k, s, r, rng));
            OneStepDecoder::new(k as f64 / r as f64).err1(&a)
        });
        out.push(AblationPoint {
            study: "normalization",
            setting: format!("{} delta={delta:.1} boolean", scheme.name()),
            value: boolean / k as f64,
        });
        out.push(AblationPoint {
            study: "normalization",
            setting: format!("{} delta={delta:.1} normalized", scheme.name()),
            value: norm / k as f64,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc() -> MonteCarlo {
        MonteCarlo::new(120, 7)
    }

    #[test]
    fn rho_sweep_optimum_near_canonical() {
        let pts = rho_sweep(Scheme::Bgc, 40, 5, 0.25, &[0.5, 1.0, 2.0], &mc());
        assert_eq!(pts.len(), 3);
        // Canonical (factor 1.0) beats gross misscalings.
        assert!(pts[1].value < pts[0].value, "{pts:?}");
        assert!(pts[1].value < pts[2].value, "{pts:?}");
    }

    #[test]
    fn rbgc_paper_setting_present() {
        let pts = rbgc_threshold(30, 3, 0.3, &[(2.0, 1.0), (3.0, 2.0)], &mc());
        assert_eq!(pts.len(), 2);
        assert!(pts.iter().all(|p| p.value.is_finite() && p.value >= 0.0));
    }

    #[test]
    fn lsqr_error_decreases_with_budget() {
        let pts = lsqr_tolerance(Scheme::Bgc, 30, 5, 0.3, &[1, 4, 64], &mc());
        // More iterations => no worse error (monotone within noise).
        let cap1 = pts.iter().find(|p| p.setting == "cap=1").unwrap().value;
        let cap64 = pts.iter().find(|p| p.setting == "cap=64").unwrap().value;
        assert!(cap64 <= cap1 + 1e-9, "cap64 {cap64} > cap1 {cap1}");
    }

    #[test]
    fn normalization_stays_in_regime() {
        // The ablation's documented (negative) finding: normalization
        // does not rescue BGC's one-step error — coverage randomness,
        // not degree variance, drives it.
        let pts = normalization(Scheme::Bgc, 40, 5, &[0.3], &mc());
        let boolean = pts.iter().find(|p| p.setting.ends_with("boolean")).unwrap().value;
        let norm = pts.iter().find(|p| p.setting.ends_with("normalized")).unwrap().value;
        let ratio = norm / boolean;
        assert!((0.8..2.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn csv_format() {
        let p = AblationPoint { study: "rho_sweep", setting: "x".into(), value: 0.5 };
        assert_eq!(p.to_csv(), "rho_sweep,x,5.000000e-1");
    }
}
