//! Regeneration of the paper's closed-form results as tables: the
//! theorem-vs-measured comparisons recorded in EXPERIMENTS.md.
//!
//! * thm5  — E\[err_1(A_frac)\] closed form vs Monte-Carlo.
//! * thm6  — E\[err(A_frac)\]  closed form vs Monte-Carlo.
//! * thm8  — P(err > αs) vs the 1/k bound at the theorem's s threshold.
//! * thm10 — adversarial FRC error = k - r, attack vs random stragglers.
//! * thm11 — DkS reduction identity gap + heuristic-vs-exhaustive ratio.
//! * thm21 — BGC / rBGC one-step error vs the C²k/((1-δ)s) envelope.
//!
//! Like the figures, every table is *(per-shard partials) ∘ (finalize)*:
//! the `*_partials` variants run any [`Shard`] of the trial range and
//! return [`TablePartialPoint`]s; the classic `*_table` entry points
//! are the `num_shards = 1` case. Deterministic rows (thm10's attack,
//! all of thm11) are recomputed identically by every shard and carried
//! as [`Partial::Exact`] values, which merge by asserting bit-equality.

use super::montecarlo::MonteCarlo;
use super::scenario::{prob_partial_under, scalar_partial_panel_under, PanelKind};
use super::shard::{Partial, PostMap, Shard};
use crate::adversary::{
    asp_objective, dks_to_asp, exhaustive_worst_case, frc_worst_stragglers, greedy_stragglers,
    local_search_stragglers, objective_identity_gap,
};
use crate::codes::{FractionalRepetitionCode, GradientCode, Scheme};
use crate::decode::{OptimalDecoder, PanelWorkspace};
use crate::graph::random_regular_graph;
use crate::linalg::LsqrOptions;
use crate::stragglers::Scenario;
use crate::util::Rng;

/// One comparison row.
#[derive(Clone, Debug)]
pub struct TableRow {
    pub table: &'static str,
    pub label: String,
    pub expected: f64,
    pub measured: f64,
    pub note: String,
}

impl TableRow {
    pub fn csv_header() -> &'static str {
        "table,label,expected,measured,note"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{:.6e},{:.6e},{}",
            self.table, self.label, self.expected, self.measured, self.note
        )
    }
}

/// Everything about an output row except the measured value: the
/// deterministic columns plus the [`PostMap`] applied to the merged
/// statistic at finalize time.
#[derive(Clone, Debug)]
pub struct RowTemplate {
    pub table: &'static str,
    pub label: String,
    pub expected: f64,
    pub note: String,
    pub post: PostMap,
}

/// One table point's *partial* state: a single Monte-Carlo (or exact)
/// statistic plus the row templates it feeds. Most points emit one row;
/// thm5 emits two (the exact and paper closed forms share one measured
/// value, so they share one partial).
#[derive(Clone, Debug)]
pub struct TablePartialPoint {
    pub rows: Vec<RowTemplate>,
    pub partial: Partial,
}

impl TablePartialPoint {
    /// Metadata equality (expected compared by bits, NaN-safe).
    pub fn same_point(&self, other: &TablePartialPoint) -> bool {
        self.rows.len() == other.rows.len()
            && self.partial.kind() == other.partial.kind()
            && self.rows.iter().zip(&other.rows).all(|(a, b)| {
                a.table == b.table
                    && a.label == b.label
                    && a.expected.to_bits() == b.expected.to_bits()
                    && a.note == b.note
                    && a.post.bits_eq(&b.post)
            })
    }

    /// Finalize a (fully-merged) partial into published table rows.
    pub fn finalize(&self) -> Vec<TableRow> {
        let raw = self.partial.value();
        self.rows
            .iter()
            .map(|t| TableRow {
                table: t.table,
                label: t.label.clone(),
                expected: t.expected,
                measured: t.post.apply(raw),
                note: t.note.clone(),
            })
            .collect()
    }
}

/// Finalize a slice of fully-merged table points.
pub fn finalize_table_points(points: &[TablePartialPoint]) -> Vec<TableRow> {
    points.iter().flat_map(|p| p.finalize()).collect()
}

// ---------------------------------------------------------------- binomials

/// ln C(n, k) via cumulative log-factorials (exact enough for k <= 10^6).
pub fn ln_binomial(n: usize, k: usize) -> f64 {
    assert!(k <= n);
    let ln_fact = |m: usize| -> f64 { (1..=m).map(|i| (i as f64).ln()).sum() };
    ln_fact(n) - ln_fact(k) - ln_fact(n - k)
}

/// C(n-s, r-s) / C(n, r) evaluated in log space.
fn binom_ratio(top_n: usize, top_k: usize, bot_n: usize, bot_k: usize) -> f64 {
    (ln_binomial(top_n, top_k) - ln_binomial(bot_n, bot_k)).exp()
}

// ------------------------------------------------------------------- thm 5

/// Thm 5 closed form as printed in the paper:
/// `E[err_1(A_frac)] = k²/(rs) - k/s - k/r + k/(rs)`
/// `                 = δk/((1-δ)s) - (s-1)/((1-δ)s)`.
///
/// ERRATUM: the paper's Lemma 4 uses P(a_j duplicates a_i) = (s-1)/k,
/// which is the *with-replacement* approximation. Sampling columns
/// without replacement (the paper's own protocol) gives (s-1)/(k-1);
/// see `thm5_exact`. The two agree as k → ∞ but differ measurably at
/// k = 20 (the gap is O(1) in the error units of the figures).
pub fn thm5_paper(k: usize, r: usize, s: usize) -> f64 {
    let (k, r, s) = (k as f64, r as f64, s as f64);
    k * k / (r * s) - k / s - k / r + k / (r * s)
}

/// Exact finite-sample expectation under without-replacement sampling:
/// `E[err_1] = k²/(rs) + k²(r-1)(s-1)/(rs(k-1)) - k`.
pub fn thm5_exact(k: usize, r: usize, s: usize) -> f64 {
    let (k, r, s) = (k as f64, r as f64, s as f64);
    k * k / (r * s) + k * k * (r - 1.0) * (s - 1.0) / (r * s * (k - 1.0)) - k
}

/// One shard of [`thm5_table`]: one Monte-Carlo mean per δ feeding the
/// exact-form and paper-form rows. Straggler selection goes through
/// the scenario spine (closed-form `expected` columns describe the
/// uniform model; under other scenarios they stay printed as the
/// uniform reference the measurement deviates from).
pub fn thm5_partials(
    k: usize,
    s: usize,
    deltas: &[f64],
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Vec<TablePartialPoint> {
    let code = Scheme::Frc.build(k, k, s);
    deltas
        .iter()
        .map(|&delta| {
            let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
            let rho = k as f64 / (r as f64 * s as f64);
            let resolved = scenario.resolve(code.as_ref(), delta, r, mc.seed);
            let partial = scalar_partial_panel_under(
                &resolved,
                mc,
                shard,
                code.as_ref(),
                PanelKind::OneStep { rho },
                |ws, g, model, rng| ws.onestep_trial_with(g, model, rho, rng),
            );
            TablePartialPoint {
                rows: vec![
                    RowTemplate {
                        table: "thm5",
                        label: format!("k={k} s={s} delta={delta:.2} exact"),
                        expected: thm5_exact(k, r, s),
                        note: "E[err1(A_frc)] (without-replacement exact)".into(),
                        post: PostMap::Identity,
                    },
                    RowTemplate {
                        table: "thm5",
                        label: format!("k={k} s={s} delta={delta:.2} paper"),
                        expected: thm5_paper(k, r, s),
                        note: "paper closed form (with-replacement approx; erratum)".into(),
                        post: PostMap::Identity,
                    },
                ],
                partial,
            }
        })
        .collect()
}

pub fn thm5_table(k: usize, s: usize, deltas: &[f64], mc: &MonteCarlo) -> Vec<TableRow> {
    finalize_table_points(&thm5_partials(k, s, deltas, &Scenario::default(), mc, Shard::full()))
}

// ------------------------------------------------------------------- thm 6

/// Thm 6: E\[err(A_frac)\] = k · P(a fixed block is fully stragglers).
///
/// ERRATUM: the paper's eq. (3.2) prints P(Y_i = 1) = C(k-s, r-s)/C(k, r),
/// which is the probability the block is fully *sampled* (all s of its
/// columns survive), not fully missed. The correct hypergeometric miss
/// probability — consistent with the paper's own Thm 7, which uses
/// C(k-(α+1)s, r)/C(k, r) — is C(k-s, r)/C(k, r) (zero when r > k-s).
pub fn thm6_expected(k: usize, r: usize, s: usize) -> f64 {
    if r > k - s {
        return 0.0; // not enough stragglers to cover a whole block
    }
    k as f64 * binom_ratio(k - s, r, k, r)
}

/// The paper's printed (typo) form, kept for the erratum row.
pub fn thm6_paper(k: usize, r: usize, s: usize) -> f64 {
    if r < s {
        return 0.0;
    }
    k as f64 * binom_ratio(k - s, r - s, k, r)
}

/// One shard of [`thm6_table`], straggler selection through the
/// scenario spine.
pub fn thm6_partials(
    k: usize,
    s: usize,
    deltas: &[f64],
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Vec<TablePartialPoint> {
    let code = Scheme::Frc.build(k, k, s);
    deltas
        .iter()
        .map(|&delta| {
            let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
            let expected = thm6_expected(k, r, s);
            let opts = LsqrOptions::default();
            // Warm-start every trial at the one-step weights ρ·1_r —
            // constant across trials at this (k, r, s) point. For FRC
            // with no stragglers this is the exact solution, and with
            // stragglers it deflates the covered blocks out of the rhs.
            let rho = k as f64 / (r as f64 * s as f64);
            let resolved = scenario.resolve(code.as_ref(), delta, r, mc.seed);
            let partial = scalar_partial_panel_under(
                &resolved,
                mc,
                shard,
                code.as_ref(),
                PanelKind::Optimal { opts: &opts, warm: Some(rho) },
                |ws, g, model, rng| ws.optimal_trial_with(g, model, &opts, Some(rho), rng),
            );
            TablePartialPoint {
                rows: vec![RowTemplate {
                    table: "thm6",
                    label: format!("k={k} s={s} delta={delta:.2}"),
                    expected,
                    note: "E[err(A_frc)]".into(),
                    post: PostMap::Identity,
                }],
                partial,
            }
        })
        .collect()
}

pub fn thm6_table(k: usize, s: usize, deltas: &[f64], mc: &MonteCarlo) -> Vec<TableRow> {
    finalize_table_points(&thm6_partials(k, s, deltas, &Scenario::default(), mc, Shard::full()))
}

// Thm 6 derivation detail: E[err] = k * P(block missed); expose the
// per-block miss probability for tests.
pub fn block_miss_probability(k: usize, r: usize, s: usize) -> f64 {
    thm6_expected(k, r, s) / k as f64
}

// ------------------------------------------------------------------- thm 8

/// One shard of [`thm8_table`], straggler selection through the
/// scenario spine.
pub fn thm8_partials(
    k: usize,
    alphas: &[usize],
    deltas: &[f64],
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Vec<TablePartialPoint> {
    let mut points = Vec::new();
    for &alpha in alphas {
        for &delta in deltas {
            let s_min = (1.0 + 1.0 / (1.0 + alpha as f64)) * (k as f64).ln() / (1.0 - delta);
            // Smallest s >= s_min with s | k.
            let s = (1..=k)
                .filter(|s| k % s == 0 && *s as f64 >= s_min)
                .min()
                .unwrap_or(k);
            let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
            let threshold = (alpha * s) as f64;
            let opts = LsqrOptions::default();
            let code = Scheme::Frc.build(k, k, s);
            let resolved = scenario.resolve(code.as_ref(), delta, r, mc.seed);
            let partial = prob_partial_under(
                &resolved,
                mc,
                shard,
                |ws, model, rng| {
                    ws.optimal_redraw_trial_with(code.as_ref(), model, &opts, None, rng)
                        > threshold + 1e-6
                },
                |ws, g, model, rng| {
                    ws.optimal_trial_with(g, model, &opts, None, rng) > threshold + 1e-6
                },
            );
            points.push(TablePartialPoint {
                rows: vec![RowTemplate {
                    table: "thm8",
                    label: format!("k={k} alpha={alpha} delta={delta:.2} s={s}"),
                    expected: 1.0 / k as f64,
                    note: "P(err > alpha*s) vs 1/k bound".into(),
                    post: PostMap::Identity,
                }],
                partial,
            });
        }
    }
    points
}

/// Thm 8: if s >= (1 + 1/(1+α)) log(k)/(1-δ) then P(err > αs) <= 1/k.
/// Rows report the theorem's s threshold, the empirical violation
/// probability at the *smallest s meeting the threshold* (and s | k),
/// and the 1/k budget.
pub fn thm8_table(k: usize, alphas: &[usize], deltas: &[f64], mc: &MonteCarlo) -> Vec<TableRow> {
    finalize_table_points(&thm8_partials(
        k,
        alphas,
        deltas,
        &Scenario::default(),
        mc,
        Shard::full(),
    ))
}

// ------------------------------------------------------------------ thm 10

/// One shard of [`thm10_table`]. The adversarial row is deterministic
/// (fixed seed-0 G, exact attack) and is carried as a replicated
/// [`Partial::Exact`]; the random-straggler row is a Monte-Carlo mean.
pub fn thm10_partials(
    k: usize,
    s: usize,
    rs: &[usize],
    mc: &MonteCarlo,
    shard: Shard,
) -> Vec<TablePartialPoint> {
    let code = FractionalRepetitionCode::new(k, k, s);
    let g = code.assignment(&mut Rng::new(0));
    let mut points = Vec::new();
    for &r in rs {
        let ns = frc_worst_stragglers(&g, r);
        let adv = OptimalDecoder::new().err(&g.select_columns(&ns));
        points.push(TablePartialPoint {
            rows: vec![RowTemplate {
                table: "thm10",
                label: format!("k={k} s={s} r={r} adversarial"),
                expected: ((k - r) / s * s) as f64, // = k - r when s | k - r
                note: "err(A) under block attack".into(),
                post: PostMap::Identity,
            }],
            partial: Partial::Exact { value: adv },
        });
        // Fixed G + uniform draws: the panel path's home turf. Each
        // panel runs one lockstep multi-RHS LSQR over the shared G
        // (per-lane results bit-identical to the scalar optimal_trial,
        // so the published CSVs are unchanged; the win is pinned by the
        // `panel/optimal/*` records in `benches/decode_throughput.rs`).
        let opts = LsqrOptions::default();
        let width = mc.panel_width.max(1);
        let partial = mc.mean_partial_panel_ws(
            shard,
            width,
            || PanelWorkspace::new(width),
            |ws, root, base, lanes, out| {
                ws.optimal_panel(&g, r, &opts, None, root, base, lanes, out);
            },
        );
        points.push(TablePartialPoint {
            rows: vec![RowTemplate {
                table: "thm10",
                label: format!("k={k} s={s} r={r} random"),
                expected: thm6_expected(k, r, s),
                note: "err(A) under random stragglers".into(),
                post: PostMap::Identity,
            }],
            partial,
        });
    }
    points
}

/// Thm 10: worst-case FRC error is exactly k - r (s | k - r); random
/// stragglers for contrast.
pub fn thm10_table(k: usize, s: usize, rs: &[usize], mc: &MonteCarlo) -> Vec<TableRow> {
    finalize_table_points(&thm10_partials(k, s, rs, mc, Shard::full()))
}

// ------------------------------------------------------------------ thm 11

/// One shard of [`thm11_table`]: fully deterministic (seeded), so every
/// shard recomputes the same [`Partial::Exact`] values and merging
/// doubles as an integrity check.
pub fn thm11_partials(seed: u64) -> Vec<TablePartialPoint> {
    let mut rng = Rng::new(seed);
    let mut points = Vec::new();

    // (a) identity gap on a random 4-regular graph, multiple rho / |S|.
    let g = random_regular_graph(12, 4, &mut rng);
    let inst = dks_to_asp(&g, 4);
    let mut max_gap = 0.0f64;
    for &rho in &[0.1, 0.3, 0.5, 0.65] {
        for _ in 0..20 {
            let t = 1 + rng.usize(12);
            let subset = rng.sample_indices(12, t);
            max_gap = max_gap.max(objective_identity_gap(&inst, &g, &subset, rho));
        }
    }
    points.push(TablePartialPoint {
        rows: vec![RowTemplate {
            table: "thm11",
            label: "reduction identity max |lhs-rhs|".into(),
            expected: 0.0,
            note: "eq 4.2/4.3 on random 4-regular graph".into(),
            post: PostMap::Identity,
        }],
        partial: Partial::Exact { value: max_gap },
    });

    // (b) heuristic vs exhaustive on tiny BGC instances.
    let (k, s, r) = (14usize, 3usize, 9usize);
    let rho = k as f64 / (r as f64 * s as f64);
    let mut greedy_ratio_sum = 0.0;
    let mut ls_ratio_sum = 0.0;
    let reps = 5;
    for i in 0..reps {
        let gmat = Scheme::Bgc.build(k, k, s).assignment(&mut rng.fork(i as u64));
        let (_, exact) = exhaustive_worst_case(&gmat, r, rho);
        let greedy = asp_objective(&gmat, &greedy_stragglers(&gmat, r, rho), rho);
        let ls = asp_objective(&gmat, &local_search_stragglers(&gmat, r, rho, 10), rho);
        greedy_ratio_sum += greedy / exact;
        ls_ratio_sum += ls / exact;
    }
    points.push(TablePartialPoint {
        rows: vec![RowTemplate {
            table: "thm11",
            label: format!("greedy/exhaustive ratio (k={k} s={s} r={r})"),
            expected: 1.0,
            note: "<1 shows poly-time adversary suboptimality".into(),
            post: PostMap::Identity,
        }],
        partial: Partial::Exact { value: greedy_ratio_sum / reps as f64 },
    });
    points.push(TablePartialPoint {
        rows: vec![RowTemplate {
            table: "thm11",
            label: format!("local-search/exhaustive ratio (k={k} s={s} r={r})"),
            expected: 1.0,
            note: "<=1; stronger than greedy".into(),
            post: PostMap::Identity,
        }],
        partial: Partial::Exact { value: ls_ratio_sum / reps as f64 },
    });
    points
}

/// Thm 11 witnesses: (a) the reduction's objective identity holds to
/// machine precision on random d-regular graphs; (b) on small instances
/// the exhaustive optimum strictly dominates polynomial heuristics.
pub fn thm11_table(seed: u64) -> Vec<TableRow> {
    finalize_table_points(&thm11_partials(seed))
}

// ------------------------------------------------------------------- thm 3

/// One shard of [`thm3_table`].
pub fn thm3_partials(
    ks: &[usize],
    s: usize,
    mc: &MonteCarlo,
    shard: Shard,
) -> Vec<TablePartialPoint> {
    ks.iter()
        .map(|&k| {
            let bound = 2.0 * ((s - 1) as f64).sqrt();
            let partial = mc.mean_partial(shard, |rng| {
                let g = random_regular_graph(k, s, rng);
                crate::graph::spectral::lambda(&g, s, rng)
            });
            TablePartialPoint {
                rows: vec![RowTemplate {
                    table: "thm3",
                    label: format!("k={k} s={s}"),
                    expected: bound,
                    note: "lambda(G) vs Ramanujan bound 2*sqrt(s-1)".into(),
                    post: PostMap::Identity,
                }],
                partial,
            }
        })
        .collect()
}

/// Thm 3 context: λ(G) of random s-regular graphs vs the Ramanujan
/// bound 2·sqrt(s-1). The paper's §6 argument for random regular codes
/// is that they are near-Ramanujan w.h.p.; this table quantifies it.
pub fn thm3_table(ks: &[usize], s: usize, mc: &MonteCarlo) -> Vec<TableRow> {
    finalize_table_points(&thm3_partials(ks, s, mc, Shard::full()))
}

// ------------------------------------------------------------- thm 21 / 24

/// One shard of [`thm21_table`]: the raw statistic is the mean one-step
/// error; the implied constant C = sqrt(mean · (1-δ)s/k) is a
/// [`PostMap::SqrtScale`] applied after merging (a concave transform
/// must see the *merged* mean, not per-shard means).
pub fn thm21_partials(
    scheme: Scheme,
    ks: &[usize],
    s_of_k: impl Fn(usize) -> usize,
    delta: f64,
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Vec<TablePartialPoint> {
    let table = match scheme {
        Scheme::Bgc => "thm21",
        Scheme::Rbgc => "thm24",
        _ => "thm21",
    };
    ks.iter()
        .map(|&k| {
            let s = s_of_k(k);
            let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
            let rho = k as f64 / (r as f64 * s as f64);
            let code = scheme.build(k, k, s);
            let resolved = scenario.resolve(code.as_ref(), delta, r, mc.seed);
            let partial = scalar_partial_panel_under(
                &resolved,
                mc,
                shard,
                code.as_ref(),
                PanelKind::OneStep { rho },
                |ws, g, model, rng| ws.onestep_trial_with(g, model, rho, rng),
            );
            TablePartialPoint {
                rows: vec![RowTemplate {
                    table,
                    label: format!("{} k={k} s={s} delta={delta:.2}", scheme.name()),
                    expected: f64::NAN, // theorem gives O(1); report the fit
                    note: "implied constant C (should be O(1) in k)".into(),
                    post: PostMap::SqrtScale { scale: (1.0 - delta) * s as f64 / k as f64 },
                }],
                partial,
            }
        })
        .collect()
}

/// Thm 21 (BGC) / Thm 24 (rBGC): err_1(A) <= C² k / ((1-δ) s) w.h.p.
/// Rows report the implied constant C = sqrt(err_1 (1-δ) s / k) across a
/// k sweep; the theorem predicts it stays O(1) as k grows.
pub fn thm21_table(
    scheme: Scheme,
    ks: &[usize],
    s_of_k: impl Fn(usize) -> usize,
    delta: f64,
    mc: &MonteCarlo,
) -> Vec<TableRow> {
    finalize_table_points(&thm21_partials(
        scheme,
        ks,
        s_of_k,
        delta,
        &Scenario::default(),
        mc,
        Shard::full(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc() -> MonteCarlo {
        MonteCarlo::new(400, 99)
    }

    #[test]
    fn ln_binomial_small_values() {
        assert!((ln_binomial(5, 2).exp() - 10.0).abs() < 1e-9);
        assert!((ln_binomial(10, 0).exp() - 1.0).abs() < 1e-12);
        assert!((ln_binomial(52, 5).exp() - 2_598_960.0).abs() < 1e-3);
    }

    #[test]
    fn thm5_exact_matches_monte_carlo() {
        let rows = thm5_table(20, 5, &[0.25, 0.5], &mc());
        for row in rows.iter().filter(|r| r.label.ends_with("exact")) {
            let tol = 0.20 * row.expected.abs().max(0.5);
            assert!(
                (row.measured - row.expected).abs() < tol,
                "{}: measured {} vs expected {}",
                row.label,
                row.measured,
                row.expected
            );
        }
    }

    #[test]
    fn thm5_paper_form_converges_to_exact_for_large_k() {
        // The with-replacement approximation error vanishes as k grows.
        let (k, s) = (2000, 10);
        let r = 1500;
        let rel = (thm5_paper(k, r, s) - thm5_exact(k, r, s)).abs() / thm5_exact(k, r, s).abs();
        assert!(rel < 0.02, "relative gap {rel}");
    }

    #[test]
    fn thm6_matches_monte_carlo() {
        // Use a delta large enough that block misses are common.
        let rows = thm6_table(20, 5, &[0.5, 0.75], &MonteCarlo::new(2000, 99));
        for row in rows {
            let tol = 0.2 * row.expected.abs().max(0.15);
            assert!(
                (row.measured - row.expected).abs() < tol,
                "{}: measured {} vs expected {}",
                row.label,
                row.measured,
                row.expected
            );
        }
    }

    #[test]
    fn thm6_expected_is_hypergeometric_miss() {
        // k=4, s=2, r=2: blocks {0,1}, {2,3}. P(block fully missed) =
        // C(2,2)/C(4,2) = 1/6; E[err] = 4/6.
        assert!((thm6_expected(4, 2, 2) - 4.0 / 6.0).abs() < 1e-12);
        // r > k - s makes a full miss impossible.
        assert_eq!(thm6_expected(20, 16, 5), 0.0);
    }

    #[test]
    fn thm6_delta_zero_is_exact_zero() {
        let rows = thm6_table(20, 5, &[0.0], &mc());
        assert!(rows[0].measured < 1e-12);
        assert!(rows[0].expected < 1e-12);
    }

    #[test]
    fn thm8_violation_probability_below_bound() {
        // At the theorem's s threshold the empirical violation rate must
        // be <= 1/k (with Monte-Carlo slack).
        let rows = thm8_table(20, &[0], &[0.25], &mc());
        for row in rows {
            assert!(
                row.measured <= row.expected + 0.05,
                "{}: {} > {}",
                row.label,
                row.measured,
                row.expected
            );
        }
    }

    #[test]
    fn thm10_adversarial_exact() {
        let rows = thm10_table(20, 5, &[10, 15], &MonteCarlo::new(50, 1));
        for row in rows.iter().filter(|r| r.label.contains("adversarial")) {
            assert!(
                (row.measured - row.expected).abs() < 1e-8,
                "{}: {} != {}",
                row.label,
                row.measured,
                row.expected
            );
        }
    }

    #[test]
    fn thm10_random_rows_bit_identical_to_scalar_path() {
        // The random row now runs on the panel path; its partial must
        // carry the exact bits the pre-panel scalar loop produced, so
        // the published table CSVs are byte-unchanged.
        use crate::decode::DecodeWorkspace;
        let (k, s, rs) = (20usize, 5usize, [10usize, 15]);
        let mc = MonteCarlo::new(53, 1); // prime: ragged final panel
        let code = FractionalRepetitionCode::new(k, k, s);
        let g = code.assignment(&mut Rng::new(0));
        let points = thm10_partials(k, s, &rs, &mc, Shard::full());
        for (&r, pair) in rs.iter().zip(points.chunks(2)) {
            let opts = LsqrOptions::default();
            let scalar = mc.mean_partial_ws(Shard::full(), DecodeWorkspace::new, |ws, rng| {
                ws.optimal_trial(&g, r, &opts, None, rng)
            });
            let random_row = &pair[1];
            assert_eq!(
                random_row.partial.value().to_bits(),
                scalar.value().to_bits(),
                "r = {r}"
            );
        }
    }

    #[test]
    fn thm11_identity_tight_and_heuristics_bounded() {
        let rows = thm11_table(3);
        assert!(rows[0].measured < 1e-9, "identity gap {}", rows[0].measured);
        for row in &rows[1..] {
            assert!(row.measured <= 1.0 + 1e-9, "{}: ratio {}", row.label, row.measured);
            assert!(row.measured > 0.5, "{}: ratio {}", row.label, row.measured);
        }
    }

    #[test]
    fn thm5_under_latency_scenario_stays_finite() {
        let mc = MonteCarlo::new(200, 21);
        let sc = Scenario::parse("pareto:0.05,1.5").unwrap();
        let pts = thm5_partials(20, 5, &[0.25, 0.5], &sc, &mc, Shard::full());
        for row in finalize_table_points(&pts) {
            assert!(row.measured.is_finite() && row.measured >= 0.0, "{}", row.label);
        }
        // Fastest-r keeps r fixed, so the measured mean should stay in
        // the same regime as the uniform closed form (same survivor
        // count, different — latency-driven — survivor identity).
        let uniform = thm5_table(20, 5, &[0.25], &mc);
        let latency = finalize_table_points(&thm5_partials(20, 5, &[0.25], &sc, &mc, Shard::full()));
        let ratio = latency[0].measured / uniform[0].measured;
        assert!((0.2..5.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn thm21_constant_is_order_one() {
        let rows = thm21_table(
            Scheme::Bgc,
            &[30, 60],
            |k| ((k as f64).ln().ceil() as usize).max(2),
            0.3,
            &MonteCarlo::new(150, 5),
        );
        for row in rows {
            assert!(row.measured > 0.05 && row.measured < 5.0, "{}: C={}", row.label, row.measured);
        }
    }

    #[test]
    fn thm5_sharded_partials_merge_to_entry_point_bits() {
        let mc = MonteCarlo::new(90, 17);
        let sc = Scenario::default();
        let whole = thm5_table(20, 5, &[0.25, 0.5], &mc);
        let mut merged = thm5_partials(20, 5, &[0.25, 0.5], &sc, &mc, Shard::new(0, 4).unwrap());
        for sid in 1..4 {
            let part = thm5_partials(20, 5, &[0.25, 0.5], &sc, &mc, Shard::new(sid, 4).unwrap());
            for (a, b) in merged.iter_mut().zip(&part) {
                assert!(a.same_point(b));
                a.partial.merge(&b.partial).unwrap();
            }
        }
        let rows = finalize_table_points(&merged);
        assert_eq!(rows.len(), whole.len());
        for (a, b) in rows.iter().zip(&whole) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.measured.to_bits(), b.measured.to_bits(), "{}", a.label);
        }
    }
}
