//! Simulation harness: the Monte-Carlo engine plus the figure/table
//! regeneration entry points used by the CLI and the bench targets.

pub mod ablations;
pub mod figures;
pub mod montecarlo;
pub mod tables;

pub use figures::{FigPoint, FigureConfig};
pub use montecarlo::MonteCarlo;
pub use ablations::AblationPoint;
pub use tables::TableRow;
