//! Simulation harness: the Monte-Carlo engine plus the figure/table/
//! ablation regeneration entry points used by the CLI and the bench
//! targets.
//!
//! The [`shard`] module distributes any figure/table/ablation run
//! across processes/machines as disjoint trial ranges with exact
//! partial aggregates; merged shards reproduce the single-process
//! output bit-for-bit, compound artifacts (`repro merge --out`) make
//! the reduction a tree, and `repro verify` audits artifact sets
//! without merging.

pub mod ablations;
pub mod figures;
pub mod montecarlo;
pub mod scenario;
pub mod shard;
pub mod tables;

pub use figures::{FigPoint, FigureConfig};
pub use montecarlo::MonteCarlo;
pub use ablations::{AblationPartialPoint, AblationPoint};
pub use scenario::{tta_anytime, AnytimeRules, ScenarioPartialPoint, ScenarioPoint};
pub use shard::{JobKind, JobSpec, MergedRun, Shard, ShardArtifact};
pub use tables::TableRow;
