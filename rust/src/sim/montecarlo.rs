//! Monte-Carlo engine: seeded, multi-threaded trial averaging.
//!
//! Every figure point in the paper is "average X over 5000 trials"; this
//! module runs those trials across threads with per-trial forked RNG
//! streams, so results are bit-identical regardless of thread count.
//!
//! The `*_ws` variants thread a per-worker workspace (typically a
//! `decode::DecodeWorkspace`) through the trial closure, which is what
//! makes the steady-state trial loop allocation-free: scratch buffers
//! are built once per thread and reused across every trial it runs —
//! including, since the `assignment_into` re-draw path landed, the
//! assignment matrix G itself for schemes that sample a fresh G every
//! trial. Workspaces are scratch only — trial results must not depend
//! on the workspace's prior contents, so means stay independent of
//! thread count and scheduling. (A workspace-cached CSR mirror of a
//! *fixed* G is fine: it is a pure function of the figure point, not
//! of trial history.)

use crate::util::parallel::{parallel_map, parallel_map_with};
use crate::util::Rng;

/// Configuration shared by all simulation entry points.
#[derive(Clone, Copy, Debug)]
pub struct MonteCarlo {
    pub trials: usize,
    pub seed: u64,
    pub threads: usize,
}

impl MonteCarlo {
    pub fn new(trials: usize, seed: u64) -> Self {
        MonteCarlo { trials, seed, threads: crate::util::parallel::default_threads() }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Mean of `f` over `trials` independent RNG streams.
    pub fn mean(&self, f: impl Fn(&mut Rng) -> f64 + Sync) -> f64 {
        let root = Rng::new(self.seed);
        let vals = parallel_map(self.trials, self.threads, |i| {
            let mut rng = root.fork(i as u64);
            f(&mut rng)
        });
        vals.iter().sum::<f64>() / self.trials.max(1) as f64
    }

    /// Mean and sample standard deviation.
    pub fn mean_std(&self, f: impl Fn(&mut Rng) -> f64 + Sync) -> (f64, f64) {
        let root = Rng::new(self.seed);
        let vals = parallel_map(self.trials, self.threads, |i| {
            let mut rng = root.fork(i as u64);
            f(&mut rng)
        });
        let n = vals.len().max(1) as f64;
        let mean = vals.iter().sum::<f64>() / n;
        let var = if vals.len() > 1 {
            vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / (n - 1.0)
        } else {
            0.0
        };
        (mean, var.sqrt())
    }

    /// Element-wise mean of vector-valued trials (all same length) —
    /// used for the Fig. 5 curves {||u_t||^2}_t.
    pub fn mean_curve(&self, len: usize, f: impl Fn(&mut Rng) -> Vec<f64> + Sync) -> Vec<f64> {
        let root = Rng::new(self.seed);
        let curves = parallel_map(self.trials, self.threads, |i| {
            let mut rng = root.fork(i as u64);
            let c = f(&mut rng);
            assert_eq!(c.len(), len, "trial curve length mismatch");
            c
        });
        let mut mean = vec![0.0; len];
        for c in &curves {
            for (m, v) in mean.iter_mut().zip(c) {
                *m += v;
            }
        }
        for m in mean.iter_mut() {
            *m /= self.trials.max(1) as f64;
        }
        mean
    }

    /// Fraction of trials where the predicate holds (e.g. P(err > αs)).
    pub fn probability(&self, f: impl Fn(&mut Rng) -> bool + Sync) -> f64 {
        self.mean(|rng| if f(rng) { 1.0 } else { 0.0 })
    }

    /// [`MonteCarlo::mean`] with a per-thread workspace built by `init`
    /// and handed to every trial — the zero-allocation hot path.
    pub fn mean_ws<W>(
        &self,
        init: impl Fn() -> W + Sync,
        f: impl Fn(&mut W, &mut Rng) -> f64 + Sync,
    ) -> f64 {
        let root = Rng::new(self.seed);
        let vals = parallel_map_with(self.trials, self.threads, init, |ws, i| {
            let mut rng = root.fork(i as u64);
            f(ws, &mut rng)
        });
        vals.iter().sum::<f64>() / self.trials.max(1) as f64
    }

    /// [`MonteCarlo::mean_curve`] with a per-thread workspace — the
    /// Fig. 5 sweep re-draws G per trial through the workspace.
    pub fn mean_curve_ws<W>(
        &self,
        len: usize,
        init: impl Fn() -> W + Sync,
        f: impl Fn(&mut W, &mut Rng) -> Vec<f64> + Sync,
    ) -> Vec<f64> {
        let root = Rng::new(self.seed);
        let curves = parallel_map_with(self.trials, self.threads, init, |ws, i| {
            let mut rng = root.fork(i as u64);
            let c = f(ws, &mut rng);
            assert_eq!(c.len(), len, "trial curve length mismatch");
            c
        });
        let mut mean = vec![0.0; len];
        for c in &curves {
            for (m, v) in mean.iter_mut().zip(c) {
                *m += v;
            }
        }
        for m in mean.iter_mut() {
            *m /= self.trials.max(1) as f64;
        }
        mean
    }

    /// [`MonteCarlo::probability`] with a per-thread workspace.
    pub fn probability_ws<W>(
        &self,
        init: impl Fn() -> W + Sync,
        f: impl Fn(&mut W, &mut Rng) -> bool + Sync,
    ) -> f64 {
        self.mean_ws(init, |ws, rng| if f(ws, rng) { 1.0 } else { 0.0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_independent_of_thread_count() {
        let f = |rng: &mut Rng| rng.f64();
        let a = MonteCarlo { trials: 500, seed: 1, threads: 1 }.mean(f);
        let b = MonteCarlo { trials: 500, seed: 1, threads: 8 }.mean(f);
        assert_eq!(a, b);
    }

    #[test]
    fn mean_ws_matches_mean_and_thread_invariance() {
        // A workspace-using trial whose result ignores workspace history
        // must agree with the plain path at every thread count.
        let plain = MonteCarlo { trials: 400, seed: 3, threads: 4 }.mean(|rng| rng.f64());
        for threads in [1, 2, 8] {
            let ws_mean = MonteCarlo { trials: 400, seed: 3, threads }.mean_ws(
                || vec![0.0f64; 4],
                |ws, rng| {
                    ws[0] = rng.f64(); // fully overwritten each trial
                    ws[0]
                },
            );
            assert_eq!(ws_mean, plain, "threads = {threads}");
        }
    }

    #[test]
    fn probability_ws_estimates() {
        let mc = MonteCarlo::new(20_000, 4);
        let p = mc.probability_ws(|| (), |_, rng| rng.bernoulli(0.25));
        assert!((p - 0.25).abs() < 0.02, "{p}");
    }

    #[test]
    fn mean_of_uniform_is_half() {
        let mc = MonteCarlo::new(50_000, 2);
        let m = mc.mean(|rng| rng.f64());
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn std_of_constant_is_zero() {
        let mc = MonteCarlo::new(100, 3);
        let (m, s) = mc.mean_std(|_| 4.0);
        assert_eq!(m, 4.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn probability_estimates() {
        let mc = MonteCarlo::new(20_000, 4);
        let p = mc.probability(|rng| rng.bernoulli(0.25));
        assert!((p - 0.25).abs() < 0.02, "{p}");
    }

    #[test]
    fn mean_curve_ws_matches_plain_curve() {
        let mc = MonteCarlo::new(300, 6);
        let plain = mc.mean_curve(2, |rng| {
            let x = rng.f64();
            vec![x, x * x]
        });
        for threads in [1, 4] {
            let ws = MonteCarlo { threads, ..mc }.mean_curve_ws(
                2,
                || vec![0.0f64; 2],
                |buf, rng| {
                    let x = rng.f64();
                    buf[0] = x;
                    buf[1] = x * x;
                    buf.clone()
                },
            );
            for (a, b) in plain.iter().zip(&ws) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads {threads}");
            }
        }
    }

    #[test]
    fn mean_curve_elementwise() {
        let mc = MonteCarlo::new(1000, 5);
        let c = mc.mean_curve(3, |rng| {
            let x = rng.f64();
            vec![x, 2.0 * x, 1.0]
        });
        assert!((c[0] - 0.5).abs() < 0.05);
        assert!((c[1] - 2.0 * c[0]).abs() < 1e-12);
        assert_eq!(c[2], 1.0);
    }
}
