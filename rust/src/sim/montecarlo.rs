//! Monte-Carlo engine: seeded, multi-threaded, *shardable* trial
//! averaging.
//!
//! Every figure point in the paper is "average X over 5000 trials";
//! this module runs those trials across threads with per-trial forked
//! RNG streams, so results are bit-identical regardless of thread
//! count.
//!
//! Since the sharded subsystem landed ([`super::shard`]), every
//! aggregation is expressed as *(per-shard partial) ∘ (merge)*: the
//! `*_partial*` methods run any contiguous slice of the trial range and
//! return an exact [`Partial`] aggregate, and the classic single-
//! process entry points below are literally the `num_shards = 1` case
//! (`Shard::full()`) finalized in place — since PR 4 that includes
//! [`MonteCarlo::mean_std`], which rides on an exact moment accumulator
//! (count / Σx / Σx² through [`Partial::Moments`]) instead of a
//! two-pass sweep over the raw trial values. Partials accumulate
//! through [`super::shard::ExactSum`], so merging the shards of *any*
//! disjoint partition reproduces the single-process result bit-for-bit
//! — the contract `repro shard`/`repro merge` and
//! `tests/shard_parity.rs` rely on.
//!
//! The `*_ws` variants thread a per-worker workspace (typically a
//! `decode::DecodeWorkspace`) through the trial closure, which is what
//! makes the steady-state trial loop allocation-free: scratch buffers
//! are built once per thread and reused across every trial it runs —
//! including, since the `assignment_into` re-draw path landed, the
//! assignment matrix G itself for schemes that sample a fresh G every
//! trial, and, since the scenario spine landed, the straggler-selection
//! scratch (`stragglers::StragglerScratch`) behind every
//! `crate::stragglers::StragglerModel`. Workspaces are scratch only —
//! trial results must not depend on the workspace's prior contents, so
//! means stay independent of thread count and scheduling. (A
//! workspace-cached CSR mirror of a *fixed* G is fine: it is a pure
//! function of the figure point, not of trial history — as is a
//! per-point resolved straggler model, which the sweeps build *outside*
//! the trial closure and share immutably across threads.)
//!
//! [`MonteCarlo::mean_partial_panel_ws`] is the panel-batched variant:
//! the trial range is cut into panels of W lanes and each worker
//! produces a whole panel per closure call (multi-RHS decode kernels
//! amortize every pass over G across the W lanes; the final panel is a
//! ragged tail). Lane `l` of the panel at `base` still draws from
//! `root.fork(base + l)`, so batching is unobservable in the results —
//! the partial is bit-identical to the scalar path at every width.

use super::shard::{ExactSum, Partial, Shard};
use crate::util::parallel::{parallel_map_panels_with, parallel_map_with};
use crate::util::Rng;

/// Configuration shared by all simulation entry points.
///
/// `threads` and `panel_width` are **execution hints only**: results
/// are bit-identical at every thread count and panel width (the RNG
/// forks per global trial index), so neither participates in run
/// identity — `JobSpec` serialization excludes both.
#[derive(Clone, Copy, Debug)]
pub struct MonteCarlo {
    pub trials: usize,
    pub seed: u64,
    pub threads: usize,
    /// Panel width W for the panelized sweeps (lanes per
    /// [`MonteCarlo::mean_partial_panel_ws`] kernel call).
    pub panel_width: usize,
}

impl MonteCarlo {
    pub fn new(trials: usize, seed: u64) -> Self {
        MonteCarlo {
            trials,
            seed,
            threads: crate::util::parallel::default_threads(),
            panel_width: crate::decode::DEFAULT_PANEL_WIDTH,
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn with_panel_width(mut self, width: usize) -> Self {
        self.panel_width = width.max(1);
        self
    }

    // ------------------------------------------- shard-aware primitives

    /// Partial mean of `f` over this shard's slice of the trial range.
    /// Trial `i` always draws from `root.fork(i)` — the global trial
    /// index, not the within-shard offset — so the set of trial values
    /// is independent of the shard layout, and the exact-sum partial
    /// merges to the unsharded mean bit-for-bit.
    pub fn mean_partial_ws<W>(
        &self,
        shard: Shard,
        init: impl Fn() -> W + Sync,
        f: impl Fn(&mut W, &mut Rng) -> f64 + Sync,
    ) -> Partial {
        let root = Rng::new(self.seed);
        let range = shard.range(self.trials);
        let lo = range.start;
        let vals = parallel_map_with(range.len(), self.threads, init, |ws, j| {
            let mut rng = root.fork((lo + j) as u64);
            f(ws, &mut rng)
        });
        let mut sum = ExactSum::new();
        for &v in &vals {
            sum.add(v);
        }
        Partial::Mean { count: vals.len() as u64, sum }
    }

    /// [`MonteCarlo::mean_partial_ws`] without a workspace.
    pub fn mean_partial(&self, shard: Shard, f: impl Fn(&mut Rng) -> f64 + Sync) -> Partial {
        self.mean_partial_ws(shard, || (), |_, rng| f(rng))
    }

    /// Panel-batched [`MonteCarlo::mean_partial_ws`]: the trial range is
    /// cut into panels of `width` lanes and `f(ws, root, base, lanes,
    /// out)` produces a whole panel per call (`base` is the *global*
    /// index of the panel's first trial; the final panel may be ragged,
    /// `lanes < width`). `f` must give lane `l` the value the scalar
    /// trial closure would produce for trial `base + l` from the stream
    /// `root.fork(base + l)` — the [`crate::decode::PanelWorkspace`]
    /// methods uphold exactly that — and then the returned partial is
    /// bit-identical to the scalar entry point's at every width, thread
    /// count, and shard layout: trial values land position-addressed in
    /// global trial order, and the exact sum folds them in that order.
    pub fn mean_partial_panel_ws<W>(
        &self,
        shard: Shard,
        width: usize,
        init: impl Fn() -> W + Sync,
        f: impl Fn(&mut W, &Rng, u64, usize, &mut [f64]) + Sync,
    ) -> Partial {
        let root = Rng::new(self.seed);
        let range = shard.range(self.trials);
        let lo = range.start;
        let vals = parallel_map_panels_with(range.len(), width, self.threads, init, |ws, p, out| {
            let base = (lo + p * width) as u64;
            f(ws, &root, base, out.len(), out);
        });
        let mut sum = ExactSum::new();
        for &v in &vals {
            sum.add(v);
        }
        Partial::Mean { count: vals.len() as u64, sum }
    }

    /// Partial first and second moments (count, exact Σx, exact Σx²)
    /// of `f` over this shard's slice — the merge-safe accumulator
    /// behind [`MonteCarlo::mean_std`]. The square is taken per trial
    /// *before* accumulation, so every input to the exact sums is a
    /// pure function of the trial index; any disjoint partition merges
    /// to the same finalized (mean, std) bits.
    pub fn mean_std_partial_ws<W>(
        &self,
        shard: Shard,
        init: impl Fn() -> W + Sync,
        f: impl Fn(&mut W, &mut Rng) -> f64 + Sync,
    ) -> Partial {
        let root = Rng::new(self.seed);
        let range = shard.range(self.trials);
        let lo = range.start;
        let vals = parallel_map_with(range.len(), self.threads, init, |ws, j| {
            let mut rng = root.fork((lo + j) as u64);
            f(ws, &mut rng)
        });
        let mut sum = ExactSum::new();
        let mut sumsq = ExactSum::new();
        for &v in &vals {
            sum.add(v);
            sumsq.add(v * v);
        }
        Partial::Moments { count: vals.len() as u64, sum, sumsq }
    }

    /// Partial success count of a predicate over this shard's slice.
    pub fn probability_partial_ws<W>(
        &self,
        shard: Shard,
        init: impl Fn() -> W + Sync,
        f: impl Fn(&mut W, &mut Rng) -> bool + Sync,
    ) -> Partial {
        let root = Rng::new(self.seed);
        let range = shard.range(self.trials);
        let lo = range.start;
        let vals = parallel_map_with(range.len(), self.threads, init, |ws, j| {
            let mut rng = root.fork((lo + j) as u64);
            f(ws, &mut rng)
        });
        let hits = vals.iter().filter(|&&hit| hit).count() as u64;
        Partial::Prob { count: vals.len() as u64, hits }
    }

    /// Partial element-wise curve sums over this shard's slice (all
    /// trial curves must have length `len`).
    pub fn mean_curve_partial_ws<W>(
        &self,
        len: usize,
        shard: Shard,
        init: impl Fn() -> W + Sync,
        f: impl Fn(&mut W, &mut Rng) -> Vec<f64> + Sync,
    ) -> Partial {
        let root = Rng::new(self.seed);
        let range = shard.range(self.trials);
        let lo = range.start;
        let curves = parallel_map_with(range.len(), self.threads, init, |ws, j| {
            let mut rng = root.fork((lo + j) as u64);
            let c = f(ws, &mut rng);
            assert_eq!(c.len(), len, "trial curve length mismatch");
            c
        });
        let mut sums: Vec<ExactSum> = (0..len).map(|_| ExactSum::new()).collect();
        for c in &curves {
            for (s, &v) in sums.iter_mut().zip(c) {
                s.add(v);
            }
        }
        Partial::Curve { count: curves.len() as u64, sums }
    }

    // ------------------------------- single-process (num_shards = 1) API

    /// Mean of `f` over `trials` independent RNG streams — the
    /// `num_shards = 1` case of [`MonteCarlo::mean_partial`].
    pub fn mean(&self, f: impl Fn(&mut Rng) -> f64 + Sync) -> f64 {
        self.mean_partial(Shard::full(), f).value()
    }

    /// Mean and sample standard deviation — the `num_shards = 1` case
    /// of [`MonteCarlo::mean_std_partial_ws`], finalized via
    /// [`Partial::mean_std`]. Accumulates exact moments (count, Σx,
    /// Σx²) instead of the pre-PR-4 two-pass sweep, so it is shardable
    /// like everything else. Trade-off: the one-pass variance identity
    /// cancels when `mean² ≫ var` (relative error ~ `(mean²/var)·2⁻⁵³`
    /// despite the exact sums) — center the trial values in `f` if your
    /// statistic lives in that regime; see [`Partial::mean_std`]. No
    /// figure/table output uses `mean_std`.
    pub fn mean_std(&self, f: impl Fn(&mut Rng) -> f64 + Sync) -> (f64, f64) {
        self.mean_std_ws(|| (), |_, rng| f(rng))
    }

    /// [`MonteCarlo::mean_std`] with a per-thread workspace.
    pub fn mean_std_ws<W>(
        &self,
        init: impl Fn() -> W + Sync,
        f: impl Fn(&mut W, &mut Rng) -> f64 + Sync,
    ) -> (f64, f64) {
        self.mean_std_partial_ws(Shard::full(), init, f).mean_std()
    }

    /// Element-wise mean of vector-valued trials (all same length) —
    /// used for the Fig. 5 curves {||u_t||^2}_t.
    pub fn mean_curve(&self, len: usize, f: impl Fn(&mut Rng) -> Vec<f64> + Sync) -> Vec<f64> {
        self.mean_curve_ws(len, || (), |_, rng| f(rng))
    }

    /// Fraction of trials where the predicate holds (e.g. P(err > αs)).
    pub fn probability(&self, f: impl Fn(&mut Rng) -> bool + Sync) -> f64 {
        self.probability_ws(|| (), |_, rng| f(rng))
    }

    /// [`MonteCarlo::mean`] with a per-thread workspace built by `init`
    /// and handed to every trial — the zero-allocation hot path.
    pub fn mean_ws<W>(
        &self,
        init: impl Fn() -> W + Sync,
        f: impl Fn(&mut W, &mut Rng) -> f64 + Sync,
    ) -> f64 {
        self.mean_partial_ws(Shard::full(), init, f).value()
    }

    /// [`MonteCarlo::mean_curve`] with a per-thread workspace — the
    /// Fig. 5 sweep re-draws G per trial through the workspace.
    pub fn mean_curve_ws<W>(
        &self,
        len: usize,
        init: impl Fn() -> W + Sync,
        f: impl Fn(&mut W, &mut Rng) -> Vec<f64> + Sync,
    ) -> Vec<f64> {
        self.mean_curve_partial_ws(len, Shard::full(), init, f).curve_values()
    }

    /// [`MonteCarlo::probability`] with a per-thread workspace.
    pub fn probability_ws<W>(
        &self,
        init: impl Fn() -> W + Sync,
        f: impl Fn(&mut W, &mut Rng) -> bool + Sync,
    ) -> f64 {
        self.probability_partial_ws(Shard::full(), init, f).value()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_independent_of_thread_count() {
        let f = |rng: &mut Rng| rng.f64();
        let a = MonteCarlo::new(500, 1).with_threads(1).mean(f);
        let b = MonteCarlo::new(500, 1).with_threads(8).mean(f);
        assert_eq!(a, b);
    }

    #[test]
    fn mean_ws_matches_mean_and_thread_invariance() {
        // A workspace-using trial whose result ignores workspace history
        // must agree with the plain path at every thread count.
        let plain = MonteCarlo::new(400, 3).with_threads(4).mean(|rng| rng.f64());
        for threads in [1, 2, 8] {
            let ws_mean = MonteCarlo::new(400, 3).with_threads(threads).mean_ws(
                || vec![0.0f64; 4],
                |ws, rng| {
                    ws[0] = rng.f64(); // fully overwritten each trial
                    ws[0]
                },
            );
            assert_eq!(ws_mean, plain, "threads = {threads}");
        }
    }

    #[test]
    fn sharded_mean_merges_to_single_process_bits() {
        let mc = MonteCarlo::new(501, 11).with_threads(4);
        let whole = mc.mean_ws(|| (), |_, rng| rng.f64() - 0.5);
        for num_shards in [1usize, 2, 3, 7] {
            let mut merged: Option<Partial> = None;
            for sid in 0..num_shards {
                let shard = Shard::new(sid, num_shards).unwrap();
                // Vary thread counts per shard: must not matter.
                let mc_s = MonteCarlo { threads: 1 + sid, ..mc };
                let part = mc_s.mean_partial_ws(shard, || (), |_, rng| rng.f64() - 0.5);
                match merged.as_mut() {
                    None => merged = Some(part),
                    Some(m) => m.merge(&part).unwrap(),
                }
            }
            let merged = merged.unwrap();
            assert_eq!(merged.mc_trials(), Some(501));
            assert_eq!(
                merged.value().to_bits(),
                whole.to_bits(),
                "num_shards = {num_shards}"
            );
        }
    }

    #[test]
    fn sharded_probability_and_curve_merge_to_single_process_bits() {
        let mc = MonteCarlo::new(300, 12).with_threads(3);
        let p_whole = mc.probability_ws(|| (), |_, rng| rng.bernoulli(0.3));
        let c_whole = mc.mean_curve_ws(2, || (), |_, rng| {
            let x = rng.f64();
            vec![x, x * x]
        });
        for num_shards in [2usize, 5] {
            let mut p: Option<Partial> = None;
            let mut c: Option<Partial> = None;
            for sid in 0..num_shards {
                let shard = Shard::new(sid, num_shards).unwrap();
                let pp = mc.probability_partial_ws(shard, || (), |_, rng| rng.bernoulli(0.3));
                let cc = mc.mean_curve_partial_ws(2, shard, || (), |_, rng| {
                    let x = rng.f64();
                    vec![x, x * x]
                });
                match p.as_mut() {
                    None => p = Some(pp),
                    Some(m) => m.merge(&pp).unwrap(),
                }
                match c.as_mut() {
                    None => c = Some(cc),
                    Some(m) => m.merge(&cc).unwrap(),
                }
            }
            assert_eq!(p.unwrap().value().to_bits(), p_whole.to_bits());
            let c_merged = c.unwrap().curve_values();
            assert_eq!(c_merged.len(), c_whole.len());
            for (a, b) in c_merged.iter().zip(&c_whole) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn panel_partial_matches_scalar_partial_bits() {
        // A panel closure whose lanes reproduce the scalar trial from
        // the same forked stream must yield the same Partial bits for
        // every width / thread count / shard layout — including ragged
        // tails (401 is prime to every width below).
        let mc = MonteCarlo::new(401, 17).with_threads(4);
        let trial = |rng: &mut Rng| rng.f64() * 2.0 - 0.7;
        let reference = mc.mean_partial_ws(Shard::full(), || (), |_, rng| trial(rng));
        for width in [1usize, 3, 4, 8] {
            for threads in [1usize, 5] {
                let mc_t = MonteCarlo { threads, ..mc };
                let panel = mc_t.mean_partial_panel_ws(
                    Shard::full(),
                    width,
                    || (),
                    |_, root, base, lanes, out| {
                        for (l, slot) in out.iter_mut().enumerate().take(lanes) {
                            let mut rng = root.fork(base + l as u64);
                            *slot = trial(&mut rng);
                        }
                    },
                );
                assert_eq!(panel.mc_trials(), Some(401));
                assert_eq!(
                    panel.value().to_bits(),
                    reference.value().to_bits(),
                    "width {width} threads {threads}"
                );
            }
        }
        // Sharded panels merge to the same bits too.
        for num_shards in [2usize, 3] {
            let mut merged: Option<Partial> = None;
            for sid in 0..num_shards {
                let shard = Shard::new(sid, num_shards).unwrap();
                let part = mc.mean_partial_panel_ws(shard, 4, || (), |_, root, base, lanes, out| {
                    for (l, slot) in out.iter_mut().enumerate().take(lanes) {
                        let mut rng = root.fork(base + l as u64);
                        *slot = trial(&mut rng);
                    }
                });
                match merged.as_mut() {
                    None => merged = Some(part),
                    Some(m) => m.merge(&part).unwrap(),
                }
            }
            assert_eq!(merged.unwrap().value().to_bits(), reference.value().to_bits());
        }
    }

    #[test]
    fn probability_ws_estimates() {
        let mc = MonteCarlo::new(20_000, 4);
        let p = mc.probability_ws(|| (), |_, rng| rng.bernoulli(0.25));
        assert!((p - 0.25).abs() < 0.02, "{p}");
    }

    #[test]
    fn mean_of_uniform_is_half() {
        let mc = MonteCarlo::new(50_000, 2);
        let m = mc.mean(|rng| rng.f64());
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn std_of_constant_is_zero() {
        let mc = MonteCarlo::new(100, 3);
        let (m, s) = mc.mean_std(|_| 4.0);
        assert_eq!(m, 4.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn mean_std_estimates_uniform_moments() {
        let mc = MonteCarlo::new(20_000, 8);
        let (m, s) = mc.mean_std(|rng| rng.f64());
        assert!((m - 0.5).abs() < 0.01, "{m}");
        assert!((s - (1.0f64 / 12.0).sqrt()).abs() < 0.01, "{s}");
    }

    #[test]
    fn sharded_mean_std_merges_to_single_process_bits() {
        let mc = MonteCarlo::new(501, 13).with_threads(4);
        let trial = |_: &mut (), rng: &mut Rng| rng.f64() * 3.0 - 1.0;
        let (m_whole, s_whole) = mc.mean_std(|rng| rng.f64() * 3.0 - 1.0);
        for num_shards in [1usize, 2, 3, 7] {
            let mut merged: Option<Partial> = None;
            for sid in 0..num_shards {
                let shard = Shard::new(sid, num_shards).unwrap();
                // Vary thread counts per shard: must not matter.
                let mc_s = MonteCarlo { threads: 1 + sid, ..mc };
                let part = mc_s.mean_std_partial_ws(shard, || (), trial);
                match merged.as_mut() {
                    None => merged = Some(part),
                    Some(m) => m.merge(&part).unwrap(),
                }
            }
            let merged = merged.unwrap();
            assert_eq!(merged.mc_trials(), Some(501));
            let (m, s) = merged.mean_std();
            assert_eq!(m.to_bits(), m_whole.to_bits(), "num_shards = {num_shards}");
            assert_eq!(s.to_bits(), s_whole.to_bits(), "num_shards = {num_shards}");
        }
    }

    #[test]
    fn probability_estimates() {
        let mc = MonteCarlo::new(20_000, 4);
        let p = mc.probability(|rng| rng.bernoulli(0.25));
        assert!((p - 0.25).abs() < 0.02, "{p}");
    }

    #[test]
    fn mean_curve_ws_matches_plain_curve() {
        let mc = MonteCarlo::new(300, 6);
        let plain = mc.mean_curve(2, |rng| {
            let x = rng.f64();
            vec![x, x * x]
        });
        for threads in [1, 4] {
            let ws = MonteCarlo { threads, ..mc }.mean_curve_ws(
                2,
                || vec![0.0f64; 2],
                |buf, rng| {
                    let x = rng.f64();
                    buf[0] = x;
                    buf[1] = x * x;
                    buf.clone()
                },
            );
            for (a, b) in plain.iter().zip(&ws) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads {threads}");
            }
        }
    }

    #[test]
    fn mean_curve_elementwise() {
        let mc = MonteCarlo::new(1000, 5);
        let c = mc.mean_curve(3, |rng| {
            let x = rng.f64();
            vec![x, 2.0 * x, 1.0]
        });
        assert!((c[0] - 0.5).abs() < 0.05);
        assert!((c[1] - 2.0 * c[0]).abs() < 1e-12);
        assert_eq!(c[2], 1.0);
    }
}
