//! The `repro scenario` job family: **time-to-accuracy** sweeps — the
//! plot the paper's abstract promises ("the slowest compute nodes in
//! the system dictate the overall running time") but no figure/table
//! entry point produces.
//!
//! The `tta` study sweeps the straggler-fraction grid δ ∈ {0.05..0.90}
//! for every Fig. 2-4 scheme under a latency scenario, with **two
//! deadline-policy arms** per scheme:
//!
//! * `fastest-r` — the master waits for the r = (1-δ)k fastest workers;
//!   gather wall-clock is the r-th order statistic of the latency
//!   draws (random per trial).
//! * `deadline` — the master stops at the fixed wall-clock
//!   `quantile(1-δ)` of the latency model (the deadline admitting a
//!   (1-δ) fraction in expectation); the responding set — and hence
//!   err₁ — varies per trial, the gather time does not.
//!
//! Each point aggregates a 2-element [`Partial::Curve`]
//! (Σ gather, Σ err₁), so scenario runs shard/merge/verify/tree-reduce
//! exactly like every figure and table: the per-trial pair is a pure
//! function of the trial index, and curve partials fold through
//! `ExactSum`. Finalizing yields (mean gather, mean err₁/k) — a
//! parametric time-to-accuracy curve traced by δ, per scheme and arm.

use anyhow::{bail, Result};

use super::montecarlo::MonteCarlo;
use super::shard::{Partial, Shard};
use crate::codes::GradientCode;
use crate::decode::{DecodeWorkspace, PanelWorkspace};
use crate::linalg::{CscMatrix, LsqrOptions};
use crate::sim::figures::FIG_SCHEMES;
use crate::stragglers::{
    DeadlinePolicy, LatencyModel, LatencyStragglers, PolicySpec, ResolvedScenario, Scenario,
    StragglerModel,
};
use crate::util::Rng;

/// Aggregate one sweep point's **scalar** statistic under a resolved
/// scenario — the single dispatch every figure/table/ablation sweep
/// shares (so no call site can pair the re-draw/standing trial
/// flavors wrongly):
///
/// * re-draw scenarios (uniform, latency) run this shard's slice of
///   the Monte-Carlo trial range through `redraw`;
/// * standing-assignment scenarios (adversarial — fixed survivors
///   replayed against a fixed G, no RNG consumed) are deterministic,
///   so the point collapses to **one** decode carried as a replicated
///   [`Partial::Exact`] (merged by bit-equality across shards, like
///   thm10's attack row) instead of `trials` identical solves.
pub fn scalar_partial_under(
    resolved: &ResolvedScenario,
    mc: &MonteCarlo,
    shard: Shard,
    redraw: impl Fn(&mut DecodeWorkspace, &dyn StragglerModel, &mut Rng) -> f64 + Sync,
    standing: impl FnOnce(&mut DecodeWorkspace, &CscMatrix, &dyn StragglerModel, &mut Rng) -> f64,
) -> Partial {
    match &resolved.standing_g {
        None => mc.mean_partial_ws(shard, DecodeWorkspace::new, |ws, rng| {
            redraw(ws, &*resolved.model, rng)
        }),
        Some(g) => {
            let mut ws = DecodeWorkspace::new();
            // The model replays a planned set without touching the RNG;
            // the seeded stream is only a formality of the trial API.
            let mut rng = Rng::new(mc.seed);
            Partial::Exact { value: standing(&mut ws, g, &*resolved.model, &mut rng) }
        }
    }
}

/// Which redraw arm a panelized sweep point runs — the re-draw half of
/// the [`scalar_partial_panel_under`] dispatch.
#[derive(Clone, Copy)]
pub enum PanelKind<'a> {
    /// One-step err₁ redraw trials — the fused lane-strided coverage
    /// panel ([`PanelWorkspace::onestep_redraw_panel_with`]).
    OneStep { rho: f64 },
    /// Optimal (LSQR) redraw trials — per-lane delegation
    /// ([`PanelWorkspace::optimal_redraw_panel_with`]); distinct
    /// per-lane G leaves nothing to fuse.
    Optimal { opts: &'a LsqrOptions, warm: Option<f64> },
}

/// Panel-batched [`scalar_partial_under`] — the dispatch behind every
/// panelized figure/table sweep point:
///
/// * re-draw scenarios (uniform, latency) run this shard's slice of
///   the trial range in [`PanelWorkspace`] panels of `mc.panel_width`
///   lanes. Lane `l` of the panel at `base` forks `root.fork(base + l)`
///   — the scalar trial's stream — so the partial is **bit-identical
///   to [`scalar_partial_under`] at every width**, and published CSVs
///   are unchanged by panelization (pinned in
///   `tests/decode_parity.rs`);
/// * standing-assignment scenarios (adversarial) are deterministic and
///   collapse to the same single-decode [`Partial::Exact`] as the
///   scalar dispatch — a collapsed point has nothing to batch.
pub fn scalar_partial_panel_under(
    resolved: &ResolvedScenario,
    mc: &MonteCarlo,
    shard: Shard,
    code: &dyn GradientCode,
    kind: PanelKind<'_>,
    standing: impl FnOnce(&mut DecodeWorkspace, &CscMatrix, &dyn StragglerModel, &mut Rng) -> f64,
) -> Partial {
    match &resolved.standing_g {
        None => {
            let width = mc.panel_width.max(1);
            mc.mean_partial_panel_ws(
                shard,
                width,
                || PanelWorkspace::new(width),
                |ws, root, base, lanes, out| match kind {
                    PanelKind::OneStep { rho } => ws.onestep_redraw_panel_with(
                        code,
                        &*resolved.model,
                        rho,
                        root,
                        base,
                        lanes,
                        out,
                    ),
                    PanelKind::Optimal { opts, warm } => ws.optimal_redraw_panel_with(
                        code,
                        &*resolved.model,
                        opts,
                        warm,
                        root,
                        base,
                        lanes,
                        out,
                    ),
                },
            )
        }
        Some(g) => {
            let mut ws = DecodeWorkspace::new();
            // Same collapse as scalar_partial_under: the model replays a
            // planned set without touching the RNG.
            let mut rng = Rng::new(mc.seed);
            Partial::Exact { value: standing(&mut ws, g, &*resolved.model, &mut rng) }
        }
    }
}

/// [`scalar_partial_under`] for **probability** statistics (thm8):
/// re-draw scenarios count successes over the shard's trial range;
/// deterministic standing points collapse to an exact 0/1 value.
pub fn prob_partial_under(
    resolved: &ResolvedScenario,
    mc: &MonteCarlo,
    shard: Shard,
    redraw: impl Fn(&mut DecodeWorkspace, &dyn StragglerModel, &mut Rng) -> bool + Sync,
    standing: impl FnOnce(&mut DecodeWorkspace, &CscMatrix, &dyn StragglerModel, &mut Rng) -> bool,
) -> Partial {
    match &resolved.standing_g {
        None => mc.probability_partial_ws(shard, DecodeWorkspace::new, |ws, rng| {
            redraw(ws, &*resolved.model, rng)
        }),
        Some(g) => {
            let mut ws = DecodeWorkspace::new();
            let mut rng = Rng::new(mc.seed);
            let hit = standing(&mut ws, g, &*resolved.model, &mut rng);
            Partial::Exact { value: if hit { 1.0 } else { 0.0 } }
        }
    }
}

/// The deadline-policy arms every `tta` sweep emits.
pub const TTA_POLICIES: [&str; 2] = ["fastest-r", "deadline"];

/// The `tta3` study's arms: the two deadline-policy arms plus the
/// survivor-set-optimal decoder (Glasgow & Wootters, *Approximate
/// Gradient Coding with Optimal Decoding*) on the fastest-r survivor
/// draw — err(A) rides the `err1` CSV column, putting the optimal
/// decoder's time-to-accuracy frontier alongside the one-step arms.
/// A strict superset of [`TTA_POLICIES`] so `tta` artifacts intern
/// unchanged.
pub const TTA3_POLICIES: [&str; 3] = ["fastest-r", "deadline", "optimal"];

/// The δ grid the `tta` study sweeps (the Fig. 2-4 grid).
pub fn tta_deltas() -> Vec<f64> {
    (1..=18).map(|i| i as f64 * 0.05).collect()
}

/// The `latparam` study's arms: each sweeps one latency-model
/// parameter at a fixed deadline. They ride the `policy` CSV/artifact
/// column (an arm label, like the `tta` family's deadline-policy
/// arms), and the swept parameter value rides the `delta` column.
pub const LATPARAM_ARMS: [&str; 2] = ["pareto-shape", "sexp-rate"];

/// Every arm label a scenario artifact's `policy` column may carry —
/// the intern registry for scenario shard artifacts. Strict superset
/// of [`TTA3_POLICIES`] (so older artifacts parse unchanged) plus the
/// [`LATPARAM_ARMS`].
pub const SCENARIO_POLICIES: [&str; 5] =
    ["fastest-r", "deadline", "optimal", "pareto-shape", "sexp-rate"];

/// The fixed deadline the `latparam` study (and the matching
/// `repro load --workload latparam` traffic source) evaluates at: the
/// base model's 80th-percentile completion time, so the sweep measures
/// how err₁ at a realistic cutoff responds as the tail gets heavier or
/// the service rate drops.
pub fn latparam_deadline(base: &LatencyModel) -> f64 {
    base.quantile(0.8)
}

/// The latency models one `latparam` arm sweeps: 18 `(parameter,
/// model)` points, mirroring the 18-point δ grid of the `tta` family.
///
/// * `pareto-shape` — Pareto tail index α ∈ {1.1, 1.2, …, 2.8} (heavy
///   → light tail) at the base model's scale (0.02 if the base is not
///   Pareto).
/// * `sexp-rate` — shifted-exponential service rate ∈ {10, 20, …, 180}
///   at the base model's shift (0.02 if the base is not shifted-exp).
///
/// Deterministic functions of the base model only, so the sweep is
/// part of the job identity and `repro load` can rebuild the identical
/// grid client-side.
pub fn latparam_models(arm: &str, base: &LatencyModel) -> Vec<(f64, LatencyModel)> {
    match arm {
        "pareto-shape" => {
            let scale = match *base {
                LatencyModel::Pareto { scale, .. } => scale,
                _ => 0.02,
            };
            (1..=18)
                .map(|i| {
                    let shape = 1.0 + i as f64 * 0.1;
                    (shape, LatencyModel::Pareto { scale, shape })
                })
                .collect()
        }
        "sexp-rate" => {
            let b = match *base {
                LatencyModel::ShiftedExp { base, .. } => base,
                _ => 0.02,
            };
            (1..=18)
                .map(|i| {
                    let rate = 10.0 * i as f64;
                    (rate, LatencyModel::ShiftedExp { base: b, rate })
                })
                .collect()
        }
        other => panic!("unknown latparam arm {other:?} (one of {LATPARAM_ARMS:?})"),
    }
}

/// The survivor count a latency model is expected to deliver by the
/// deadline: ⌈CDF(T)·k⌋ clamped to [1, k]. Sets the one-step ρ for a
/// `latparam` point and the `r` of the matching `repro load` decode
/// template.
pub fn latparam_expected_r(model: &LatencyModel, deadline: f64, k: usize) -> usize {
    ((model.cdf(deadline) * k as f64).round() as usize).clamp(1, k)
}

/// One published time-to-accuracy point.
#[derive(Clone, Debug)]
pub struct ScenarioPoint {
    pub study: &'static str,
    pub scheme: String,
    /// Deadline-policy arm (one of [`TTA_POLICIES`]).
    pub policy: &'static str,
    pub s: usize,
    pub delta: f64,
    /// Mean gather wall-clock (seconds under the latency model).
    pub gather: f64,
    /// Mean one-step error err₁/k.
    pub err1: f64,
}

impl ScenarioPoint {
    pub fn csv_header() -> &'static str {
        "scenario,scheme,policy,s,delta,gather,err1"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{:.3},{:.6e},{:.6e}",
            self.study, self.scheme, self.policy, self.s, self.delta, self.gather, self.err1
        )
    }
}

/// One scenario point's *partial* state: sweep metadata plus the exact
/// 2-element curve partial (Σ gather, Σ err₁) over this shard's trials.
#[derive(Clone, Debug)]
pub struct ScenarioPartialPoint {
    pub study: &'static str,
    pub scheme: String,
    pub policy: &'static str,
    pub s: usize,
    pub delta: f64,
    /// The sweep's k (finalize divides the err₁ mean by it).
    pub k: usize,
    pub partial: Partial,
}

impl ScenarioPartialPoint {
    /// Metadata equality (delta compared by bits) — merge refuses to
    /// combine partials from different sweep points.
    pub fn same_point(&self, other: &ScenarioPartialPoint) -> bool {
        self.study == other.study
            && self.scheme == other.scheme
            && self.policy == other.policy
            && self.s == other.s
            && self.delta.to_bits() == other.delta.to_bits()
            && self.k == other.k
            && self.partial.kind() == other.partial.kind()
    }

    /// Finalize a (fully-merged) partial into the published point.
    pub fn finalize(&self) -> ScenarioPoint {
        let curve = self.partial.curve_values();
        let (gather, err1_total) = match curve.as_slice() {
            [g, e] => (*g, *e),
            _ => (f64::NAN, f64::NAN),
        };
        ScenarioPoint {
            study: self.study,
            scheme: self.scheme.clone(),
            policy: self.policy,
            s: self.s,
            delta: self.delta,
            gather,
            err1: err1_total / self.k as f64,
        }
    }
}

/// Finalize a slice of fully-merged partial points.
pub fn finalize_scenario_points(points: &[ScenarioPartialPoint]) -> Vec<ScenarioPoint> {
    points.iter().map(|p| p.finalize()).collect()
}

/// Extract the latency model a `tta`-family sweep runs on. The
/// scenario must carry a latency model with the default (fastest-r)
/// policy — the sweep derives the deadline arms itself: FastestR(r(δ))
/// and Fixed(quantile(1-δ)); uniform and adversarial scenarios have no
/// wall-clock axis and are rejected, as is an explicit `deadline:T`
/// policy (the deadline axis is swept, not fixed).
fn tta_latency_model(scenario: &Scenario) -> Result<LatencyModel> {
    match scenario {
        Scenario::Latency { model, policy: PolicySpec::FastestR } => Ok(*model),
        Scenario::Latency { .. } => bail!(
            "the scenario job sweeps the deadline axis itself (fastest-r per point plus \
             model quantiles); drop the explicit deadline:T policy from --stragglers"
        ),
        other => bail!(
            "the scenario job needs a latency straggler model \
             (--stragglers shifted-exp:..|pareto:..|bimodal:..), got {other}"
        ),
    }
}

/// Shared sweep core of the `tta` family: one point per
/// (arm, scheme, δ). One-step arms stream each trial's survivors
/// through the workspace's incremental decoder in arrival order — the
/// exact err₁ is bit-identical to the historical batch path
/// (prefix-parity contract at the full prefix), so published `tta`
/// CSVs are byte-unchanged. The `optimal` arm decodes the same
/// fastest-r survivor draws with the survivor-set-optimal LSQR solve
/// (warm-started at ρ·1, per-trial pure — shard invariance needs no
/// cross-trial state).
fn tta_family_partials(
    study: &'static str,
    policies: &'static [&'static str],
    k: usize,
    s: usize,
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Result<Vec<ScenarioPartialPoint>> {
    let latency = tta_latency_model(scenario)?;
    let opts = LsqrOptions::default();
    let mut out = Vec::new();
    for &policy_arm in policies {
        for &scheme in &FIG_SCHEMES {
            for delta in tta_deltas() {
                let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
                let rho = k as f64 / (r as f64 * s as f64);
                let code = scheme.build(k, k, s);
                let policy = match policy_arm {
                    "deadline" => DeadlinePolicy::Fixed(latency.quantile(1.0 - delta)),
                    // fastest-r and the optimal arm share the
                    // fastest-r survivor draw (and RNG stream).
                    _ => DeadlinePolicy::FastestR(r),
                };
                let model = LatencyStragglers { model: latency, policy };
                let partial = mc.mean_curve_partial_ws(2, shard, DecodeWorkspace::new, |ws, rng| {
                    let err = match policy_arm {
                        "optimal" => ws.optimal_redraw_trial_with(
                            code.as_ref(),
                            &model as &dyn StragglerModel,
                            &opts,
                            Some(rho),
                            rng,
                        ),
                        _ => ws.onestep_incremental_redraw_trial_with(
                            code.as_ref(),
                            &model as &dyn StragglerModel,
                            rho,
                            rng,
                        ),
                    };
                    vec![ws.last_gather_time(), err]
                });
                out.push(ScenarioPartialPoint {
                    study,
                    scheme: scheme.name().to_string(),
                    policy: policy_arm,
                    s,
                    delta,
                    k,
                    partial,
                });
            }
        }
    }
    Ok(out)
}

/// One shard of the `tta` study; see [`tta_family_partials`] for the
/// arm derivation and the incremental-decode parity contract.
pub fn tta_partials(
    k: usize,
    s: usize,
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Result<Vec<ScenarioPartialPoint>> {
    tta_family_partials("tta", &TTA_POLICIES, k, s, scenario, mc, shard)
}

/// One shard of the `tta3` study: [`tta_partials`] plus the
/// survivor-set-optimal third arm ([`TTA3_POLICIES`]).
pub fn tta3_partials(
    k: usize,
    s: usize,
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Result<Vec<ScenarioPartialPoint>> {
    tta_family_partials("tta3", &TTA3_POLICIES, k, s, scenario, mc, shard)
}

/// The single-process `tta` study (the `num_shards = 1` case of
/// [`tta_partials`]) — what `repro scenario` prints.
pub fn tta(k: usize, s: usize, scenario: &Scenario, mc: &MonteCarlo) -> Result<Vec<ScenarioPoint>> {
    Ok(finalize_scenario_points(&tta_partials(k, s, scenario, mc, Shard::full())?))
}

/// The single-process `tta3` study.
pub fn tta3(k: usize, s: usize, scenario: &Scenario, mc: &MonteCarlo) -> Result<Vec<ScenarioPoint>> {
    Ok(finalize_scenario_points(&tta3_partials(k, s, scenario, mc, Shard::full())?))
}

/// One shard of the `latparam` study: the latency-parameter sweep.
///
/// Where the `tta` family sweeps the deadline axis under one latency
/// model, `latparam` holds the deadline fixed
/// ([`latparam_deadline`]: the base model's 80th percentile) and
/// sweeps the latency-model *parameters* — Pareto tail index and
/// shifted-exp service rate ([`latparam_models`]) — measuring the
/// err₁ each scheme achieves when the master cuts off at that
/// wall-clock. One point per (arm, scheme, parameter); the swept
/// parameter rides the `delta` column, the arm rides `policy`. Every
/// trial decodes the fixed-deadline survivor draw through the
/// incremental one-step decoder with ρ set from the expected survivor
/// count ([`latparam_expected_r`]); `gather` finalizes to the deadline
/// itself (a fixed-deadline policy's gather time is the deadline),
/// which pins the sweep's time axis exactly.
///
/// Same 2-element `Partial::Curve` spine as `tta`, so shards merge,
/// verify, and tree-reduce identically.
pub fn latparam_partials(
    k: usize,
    s: usize,
    scenario: &Scenario,
    mc: &MonteCarlo,
    shard: Shard,
) -> Result<Vec<ScenarioPartialPoint>> {
    let base = tta_latency_model(scenario)?;
    let deadline = latparam_deadline(&base);
    let mut out = Vec::new();
    for &arm in &LATPARAM_ARMS {
        for &scheme in &FIG_SCHEMES {
            for (param, swept) in latparam_models(arm, &base) {
                let r = latparam_expected_r(&swept, deadline, k);
                let rho = k as f64 / (r as f64 * s as f64);
                let code = scheme.build(k, k, s);
                let model =
                    LatencyStragglers { model: swept, policy: DeadlinePolicy::Fixed(deadline) };
                let partial = mc.mean_curve_partial_ws(2, shard, DecodeWorkspace::new, |ws, rng| {
                    let err = ws.onestep_incremental_redraw_trial_with(
                        code.as_ref(),
                        &model as &dyn StragglerModel,
                        rho,
                        rng,
                    );
                    vec![ws.last_gather_time(), err]
                });
                out.push(ScenarioPartialPoint {
                    study: "latparam",
                    scheme: scheme.name().to_string(),
                    policy: arm,
                    s,
                    delta: param,
                    k,
                    partial,
                });
            }
        }
    }
    Ok(out)
}

/// The single-process `latparam` study.
pub fn latparam(
    k: usize,
    s: usize,
    scenario: &Scenario,
    mc: &MonteCarlo,
) -> Result<Vec<ScenarioPoint>> {
    Ok(finalize_scenario_points(&latparam_partials(k, s, scenario, mc, Shard::full())?))
}

/// Anytime stopping rules for the single-process `repro scenario`
/// sweep. Deliberately **not** part of the shardable job identity:
/// the rules change what a trial measures, so they are CLI-only flags
/// on `repro scenario` and are rejected by `repro shard`/`repro run`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AnytimeRules {
    /// Cancel-on-target: stop the gather at the first arrival whose
    /// exact err₁ satisfies err₁/k ≤ target.
    pub target_err1: Option<f64>,
    /// Mid-round deadline revision `(at, to)`: at wall-clock `at` the
    /// master revises its cutoff to `to` (effective cutoff
    /// `max(at, to)`, clamped to the arm's own gather — revision only
    /// shortens).
    pub revise: Option<(f64, f64)>,
}

impl AnytimeRules {
    pub fn is_empty(&self) -> bool {
        self.target_err1.is_none() && self.revise.is_none()
    }
}

/// The `tta` sweep under anytime stopping rules (study id
/// `tta-anytime`): every trial streams its arrivals through the
/// incremental decoder and applies the rules mid-gather, so `gather`
/// is the wall-clock the master *actually* stopped at (the stopping
/// arrival's completion time, or the revised deadline) and `err1` is
/// the exact error of the prefix in hand. With empty rules the values
/// reproduce the `tta` study bit for bit.
pub fn tta_anytime(
    k: usize,
    s: usize,
    scenario: &Scenario,
    mc: &MonteCarlo,
    rules: AnytimeRules,
) -> Result<Vec<ScenarioPoint>> {
    let latency = tta_latency_model(scenario)?;
    let mut out = Vec::new();
    for policy_arm in TTA_POLICIES {
        for &scheme in &FIG_SCHEMES {
            for delta in tta_deltas() {
                let r = (((1.0 - delta) * k as f64).round() as usize).clamp(1, k);
                let rho = k as f64 / (r as f64 * s as f64);
                let code = scheme.build(k, k, s);
                let policy = match policy_arm {
                    "deadline" => DeadlinePolicy::Fixed(latency.quantile(1.0 - delta)),
                    _ => DeadlinePolicy::FastestR(r),
                };
                let model = LatencyStragglers { model: latency, policy };
                let partial =
                    mc.mean_curve_partial_ws(2, Shard::full(), DecodeWorkspace::new, |ws, rng| {
                        let (gather, err1) = ws.onestep_incremental_anytime_redraw_trial_with(
                            code.as_ref(),
                            &model as &dyn StragglerModel,
                            rho,
                            rules.target_err1,
                            rules.revise,
                            rng,
                        );
                        vec![gather, err1]
                    });
                out.push(ScenarioPartialPoint {
                    study: "tta-anytime",
                    scheme: scheme.name().to_string(),
                    policy: policy_arm,
                    s,
                    delta,
                    k,
                    partial,
                });
            }
        }
    }
    Ok(finalize_scenario_points(&out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::shard::ExactSum;

    fn pareto() -> Scenario {
        Scenario::parse("pareto:0.05,1.5").unwrap()
    }

    #[test]
    fn tta_rejects_scenarios_without_a_time_axis() {
        let mc = MonteCarlo::new(4, 1);
        for bad in ["uniform", "uniform:0.2", "adversarial:greedy", "pareto:1,1.5,deadline:0.5"] {
            let sc = Scenario::parse(bad).unwrap();
            assert!(tta_partials(12, 3, &sc, &mc, Shard::full()).is_err(), "{bad}");
        }
    }

    #[test]
    fn tta_shape_and_monotone_tradeoff() {
        let mc = MonteCarlo::new(60, 5).with_threads(2);
        let pts = tta(16, 4, &pareto(), &mc).unwrap();
        // 2 arms x 3 schemes x 18 deltas.
        assert_eq!(pts.len(), 2 * 3 * 18);
        assert!(pts.iter().all(|p| p.err1.is_finite() && p.err1 >= 0.0));
        assert!(pts.iter().all(|p| p.gather.is_finite() && p.gather > 0.0));
        // The time-to-accuracy tradeoff: within one scheme and arm,
        // waiting longer (smaller δ) costs gather time. Check the
        // fastest-r arm end to end: gather at δ=0.05 (r large) is
        // >= gather at δ=0.90 (r small).
        for scheme in ["FRC", "BGC", "s-regular"] {
            let arm: Vec<&ScenarioPoint> = pts
                .iter()
                .filter(|p| p.policy == "fastest-r" && p.scheme == scheme)
                .collect();
            assert_eq!(arm.len(), 18);
            let first = arm.iter().find(|p| (p.delta - 0.05).abs() < 1e-9).unwrap();
            let last = arm.iter().find(|p| (p.delta - 0.90).abs() < 1e-9).unwrap();
            assert!(
                first.gather >= last.gather,
                "{scheme}: gather({}) < gather({})",
                first.delta,
                last.delta
            );
        }
        // Deadline-arm gather is the deterministic model quantile.
        let lat = pareto().latency_model().copied().unwrap();
        for p in pts.iter().filter(|p| p.policy == "deadline") {
            let expected = lat.quantile(1.0 - p.delta);
            assert!(
                (p.gather - expected).abs() < 1e-12,
                "deadline gather {} vs quantile {expected}",
                p.gather
            );
        }
    }

    #[test]
    fn tta_partials_are_shard_invariant() {
        let mc = MonteCarlo::new(45, 9).with_threads(2);
        let whole = tta(12, 3, &pareto(), &mc).unwrap();
        for num_shards in [2usize, 3] {
            let mut merged = tta_partials(12, 3, &pareto(), &mc, Shard::new(0, num_shards).unwrap())
                .unwrap();
            for sid in 1..num_shards {
                let part =
                    tta_partials(12, 3, &pareto(), &mc, Shard::new(sid, num_shards).unwrap())
                        .unwrap();
                for (a, b) in merged.iter_mut().zip(&part) {
                    assert!(a.same_point(b));
                    a.partial.merge(&b.partial).unwrap();
                }
            }
            let merged = finalize_scenario_points(&merged);
            assert_eq!(merged.len(), whole.len());
            for (a, b) in merged.iter().zip(&whole) {
                assert_eq!(a.gather.to_bits(), b.gather.to_bits(), "{}/{}", a.scheme, a.delta);
                assert_eq!(a.err1.to_bits(), b.err1.to_bits(), "{}/{}", a.scheme, a.delta);
            }
        }
    }

    #[test]
    fn tta3_adds_an_optimal_arm_that_dominates_fastest_r() {
        let mc = MonteCarlo::new(40, 5).with_threads(2);
        let pts = tta3(12, 3, &pareto(), &mc).unwrap();
        // 3 arms x 3 schemes x 18 deltas.
        assert_eq!(pts.len(), 3 * 3 * 18);
        // The first two arms are bit-identical to the tta study (the
        // optimal arm only appends).
        let base = tta(12, 3, &pareto(), &mc).unwrap();
        for (a, b) in base.iter().zip(&pts) {
            assert_eq!(a.scheme, b.scheme);
            assert_eq!(a.policy, b.policy);
            assert_eq!(a.gather.to_bits(), b.gather.to_bits());
            assert_eq!(a.err1.to_bits(), b.err1.to_bits());
        }
        // Per-trial, err(A) <= err1(A) (the optimal decoder minimizes
        // over all weight vectors); both arms decode the same fastest-r
        // survivor draws (same RNG stream), so the means inherit the
        // dominance.
        for p in pts.iter().filter(|p| p.policy == "optimal") {
            let onestep = pts
                .iter()
                .find(|q| {
                    q.policy == "fastest-r"
                        && q.scheme == p.scheme
                        && q.delta.to_bits() == p.delta.to_bits()
                })
                .unwrap();
            assert!(
                p.err1 <= onestep.err1 + 1e-9,
                "{}/{}: optimal {} > one-step {}",
                p.scheme,
                p.delta,
                p.err1,
                onestep.err1
            );
            assert_eq!(p.gather.to_bits(), onestep.gather.to_bits());
        }
    }

    #[test]
    fn tta3_partials_are_shard_invariant() {
        let mc = MonteCarlo::new(30, 9).with_threads(2);
        let whole = tta3(10, 3, &pareto(), &mc).unwrap();
        let num_shards = 3usize;
        let mut merged =
            tta3_partials(10, 3, &pareto(), &mc, Shard::new(0, num_shards).unwrap()).unwrap();
        for sid in 1..num_shards {
            let part =
                tta3_partials(10, 3, &pareto(), &mc, Shard::new(sid, num_shards).unwrap()).unwrap();
            for (a, b) in merged.iter_mut().zip(&part) {
                assert!(a.same_point(b));
                a.partial.merge(&b.partial).unwrap();
            }
        }
        let merged = finalize_scenario_points(&merged);
        assert_eq!(merged.len(), whole.len());
        for (a, b) in merged.iter().zip(&whole) {
            assert_eq!(a.gather.to_bits(), b.gather.to_bits(), "{}/{}/{}", a.policy, a.scheme, a.delta);
            assert_eq!(a.err1.to_bits(), b.err1.to_bits(), "{}/{}/{}", a.policy, a.scheme, a.delta);
        }
    }

    #[test]
    fn latparam_sweeps_both_arms_at_the_fixed_deadline() {
        let mc = MonteCarlo::new(30, 13).with_threads(2);
        let pts = latparam(12, 3, &pareto(), &mc).unwrap();
        // 2 arms x 3 schemes x 18 parameter points.
        assert_eq!(pts.len(), 2 * 3 * 18);
        let base = pareto().latency_model().copied().unwrap();
        let deadline = latparam_deadline(&base);
        for p in &pts {
            assert_eq!(p.study, "latparam");
            assert!(LATPARAM_ARMS.contains(&p.policy), "{}", p.policy);
            // Fixed-deadline gather is the deadline itself (up to the
            // mean's final rounding).
            assert!(
                (p.gather - deadline).abs() < 1e-12,
                "{}/{}: gather {} vs deadline {deadline}",
                p.policy,
                p.delta,
                p.gather
            );
            assert!(p.err1.is_finite() && p.err1 >= 0.0);
        }
        // Heavier tails / slower service hurt: the first parameter
        // point of each arm (α=1.1, rate=10) admits fewer survivors by
        // the deadline than the last (α=2.8, rate=180), so its
        // expected err₁ is at least as large. Compare via the expected
        // survivor counts, which are deterministic.
        for arm in LATPARAM_ARMS {
            let models = latparam_models(arm, &base);
            let r_first = latparam_expected_r(&models[0].1, deadline, 12);
            let r_last = latparam_expected_r(&models[17].1, deadline, 12);
            assert!(
                r_first < r_last,
                "{arm}: expected survivors {r_first} !< {r_last}"
            );
        }
    }

    #[test]
    fn latparam_partials_are_shard_invariant() {
        let mc = MonteCarlo::new(24, 11).with_threads(2);
        let whole = latparam(10, 3, &pareto(), &mc).unwrap();
        let num_shards = 3usize;
        let mut merged =
            latparam_partials(10, 3, &pareto(), &mc, Shard::new(0, num_shards).unwrap()).unwrap();
        for sid in 1..num_shards {
            let part =
                latparam_partials(10, 3, &pareto(), &mc, Shard::new(sid, num_shards).unwrap())
                    .unwrap();
            for (a, b) in merged.iter_mut().zip(&part) {
                assert!(a.same_point(b));
                a.partial.merge(&b.partial).unwrap();
            }
        }
        let merged = finalize_scenario_points(&merged);
        assert_eq!(merged.len(), whole.len());
        for (a, b) in merged.iter().zip(&whole) {
            assert_eq!(a.gather.to_bits(), b.gather.to_bits(), "{}/{}/{}", a.policy, a.scheme, a.delta);
            assert_eq!(a.err1.to_bits(), b.err1.to_bits(), "{}/{}/{}", a.policy, a.scheme, a.delta);
        }
    }

    #[test]
    fn anytime_with_empty_rules_reproduces_tta_bitwise() {
        let mc = MonteCarlo::new(25, 7).with_threads(2);
        let base = tta(10, 3, &pareto(), &mc).unwrap();
        let anytime = tta_anytime(10, 3, &pareto(), &mc, AnytimeRules::default()).unwrap();
        assert_eq!(base.len(), anytime.len());
        for (a, b) in base.iter().zip(&anytime) {
            assert_eq!(b.study, "tta-anytime");
            assert_eq!(a.scheme, b.scheme);
            assert_eq!(a.policy, b.policy);
            assert_eq!(a.gather.to_bits(), b.gather.to_bits(), "{}/{}", a.scheme, a.delta);
            assert_eq!(a.err1.to_bits(), b.err1.to_bits(), "{}/{}", a.scheme, a.delta);
        }
    }

    #[test]
    fn anytime_rules_only_shorten_the_gather() {
        let mc = MonteCarlo::new(25, 7).with_threads(2);
        let base = tta(10, 3, &pareto(), &mc).unwrap();
        let target = tta_anytime(
            10,
            3,
            &pareto(),
            &mc,
            AnytimeRules { target_err1: Some(0.5), revise: None },
        )
        .unwrap();
        for (a, b) in base.iter().zip(&target) {
            assert!(b.gather <= a.gather + 1e-12, "{}/{}", a.scheme, a.delta);
        }
        let revised = tta_anytime(
            10,
            3,
            &pareto(),
            &mc,
            AnytimeRules { target_err1: None, revise: Some((0.05, 0.2)) },
        )
        .unwrap();
        for (a, b) in base.iter().zip(&revised) {
            assert!(b.gather <= a.gather + 1e-12, "{}/{}", a.scheme, a.delta);
        }
    }

    #[test]
    fn finalize_divides_err1_by_k_only() {
        let mut g = ExactSum::new();
        g.add(3.0);
        let mut e = ExactSum::new();
        e.add(20.0);
        let p = ScenarioPartialPoint {
            study: "tta",
            scheme: "BGC".into(),
            policy: "fastest-r",
            s: 4,
            delta: 0.25,
            k: 10,
            partial: Partial::Curve { count: 2, sums: vec![g, e] },
        };
        let f = p.finalize();
        assert_eq!(f.gather, 1.5); // 3.0 / 2 trials
        assert_eq!(f.err1, 1.0); // 20.0 / 2 trials / k=10
        assert_eq!(
            f.to_csv(),
            "tta,BGC,fastest-r,4,0.250,1.500000e0,1.000000e0"
        );
    }
}
