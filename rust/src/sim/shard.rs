//! Sharded Monte-Carlo: split a trial-averaged run across processes /
//! machines and merge the pieces back **bit-for-bit**.
//!
//! Every figure and table in the paper is a "mean over N trials"
//! estimate. [`super::montecarlo::MonteCarlo`] already forks one RNG
//! stream per *trial index* (not per thread), so trial `i` produces the
//! same value no matter which thread — or, with this module, which
//! **process** — runs it. Sharding therefore only has to solve the
//! aggregation problem: floating-point addition is not associative, so
//! naive per-shard sums would drift by an ulp depending on where the
//! shard boundaries fall.
//!
//! The fix is [`ExactSum`], an exact accumulator (Shewchuk's expansion
//! algorithm, the same one behind Python's `math.fsum`): it represents
//! the *exact real-number* running sum as a list of non-overlapping
//! f64 partials, merges are exact, and [`ExactSum::round`] produces the
//! correctly-rounded f64 of the true sum. Correct rounding is a
//! function of the exact value alone, so **any partition of the trials
//! merges to the same bits** — the single-process entry points are
//! literally the `num_shards = 1` case of the sharded path (pinned by
//! `tests/shard_parity.rs` and the CI fan-out job).
//!
//! # The pieces
//!
//! * [`Shard`] — which contiguous slice of the trial range this process
//!   owns ([`Shard::range`] partitions `0..trials` for any shard count).
//! * [`Partial`] — an exact partial aggregate of one figure/table/
//!   ablation point: count + [`ExactSum`] for means, count + Σx + Σx²
//!   for moments (the shardable `mean_std`), success counts for
//!   probabilities, per-element sums for curves, and a replicated
//!   `Exact` value for deterministic (non-Monte-Carlo) rows.
//! * [`JobSpec`] — a figure/table/ablation/scenario run identified by
//!   (kind, id, trials, seed, k, s, tmax, scenario); [`JobSpec::run`]
//!   executes any shard of it. The id registries ([`FIGURE_IDS`],
//!   [`TABLE_IDS`], [`ABLATION_IDS`], [`SCENARIO_IDS`]) are shared with
//!   the CLI, so every producible job is also mergeable. The straggler
//!   scenario rides in the job (artifact format v3; v2/v1 parse as the
//!   uniform default), so scenario sweeps shard/merge/verify/
//!   tree-reduce exactly like everything else.
//! * [`ShardArtifact`] — the on-disk JSON form of a set of shards'
//!   partials (`repro shard --out FILE` writes a single-shard artifact;
//!   `repro merge --out FILE` folds any disjoint subset into a
//!   *compound* artifact covering several shard ids, which is what
//!   makes tree-reduction over thousands of shards possible).
//!   [`ShardArtifact::merge`] validates the partition (all shards
//!   covered, same job, exactly once) and folds the partials back into
//!   the unsharded result; [`ShardArtifact::merge_partial`] does the
//!   same for an incomplete subset and re-emits an artifact;
//!   [`ShardArtifact::verify_set`] audits a set (same job, disjoint
//!   complete partition, per-artifact trial accounting) without
//!   merging.
//!
//! All f64 payloads in the artifact are serialized as **hex bit
//! patterns** (e.g. `"3fd0000000000000"` for 0.25), so a JSON round
//! trip through [`crate::util::Json`] is exact by construction — no
//! shortest-float printing subtleties involved. Every artifact also
//! carries an FNV-1a **checksum** of its canonical body; parsing
//! recomputes and compares it, so a corrupted or hand-edited artifact
//! is rejected before it can poison a merge.
//!
//! # Example: in-process shard/merge parity
//!
//! ```
//! use gradcode::sim::shard::{Partial, Shard};
//! use gradcode::sim::MonteCarlo;
//!
//! let mc = MonteCarlo::new(500, 7);
//! let whole = mc.mean(|rng| rng.f64());
//!
//! // The same run, split into 3 shards and merged.
//! let mut merged: Option<Partial> = None;
//! for sid in 0..3 {
//!     let shard = Shard::new(sid, 3).unwrap();
//!     let part = mc.mean_partial(shard, |rng| rng.f64());
//!     match merged.as_mut() {
//!         None => merged = Some(part),
//!         Some(m) => m.merge(&part).unwrap(),
//!     }
//! }
//! assert_eq!(merged.unwrap().value().to_bits(), whole.to_bits());
//! ```

use std::ops::Range;

use anyhow::{bail, Context, Result};

use super::ablations::{self, AblationPartialPoint};
use super::figures::{self, FigPartialPoint, FigureConfig};
use super::montecarlo::MonteCarlo;
use super::scenario as scenario_mod;
use super::scenario::ScenarioPartialPoint;
use super::tables::{self, RowTemplate, TablePartialPoint};
use crate::codes::Scheme;
use crate::stragglers::Scenario;
use crate::util::Json;

// ------------------------------------------------------------ ExactSum

/// Exact f64 accumulator: Shewchuk's non-overlapping expansion, as in
/// Python's `math.fsum`. The list of partials represents the exact
/// real-number sum of everything added so far, so accumulation and
/// [`ExactSum::merge`] are associative and commutative *exactly*, and
/// [`ExactSum::round`] — the correctly-rounded f64 of the true sum —
/// does not depend on how the inputs were grouped. This is the property
/// the shard/merge bit-parity guarantee rests on.
///
/// Inputs must be finite (the Monte-Carlo trial values always are);
/// non-finite inputs poison the expansion like they would a plain sum.
///
/// ```
/// use gradcode::sim::shard::ExactSum;
/// let mut s = ExactSum::new();
/// for x in [1e100, 1.0, -1e100] {
///     s.add(x);
/// }
/// // A plain left-to-right f64 sum would return 0.0 here.
/// assert_eq!(s.round(), 1.0);
/// ```
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ExactSum {
    /// Non-overlapping partials in increasing magnitude order.
    partials: Vec<f64>,
}

impl ExactSum {
    pub fn new() -> Self {
        ExactSum { partials: Vec::new() }
    }

    /// Add one value, maintaining the non-overlapping invariant via a
    /// chain of exact two-sums.
    pub fn add(&mut self, mut x: f64) {
        let mut i = 0;
        for j in 0..self.partials.len() {
            let mut y = self.partials[j];
            if x.abs() < y.abs() {
                std::mem::swap(&mut x, &mut y);
            }
            let hi = x + y;
            let lo = y - (hi - x);
            if lo != 0.0 {
                self.partials[i] = lo;
                i += 1;
            }
            x = hi;
        }
        self.partials.truncate(i);
        self.partials.push(x);
    }

    /// Fold another accumulator in. Exact: the merged expansion
    /// represents the sum of both exact values, so grouping is
    /// invisible to [`ExactSum::round`].
    pub fn merge(&mut self, other: &ExactSum) {
        for &p in &other.partials {
            self.add(p);
        }
    }

    /// The correctly-rounded (round-to-nearest-even) f64 of the exact
    /// sum. Ported from CPython's `math.fsum` final rounding, including
    /// the half-ulp tie correction across partials.
    pub fn round(&self) -> f64 {
        let p = &self.partials;
        let mut n = p.len();
        let mut hi = 0.0;
        if n > 0 {
            n -= 1;
            hi = p[n];
            let mut lo = 0.0;
            while n > 0 {
                let x = hi;
                n -= 1;
                let y = p[n];
                hi = x + y;
                let yr = hi - x;
                lo = y - yr;
                if lo != 0.0 {
                    break;
                }
            }
            // Make round-half-even correct when the discarded tail
            // is exactly half an ulp and points the same way as the
            // next-lower partial.
            if n > 0 && ((lo < 0.0 && p[n - 1] < 0.0) || (lo > 0.0 && p[n - 1] > 0.0)) {
                let y = lo * 2.0;
                let x = hi + y;
                if y == x - hi {
                    hi = x;
                }
            }
        }
        hi
    }

    /// The raw expansion (read-only; for serialization and tests).
    pub fn partials(&self) -> &[f64] {
        &self.partials
    }

    /// Rebuild from serialized partials. Values are re-accumulated, so
    /// the invariant holds even if the input list was not a valid
    /// expansion; the represented exact value is preserved either way.
    pub fn from_partials(values: &[f64]) -> Self {
        let mut s = ExactSum::new();
        for &v in values {
            s.add(v);
        }
        s
    }
}

// --------------------------------------------------------------- Shard

/// One slice of a sharded Monte-Carlo run: `shard_id` of `num_shards`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    pub shard_id: usize,
    pub num_shards: usize,
}

impl Shard {
    /// The whole run as a single shard — what every single-process
    /// entry point uses.
    pub fn full() -> Shard {
        Shard { shard_id: 0, num_shards: 1 }
    }

    pub fn new(shard_id: usize, num_shards: usize) -> Result<Shard> {
        if num_shards == 0 {
            bail!("num_shards must be >= 1");
        }
        if shard_id >= num_shards {
            bail!("shard_id {shard_id} out of range for num_shards {num_shards}");
        }
        Ok(Shard { shard_id, num_shards })
    }

    /// This shard's contiguous trial range. For every `num_shards` the
    /// ranges `[i * trials / N, (i+1) * trials / N)` are disjoint,
    /// ordered, and cover `0..trials` exactly; sizes differ by at most
    /// one trial.
    ///
    /// ```
    /// use gradcode::sim::shard::Shard;
    /// let covered: usize = (0..7)
    ///     .map(|i| Shard::new(i, 7).unwrap().range(100).len())
    ///     .sum();
    /// assert_eq!(covered, 100);
    /// ```
    pub fn range(&self, trials: usize) -> Range<usize> {
        let lo = trials * self.shard_id / self.num_shards;
        let hi = trials * (self.shard_id + 1) / self.num_shards;
        lo..hi
    }
}

// ------------------------------------------------------------- Partial

/// An exact partial aggregate of one figure/table/ablation point over a
/// shard's trial range. Merging partials from a disjoint trial
/// partition and finalizing gives bit-identical results to the
/// unsharded run.
#[derive(Clone, Debug, PartialEq)]
pub enum Partial {
    /// Partial mean: trial count and exact sum of trial values.
    Mean { count: u64, sum: ExactSum },
    /// Partial first and second moments: trial count, exact Σx, and
    /// exact Σx² — the merge-safe accumulator behind the shardable
    /// `MonteCarlo::mean_std` (the per-trial square `x·x` is computed
    /// before accumulation, so it is identical under any partition).
    Moments { count: u64, sum: ExactSum, sumsq: ExactSum },
    /// Partial probability: trial count and number of successes.
    Prob { count: u64, hits: u64 },
    /// Partial element-wise curve mean (Fig. 5's error trajectories).
    Curve { count: u64, sums: Vec<ExactSum> },
    /// A deterministic (non-Monte-Carlo) value, recomputed identically
    /// by every shard; merge asserts bit-equality as an integrity check.
    Exact { value: f64 },
}

impl Partial {
    pub fn kind(&self) -> &'static str {
        match self {
            Partial::Mean { .. } => "mean",
            Partial::Moments { .. } => "moments",
            Partial::Prob { .. } => "prob",
            Partial::Curve { .. } => "curve",
            Partial::Exact { .. } => "exact",
        }
    }

    /// Trials aggregated so far (None for deterministic values).
    pub fn mc_trials(&self) -> Option<u64> {
        match self {
            Partial::Mean { count, .. }
            | Partial::Moments { count, .. }
            | Partial::Prob { count, .. }
            | Partial::Curve { count, .. } => Some(*count),
            Partial::Exact { .. } => None,
        }
    }

    /// Fold another shard's partial for the same point into this one.
    pub fn merge(&mut self, other: &Partial) -> Result<()> {
        match (self, other) {
            (Partial::Mean { count, sum }, Partial::Mean { count: c2, sum: s2 }) => {
                *count += c2;
                sum.merge(s2);
                Ok(())
            }
            (
                Partial::Moments { count, sum, sumsq },
                Partial::Moments { count: c2, sum: s2, sumsq: q2 },
            ) => {
                *count += c2;
                sum.merge(s2);
                sumsq.merge(q2);
                Ok(())
            }
            (Partial::Prob { count, hits }, Partial::Prob { count: c2, hits: h2 }) => {
                *count += c2;
                *hits += h2;
                Ok(())
            }
            (Partial::Curve { count, sums }, Partial::Curve { count: c2, sums: s2 }) => {
                if sums.len() != s2.len() {
                    bail!("curve length mismatch: {} vs {}", sums.len(), s2.len());
                }
                *count += c2;
                for (a, b) in sums.iter_mut().zip(s2) {
                    a.merge(b);
                }
                Ok(())
            }
            (Partial::Exact { value }, Partial::Exact { value: v2 }) => {
                if value.to_bits() != v2.to_bits() {
                    bail!(
                        "deterministic value disagrees across shards: {value:?} vs {v2:?} \
                         (artifacts from different code versions or corrupted?)"
                    );
                }
                Ok(())
            }
            (a, b) => bail!("cannot merge partial kind {:?} with {:?}", a.kind(), b.kind()),
        }
    }

    /// Finalized scalar statistic: mean, probability, or the exact
    /// value. `Curve` partials have no scalar value and return NaN —
    /// use [`Partial::curve_values`] for those.
    pub fn value(&self) -> f64 {
        match self {
            Partial::Mean { count, sum } | Partial::Moments { count, sum, .. } => {
                sum.round() / (*count).max(1) as f64
            }
            Partial::Prob { count, hits } => *hits as f64 / (*count).max(1) as f64,
            Partial::Exact { value } => *value,
            Partial::Curve { .. } => f64::NAN,
        }
    }

    /// Finalized (mean, sample std) of a [`Partial::Moments`] aggregate:
    /// `var = (Σx² − (Σx)²/n) / (n−1)`, clamped at 0 against rounding.
    /// Every input is a correctly-rounded function of the exact sums
    /// plus the count, so the result is invariant under the shard
    /// partition — the property `repro`-level `mean_std` sharding rests
    /// on. Non-moment partials return `(value, NaN)`.
    ///
    /// Accuracy caveat: the sums are exact, but the one-pass identity
    /// itself cancels catastrophically when `mean² ≫ var` — the
    /// relative error in `var` grows like `(mean²/var)·2⁻⁵³`. For such
    /// data, center the trial values before accumulating (the shift is
    /// deterministic per trial, so sharding is unaffected). The
    /// pre-moments two-pass `mean_std` did not have this failure mode
    /// but could not shard; no figure/table output uses `mean_std`.
    pub fn mean_std(&self) -> (f64, f64) {
        match self {
            Partial::Moments { count, sum, sumsq } => {
                let n = (*count).max(1) as f64;
                let sum_r = sum.round();
                let mean = sum_r / n;
                let std = if *count > 1 {
                    let var = (sumsq.round() - sum_r * sum_r / n) / (n - 1.0);
                    var.max(0.0).sqrt()
                } else {
                    0.0
                };
                (mean, std)
            }
            p => (p.value(), f64::NAN),
        }
    }

    /// Finalized element-wise curve means (empty for scalar kinds).
    pub fn curve_values(&self) -> Vec<f64> {
        match self {
            Partial::Curve { count, sums } => {
                let n = (*count).max(1) as f64;
                sums.iter().map(|s| s.round() / n).collect()
            }
            _ => Vec::new(),
        }
    }
}

// ---------------------------------------------------------- post-maps

/// Deterministic transform applied to a merged scalar statistic at
/// finalize time (it must run *after* merging, not per shard, so it is
/// recorded in the artifact instead of being baked into the partial).
#[derive(Clone, Copy, Debug)]
pub enum PostMap {
    Identity,
    /// `x ↦ sqrt(x · scale)` — the thm21/thm24 implied-constant fit
    /// `C = sqrt(mean_err1 · (1-δ) s / k)`.
    SqrtScale { scale: f64 },
}

impl PostMap {
    pub fn apply(&self, x: f64) -> f64 {
        match self {
            PostMap::Identity => x,
            PostMap::SqrtScale { scale } => (x * scale).sqrt(),
        }
    }

    /// Bit-level equality (scale compared by bits, so NaN-safe).
    pub fn bits_eq(&self, other: &PostMap) -> bool {
        match (self, other) {
            (PostMap::Identity, PostMap::Identity) => true,
            (PostMap::SqrtScale { scale: a }, PostMap::SqrtScale { scale: b }) => {
                a.to_bits() == b.to_bits()
            }
            _ => false,
        }
    }
}

// ------------------------------------------------------------- JobSpec

/// What kind of run a shard artifact belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobKind {
    Figure,
    Table,
    Ablation,
    Scenario,
}

impl JobKind {
    pub fn name(&self) -> &'static str {
        match self {
            JobKind::Figure => "figure",
            JobKind::Table => "table",
            JobKind::Ablation => "ablation",
            JobKind::Scenario => "scenario",
        }
    }

    pub fn parse(s: &str) -> Result<JobKind> {
        match s {
            "figure" => Ok(JobKind::Figure),
            "table" => Ok(JobKind::Table),
            "ablation" => Ok(JobKind::Ablation),
            "scenario" => Ok(JobKind::Scenario),
            other => bail!("unknown job kind {other:?} (figure|table|ablation|scenario)"),
        }
    }
}

/// A fully-specified figure/table/ablation/scenario run: everything
/// that determines the output bits. Two artifacts merge only if their
/// jobs are identical.
///
/// `id` is `"2".."5"` for figures, `"thm5".."thm24"` for tables, an
/// [`ABLATION_IDS`] study for ablations, and a [`SCENARIO_IDS`] study
/// for scenario runs; `s` is table/ablation/scenario-only (0 for
/// figures, which sweep the paper's s values) and `tmax` is
/// Figure-5-only (0 otherwise). `scenario` is the straggler scenario
/// (`--stragglers`; the uniform default reproduces the pre-scenario
/// output byte-for-byte) — part of the run identity, compared bitwise
/// on its f64 parameters.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobSpec {
    pub kind: JobKind,
    pub id: String,
    pub trials: usize,
    pub seed: u64,
    pub k: usize,
    pub s: usize,
    pub tmax: usize,
    pub scenario: Scenario,
}

impl JobSpec {
    /// Execute one shard of this job. `threads` overrides the intra-
    /// process worker count (results are thread-count invariant; this
    /// only changes wall-clock). The full run is `shard = Shard::full()`
    /// — exactly what `repro figures` / `repro tables` execute.
    pub fn run(&self, shard: Shard, threads: Option<usize>) -> Result<ShardPoints> {
        self.run_hinted(shard, threads, None)
    }

    /// [`JobSpec::run`] with every execution hint: `threads` and
    /// `panel_width` (the `--panel-width` flag). Both are wall-clock
    /// knobs only — neither is part of the job identity or the artifact,
    /// and the output bits are invariant in them (panel lanes replay the
    /// exact per-trial RNG forks; pinned by `tests/decode_parity.rs`).
    pub fn run_hinted(
        &self,
        shard: Shard,
        threads: Option<usize>,
        panel_width: Option<usize>,
    ) -> Result<ShardPoints> {
        let mut mc = MonteCarlo::new(self.trials, self.seed);
        if let Some(t) = threads {
            mc = mc.with_threads(t);
        }
        if let Some(w) = panel_width {
            mc = mc.with_panel_width(w);
        }
        let scenario = &self.scenario;
        match self.kind {
            JobKind::Figure => {
                let mut cfg = FigureConfig::paper(self.trials, self.seed);
                cfg.k = self.k;
                cfg.mc = mc;
                let pts = match self.id.as_str() {
                    "2" => figures::figure2_partials(&cfg, scenario, shard),
                    "3" => figures::figure3_partials(&cfg, scenario, shard),
                    "4" => figures::figure4_partials(&cfg, scenario, shard),
                    "5" => figures::figure5_partials(&cfg, self.tmax, scenario, shard),
                    other => bail!("unknown figure {other:?} (paper has figures 2-5)"),
                };
                Ok(ShardPoints::Fig(pts))
            }
            JobKind::Table => {
                let (k, s) = (self.k, self.s);
                let deltas = [0.1, 0.25, 0.5, 0.75];
                // thm3 never samples stragglers, and thm10/thm11 carry
                // their own adversarial-vs-random protocol; a non-default
                // scenario would be a silent no-op there.
                if !scenario.is_default() && matches!(self.id.as_str(), "thm3" | "thm10" | "thm11")
                {
                    bail!("--stragglers is not supported for table {}", self.id);
                }
                let pts = match self.id.as_str() {
                    "thm3" => tables::thm3_partials(&[k / 2, k, 2 * k], s, &mc, shard),
                    "thm5" => tables::thm5_partials(k, s, &deltas, scenario, &mc, shard),
                    "thm6" => tables::thm6_partials(k, s, &deltas, scenario, &mc, shard),
                    "thm8" => tables::thm8_partials(
                        k,
                        &[0, 1, 2],
                        &[0.1, 0.25, 0.5],
                        scenario,
                        &mc,
                        shard,
                    ),
                    "thm10" => {
                        tables::thm10_partials(k, s, &[k / 4, k / 2, 3 * k / 4], &mc, shard)
                    }
                    "thm11" => tables::thm11_partials(self.seed),
                    "thm21" => tables::thm21_partials(
                        Scheme::Bgc,
                        &[50, 100, 200, 400],
                        |k| ((k as f64).ln().ceil() as usize).max(2),
                        0.25,
                        scenario,
                        &mc,
                        shard,
                    ),
                    "thm24" => tables::thm21_partials(
                        Scheme::Rbgc,
                        &[50, 100, 200, 400],
                        |k| ((k as f64).ln().ceil() as usize).max(2),
                        0.25,
                        scenario,
                        &mc,
                        shard,
                    ),
                    other => bail!("unknown table {other:?}"),
                };
                Ok(ShardPoints::Table(pts))
            }
            JobKind::Ablation => {
                let pts =
                    ablations::study_partials(&self.id, self.k, self.s, scenario, &mc, shard)?;
                Ok(ShardPoints::Ablation(pts))
            }
            JobKind::Scenario => {
                let pts = match self.id.as_str() {
                    "tta" => scenario_mod::tta_partials(self.k, self.s, scenario, &mc, shard)?,
                    "tta3" => scenario_mod::tta3_partials(self.k, self.s, scenario, &mc, shard)?,
                    "latparam" => {
                        scenario_mod::latparam_partials(self.k, self.s, scenario, &mc, shard)?
                    }
                    other => bail!(
                        "unknown scenario study {other:?} (one of {})",
                        SCENARIO_IDS.join("|")
                    ),
                };
                Ok(ShardPoints::Scenario(pts))
            }
        }
    }

    /// The canonical JSON form of this job — the same encoding the
    /// shard-artifact format embeds, reused verbatim as the serve-socket
    /// wire format (`serve::protocol`). Seeds travel as decimal strings
    /// (u64 exceeds f64's exact-integer range) and the scenario as its
    /// canonical parse-fixed-point string.
    pub fn to_json(&self) -> Json {
        job_to_json(self)
    }

    /// Parse [`JobSpec::to_json`]'s encoding back. An absent `scenario`
    /// field means the uniform default (v1/v2 artifacts predate it).
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        job_from_json(j)
    }
}

// --------------------------------------------------------- ShardPoints

/// The per-point partials of one shard (or of a merged run).
#[derive(Clone, Debug)]
pub enum ShardPoints {
    Fig(Vec<FigPartialPoint>),
    Table(Vec<TablePartialPoint>),
    Ablation(Vec<AblationPartialPoint>),
    Scenario(Vec<ScenarioPartialPoint>),
}

impl ShardPoints {
    pub fn len(&self) -> usize {
        match self {
            ShardPoints::Fig(v) => v.len(),
            ShardPoints::Table(v) => v.len(),
            ShardPoints::Ablation(v) => v.len(),
            ShardPoints::Scenario(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fold another shard's points in. Points must line up exactly
    /// (same order, same metadata — [`ShardPoints::check_aligned`], the
    /// single home of that validation); they do by construction, since
    /// every shard enumerates the same sweep.
    pub fn merge_from(&mut self, other: &ShardPoints) -> Result<()> {
        self.check_aligned(other)?;
        match (self, other) {
            (ShardPoints::Fig(a), ShardPoints::Fig(b)) => {
                for (i, (pa, pb)) in a.iter_mut().zip(b).enumerate() {
                    pa.partial.merge(&pb.partial).with_context(|| format!("figure point {i}"))?;
                }
                Ok(())
            }
            (ShardPoints::Table(a), ShardPoints::Table(b)) => {
                for (i, (pa, pb)) in a.iter_mut().zip(b).enumerate() {
                    pa.partial.merge(&pb.partial).with_context(|| format!("table point {i}"))?;
                }
                Ok(())
            }
            (ShardPoints::Ablation(a), ShardPoints::Ablation(b)) => {
                for (i, (pa, pb)) in a.iter_mut().zip(b).enumerate() {
                    pa.partial
                        .merge(&pb.partial)
                        .with_context(|| format!("ablation point {i}"))?;
                }
                Ok(())
            }
            (ShardPoints::Scenario(a), ShardPoints::Scenario(b)) => {
                for (i, (pa, pb)) in a.iter_mut().zip(b).enumerate() {
                    pa.partial
                        .merge(&pb.partial)
                        .with_context(|| format!("scenario point {i}"))?;
                }
                Ok(())
            }
            _ => unreachable!("check_aligned verified matching point kinds"),
        }
    }

    /// The alignment validation shared by [`ShardPoints::merge_from`]
    /// (which runs it before folding) and `verify` (which audits a set
    /// without folding): same kind, same point count, same per-point
    /// metadata.
    pub fn check_aligned(&self, other: &ShardPoints) -> Result<()> {
        let mismatch = |i: usize| -> Result<()> {
            bail!("point {i} metadata mismatch across artifacts");
        };
        match (self, other) {
            (ShardPoints::Fig(a), ShardPoints::Fig(b)) if a.len() == b.len() => {
                for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
                    if !pa.same_point(pb) {
                        return mismatch(i);
                    }
                }
                Ok(())
            }
            (ShardPoints::Table(a), ShardPoints::Table(b)) if a.len() == b.len() => {
                for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
                    if !pa.same_point(pb) {
                        return mismatch(i);
                    }
                }
                Ok(())
            }
            (ShardPoints::Ablation(a), ShardPoints::Ablation(b)) if a.len() == b.len() => {
                for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
                    if !pa.same_point(pb) {
                        return mismatch(i);
                    }
                }
                Ok(())
            }
            (ShardPoints::Scenario(a), ShardPoints::Scenario(b)) if a.len() == b.len() => {
                for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
                    if !pa.same_point(pb) {
                        return mismatch(i);
                    }
                }
                Ok(())
            }
            (a, b) => bail!(
                "point sets do not line up ({} point(s) vs {} of possibly different kind)",
                a.len(),
                b.len()
            ),
        }
    }

    /// Verify every Monte-Carlo point aggregated exactly `expected`
    /// trials (for a full merge that is `job.trials`; for a partial
    /// artifact it is the total size of its covered shard ranges).
    pub fn check_trials(&self, expected: u64) -> Result<()> {
        let check = |i: usize, got: Option<u64>| -> Result<()> {
            if let Some(count) = got {
                if count != expected {
                    bail!("point {i} aggregated {count} trials, expected {expected}");
                }
            }
            Ok(())
        };
        match self {
            ShardPoints::Fig(v) => {
                for (i, p) in v.iter().enumerate() {
                    check(i, p.partial.mc_trials())?;
                }
            }
            ShardPoints::Table(v) => {
                for (i, p) in v.iter().enumerate() {
                    check(i, p.partial.mc_trials())?;
                }
            }
            ShardPoints::Ablation(v) => {
                for (i, p) in v.iter().enumerate() {
                    check(i, p.partial.mc_trials())?;
                }
            }
            ShardPoints::Scenario(v) => {
                for (i, p) in v.iter().enumerate() {
                    check(i, p.partial.mc_trials())?;
                }
            }
        }
        Ok(())
    }

    /// Finalize to the exact CSV the unsharded CLI path prints
    /// (header + one line per output row, trailing newline).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        match self {
            ShardPoints::Fig(v) => {
                out.push_str(figures::FigPoint::csv_header());
                out.push('\n');
                for p in v {
                    for fp in p.finalize() {
                        out.push_str(&fp.to_csv());
                        out.push('\n');
                    }
                }
            }
            ShardPoints::Table(v) => {
                out.push_str(tables::TableRow::csv_header());
                out.push('\n');
                for p in v {
                    for row in p.finalize() {
                        out.push_str(&row.to_csv());
                        out.push('\n');
                    }
                }
            }
            ShardPoints::Ablation(v) => {
                out.push_str(ablations::AblationPoint::csv_header());
                out.push('\n');
                for p in v {
                    out.push_str(&p.finalize().to_csv());
                    out.push('\n');
                }
            }
            ShardPoints::Scenario(v) => {
                out.push_str(scenario_mod::ScenarioPoint::csv_header());
                out.push('\n');
                for p in v {
                    out.push_str(&p.finalize().to_csv());
                    out.push('\n');
                }
            }
        }
        out
    }
}

// ------------------------------------------------------- ShardArtifact

/// On-disk format tag; bump on incompatible schema changes. v3 added
/// the `scenario` job field (straggler scenario as run identity) and
/// the scenario point kind; v2 added compound `shard_ids`
/// (tree-reduction) and the body checksum. [`ShardArtifact::parse`]
/// still accepts [`SHARD_FORMAT_V2`] (scenario defaults to uniform —
/// exactly what every v2 artifact computed) and [`SHARD_FORMAT_V1`]
/// files.
pub const SHARD_FORMAT: &str = "gradcode-shard/v3";

/// The PR-4 era format: compound `shard_ids` + checksum, no scenario
/// field. Read-compatible, parsed as the uniform scenario.
pub const SHARD_FORMAT_V2: &str = "gradcode-shard/v2";

/// The PR-3 era single-shard format (`shard_id` field, no checksum).
/// Read-compatible; everything written today is [`SHARD_FORMAT`].
pub const SHARD_FORMAT_V1: &str = "gradcode-shard/v1";

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64-bit over the canonical (compact) body serialization —
/// cheap, dependency-free integrity hash for artifact files. This
/// guards against corruption and accidental edits, not adversaries.
/// Production checksums stream through [`Fnv1aSink`] instead; this
/// buffer-based twin remains as the reference the streaming pin test
/// compares against.
#[cfg(test)]
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// A `fmt::Write` sink folding FNV-1a over everything written to it —
/// the streaming half of the artifact checksum: the JSON writer streams
/// the canonical body straight through the hash, so checksumming never
/// materializes the multi-megabyte body `String` (it used to, once per
/// write *and* once per parse; tree-reduction collection points fold
/// thousands of such artifacts).
struct Fnv1aSink {
    h: u64,
}

impl Fnv1aSink {
    fn new() -> Self {
        Fnv1aSink { h: FNV_OFFSET }
    }
}

impl std::fmt::Write for Fnv1aSink {
    fn write_str(&mut self, s: &str) -> std::fmt::Result {
        for &b in s.as_bytes() {
            self.h ^= b as u64;
            self.h = self.h.wrapping_mul(FNV_PRIME);
        }
        Ok(())
    }
}

/// A serialized set of shard partials: the job identity, which shard
/// ids it covers (one for a freshly computed shard; several for a
/// compound artifact produced by `repro merge --out`), and the
/// per-point partial aggregates.
#[derive(Clone, Debug)]
pub struct ShardArtifact {
    pub job: JobSpec,
    /// Sorted, distinct shard ids folded into this artifact, each
    /// `< num_shards`.
    pub shard_ids: Vec<usize>,
    pub num_shards: usize,
    pub points: ShardPoints,
}

/// A validated, fully-merged run ready to emit CSV.
#[derive(Clone, Debug)]
pub struct MergedRun {
    pub job: JobSpec,
    pub points: ShardPoints,
}

impl MergedRun {
    pub fn to_csv(&self) -> String {
        self.points.to_csv()
    }
}

fn validate_shard_ids(ids: &[usize], num_shards: usize) -> Result<()> {
    if ids.is_empty() {
        bail!("artifact covers no shard ids");
    }
    if !ids.windows(2).all(|w| w[0] < w[1]) {
        bail!("shard_ids must be sorted and distinct, got {ids:?}");
    }
    let max = *ids.last().expect("non-empty");
    if max >= num_shards {
        bail!("shard id {max} out of range for num_shards {num_shards}");
    }
    Ok(())
}

impl ShardArtifact {
    /// Run one shard of `job` and package the result.
    pub fn compute(job: &JobSpec, shard: Shard, threads: Option<usize>) -> Result<ShardArtifact> {
        Self::compute_hinted(job, shard, threads, None)
    }

    /// [`ShardArtifact::compute`] with the full execution-hint set
    /// (thread count and panel width); hints never enter the artifact.
    pub fn compute_hinted(
        job: &JobSpec,
        shard: Shard,
        threads: Option<usize>,
        panel_width: Option<usize>,
    ) -> Result<ShardArtifact> {
        let points = job.run_hinted(shard, threads, panel_width)?;
        Ok(ShardArtifact {
            job: job.clone(),
            shard_ids: vec![shard.shard_id],
            num_shards: shard.num_shards,
            points,
        })
    }

    /// Total Monte-Carlo trials the covered shard ranges contain — what
    /// every MC point of this artifact must have aggregated.
    pub fn covered_trials(&self) -> u64 {
        self.shard_ids
            .iter()
            .map(|&i| {
                let shard = Shard { shard_id: i, num_shards: self.num_shards };
                shard.range(self.job.trials).len() as u64
            })
            .sum()
    }

    /// Set-level validation shared by the merge paths and
    /// [`ShardArtifact::verify_set`] — the single home of the rules, so
    /// `verify` can never accept a set `merge` rejects (or vice versa):
    /// same job and `num_shards` everywhere, pairwise-disjoint shard id
    /// sets. Returns (num_shards, sorted union of covered ids);
    /// completeness is the caller's policy via [`require_complete`].
    fn validate_set(shards: &[ShardArtifact]) -> Result<(usize, Vec<usize>)> {
        if shards.is_empty() {
            bail!("no shard artifacts given");
        }
        let num_shards = shards[0].num_shards;
        for s in &shards[1..] {
            if s.num_shards != num_shards {
                bail!("num_shards disagrees: {} vs {}", s.num_shards, num_shards);
            }
            if s.job != shards[0].job {
                bail!(
                    "artifacts come from different jobs: {:?} vs {:?}",
                    s.job,
                    shards[0].job
                );
            }
        }
        let mut covered: Vec<usize> =
            shards.iter().flat_map(|s| s.shard_ids.iter().copied()).collect();
        covered.sort_unstable();
        if let Some(w) = covered.windows(2).find(|w| w[0] == w[1]) {
            bail!("shard id {} appears in more than one artifact (overlapping set)", w[0]);
        }
        validate_shard_ids(&covered, num_shards)?;
        Ok((num_shards, covered))
    }

    /// Validate ([`ShardArtifact::validate_set`]) and fold: points are
    /// aligned and folded in ascending-first-id order. Returns the
    /// folded points plus the sorted union of covered ids.
    fn fold(mut shards: Vec<ShardArtifact>) -> Result<(JobSpec, usize, ShardPoints, Vec<usize>)> {
        let (num_shards, covered) = Self::validate_set(&shards)?;
        shards.sort_by_key(|s| s.shard_ids.first().copied().unwrap_or(usize::MAX));
        let mut iter = shards.into_iter();
        let first = iter.next().expect("non-empty");
        let job = first.job;
        let mut points = first.points;
        for s in iter {
            points
                .merge_from(&s.points)
                .with_context(|| format!("merging shards {:?}", s.shard_ids))?;
        }
        Ok((job, num_shards, points, covered))
    }

    /// Validate a set of shard artifacts and fold them into the
    /// unsharded result: same job everywhere, shard ids covering
    /// `0..num_shards` exactly once (compound artifacts count for every
    /// id they fold), metadata aligned pointwise, and every Monte-Carlo
    /// point accounting for exactly `job.trials` trials.
    pub fn merge(shards: Vec<ShardArtifact>) -> Result<MergedRun> {
        let (job, num_shards, points, covered) = Self::fold(shards)?;
        require_complete(&covered, num_shards)?;
        points.check_trials(job.trials as u64)?;
        Ok(MergedRun { job, points })
    }

    /// Fold any disjoint subset of a job's artifacts into a single
    /// *compound* artifact (the `repro merge --out` path). Folding is
    /// exact, so any reduction tree over the shards — pairwise, 8→2→1,
    /// whatever the orchestration favors — finalizes to the same bits
    /// as a flat [`ShardArtifact::merge`] of the leaves.
    pub fn merge_partial(shards: Vec<ShardArtifact>) -> Result<ShardArtifact> {
        let (job, num_shards, points, covered) = Self::fold(shards)?;
        let folded = ShardArtifact { job, shard_ids: covered, num_shards, points };
        points_check(&folded)?;
        Ok(folded)
    }

    /// Audit an artifact set **without merging**: the same set-level
    /// rules as the merge paths (one shared `validate_set`, so `verify`
    /// can never accept a set `merge` rejects) plus pointwise-aligned
    /// metadata, complete `0..num_shards` coverage, and per-artifact
    /// trial accounting (every Monte-Carlo point holds exactly the
    /// trials of its covered ranges). Checksum integrity is enforced
    /// earlier, by [`ShardArtifact::parse`].
    pub fn verify_set(shards: &[ShardArtifact]) -> Result<()> {
        let (num_shards, covered) = Self::validate_set(shards)?;
        for s in &shards[1..] {
            shards[0]
                .points
                .check_aligned(&s.points)
                .with_context(|| format!("artifact covering shards {:?}", s.shard_ids))?;
        }
        require_complete(&covered, num_shards)?;
        for s in shards {
            points_check(s).with_context(|| {
                format!("trial accounting of artifact covering shards {:?}", s.shard_ids)
            })?;
        }
        Ok(())
    }

    /// Serialize to the artifact JSON (pretty-printed for readable
    /// diffs; all f64 payloads as hex bit patterns; body checksummed).
    pub fn to_json_string(&self) -> String {
        self.to_json().write_pretty()
    }

    /// Parse an artifact file's contents (checksum-verified).
    pub fn parse(text: &str) -> Result<ShardArtifact> {
        Self::from_json(&Json::parse(text).context("invalid JSON")?)
    }

    /// Hex FNV-1a digest of the artifact body: the compact
    /// serialization of the object with the `checksum` field omitted,
    /// **streamed** through [`Fnv1aSink`] ([`Json::write_excluding_to`])
    /// — no deep clone of the points payload and no materialized body
    /// `String` either, which matters when tree-reduction collection
    /// points parse thousands of multi-MB artifacts. Stable across
    /// write→parse→write because the writer is canonical (sorted keys,
    /// shortest-round-trip numbers, hex f64 payloads); pinned equal to
    /// the materializing hash by a test below.
    fn checksum_of(body: &Json) -> Result<String> {
        body.as_obj().context("artifact body must be an object")?;
        let mut sink = Fnv1aSink::new();
        body.write_excluding_to("checksum", &mut sink)
            .expect("Fnv1aSink never fails");
        Ok(format!("{:016x}", sink.h))
    }

    pub fn to_json(&self) -> Json {
        let points = match &self.points {
            ShardPoints::Fig(v) => Json::Arr(v.iter().map(fig_point_to_json).collect()),
            ShardPoints::Table(v) => Json::Arr(v.iter().map(table_point_to_json).collect()),
            ShardPoints::Ablation(v) => {
                Json::Arr(v.iter().map(ablation_point_to_json).collect())
            }
            ShardPoints::Scenario(v) => {
                Json::Arr(v.iter().map(scenario_point_to_json).collect())
            }
        };
        let body = obj(vec![
            ("format", Json::Str(SHARD_FORMAT.to_string())),
            ("job", job_to_json(&self.job)),
            ("num_shards", Json::Num(self.num_shards as f64)),
            (
                "shard_ids",
                Json::Arr(self.shard_ids.iter().map(|&i| Json::Num(i as f64)).collect()),
            ),
            ("points", points),
        ]);
        let digest = Self::checksum_of(&body).expect("artifact body is an object");
        let Json::Obj(mut m) = body else { unreachable!("obj() builds an object") };
        m.insert("checksum".to_string(), Json::Str(digest));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<ShardArtifact> {
        let format = j.get("format")?.as_str()?;
        let legacy_v1 = format == SHARD_FORMAT_V1;
        let legacy_v2 = format == SHARD_FORMAT_V2;
        if !legacy_v1 && !legacy_v2 && format != SHARD_FORMAT {
            bail!("unsupported artifact format {format:?} (expected {SHARD_FORMAT:?})");
        }
        match j.opt("checksum") {
            Some(stored) => {
                let stored = stored.as_str()?;
                let expect = Self::checksum_of(j)?;
                if stored != expect {
                    bail!(
                        "checksum mismatch: artifact claims {stored}, content hashes to \
                         {expect} (corrupted or hand-edited artifact?)"
                    );
                }
            }
            None if legacy_v1 => {} // v1 predates checksums
            None => bail!("artifact has no checksum (required by {SHARD_FORMAT:?})"),
        }
        let job = job_from_json(j.get("job")?).context("job")?;
        let num_shards = j.get("num_shards")?.as_usize()?;
        let shard_ids: Vec<usize> = match j.opt("shard_ids") {
            Some(arr) => arr.as_arr()?.iter().map(Json::as_usize).collect::<Result<_>>()?,
            // Legacy v1 single-shard header.
            None => vec![j.get("shard_id")?.as_usize()?],
        };
        validate_shard_ids(&shard_ids, num_shards).context("shard header")?;
        let raw_points = j.get("points")?.as_arr()?;
        let points = match job.kind {
            JobKind::Figure => ShardPoints::Fig(
                raw_points
                    .iter()
                    .enumerate()
                    .map(|(i, p)| fig_point_from_json(p).with_context(|| format!("point {i}")))
                    .collect::<Result<Vec<_>>>()?,
            ),
            JobKind::Table => ShardPoints::Table(
                raw_points
                    .iter()
                    .enumerate()
                    .map(|(i, p)| table_point_from_json(p).with_context(|| format!("point {i}")))
                    .collect::<Result<Vec<_>>>()?,
            ),
            JobKind::Ablation => ShardPoints::Ablation(
                raw_points
                    .iter()
                    .enumerate()
                    .map(|(i, p)| {
                        ablation_point_from_json(p).with_context(|| format!("point {i}"))
                    })
                    .collect::<Result<Vec<_>>>()?,
            ),
            JobKind::Scenario => ShardPoints::Scenario(
                raw_points
                    .iter()
                    .enumerate()
                    .map(|(i, p)| {
                        scenario_point_from_json(p).with_context(|| format!("point {i}"))
                    })
                    .collect::<Result<Vec<_>>>()?,
            ),
        };
        Ok(ShardArtifact { job, shard_ids, num_shards, points })
    }
}

/// Per-artifact trial accounting: every MC point holds exactly the
/// trials of the artifact's covered ranges.
fn points_check(artifact: &ShardArtifact) -> Result<()> {
    artifact.points.check_trials(artifact.covered_trials())
}

/// The full-partition requirement shared by [`ShardArtifact::merge`]
/// and [`ShardArtifact::verify_set`]: the sorted covered ids must be
/// exactly `0..num_shards`.
fn require_complete(covered: &[usize], num_shards: usize) -> Result<()> {
    let expected: Vec<usize> = (0..num_shards).collect();
    if covered != expected {
        let missing: Vec<usize> =
            expected.into_iter().filter(|i| !covered.contains(i)).collect();
        bail!("incomplete partition: ids {covered:?} of 0..{num_shards} (missing {missing:?})");
    }
    Ok(())
}

// ------------------------------------------------- JSON (de)serialization

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// f64 → exact hex bit pattern (`"3fd0000000000000"`); the inverse of
/// [`f64_from_bits_json`]. Used for every f64 payload in the artifact
/// so round trips are exact for all values including NaN and -0.0.
fn f64_to_bits_json(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

fn f64_from_bits_json(j: &Json) -> Result<f64> {
    let s = j.as_str()?;
    let bits = u64::from_str_radix(s, 16).with_context(|| format!("bad f64 bits {s:?}"))?;
    Ok(f64::from_bits(bits))
}

fn exact_sum_to_json(s: &ExactSum) -> Json {
    Json::Arr(s.partials().iter().map(|&p| f64_to_bits_json(p)).collect())
}

fn exact_sum_from_json(j: &Json) -> Result<ExactSum> {
    let vals = j
        .as_arr()?
        .iter()
        .map(f64_from_bits_json)
        .collect::<Result<Vec<f64>>>()?;
    Ok(ExactSum::from_partials(&vals))
}

fn partial_to_json(p: &Partial) -> Json {
    match p {
        Partial::Mean { count, sum } => obj(vec![
            ("kind", Json::Str("mean".into())),
            ("count", Json::Num(*count as f64)),
            ("sum", exact_sum_to_json(sum)),
        ]),
        Partial::Moments { count, sum, sumsq } => obj(vec![
            ("kind", Json::Str("moments".into())),
            ("count", Json::Num(*count as f64)),
            ("sum", exact_sum_to_json(sum)),
            ("sumsq", exact_sum_to_json(sumsq)),
        ]),
        Partial::Prob { count, hits } => obj(vec![
            ("kind", Json::Str("prob".into())),
            ("count", Json::Num(*count as f64)),
            ("hits", Json::Num(*hits as f64)),
        ]),
        Partial::Curve { count, sums } => obj(vec![
            ("kind", Json::Str("curve".into())),
            ("count", Json::Num(*count as f64)),
            ("sums", Json::Arr(sums.iter().map(exact_sum_to_json).collect())),
        ]),
        Partial::Exact { value } => obj(vec![
            ("kind", Json::Str("exact".into())),
            ("value", f64_to_bits_json(*value)),
        ]),
    }
}

fn partial_from_json(j: &Json) -> Result<Partial> {
    match j.get("kind")?.as_str()? {
        "mean" => Ok(Partial::Mean {
            count: j.get("count")?.as_usize()? as u64,
            sum: exact_sum_from_json(j.get("sum")?)?,
        }),
        "moments" => Ok(Partial::Moments {
            count: j.get("count")?.as_usize()? as u64,
            sum: exact_sum_from_json(j.get("sum")?)?,
            sumsq: exact_sum_from_json(j.get("sumsq")?)?,
        }),
        "prob" => Ok(Partial::Prob {
            count: j.get("count")?.as_usize()? as u64,
            hits: j.get("hits")?.as_usize()? as u64,
        }),
        "curve" => Ok(Partial::Curve {
            count: j.get("count")?.as_usize()? as u64,
            sums: j
                .get("sums")?
                .as_arr()?
                .iter()
                .map(exact_sum_from_json)
                .collect::<Result<Vec<_>>>()?,
        }),
        "exact" => Ok(Partial::Exact { value: f64_from_bits_json(j.get("value")?)? }),
        other => bail!("unknown partial kind {other:?}"),
    }
}

fn post_to_json(p: &PostMap) -> Json {
    match p {
        PostMap::Identity => obj(vec![("kind", Json::Str("identity".into()))]),
        PostMap::SqrtScale { scale } => obj(vec![
            ("kind", Json::Str("sqrt_scale".into())),
            ("scale", f64_to_bits_json(*scale)),
        ]),
    }
}

fn post_from_json(j: &Json) -> Result<PostMap> {
    match j.get("kind")?.as_str()? {
        "identity" => Ok(PostMap::Identity),
        "sqrt_scale" => Ok(PostMap::SqrtScale { scale: f64_from_bits_json(j.get("scale")?)? }),
        other => bail!("unknown post-map kind {other:?}"),
    }
}

fn job_to_json(job: &JobSpec) -> Json {
    obj(vec![
        ("kind", Json::Str(job.kind.name().to_string())),
        ("id", Json::Str(job.id.clone())),
        ("trials", Json::Num(job.trials as f64)),
        // u64 seeds can exceed f64's exact-integer range; keep decimal text.
        ("seed", Json::Str(job.seed.to_string())),
        ("k", Json::Num(job.k as f64)),
        ("s", Json::Num(job.s as f64)),
        ("tmax", Json::Num(job.tmax as f64)),
        // Canonical scenario string (a parse fixed point; f64 params in
        // shortest round-trip form, so the value survives exactly).
        ("scenario", Json::Str(job.scenario.to_string())),
    ])
}

fn job_from_json(j: &Json) -> Result<JobSpec> {
    // v1/v2 artifacts predate the scenario field; everything they ever
    // computed was the uniform default, so that is what absence means.
    let scenario = match j.opt("scenario") {
        Some(s) => Scenario::parse(s.as_str()?).context("scenario")?,
        None => Scenario::default(),
    };
    Ok(JobSpec {
        kind: JobKind::parse(j.get("kind")?.as_str()?)?,
        id: j.get("id")?.as_str()?.to_string(),
        trials: j.get("trials")?.as_usize()?,
        seed: j.get("seed")?.as_str()?.parse::<u64>().context("seed")?,
        k: j.get("k")?.as_usize()?,
        s: j.get("s")?.as_usize()?,
        tmax: j.get("tmax")?.as_usize()?,
        scenario,
    })
}

/// Every figure id the artifact format knows. Single registry: the
/// CLI validates against it and deserialization interns through it, so
/// a new figure cannot be producible-but-unmergeable.
pub const FIGURE_IDS: [&str; 4] = ["fig2", "fig3", "fig4", "fig5"];

/// Every table id the artifact format and the CLI accept — the single
/// registry `repro tables`/`repro shard` whitelist from and that
/// artifact deserialization interns against (keep [`JobSpec::run`]'s
/// match in step when extending it).
pub const TABLE_IDS: [&str; 8] =
    ["thm3", "thm5", "thm6", "thm8", "thm10", "thm11", "thm21", "thm24"];

/// The tables whose `--s` flag is meaningful; the rest derive s
/// internally (thm8: log-threshold, thm21/24: ln k, thm11: fixed
/// instance) and reject the flag. Shared by the CLI's flag validation
/// and the fan-out scheduler's child-argv reconstruction
/// (`serve::scheduler`), which must agree on when `--s` is legal.
pub const TABLES_WITH_S: [&str; 4] = ["thm3", "thm5", "thm6", "thm10"];

/// The tables with no uniform straggler sampling to swap out (thm3:
/// spectral, thm10/thm11: their own adversarial protocol); they reject
/// `--stragglers` rather than silently ignore it.
pub const TABLES_WITHOUT_SCENARIO: [&str; 3] = ["thm3", "thm10", "thm11"];

/// Every ablation study id the CLI (`repro ablation --study`,
/// `repro shard --ablation`, `repro run --ablation`) and
/// [`JobSpec::run`] accept — the single registry, like [`TABLE_IDS`],
/// so a study cannot be producible-but-unmergeable (the dispatch lives
/// in `ablations::study_partials`).
pub const ABLATION_IDS: [&str; 4] = ["rho", "rbgc", "lsqr", "normalization"];

/// Point-level study names (the `study` CSV column), interned on
/// deserialization like figure/table ids.
pub const ABLATION_STUDIES: [&str; 4] =
    ["rho_sweep", "rbgc_threshold", "lsqr_tolerance", "normalization"];

/// Every scenario study id the CLI (`repro scenario`,
/// `repro shard --scenario`, `repro run --scenario`) and
/// [`JobSpec::run`] accept — the single registry, like [`TABLE_IDS`],
/// so a study cannot be producible-but-unmergeable.
pub const SCENARIO_IDS: [&str; 3] = ["tta", "tta3", "latparam"];

/// Intern a deserialized name against one of the static id registries,
/// yielding the `&'static str` the point structs carry — the single
/// copy behind every per-registry wrapper below.
fn intern(name: &str, registry: &'static [&'static str], what: &str) -> Result<&'static str> {
    registry
        .iter()
        .find(|&&id| id == name)
        .copied()
        .ok_or_else(|| anyhow::anyhow!("unknown {what} {name:?} in artifact"))
}

fn fig_point_to_json(p: &FigPartialPoint) -> Json {
    obj(vec![
        ("figure", Json::Str(p.figure.to_string())),
        ("scheme", Json::Str(p.scheme.clone())),
        ("s", Json::Num(p.s as f64)),
        ("delta", f64_to_bits_json(p.delta)),
        ("k", Json::Num(p.k as f64)),
        ("partial", partial_to_json(&p.partial)),
    ])
}

fn fig_point_from_json(j: &Json) -> Result<FigPartialPoint> {
    Ok(FigPartialPoint {
        figure: intern(j.get("figure")?.as_str()?, &FIGURE_IDS, "figure id")?,
        scheme: j.get("scheme")?.as_str()?.to_string(),
        s: j.get("s")?.as_usize()?,
        delta: f64_from_bits_json(j.get("delta")?)?,
        k: j.get("k")?.as_usize()?,
        partial: partial_from_json(j.get("partial")?)?,
    })
}

fn table_point_to_json(p: &TablePartialPoint) -> Json {
    let rows = p
        .rows
        .iter()
        .map(|r| {
            obj(vec![
                ("table", Json::Str(r.table.to_string())),
                ("label", Json::Str(r.label.clone())),
                ("expected", f64_to_bits_json(r.expected)),
                ("note", Json::Str(r.note.clone())),
                ("post", post_to_json(&r.post)),
            ])
        })
        .collect();
    obj(vec![("rows", Json::Arr(rows)), ("partial", partial_to_json(&p.partial))])
}

fn ablation_point_to_json(p: &AblationPartialPoint) -> Json {
    obj(vec![
        ("study", Json::Str(p.study.to_string())),
        ("setting", Json::Str(p.setting.clone())),
        ("k", Json::Num(p.k as f64)),
        ("partial", partial_to_json(&p.partial)),
    ])
}

fn ablation_point_from_json(j: &Json) -> Result<AblationPartialPoint> {
    Ok(AblationPartialPoint {
        study: intern(j.get("study")?.as_str()?, &ABLATION_STUDIES, "ablation study")?,
        setting: j.get("setting")?.as_str()?.to_string(),
        k: j.get("k")?.as_usize()?,
        partial: partial_from_json(j.get("partial")?)?,
    })
}

fn scenario_point_to_json(p: &ScenarioPartialPoint) -> Json {
    obj(vec![
        ("study", Json::Str(p.study.to_string())),
        ("scheme", Json::Str(p.scheme.clone())),
        ("policy", Json::Str(p.policy.to_string())),
        ("s", Json::Num(p.s as f64)),
        ("delta", f64_to_bits_json(p.delta)),
        ("k", Json::Num(p.k as f64)),
        ("partial", partial_to_json(&p.partial)),
    ])
}

fn scenario_point_from_json(j: &Json) -> Result<ScenarioPartialPoint> {
    Ok(ScenarioPartialPoint {
        study: intern(j.get("study")?.as_str()?, &SCENARIO_IDS, "scenario study")?,
        scheme: j.get("scheme")?.as_str()?.to_string(),
        policy: intern(
            j.get("policy")?.as_str()?,
            &scenario_mod::SCENARIO_POLICIES,
            "scenario policy",
        )?,
        s: j.get("s")?.as_usize()?,
        delta: f64_from_bits_json(j.get("delta")?)?,
        k: j.get("k")?.as_usize()?,
        partial: partial_from_json(j.get("partial")?)?,
    })
}

fn table_point_from_json(j: &Json) -> Result<TablePartialPoint> {
    let rows = j
        .get("rows")?
        .as_arr()?
        .iter()
        .map(|r| {
            Ok(RowTemplate {
                table: intern(r.get("table")?.as_str()?, &TABLE_IDS, "table id")?,
                label: r.get("label")?.as_str()?.to_string(),
                expected: f64_from_bits_json(r.get("expected")?)?,
                note: r.get("note")?.as_str()?.to_string(),
                post: post_from_json(r.get("post")?)?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(TablePartialPoint { rows, partial: partial_from_json(j.get("partial")?)? })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn exact_sum_handles_catastrophic_cancellation() {
        let mut s = ExactSum::new();
        for x in [1e100, 1.0, -1e100] {
            s.add(x);
        }
        assert_eq!(s.round(), 1.0);

        let mut s = ExactSum::new();
        s.add(1.0);
        for _ in 0..10 {
            s.add(1e-16);
        }
        // Plain summation would return 1.0; the exact sum rounds up.
        assert_eq!(s.round(), 1.0 + 1.0e-15);
    }

    #[test]
    fn exact_sum_empty_and_single() {
        assert_eq!(ExactSum::new().round(), 0.0);
        let mut s = ExactSum::new();
        s.add(-2.5);
        assert_eq!(s.round(), -2.5);
    }

    #[test]
    fn exact_sum_partition_invariance_fuzz() {
        let mut rng = Rng::new(99);
        for case in 0..50 {
            // Values spanning ~20 orders of magnitude with mixed signs.
            let n = 5 + rng.usize(200);
            let vals: Vec<f64> = (0..n)
                .map(|_| {
                    let mag = 10f64.powi(rng.usize(20) as i32 - 10);
                    (rng.f64() - 0.5) * mag
                })
                .collect();
            let mut whole = ExactSum::new();
            for &v in &vals {
                whole.add(v);
            }
            // Random contiguous partition into 1..=7 pieces, merged.
            let pieces = 1 + rng.usize(7);
            let mut merged = ExactSum::new();
            for i in 0..pieces {
                let lo = vals.len() * i / pieces;
                let hi = vals.len() * (i + 1) / pieces;
                let mut part = ExactSum::new();
                for &v in &vals[lo..hi] {
                    part.add(v);
                }
                merged.merge(&part);
            }
            assert_eq!(
                whole.round().to_bits(),
                merged.round().to_bits(),
                "case {case}: partition changed the rounded sum"
            );
        }
    }

    #[test]
    fn shard_ranges_partition_every_trial_count() {
        for &trials in &[0usize, 1, 5, 60, 5000] {
            for &n in &[1usize, 2, 3, 7, 64] {
                let mut covered = Vec::new();
                let mut prev_end = 0;
                for i in 0..n {
                    let r = Shard::new(i, n).unwrap().range(trials);
                    assert_eq!(r.start, prev_end, "trials={trials} n={n} i={i}");
                    prev_end = r.end;
                    covered.extend(r);
                }
                assert_eq!(covered, (0..trials).collect::<Vec<_>>(), "trials={trials} n={n}");
            }
        }
    }

    #[test]
    fn shard_new_validates() {
        assert!(Shard::new(0, 0).is_err());
        assert!(Shard::new(3, 3).is_err());
        assert!(Shard::new(2, 3).is_ok());
    }

    #[test]
    fn partial_mean_merge_matches_whole() {
        let vals: Vec<f64> = (0..97).map(|i| ((i * 37) % 101) as f64 * 0.01).collect();
        let mut whole = ExactSum::new();
        for &v in &vals {
            whole.add(v);
        }
        let whole = Partial::Mean { count: vals.len() as u64, sum: whole };

        let mut halves = [ExactSum::new(), ExactSum::new()];
        let mut counts = [0u64, 0u64];
        for (i, &v) in vals.iter().enumerate() {
            halves[i % 2].add(v);
            counts[i % 2] += 1;
        }
        let mut merged = Partial::Mean { count: counts[0], sum: halves[0].clone() };
        let second = Partial::Mean { count: counts[1], sum: halves[1].clone() };
        merged.merge(&second).unwrap();
        assert_eq!(merged.value().to_bits(), whole.value().to_bits());
    }

    #[test]
    fn partial_kind_mismatch_and_exact_disagreement_fail() {
        let mut m = Partial::Mean { count: 1, sum: ExactSum::new() };
        assert!(m.merge(&Partial::Prob { count: 1, hits: 0 }).is_err());
        let mut e = Partial::Exact { value: 1.0 };
        assert!(e.merge(&Partial::Exact { value: 1.0 }).is_ok());
        assert!(e.merge(&Partial::Exact { value: 2.0 }).is_err());
    }

    #[test]
    fn exact_sum_json_roundtrip_preserves_bits() {
        let mut s = ExactSum::new();
        for x in [1e100, 1.0, -1e-300, 0.1, f64::MIN_POSITIVE] {
            s.add(x);
        }
        let j = exact_sum_to_json(&s);
        let text = j.write();
        let back = exact_sum_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.round().to_bits(), s.round().to_bits());
    }

    #[test]
    fn partial_json_roundtrip_all_kinds() {
        let mut sum = ExactSum::new();
        sum.add(0.3);
        sum.add(1e-17);
        let mut sumsq = ExactSum::new();
        sumsq.add(0.09);
        sumsq.add(1e-19);
        let cases = [
            Partial::Mean { count: 42, sum: sum.clone() },
            Partial::Moments { count: 42, sum: sum.clone(), sumsq },
            Partial::Prob { count: 100, hits: 3 },
            Partial::Curve { count: 7, sums: vec![sum.clone(), ExactSum::new()] },
            Partial::Exact { value: f64::NAN },
            Partial::Exact { value: -0.0 },
        ];
        for p in &cases {
            let back = partial_from_json(&Json::parse(&partial_to_json(p).write()).unwrap())
                .unwrap();
            assert_eq!(back.kind(), p.kind());
            assert_eq!(back.value().to_bits(), p.value().to_bits());
            let (m0, s0) = p.mean_std();
            let (m1, s1) = back.mean_std();
            assert_eq!(m1.to_bits(), m0.to_bits());
            assert_eq!(s1.to_bits(), s0.to_bits());
            assert_eq!(
                back.curve_values().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                p.curve_values().iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn moments_merge_is_partition_invariant() {
        let vals: Vec<f64> = (0..137).map(|i| ((i * 29) % 83) as f64 * 0.013 - 0.4).collect();
        let moments_of = |slice: &[f64]| -> Partial {
            let mut sum = ExactSum::new();
            let mut sumsq = ExactSum::new();
            for &v in slice {
                sum.add(v);
                sumsq.add(v * v);
            }
            Partial::Moments { count: slice.len() as u64, sum, sumsq }
        };
        let whole = moments_of(&vals);
        let (m_whole, s_whole) = whole.mean_std();
        assert!(s_whole > 0.0);
        for pieces in [2usize, 3, 7] {
            let mut merged: Option<Partial> = None;
            for i in 0..pieces {
                let lo = vals.len() * i / pieces;
                let hi = vals.len() * (i + 1) / pieces;
                let part = moments_of(&vals[lo..hi]);
                match merged.as_mut() {
                    None => merged = Some(part),
                    Some(m) => m.merge(&part).unwrap(),
                }
            }
            let (m, s) = merged.unwrap().mean_std();
            assert_eq!(m.to_bits(), m_whole.to_bits(), "pieces={pieces}");
            assert_eq!(s.to_bits(), s_whole.to_bits(), "pieces={pieces}");
        }
        // Constant data: exact zero std through the moments identity.
        let (m, s) = moments_of(&[4.0; 50]).mean_std();
        assert_eq!(m, 4.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn merge_rejects_bad_partitions() {
        let job = JobSpec {
            kind: JobKind::Table,
            id: "thm11".into(),
            trials: 10,
            seed: 1,
            k: 10,
            s: 2,
            tmax: 0,
            scenario: Scenario::default(),
        };
        let point = TablePartialPoint {
            rows: vec![RowTemplate {
                table: "thm11",
                label: "x".into(),
                expected: 0.0,
                note: "n".into(),
                post: PostMap::Identity,
            }],
            partial: Partial::Exact { value: 1.5 },
        };
        let art = |sid: usize, n: usize| ShardArtifact {
            job: job.clone(),
            shard_ids: vec![sid],
            num_shards: n,
            points: ShardPoints::Table(vec![point.clone()]),
        };
        // Missing shard 1 of 2.
        assert!(ShardArtifact::merge(vec![art(0, 2)]).is_err());
        // Duplicate shard id.
        assert!(ShardArtifact::merge(vec![art(0, 2), art(0, 2)]).is_err());
        // Mismatched num_shards.
        assert!(ShardArtifact::merge(vec![art(0, 2), art(1, 3)]).is_err());
        // Mismatched job.
        let mut other = art(1, 2);
        other.job.seed = 2;
        assert!(ShardArtifact::merge(vec![art(0, 2), other]).is_err());
        // Valid 2-shard partition of a deterministic point.
        let merged = ShardArtifact::merge(vec![art(0, 2), art(1, 2)]).unwrap();
        assert_eq!(merged.points.len(), 1);
        // Folding a subset gives a compound artifact; overlaps reject.
        let folded = ShardArtifact::merge_partial(vec![art(0, 3), art(2, 3)]).unwrap();
        assert_eq!(folded.shard_ids, vec![0, 2]);
        assert!(ShardArtifact::merge_partial(vec![folded.clone(), art(2, 3)]).is_err());
        // Compound + disjoint remainder completes the partition.
        assert!(ShardArtifact::merge(vec![folded.clone(), art(1, 3)]).is_ok());
        assert!(ShardArtifact::verify_set(&[folded.clone(), art(1, 3)]).is_ok());
        assert!(ShardArtifact::verify_set(&[folded]).is_err());
    }

    #[test]
    fn checksum_rejects_tampered_artifacts() {
        // thm11 is deterministic and cheap — a good artifact fixture.
        let job = JobSpec {
            kind: JobKind::Table,
            id: "thm11".into(),
            trials: 10,
            seed: 3,
            k: 12,
            s: 3,
            tmax: 0,
            scenario: Scenario::default(),
        };
        let art = ShardArtifact::compute(&job, Shard::new(0, 2).unwrap(), Some(1)).unwrap();
        let text = art.to_json_string();
        assert!(text.contains("\"checksum\""));
        // Pristine text parses.
        assert!(ShardArtifact::parse(&text).is_ok());
        // Tampering with the body (without refreshing the checksum)
        // must be caught.
        let tampered = text.replacen("\"num_shards\": 2", "\"num_shards\": 4", 1);
        assert_ne!(tampered, text);
        let err = ShardArtifact::parse(&tampered).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        // Tampering with the checksum itself is equally fatal.
        let bad_sum = text.replacen("\"checksum\": \"", "\"checksum\": \"f00d", 1);
        assert!(ShardArtifact::parse(&bad_sum).is_err());
    }

    #[test]
    fn legacy_v1_artifacts_still_parse() {
        let job = JobSpec {
            kind: JobKind::Table,
            id: "thm11".into(),
            trials: 10,
            seed: 3,
            k: 12,
            s: 3,
            tmax: 0,
            scenario: Scenario::default(),
        };
        let art = ShardArtifact::compute(&job, Shard::new(1, 3).unwrap(), Some(1)).unwrap();
        // Rewrite the v3 artifact into the PR-3 v1 shape: single
        // shard_id field, no shard_ids, no checksum, no job scenario.
        let Json::Obj(mut m) = art.to_json() else { panic!("artifact is an object") };
        m.remove("checksum");
        m.remove("shard_ids");
        m.insert("format".into(), Json::Str(SHARD_FORMAT_V1.into()));
        m.insert("shard_id".into(), Json::Num(1.0));
        let Some(Json::Obj(job_obj)) = m.get_mut("job") else { panic!("job is an object") };
        job_obj.remove("scenario");
        let text = Json::Obj(m).write_pretty();
        let parsed = ShardArtifact::parse(&text).unwrap();
        assert_eq!(parsed.shard_ids, vec![1]);
        assert_eq!(parsed.num_shards, 3);
        // The missing scenario parses as the uniform default, so v1
        // artifacts stay mergeable with fresh uniform runs.
        assert!(parsed.job.scenario.is_default());
        assert_eq!(parsed.job, job);
        // Re-serializing upgrades to v3 with a checksum.
        assert!(parsed.to_json_string().contains(SHARD_FORMAT));
    }

    /// The v2→v3 compatibility contract: a v2 artifact (no scenario
    /// field anywhere, v2 format tag, checksum over the v2 body) parses
    /// as the uniform scenario and merges with fresh v3 artifacts of
    /// the same (uniform) job.
    #[test]
    fn legacy_v2_artifacts_parse_as_uniform_and_merge_with_v3() {
        let job = JobSpec {
            kind: JobKind::Table,
            id: "thm11".into(),
            trials: 10,
            seed: 3,
            k: 12,
            s: 3,
            tmax: 0,
            scenario: Scenario::default(),
        };
        let art = ShardArtifact::compute(&job, Shard::new(0, 2).unwrap(), Some(1)).unwrap();
        // Rewrite into the exact v2 shape: drop job.scenario, set the
        // v2 format tag, recompute the checksum over the v2 body.
        let Json::Obj(mut m) = art.to_json() else { panic!("artifact is an object") };
        m.remove("checksum");
        m.insert("format".into(), Json::Str(SHARD_FORMAT_V2.into()));
        let Some(Json::Obj(job_obj)) = m.get_mut("job") else { panic!("job is an object") };
        job_obj.remove("scenario");
        let body = Json::Obj(m);
        let digest = ShardArtifact::checksum_of(&body).unwrap();
        let Json::Obj(mut m) = body else { unreachable!() };
        m.insert("checksum".into(), Json::Str(digest));
        let text = Json::Obj(m).write_pretty();
        assert!(text.contains(SHARD_FORMAT_V2));

        let parsed = ShardArtifact::parse(&text).unwrap();
        assert!(parsed.job.scenario.is_default(), "v2 must parse as uniform");
        assert_eq!(parsed.job, job);
        // Round trip: v2 in, v3 (with scenario) out, same points.
        let reserialized = parsed.to_json_string();
        assert!(reserialized.contains(SHARD_FORMAT));
        assert!(reserialized.contains("\"scenario\""));
        // And it merges with a fresh v3 shard of the same job.
        let v3 = ShardArtifact::compute(&job, Shard::new(1, 2).unwrap(), Some(1)).unwrap();
        let merged = ShardArtifact::merge(vec![parsed, v3]).unwrap();
        assert_eq!(merged.to_csv(), job.run(Shard::full(), Some(1)).unwrap().to_csv());
        // A tampered v2 body is still caught by its checksum.
        let tampered = text.replacen("\"trials\": 10", "\"trials\": 11", 1);
        assert_ne!(tampered, text);
        assert!(ShardArtifact::parse(&tampered).is_err());
    }

    /// Satellite pin: the streamed FNV-1a checksum (fmt::Write sink
    /// through the JSON writer) equals the materialize-then-hash path
    /// byte for byte.
    #[test]
    fn streamed_checksum_equals_materialized_hash() {
        let job = JobSpec {
            kind: JobKind::Table,
            id: "thm5".into(),
            trials: 10,
            seed: 5,
            k: 12,
            s: 3,
            tmax: 0,
            scenario: Scenario::parse("pareto:0.02,1.5").unwrap(),
        };
        let art = ShardArtifact::compute(&job, Shard::new(0, 1).unwrap(), Some(1)).unwrap();
        let body = art.to_json(); // includes the checksum field
        let streamed = ShardArtifact::checksum_of(&body).unwrap();
        let materialized =
            format!("{:016x}", fnv1a64(body.write_excluding("checksum").as_bytes()));
        assert_eq!(streamed, materialized);
        // And on a hostile-string body (escapes must stream identically).
        let j = Json::parse(r#"{"a": "q\"uo\\te\nnl", "checksum": "x", "b": [1.5, -0.0]}"#)
            .unwrap();
        let streamed = ShardArtifact::checksum_of(&j).unwrap();
        let materialized = format!("{:016x}", fnv1a64(j.write_excluding("checksum").as_bytes()));
        assert_eq!(streamed, materialized);
    }

    /// Scenario (tta) artifacts round-trip and shard/merge like every
    /// other job family.
    #[test]
    fn scenario_job_artifacts_roundtrip_and_merge() {
        let job = JobSpec {
            kind: JobKind::Scenario,
            id: "tta".into(),
            trials: 12,
            seed: 7,
            k: 10,
            s: 2,
            tmax: 0,
            scenario: Scenario::parse("pareto:0.05,1.5").unwrap(),
        };
        let unsharded = job.run(Shard::full(), Some(2)).unwrap().to_csv();
        assert!(unsharded.starts_with("scenario,scheme,policy,s,delta,gather,err1\n"));
        let arts: Vec<ShardArtifact> = (0..3)
            .map(|sid| {
                let art =
                    ShardArtifact::compute(&job, Shard::new(sid, 3).unwrap(), Some(1)).unwrap();
                ShardArtifact::parse(&art.to_json_string()).unwrap()
            })
            .collect();
        assert!(ShardArtifact::verify_set(&arts).is_ok());
        let merged = ShardArtifact::merge(arts).unwrap();
        assert_eq!(merged.to_csv(), unsharded);
        // A scenario job refuses to merge with the same job under a
        // different scenario (the scenario is run identity).
        let mut other = job.clone();
        other.scenario = Scenario::parse("pareto:0.05,2.5").unwrap();
        let a0 = ShardArtifact::compute(&job, Shard::new(0, 2).unwrap(), Some(1)).unwrap();
        let b1 = ShardArtifact::compute(&other, Shard::new(1, 2).unwrap(), Some(1)).unwrap();
        assert!(ShardArtifact::merge(vec![a0, b1]).is_err());
        // Uniform scenarios are rejected for tta at run time.
        let mut bad = job.clone();
        bad.scenario = Scenario::default();
        assert!(bad.run(Shard::full(), Some(1)).is_err());
    }

    /// The latparam study rides the same scenario-job spine: artifacts
    /// round-trip through JSON (interning the new sweep-arm policy
    /// labels) and shards merge back to the unsharded run.
    #[test]
    fn latparam_job_artifacts_roundtrip_and_merge() {
        let job = JobSpec {
            kind: JobKind::Scenario,
            id: "latparam".into(),
            trials: 9,
            seed: 3,
            k: 8,
            s: 2,
            tmax: 0,
            scenario: Scenario::parse("pareto:0.05,1.5").unwrap(),
        };
        let unsharded = job.run(Shard::full(), Some(2)).unwrap().to_csv();
        assert!(unsharded.starts_with("scenario,scheme,policy,s,delta,gather,err1\n"));
        assert!(unsharded.contains(",pareto-shape,"));
        assert!(unsharded.contains(",sexp-rate,"));
        let arts: Vec<ShardArtifact> = (0..2)
            .map(|sid| {
                let art =
                    ShardArtifact::compute(&job, Shard::new(sid, 2).unwrap(), Some(1)).unwrap();
                ShardArtifact::parse(&art.to_json_string()).unwrap()
            })
            .collect();
        assert!(ShardArtifact::verify_set(&arts).is_ok());
        let merged = ShardArtifact::merge(arts).unwrap();
        assert_eq!(merged.to_csv(), unsharded);
    }
}
