//! Regeneration of every figure in the paper's §6 (Figures 2-5).
//!
//! Each function returns the plotted series as data points; the CLI
//! prints them as CSV, and `rust/benches/fig*` wrap them with timing.
//! Paper protocol: k = 100, r = (1-δ)k, 5000 trials per point,
//! ρ = k/(rs) for one-step decoding, ν = ||A||² for the Fig. 5 curves.
//!
//! Every figure is expressed as *(per-shard partials) ∘ (finalize)*:
//! the `*_partials` variants run any [`Shard`] of the trial range and
//! return [`FigPartialPoint`]s (exact partial aggregates plus the point
//! metadata), and the classic `figure2`..`figure5` entry points are the
//! `num_shards = 1` case. `repro shard`/`repro merge` distribute the
//! same sweep across processes and reproduce these functions' output
//! bit-for-bit (see [`super::shard`] and `tests/shard_parity.rs`).

use super::montecarlo::MonteCarlo;
use super::scenario::{scalar_partial_panel_under, PanelKind};
use super::shard::{Partial, Shard};
use crate::codes::Scheme;
use crate::decode::{algorithmic_error_curve, DecodeWorkspace, StepSize};
use crate::linalg::{CscMatrix, LsqrOptions};
use crate::stragglers::Scenario;
use crate::util::Rng;

/// One plotted point: figure id, series labels, x, y.
#[derive(Clone, Debug)]
pub struct FigPoint {
    pub figure: &'static str,
    pub scheme: String,
    pub s: usize,
    pub delta: f64,
    /// Iteration index for Fig. 5; 0 otherwise.
    pub t: usize,
    pub value: f64,
}

impl FigPoint {
    pub fn csv_header() -> &'static str {
        "figure,scheme,s,delta,t,value"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{:.3},{},{:.6e}",
            self.figure, self.scheme, self.s, self.delta, self.t, self.value
        )
    }
}

/// One figure point's *partial* state: the sweep metadata plus an exact
/// partial aggregate of this shard's trials. Finalizing a fully-merged
/// partial yields the published [`FigPoint`]s (one per point; `t_max+1`
/// per point for the Fig. 5 curves).
#[derive(Clone, Debug)]
pub struct FigPartialPoint {
    pub figure: &'static str,
    pub scheme: String,
    pub s: usize,
    pub delta: f64,
    /// The figure's k (finalize divides the mean by it).
    pub k: usize,
    pub partial: Partial,
}

impl FigPartialPoint {
    /// Metadata equality (delta compared by bits) — merge refuses to
    /// combine partials from different sweep points.
    pub fn same_point(&self, other: &FigPartialPoint) -> bool {
        self.figure == other.figure
            && self.scheme == other.scheme
            && self.s == other.s
            && self.delta.to_bits() == other.delta.to_bits()
            && self.k == other.k
            && self.partial.kind() == other.partial.kind()
    }

    /// Finalize a (fully-merged) partial into published figure points.
    pub fn finalize(&self) -> Vec<FigPoint> {
        match &self.partial {
            Partial::Curve { .. } => self
                .partial
                .curve_values()
                .iter()
                .enumerate()
                .map(|(t, &v)| FigPoint {
                    figure: self.figure,
                    scheme: self.scheme.clone(),
                    s: self.s,
                    delta: self.delta,
                    t,
                    value: v / self.k as f64,
                })
                .collect(),
            p => vec![FigPoint {
                figure: self.figure,
                scheme: self.scheme.clone(),
                s: self.s,
                delta: self.delta,
                t: 0,
                value: p.value() / self.k as f64,
            }],
        }
    }
}

/// Finalize a slice of fully-merged partial points.
pub fn finalize_fig_points(points: &[FigPartialPoint]) -> Vec<FigPoint> {
    points.iter().flat_map(|p| p.finalize()).collect()
}

/// Shared sweep configuration (paper defaults).
#[derive(Clone, Debug)]
pub struct FigureConfig {
    pub k: usize,
    pub s_values: Vec<usize>,
    pub deltas: Vec<f64>,
    pub mc: MonteCarlo,
}

impl FigureConfig {
    /// Paper settings: k=100, s ∈ {5, 10}, δ ∈ {0.05..0.9}, 5000 trials.
    pub fn paper(trials: usize, seed: u64) -> Self {
        FigureConfig {
            k: 100,
            s_values: vec![5, 10],
            deltas: (1..=18).map(|i| i as f64 * 0.05).collect(),
            mc: MonteCarlo::new(trials, seed),
        }
    }

    pub fn r(&self, delta: f64) -> usize {
        (((1.0 - delta) * self.k as f64).round() as usize).clamp(1, self.k)
    }
}

/// Draw A for one trial: build G (randomized schemes re-draw per trial,
/// exactly like the paper's simulations) and keep r uniform columns.
pub fn draw_non_straggler_matrix(
    scheme: Scheme,
    k: usize,
    s: usize,
    r: usize,
    rng: &mut Rng,
) -> CscMatrix {
    let code = scheme.build(k, k, s);
    let g = code.assignment(rng);
    let idx = rng.sample_indices(k, r);
    g.select_columns(&idx)
}

/// The three schemes compared in Figs. 2-4.
pub const FIG_SCHEMES: [Scheme; 3] = [Scheme::Frc, Scheme::Bgc, Scheme::RegularGraph];

/// Figure 2: average one-step error err_1(A)/k vs δ, ρ = k/(rs).
pub fn figure2(cfg: &FigureConfig) -> Vec<FigPoint> {
    finalize_fig_points(&figure2_partials(cfg, &Scenario::default(), Shard::full()))
}

/// One shard of [`figure2`] under a straggler scenario (the default
/// uniform scenario reproduces [`figure2`] bit for bit).
pub fn figure2_partials(
    cfg: &FigureConfig,
    scenario: &Scenario,
    shard: Shard,
) -> Vec<FigPartialPoint> {
    error_sweep_partials(cfg, "fig2", &FIG_SCHEMES, ErrorKind::OneStep, scenario, shard)
}

/// Figure 3: average optimal decoding error err(A)/k vs δ.
pub fn figure3(cfg: &FigureConfig) -> Vec<FigPoint> {
    finalize_fig_points(&figure3_partials(cfg, &Scenario::default(), Shard::full()))
}

/// One shard of [`figure3`] under a straggler scenario.
pub fn figure3_partials(
    cfg: &FigureConfig,
    scenario: &Scenario,
    shard: Shard,
) -> Vec<FigPartialPoint> {
    error_sweep_partials(cfg, "fig3", &FIG_SCHEMES, ErrorKind::Optimal, scenario, shard)
}

/// Figure 4: one-step vs optimal per scheme (six panels). Emitted as
/// both error kinds per scheme; the scheme label carries the decoder.
pub fn figure4(cfg: &FigureConfig) -> Vec<FigPoint> {
    finalize_fig_points(&figure4_partials(cfg, &Scenario::default(), Shard::full()))
}

/// One shard of [`figure4`] under a straggler scenario.
pub fn figure4_partials(
    cfg: &FigureConfig,
    scenario: &Scenario,
    shard: Shard,
) -> Vec<FigPartialPoint> {
    let mut out = Vec::new();
    for kind in [ErrorKind::OneStep, ErrorKind::Optimal] {
        for mut p in error_sweep_partials(cfg, "fig4", &FIG_SCHEMES, kind, scenario, shard) {
            p.scheme = format!("{}/{}", p.scheme, kind.label());
            out.push(p);
        }
    }
    out
}

/// Figure 5: algorithmic decoding error ||u_t||²/k of a BGC for
/// δ ∈ {0.1, 0.2, 0.3, 0.5, 0.8}, ν = ||A||², t = 0..=t_max.
pub fn figure5(cfg: &FigureConfig, t_max: usize) -> Vec<FigPoint> {
    finalize_fig_points(&figure5_partials(cfg, t_max, &Scenario::default(), Shard::full()))
}

/// One shard of [`figure5`]: a [`Partial::Curve`] per (s, δ) point,
/// with straggler selection through the scenario spine.
pub fn figure5_partials(
    cfg: &FigureConfig,
    t_max: usize,
    scenario: &Scenario,
    shard: Shard,
) -> Vec<FigPartialPoint> {
    let deltas = [0.1, 0.2, 0.3, 0.5, 0.8];
    let mut out = Vec::new();
    for &s in &cfg.s_values {
        for &delta in &deltas {
            let r = cfg.r(delta);
            let k = cfg.k;
            let code = Scheme::Bgc.build(k, k, s);
            let resolved = scenario.resolve(code.as_ref(), delta, r, cfg.mc.seed);
            let partial =
                cfg.mc.mean_curve_partial_ws(t_max + 1, shard, DecodeWorkspace::new, |ws, rng| {
                    let a = match &resolved.standing_g {
                        None => ws.redraw_submatrix_with(code.as_ref(), &*resolved.model, rng),
                        Some(g) => ws.select_submatrix_with(g, &*resolved.model, rng),
                    };
                    algorithmic_error_curve(a, StepSize::SpectralNormSq, t_max, rng)
                });
            out.push(FigPartialPoint {
                figure: "fig5",
                scheme: "BGC".to_string(),
                s,
                delta,
                k,
                partial,
            });
        }
    }
    out
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    OneStep,
    Optimal,
}

impl ErrorKind {
    pub fn label(&self) -> &'static str {
        match self {
            ErrorKind::OneStep => "one-step",
            ErrorKind::Optimal => "optimal",
        }
    }
}

/// The shared sweep engine behind Figures 2-4, running on the fused
/// straggler→decode pipeline: each worker thread owns one
/// [`DecodeWorkspace`], every trial re-draws G *into the workspace*
/// (`assignment_into` — no allocation even for randomized schemes),
/// selects stragglers through the scenario spine, and decodes without
/// materializing A (one-step) or allocating solver state (optimal).
/// Under the default uniform scenario, per-trial RNG consumption
/// matches the historical hard-coded sampling, so seeded *trial
/// values* are unchanged; the final mean is the correctly-rounded
/// exact sum (see [`super::shard::ExactSum`]). Adversarial scenarios
/// run in the standing-assignment setting — G drawn once per point
/// (seeded by the job), the attack planned against it — which makes
/// every trial deterministic, so the point collapses to one exact
/// decode instead of `trials` identical solves. Re-draw points run in
/// [`crate::decode::PanelWorkspace`] panels of `mc.panel_width` lanes
/// through [`scalar_partial_panel_under`] — the RNG-fork-per-lane
/// lockstep keeps every published CSV byte-identical to the scalar
/// path at any width. Runs only the `shard` slice of each point's
/// trials and returns exact partials.
fn error_sweep_partials(
    cfg: &FigureConfig,
    figure: &'static str,
    schemes: &[Scheme],
    kind: ErrorKind,
    scenario: &Scenario,
    shard: Shard,
) -> Vec<FigPartialPoint> {
    let opts = LsqrOptions::default();
    let mut out = Vec::new();
    for &scheme in schemes {
        for &s in &cfg.s_values {
            for &delta in &cfg.deltas {
                let r = cfg.r(delta);
                let k = cfg.k;
                let rho = k as f64 / (r as f64 * s as f64);
                let code = scheme.build(k, k, s);
                let resolved = scenario.resolve(code.as_ref(), delta, r, cfg.mc.seed);
                let panel_kind = match kind {
                    ErrorKind::OneStep => PanelKind::OneStep { rho },
                    ErrorKind::Optimal => PanelKind::Optimal { opts: &opts, warm: None },
                };
                let partial = scalar_partial_panel_under(
                    &resolved,
                    &cfg.mc,
                    shard,
                    code.as_ref(),
                    panel_kind,
                    |ws, g, model, rng| match kind {
                        ErrorKind::OneStep => ws.onestep_trial_with(g, model, rho, rng),
                        ErrorKind::Optimal => ws.optimal_trial_with(g, model, &opts, None, rng),
                    },
                );
                out.push(FigPartialPoint {
                    figure,
                    scheme: scheme.name().to_string(),
                    s,
                    delta,
                    k,
                    partial,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> FigureConfig {
        FigureConfig {
            k: 20,
            s_values: vec![5],
            deltas: vec![0.2, 0.5],
            mc: MonteCarlo::new(60, 42),
        }
    }

    #[test]
    fn figure2_has_expected_shape_and_ordering() {
        let cfg = tiny_cfg();
        let pts = figure2(&cfg);
        assert_eq!(pts.len(), 3 * 1 * 2); // schemes x s x deltas
        // Error grows with delta for every scheme.
        for scheme in ["FRC", "BGC", "s-regular"] {
            let vals: Vec<f64> = pts
                .iter()
                .filter(|p| p.scheme == scheme)
                .map(|p| p.value)
                .collect();
            assert!(vals[1] >= vals[0] * 0.8, "{scheme}: {vals:?}");
        }
    }

    #[test]
    fn figure3_frc_below_bgc() {
        // The paper's headline qualitative result (Fig. 3): FRC's optimal
        // decoding error is far below BGC's.
        let cfg = tiny_cfg();
        let pts = figure3(&cfg);
        let get = |scheme: &str, delta: f64| {
            pts.iter()
                .find(|p| p.scheme == scheme && (p.delta - delta).abs() < 1e-9)
                .unwrap()
                .value
        };
        assert!(get("FRC", 0.2) < get("BGC", 0.2));
        assert!(get("FRC", 0.5) < get("BGC", 0.5));
    }

    #[test]
    fn figure4_contains_both_decoders() {
        let cfg = tiny_cfg();
        let pts = figure4(&cfg);
        assert!(pts.iter().any(|p| p.scheme.ends_with("/one-step")));
        assert!(pts.iter().any(|p| p.scheme.ends_with("/optimal")));
        // one-step >= optimal pointwise (same sweep, same seeds).
        for p1 in pts.iter().filter(|p| p.scheme.ends_with("/one-step")) {
            let base = p1.scheme.trim_end_matches("/one-step");
            let p2 = pts
                .iter()
                .find(|p| {
                    p.scheme == format!("{base}/optimal")
                        && p.s == p1.s
                        && (p.delta - p1.delta).abs() < 1e-9
                })
                .unwrap();
            assert!(
                p1.value >= p2.value - 1e-9,
                "{}: one-step {} < optimal {}",
                p1.scheme,
                p1.value,
                p2.value
            );
        }
    }

    #[test]
    fn figure5_curves_decrease_in_t() {
        let cfg = FigureConfig {
            k: 20,
            s_values: vec![5],
            deltas: vec![],
            mc: MonteCarlo::new(30, 7),
        };
        let pts = figure5(&cfg, 6);
        // Group by delta and check monotone decrease.
        for &delta in &[0.1, 0.5, 0.8] {
            let mut vals: Vec<(usize, f64)> = pts
                .iter()
                .filter(|p| (p.delta - delta).abs() < 1e-9)
                .map(|p| (p.t, p.value))
                .collect();
            vals.sort_by_key(|&(t, _)| t);
            assert_eq!(vals[0].0, 0);
            assert!((vals[0].1 - 1.0).abs() < 1e-12, "u_0 = k -> value 1.0");
            for w in vals.windows(2) {
                assert!(w[1].1 <= w[0].1 + 1e-9, "delta {delta}: not monotone");
            }
        }
    }

    #[test]
    fn figure2_sharded_partials_merge_to_entry_point_bits() {
        let cfg = tiny_cfg();
        let scenario = Scenario::default();
        let whole = figure2(&cfg);
        let mut merged = figure2_partials(&cfg, &scenario, Shard::new(0, 3).unwrap());
        for sid in 1..3 {
            let part = figure2_partials(&cfg, &scenario, Shard::new(sid, 3).unwrap());
            for (a, b) in merged.iter_mut().zip(&part) {
                assert!(a.same_point(b));
                a.partial.merge(&b.partial).unwrap();
            }
        }
        let merged = finalize_fig_points(&merged);
        assert_eq!(merged.len(), whole.len());
        for (a, b) in merged.iter().zip(&whole) {
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "{}/{}", a.scheme, a.delta);
        }
    }

    #[test]
    fn latency_and_adversarial_scenarios_produce_finite_sweeps() {
        let cfg = tiny_cfg();
        let n_points = figure2(&cfg).len();
        for spec in ["pareto:0.05,1.5", "pareto:0.05,1.5,deadline:0.2", "adversarial:greedy"] {
            let scenario = Scenario::parse(spec).unwrap();
            let pts = figure2_partials(&cfg, &scenario, Shard::full());
            assert_eq!(pts.len(), n_points, "{spec}");
            let vals = finalize_fig_points(&pts);
            assert!(
                vals.iter().all(|p| p.value.is_finite() && p.value >= 0.0),
                "{spec}: {vals:?}"
            );
        }
        // Adversarial selection is at least as damaging as uniform on
        // the one-step objective, pointwise in expectation — sanity
        // check one point rather than assert a theorem.
        let uniform = finalize_fig_points(&figure2_partials(
            &cfg,
            &Scenario::default(),
            Shard::full(),
        ));
        let adv = finalize_fig_points(&figure2_partials(
            &cfg,
            &Scenario::parse("adversarial:greedy").unwrap(),
            Shard::full(),
        ));
        let mean_uniform: f64 = uniform.iter().map(|p| p.value).sum::<f64>();
        let mean_adv: f64 = adv.iter().map(|p| p.value).sum::<f64>();
        assert!(mean_adv >= 0.5 * mean_uniform, "adv {mean_adv} vs uniform {mean_uniform}");
    }

    #[test]
    fn csv_roundtrip_format() {
        let p = FigPoint {
            figure: "fig2",
            scheme: "FRC".into(),
            s: 5,
            delta: 0.25,
            t: 0,
            value: 0.125,
        };
        assert_eq!(p.to_csv(), "fig2,FRC,5,0.250,0,1.250000e-1");
    }
}
