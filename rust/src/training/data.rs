//! Synthetic datasets, partitioned into the paper's k task shards.
//!
//! The paper's f_i are per-sample (or per-shard) gradients of a training
//! loss (§2.2); we generate (a) a noisy linear-regression problem with a
//! known planted model w*, and (b) a teacher-MLP regression problem, and
//! split both into k equal shards — one per task.

use crate::runtime::{LinearDims, MlpDims};
use crate::util::Rng;

/// One task shard: x is (m, d)-row-major, y is (m * d_out) (d_out = 1
/// for the linear model).
#[derive(Clone, Debug, Default)]
pub struct Shard {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
}

/// Linear-regression dataset with planted w*.
#[derive(Clone, Debug)]
pub struct LinearDataset {
    pub dims: LinearDims,
    pub shards: Vec<Shard>,
    pub w_star: Vec<f32>,
    pub noise: f64,
}

impl LinearDataset {
    pub fn generate(dims: LinearDims, k: usize, noise: f64, rng: &mut Rng) -> Self {
        let w_star: Vec<f32> = (0..dims.d).map(|_| rng.normal() as f32).collect();
        let shards = (0..k)
            .map(|_| {
                let x: Vec<f32> =
                    (0..dims.m * dims.d).map(|_| rng.normal() as f32).collect();
                let y: Vec<f32> = (0..dims.m)
                    .map(|i| {
                        let row = &x[i * dims.d..(i + 1) * dims.d];
                        let clean: f32 =
                            row.iter().zip(&w_star).map(|(a, b)| a * b).sum();
                        clean + (rng.normal() * noise) as f32
                    })
                    .collect();
                Shard { x, y }
            })
            .collect();
        LinearDataset { dims, shards, w_star, noise }
    }

    /// Full-batch mean loss 0.5/m ||X w - y||^2 averaged over shards
    /// (exact, Rust-side; used for loss curves and tests).
    pub fn loss(&self, w: &[f32]) -> f64 {
        let (m, d) = (self.dims.m, self.dims.d);
        let mut total = 0.0f64;
        for shard in &self.shards {
            for i in 0..m {
                let row = &shard.x[i * d..(i + 1) * d];
                let pred: f32 = row.iter().zip(w).map(|(a, b)| a * b).sum();
                let r = (pred - shard.y[i]) as f64;
                total += 0.5 * r * r;
            }
        }
        total / (self.shards.len() * m) as f64
    }
}

/// Teacher-MLP regression dataset (targets from a random frozen MLP).
#[derive(Clone, Debug)]
pub struct MlpDataset {
    pub dims: MlpDims,
    pub shards: Vec<Shard>,
    pub teacher: Vec<f32>,
}

impl MlpDataset {
    pub fn generate(dims: MlpDims, k: usize, rng: &mut Rng) -> Self {
        let teacher: Vec<f32> =
            (0..dims.flat_dim).map(|_| (rng.normal() * 0.5) as f32).collect();
        let shards = (0..k)
            .map(|_| {
                let x: Vec<f32> =
                    (0..dims.m * dims.d_in).map(|_| rng.normal() as f32).collect();
                let y = teacher_forward(&teacher, &x, dims);
                Shard { x, y }
            })
            .collect();
        MlpDataset { dims, shards, teacher }
    }
}

/// Forward pass of the frozen teacher (same architecture as the model).
fn teacher_forward(theta: &[f32], x: &[f32], dims: MlpDims) -> Vec<f32> {
    let MlpDims { m, d_in, d_hidden, d_out, .. } = dims;
    let (w1, rest) = theta.split_at(d_in * d_hidden);
    let (b1, rest) = rest.split_at(d_hidden);
    let (w2, b2) = rest.split_at(d_hidden * d_out);
    let mut y = vec![0.0f32; m * d_out];
    for i in 0..m {
        let mut h = vec![0.0f32; d_hidden];
        for j in 0..d_hidden {
            let mut z = b1[j];
            for t in 0..d_in {
                z += x[i * d_in + t] * w1[t * d_hidden + j];
            }
            h[j] = z.tanh();
        }
        for j in 0..d_out {
            let mut o = b2[j];
            for t in 0..d_hidden {
                o += h[t] * w2[t * d_out + j];
            }
            y[i * d_out + j] = o;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    const LIN: LinearDims = LinearDims { m: 8, d: 4 };
    const MLP: MlpDims =
        MlpDims { m: 4, d_in: 3, d_hidden: 5, d_out: 2, flat_dim: 3 * 5 + 5 + 5 * 2 + 2 };

    #[test]
    fn linear_shapes_and_count() {
        let ds = LinearDataset::generate(LIN, 10, 0.1, &mut Rng::new(1));
        assert_eq!(ds.shards.len(), 10);
        for s in &ds.shards {
            assert_eq!(s.x.len(), 32);
            assert_eq!(s.y.len(), 8);
        }
    }

    #[test]
    fn linear_loss_minimized_at_w_star_when_noiseless() {
        let ds = LinearDataset::generate(LIN, 5, 0.0, &mut Rng::new(2));
        let at_star = ds.loss(&ds.w_star);
        assert!(at_star < 1e-10, "{at_star}");
        let zero = vec![0.0f32; LIN.d];
        assert!(ds.loss(&zero) > at_star + 0.1);
    }

    #[test]
    fn linear_noise_raises_floor() {
        let ds = LinearDataset::generate(LIN, 20, 0.5, &mut Rng::new(3));
        let at_star = ds.loss(&ds.w_star);
        // E[loss at w*] = 0.5 * noise^2 = 0.125.
        assert!((at_star - 0.125).abs() < 0.08, "{at_star}");
    }

    #[test]
    fn mlp_targets_come_from_teacher() {
        let ds = MlpDataset::generate(MLP, 3, &mut Rng::new(4));
        // Recomputing targets with the stored teacher matches exactly.
        for s in &ds.shards {
            assert_eq!(s.y, teacher_forward(&ds.teacher, &s.x, MLP));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = LinearDataset::generate(LIN, 4, 0.1, &mut Rng::new(9));
        let b = LinearDataset::generate(LIN, 4, 0.1, &mut Rng::new(9));
        assert_eq!(a.shards[2].x, b.shards[2].x);
    }
}
