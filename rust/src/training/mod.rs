//! Training layer: synthetic datasets and the end-to-end coded GD loop.

pub mod data;
pub mod driver;

pub use data::{LinearDataset, MlpDataset, Shard};
pub use driver::{train, TrainConfig, TrainOutcome};
