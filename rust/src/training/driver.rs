//! End-to-end coded training driver: the paper's full loop.
//!
//! Per step: broadcast params → every worker computes its coded message
//! (PJRT or native backend, parallel over OS threads) → latency model +
//! deadline pick the survivors → master decodes → gradient-descent
//! update with the decoded estimate of Σ_i ∇f_i. This is the system the
//! abstract promises: "fast and approximately accurate distributed
//! computation" under stragglers.

use std::time::Instant;

use anyhow::{anyhow, Result};

use super::data::{LinearDataset, MlpDataset, Shard};
use crate::codes::Scheme;
use crate::coordinator::{
    gather_and_decode, specs_from_assignment, worker::compute_message, worker::ModelKind,
    CoordinatorConfig, Message, RoundMetrics, TrainingHistory,
};
use crate::decode::DecodeWorkspace;
use crate::runtime::Backend;
use crate::util::{parallel::parallel_map, Rng};

/// Training hyper-parameters on top of the coordinator config.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub coordinator: CoordinatorConfig,
    pub model: ModelKind,
    pub steps: usize,
    pub lr: f64,
    /// Label noise for the linear dataset.
    pub noise: f64,
}

impl TrainConfig {
    pub fn new(scheme: Scheme, k: usize, s: usize, model: ModelKind) -> Self {
        TrainConfig {
            coordinator: CoordinatorConfig::new(scheme, k, s),
            model,
            steps: 100,
            lr: 0.5,
            noise: 0.05,
        }
    }
}

/// Outcome: per-round metrics + the final parameters.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub history: TrainingHistory,
    pub params: Vec<f32>,
}

/// Train the configured model with coded gradient aggregation.
pub fn train(backend: &Backend, cfg: &TrainConfig) -> Result<TrainOutcome> {
    let k = cfg.coordinator.k;
    let mut rng = Rng::new(cfg.coordinator.seed);

    // Data + code are fixed for the run (the paper's standing assignment).
    let (shards, mut params, linear_ds): (Vec<Shard>, Vec<f32>, Option<LinearDataset>) =
        match cfg.model {
            ModelKind::Linear => {
                let dims = backend.linear_dims();
                let ds = LinearDataset::generate(dims, k, cfg.noise, &mut rng);
                let params = vec![0.0f32; dims.d];
                (ds.shards.clone(), params, Some(ds))
            }
            ModelKind::Mlp => {
                let dims = backend.mlp_dims();
                let ds = MlpDataset::generate(dims, k, &mut rng);
                let params: Vec<f32> =
                    (0..dims.flat_dim).map(|_| (rng.normal() * 0.1) as f32).collect();
                (ds.shards, params, None)
            }
        };

    let code = cfg.coordinator.scheme.build(k, k, cfg.coordinator.s);
    let g = code.assignment(&mut rng);
    let specs = specs_from_assignment(&g);

    // One decode workspace for the whole run: every round's straggler
    // draw, survivor submatrix, and decode solve reuse these buffers.
    let mut decode_ws = DecodeWorkspace::new();
    let mut history = TrainingHistory::default();
    for step in 0..cfg.steps {
        let t0 = Instant::now();

        // Worker phase (parallel; each closure submits to the engine pool).
        let results: Vec<Option<Message>> =
            parallel_map(specs.len(), cfg.coordinator.threads, |j| {
                compute_message(backend, cfg.model, &params, &shards, &specs[j]).ok()
            });
        let messages: Vec<Message> = results
            .into_iter()
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("worker compute failed at step {step}"))?;

        // Master phase.
        let round = gather_and_decode(
            &g,
            cfg.coordinator.s,
            &messages,
            cfg.coordinator.decoder,
            &cfg.coordinator.latency,
            &cfg.coordinator.deadline,
            &mut rng,
            &mut decode_ws,
        )?;

        // SGD update: estimate ≈ Σ_i ∇f_i, so the mean gradient is /k.
        let scale = (cfg.lr / k as f64) as f32;
        for (p, e) in params.iter_mut().zip(&round.estimate) {
            *p -= scale * e;
        }

        let loss = match (&linear_ds, cfg.model) {
            (Some(ds), ModelKind::Linear) => ds.loss(&params),
            _ => round.mean_loss,
        };
        history.push(RoundMetrics {
            round: step,
            loss,
            decode_err: round.decode_err,
            survivors: round.non_stragglers.len(),
            gather_time: round.gather_time,
            wall_time: t0.elapsed().as_secs_f64(),
        });
    }

    Ok(TrainOutcome { history, params })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::DecoderKind;
    use crate::runtime::{LinearDims, MlpDims};
    use crate::stragglers::{DeadlinePolicy, LatencyModel};

    fn native_backend() -> Backend {
        Backend::Native {
            linear: LinearDims { m: 8, d: 6 },
            mlp: MlpDims { m: 4, d_in: 4, d_hidden: 6, d_out: 2, flat_dim: 4 * 6 + 6 + 6 * 2 + 2 },
            s_max: 5,
        }
    }

    fn quick_cfg(scheme: Scheme, model: ModelKind) -> TrainConfig {
        let mut cfg = TrainConfig::new(scheme, 20, 5, model);
        cfg.steps = 40;
        cfg.lr = 0.4;
        cfg.coordinator.deadline = DeadlinePolicy::FastestR(15);
        cfg.coordinator.latency = LatencyModel::ShiftedExp { base: 0.01, rate: 20.0 };
        cfg.coordinator.seed = 7;
        cfg
    }

    #[test]
    fn linear_training_converges_with_frc() {
        let b = native_backend();
        let cfg = quick_cfg(Scheme::Frc, ModelKind::Linear);
        let out = train(&b, &cfg).unwrap();
        let first = out.history.rounds.first().unwrap().loss;
        let last = out.history.final_loss();
        assert!(last < 0.25 * first, "loss {first} -> {last}");
        assert_eq!(out.history.rounds.len(), 40);
    }

    #[test]
    fn linear_training_converges_with_bgc_optimal_decode() {
        let b = native_backend();
        let mut cfg = quick_cfg(Scheme::Bgc, ModelKind::Linear);
        cfg.coordinator.decoder = DecoderKind::Optimal;
        let out = train(&b, &cfg).unwrap();
        assert!(
            out.history.final_loss() < 0.5 * out.history.rounds[0].loss,
            "{:?} -> {:?}",
            out.history.rounds[0].loss,
            out.history.final_loss()
        );
    }

    #[test]
    fn mlp_training_reduces_loss() {
        let b = native_backend();
        let mut cfg = quick_cfg(Scheme::Rbgc, ModelKind::Mlp);
        cfg.steps = 60;
        cfg.lr = 1.0;
        let out = train(&b, &cfg).unwrap();
        let first = out.history.rounds[0].loss;
        let last = out.history.final_loss();
        assert!(last < 0.8 * first, "mlp loss {first} -> {last}");
    }

    #[test]
    fn survivor_counts_match_policy() {
        let b = native_backend();
        let cfg = quick_cfg(Scheme::Frc, ModelKind::Linear);
        let out = train(&b, &cfg).unwrap();
        assert!(out.history.rounds.iter().all(|m| m.survivors == 15));
    }

    #[test]
    fn deterministic_given_seed() {
        let b = native_backend();
        let cfg = quick_cfg(Scheme::Bgc, ModelKind::Linear);
        let a = train(&b, &cfg).unwrap();
        let b2 = train(&b, &cfg).unwrap();
        assert_eq!(a.params, b2.params);
    }
}
