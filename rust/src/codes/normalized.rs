//! Coefficient-normalized code wrapper — weighted gradient codes.
//!
//! The paper's codes are boolean, but its framework (§2.2) allows any
//! coefficients in G's columns. Normalizing each column by its degree
//! (entries 1/deg instead of 1) makes every worker send the *average*
//! of its task gradients. Two findings the ablation documents:
//!
//! * optimal decoding is INVARIANT to column scaling (the span of A is
//!   unchanged) — normalization is free under Algorithm 2;
//! * one-step decoding does NOT improve for BGC: the error is dominated
//!   by row-coverage randomness (which tasks get hit), not by column-
//!   degree variance, so averaging the degrees away buys nothing.

use super::GradientCode;
use crate::linalg::CscMatrix;
use crate::util::Rng;

/// Wraps any code, rescaling each column to sum to 1.
pub struct NormalizedCode<C: GradientCode> {
    pub inner: C,
}

impl<C: GradientCode> NormalizedCode<C> {
    pub fn new(inner: C) -> Self {
        NormalizedCode { inner }
    }
}

impl<C: GradientCode> GradientCode for NormalizedCode<C> {
    fn k(&self) -> usize {
        self.inner.k()
    }
    fn n(&self) -> usize {
        self.inner.n()
    }
    fn s(&self) -> usize {
        self.inner.s()
    }
    fn name(&self) -> &'static str {
        "normalized"
    }

    fn assignment(&self, rng: &mut Rng) -> CscMatrix {
        normalize_columns(&self.inner.assignment(rng))
    }
}

/// Rescale every column of G so its entries sum to 1 (zero columns are
/// left untouched).
pub fn normalize_columns(g: &CscMatrix) -> CscMatrix {
    let cols = (0..g.cols)
        .map(|j| {
            let col: Vec<(usize, f64)> = g.col(j).collect();
            let total: f64 = col.iter().map(|&(_, v)| v).sum();
            if total == 0.0 {
                col
            } else {
                col.into_iter().map(|(i, v)| (i, v / total)).collect()
            }
        })
        .collect();
    CscMatrix::from_columns(g.rows, cols)
}

/// The matching one-step ρ for a normalized code: each surviving column
/// contributes mass 1 spread over its tasks, so the expected row sum is
/// r/k and exact reconstruction needs ρ = k/r.
pub fn normalized_rho(k: usize, r: usize) -> f64 {
    k as f64 / r as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{BernoulliCode, FractionalRepetitionCode};
    use crate::decode::OneStepDecoder;

    #[test]
    fn columns_sum_to_one() {
        let g = BernoulliCode::new(30, 30, 5).assignment(&mut Rng::new(1));
        let gn = normalize_columns(&g);
        for j in 0..gn.cols {
            let total: f64 = gn.col(j).map(|(_, v)| v).sum();
            if gn.col_nnz(j) > 0 {
                assert!((total - 1.0).abs() < 1e-12, "col {j} sums to {total}");
            }
        }
    }

    #[test]
    fn normalized_frc_is_exact_with_full_response() {
        let code = NormalizedCode::new(FractionalRepetitionCode::new(12, 12, 3));
        let g = code.assignment(&mut Rng::new(2));
        // All workers respond: rho = k/r = 1.
        let err = OneStepDecoder::new(normalized_rho(12, 12)).err1(&g);
        assert!(err < 1e-12, "{err}");
    }

    #[test]
    fn normalization_preserves_optimal_error() {
        // Column scaling never changes span(A): err(A) is invariant.
        use crate::decode::OptimalDecoder;
        let (k, s, r) = (30usize, 5usize, 20usize);
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let g = BernoulliCode::new(k, k, s).assignment(&mut rng);
            let a = g.select_columns(&rng.sample_indices(k, r));
            let raw = OptimalDecoder::new().err(&a);
            let norm = OptimalDecoder::new().err(&normalize_columns(&a));
            assert!((raw - norm).abs() < 1e-6 * (1.0 + raw), "{raw} vs {norm}");
        }
    }

    #[test]
    fn normalization_does_not_help_bgc_onestep() {
        // The documented negative result: coverage noise dominates, so
        // normalized one-step error stays within the same regime as
        // boolean (and empirically slightly above it).
        let (k, s, r) = (60usize, 6usize, 45usize);
        let mut rng = Rng::new(3);
        let mut raw_total = 0.0;
        let mut norm_total = 0.0;
        for _ in 0..40 {
            let g = BernoulliCode::new(k, k, s).assignment(&mut rng);
            let idx = rng.sample_indices(k, r);
            let a = g.select_columns(&idx);
            raw_total += OneStepDecoder::canonical(k, r, s).err1(&a);
            let an = normalize_columns(&a);
            norm_total += OneStepDecoder::new(normalized_rho(k, r)).err1(&an);
        }
        let ratio = norm_total / raw_total;
        assert!(
            (0.8..2.5).contains(&ratio),
            "normalized/boolean ratio {ratio} left the expected regime"
        );
    }

    #[test]
    fn zero_columns_survive_normalization() {
        let g = CscMatrix::from_supports(4, vec![vec![0, 1], vec![]]);
        let gn = normalize_columns(&g);
        assert_eq!(gn.col_nnz(1), 0);
        assert_eq!(gn.cols, 2);
    }
}
