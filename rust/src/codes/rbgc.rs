//! Regularized Bernoulli Gradient Code (paper §5.3, Algorithm 3).
//!
//! Start from BGC; any column with more than 2s entries is thinned by
//! removing random edges until it has exactly s. This caps per-worker
//! load at 2s and — per Le-Levina-Vershynin regularization (Thm 22) —
//! restores spectral concentration for s < log k, giving the Thm 24
//! bound err_1(A') <= C^2 α^3 k / ((1-δ) s) for ALL s >= 1.

use super::{AssignmentScratch, GradientCode};
use crate::linalg::CscMatrix;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct RegularizedBernoulliCode {
    k: usize,
    n: usize,
    s: usize,
}

impl RegularizedBernoulliCode {
    pub fn new(k: usize, n: usize, s: usize) -> Self {
        assert!(k >= 1 && n >= 1);
        assert!(s >= 1 && s <= k, "need 1 <= s <= k");
        RegularizedBernoulliCode { k, n, s }
    }
}

impl GradientCode for RegularizedBernoulliCode {
    fn k(&self) -> usize {
        self.k
    }
    fn n(&self) -> usize {
        self.n
    }
    fn s(&self) -> usize {
        self.s
    }
    fn name(&self) -> &'static str {
        "rBGC"
    }

    /// Algorithm 3: Bernoulli(s/k) entries, then for every column with
    /// degree d > 2s remove random edges until d == s.
    fn assignment(&self, rng: &mut Rng) -> CscMatrix {
        let p = self.s as f64 / self.k as f64;
        let supports = (0..self.n)
            .map(|_| {
                let mut col: Vec<usize> = (0..self.k).filter(|_| rng.bernoulli(p)).collect();
                if col.len() > 2 * self.s {
                    // Remove random edges until degree s (paper's loop
                    // runs `while d > s`, i.e. thins all the way to s).
                    while col.len() > self.s {
                        let idx = rng.usize(col.len());
                        col.swap_remove(idx);
                    }
                    col.sort_unstable();
                }
                col
            })
            .collect();
        CscMatrix::from_supports(self.k, supports)
    }

    /// Allocation-free re-draw: each column is built in `scratch.col`
    /// (reserved to k once, the max possible degree), thinned with the
    /// identical swap-remove walk, sorted in place, and appended to the
    /// reused CSC buffers. Same RNG stream and layout as `assignment`.
    fn assignment_into(&self, rng: &mut Rng, out: &mut CscMatrix, scratch: &mut AssignmentScratch) {
        let p = self.s as f64 / self.k as f64;
        out.rows = self.k;
        out.cols = self.n;
        out.col_ptr.clear();
        out.row_idx.clear();
        out.vals.clear();
        out.col_ptr.push(0);
        let col = &mut scratch.col;
        col.reserve(self.k);
        for _ in 0..self.n {
            col.clear();
            col.extend((0..self.k).filter(|_| rng.bernoulli(p)));
            if col.len() > 2 * self.s {
                while col.len() > self.s {
                    let idx = rng.usize(col.len());
                    col.swap_remove(idx);
                }
                col.sort_unstable();
            }
            for &i in col.iter() {
                out.row_idx.push(i);
                out.vals.push(1.0);
            }
            out.col_ptr.push(out.row_idx.len());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::GradientCode;

    #[test]
    fn max_degree_is_at_most_2s() {
        // With s=2, k=20 collisions are common enough to exercise the
        // thinning branch over many draws.
        let code = RegularizedBernoulliCode::new(20, 20, 2);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let g = code.assignment(&mut rng);
            for j in 0..g.cols {
                assert!(g.col_nnz(j) <= 4, "col degree {} > 2s", g.col_nnz(j));
            }
        }
    }

    #[test]
    fn thinned_columns_have_exactly_s() {
        // Force thinning: s=1, k=4 -> p=0.25, degree>2 happens often.
        let code = RegularizedBernoulliCode::new(4, 50, 1);
        let mut rng = Rng::new(2);
        let mut saw_thinned = false;
        for _ in 0..100 {
            let g = code.assignment(&mut rng);
            for j in 0..g.cols {
                let d = g.col_nnz(j);
                assert!(d <= 2, "col degree {d} > 2s=2");
                if d == 1 {
                    saw_thinned = true;
                }
            }
        }
        assert!(saw_thinned);
    }

    #[test]
    fn untouched_columns_match_bernoulli_distribution() {
        // Mean degree should stay ~s (slightly below due to thinning).
        let code = RegularizedBernoulliCode::new(100, 100, 5);
        let mut rng = Rng::new(3);
        let mut total = 0usize;
        for _ in 0..30 {
            total += code.assignment(&mut rng).nnz();
        }
        let mean_deg = total as f64 / (30.0 * 100.0);
        assert!((mean_deg - 5.0).abs() < 0.5, "mean degree {mean_deg}");
    }

    #[test]
    fn supports_are_sorted_and_distinct() {
        let code = RegularizedBernoulliCode::new(10, 30, 1);
        let mut rng = Rng::new(4);
        let g = code.assignment(&mut rng);
        for j in 0..g.cols {
            let sup = g.col_support(j);
            assert!(sup.windows(2).all(|w| w[0] < w[1]), "col {j} not strictly sorted");
        }
    }
}
