//! Regularized Bernoulli Gradient Code (paper §5.3, Algorithm 3).
//!
//! Start from BGC; any column with more than 2s entries is thinned by
//! removing random edges until it has exactly s. This caps per-worker
//! load at 2s and — per Le-Levina-Vershynin regularization (Thm 22) —
//! restores spectral concentration for s < log k, giving the Thm 24
//! bound err_1(A') <= C^2 α^3 k / ((1-δ) s) for ALL s >= 1.
//!
//! The generalized family lives here too: [`ThresholdedBernoulliCode`]
//! thins columns above trigger·s down to target·s for arbitrary
//! (trigger, target) — the `rbgc` ablation study's knob — and
//! [`RegularizedBernoulliCode`] is exactly its (2, 1) instance, so the
//! Bernoulli-draw + swap-remove walk exists in one place and the two
//! cannot drift out of RNG lockstep.

use super::{AssignmentScratch, GradientCode};
use crate::linalg::CscMatrix;
use crate::util::Rng;

/// BGC with arbitrary (trigger, target) regularization thresholds:
/// Bernoulli(s/k) columns, and any column above trigger·s entries is
/// thinned down to target·s. (`trigger = 2, target = 1` is the paper's
/// Algorithm 3, i.e. [`RegularizedBernoulliCode`].)
///
/// The `_into` path builds each column in `scratch.col` and is
/// allocation-free at steady state (`tests/zero_alloc.rs`); both paths
/// consume the identical RNG stream (pinned by a test below), so the
/// seeded ablation sweeps are stable across the two.
#[derive(Clone, Debug)]
pub struct ThresholdedBernoulliCode {
    k: usize,
    n: usize,
    s: usize,
    trigger: f64,
    target: f64,
}

impl ThresholdedBernoulliCode {
    pub fn new(k: usize, n: usize, s: usize, trigger: f64, target: f64) -> Self {
        assert!(k >= 1 && n >= 1);
        assert!(s >= 1 && s <= k, "need 1 <= s <= k");
        assert!(trigger > 0.0 && target > 0.0, "thresholds must be positive");
        ThresholdedBernoulliCode { k, n, s, trigger, target }
    }

    /// (trigger·s, max(target·s, 1)) rounded to column degrees.
    fn degree_thresholds(&self) -> (usize, usize) {
        let trig = (self.trigger * self.s as f64).round() as usize;
        let targ = ((self.target * self.s as f64).round() as usize).max(1);
        (trig, targ)
    }
}

impl GradientCode for ThresholdedBernoulliCode {
    fn k(&self) -> usize {
        self.k
    }
    fn n(&self) -> usize {
        self.n
    }
    fn s(&self) -> usize {
        self.s
    }
    fn name(&self) -> &'static str {
        "thresholded-BGC"
    }

    fn assignment(&self, rng: &mut Rng) -> CscMatrix {
        let p = self.s as f64 / self.k as f64;
        let (trig, targ) = self.degree_thresholds();
        let supports = (0..self.n)
            .map(|_| {
                let mut col: Vec<usize> = (0..self.k).filter(|_| rng.bernoulli(p)).collect();
                if col.len() > trig {
                    // Remove random edges until the target degree (the
                    // paper's loop runs `while d > s`, i.e. thins all
                    // the way down, generalized to target·s here).
                    while col.len() > targ {
                        let idx = rng.usize(col.len());
                        col.swap_remove(idx);
                    }
                    col.sort_unstable();
                }
                col
            })
            .collect();
        CscMatrix::from_supports(self.k, supports)
    }

    /// Allocation-free re-draw: each column is built in `scratch.col`
    /// (reserved to k once, the max possible degree), thinned with the
    /// identical swap-remove walk, sorted in place, and appended to the
    /// reused CSC buffers. Same RNG stream and layout as `assignment`.
    fn assignment_into(&self, rng: &mut Rng, out: &mut CscMatrix, scratch: &mut AssignmentScratch) {
        let p = self.s as f64 / self.k as f64;
        let (trig, targ) = self.degree_thresholds();
        out.rows = self.k;
        out.cols = self.n;
        out.col_ptr.clear();
        out.row_idx.clear();
        out.vals.clear();
        out.col_ptr.push(0);
        let col = &mut scratch.col;
        col.reserve(self.k);
        for _ in 0..self.n {
            col.clear();
            col.extend((0..self.k).filter(|_| rng.bernoulli(p)));
            if col.len() > trig {
                while col.len() > targ {
                    let idx = rng.usize(col.len());
                    col.swap_remove(idx);
                }
                col.sort_unstable();
            }
            for &i in col.iter() {
                out.row_idx.push(i);
                out.vals.push(1.0);
            }
            out.col_ptr.push(out.row_idx.len());
        }
    }
}

#[derive(Clone, Debug)]
pub struct RegularizedBernoulliCode {
    inner: ThresholdedBernoulliCode,
}

impl RegularizedBernoulliCode {
    pub fn new(k: usize, n: usize, s: usize) -> Self {
        // Algorithm 3 == trigger 2, target 1: thin any column above 2s
        // down to exactly s. (trig = 2s and targ = s exactly — small
        // integers are exact in f64, so the generalized thresholds
        // reproduce the historical `> 2*s` / `> s` comparisons.)
        RegularizedBernoulliCode { inner: ThresholdedBernoulliCode::new(k, n, s, 2.0, 1.0) }
    }
}

impl GradientCode for RegularizedBernoulliCode {
    fn k(&self) -> usize {
        self.inner.k()
    }
    fn n(&self) -> usize {
        self.inner.n()
    }
    fn s(&self) -> usize {
        self.inner.s()
    }
    fn name(&self) -> &'static str {
        "rBGC"
    }

    /// Algorithm 3: Bernoulli(s/k) entries, then for every column with
    /// degree d > 2s remove random edges until d == s. Delegates to the
    /// (2, 1) [`ThresholdedBernoulliCode`] — one copy of the draw.
    fn assignment(&self, rng: &mut Rng) -> CscMatrix {
        self.inner.assignment(rng)
    }

    fn assignment_into(&self, rng: &mut Rng, out: &mut CscMatrix, scratch: &mut AssignmentScratch) {
        self.inner.assignment_into(rng, out, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::GradientCode;

    #[test]
    fn max_degree_is_at_most_2s() {
        // With s=2, k=20 collisions are common enough to exercise the
        // thinning branch over many draws.
        let code = RegularizedBernoulliCode::new(20, 20, 2);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let g = code.assignment(&mut rng);
            for j in 0..g.cols {
                assert!(g.col_nnz(j) <= 4, "col degree {} > 2s", g.col_nnz(j));
            }
        }
    }

    #[test]
    fn thinned_columns_have_exactly_s() {
        // Force thinning: s=1, k=4 -> p=0.25, degree>2 happens often.
        let code = RegularizedBernoulliCode::new(4, 50, 1);
        let mut rng = Rng::new(2);
        let mut saw_thinned = false;
        for _ in 0..100 {
            let g = code.assignment(&mut rng);
            for j in 0..g.cols {
                let d = g.col_nnz(j);
                assert!(d <= 2, "col degree {d} > 2s=2");
                if d == 1 {
                    saw_thinned = true;
                }
            }
        }
        assert!(saw_thinned);
    }

    #[test]
    fn untouched_columns_match_bernoulli_distribution() {
        // Mean degree should stay ~s (slightly below due to thinning).
        let code = RegularizedBernoulliCode::new(100, 100, 5);
        let mut rng = Rng::new(3);
        let mut total = 0usize;
        for _ in 0..30 {
            total += code.assignment(&mut rng).nnz();
        }
        let mean_deg = total as f64 / (30.0 * 100.0);
        assert!((mean_deg - 5.0).abs() < 0.5, "mean degree {mean_deg}");
    }

    #[test]
    fn supports_are_sorted_and_distinct() {
        let code = RegularizedBernoulliCode::new(10, 30, 1);
        let mut rng = Rng::new(4);
        let g = code.assignment(&mut rng);
        for j in 0..g.cols {
            let sup = g.col_support(j);
            assert!(sup.windows(2).all(|w| w[0] < w[1]), "col {j} not strictly sorted");
        }
    }

    #[test]
    fn rbgc_is_the_2_1_thresholded_instance() {
        // The delegation invariant: same seed, same draws, same bits —
        // Algorithm 3 is exactly (trigger 2, target 1).
        let rbgc = RegularizedBernoulliCode::new(20, 20, 3);
        let thresh = ThresholdedBernoulliCode::new(20, 20, 3, 2.0, 1.0);
        let mut ra = Rng::new(5);
        let mut rb = Rng::new(5);
        for draw in 0..15 {
            assert_eq!(rbgc.assignment(&mut ra), thresh.assignment(&mut rb), "draw {draw}");
        }
        assert_eq!(ra.next_u64(), rb.next_u64(), "rng diverged");
    }

    #[test]
    fn thresholded_assignment_into_matches_assignment() {
        let code = ThresholdedBernoulliCode::new(18, 18, 3, 1.5, 1.0);
        let mut out = CscMatrix::empty();
        let mut scratch = AssignmentScratch::new();
        let mut ra = Rng::new(7);
        let mut rb = Rng::new(7);
        for draw in 0..20 {
            let reference = code.assignment(&mut ra);
            code.assignment_into(&mut rb, &mut out, &mut scratch);
            assert_eq!(out, reference, "draw {draw}");
        }
        assert_eq!(ra.next_u64(), rb.next_u64(), "rng diverged");
    }
}
