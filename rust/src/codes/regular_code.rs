//! s-regular graph code (the expander baseline of Raviv et al. [20],
//! paper §6): G is the adjacency matrix of a random s-regular graph on
//! k vertices. Random regular graphs are near-Ramanujan w.h.p. [15], so
//! this is the paper's practical stand-in for explicit Ramanujan
//! constructions (which are "notoriously tricky to compute").

use super::{AssignmentScratch, GradientCode};
use crate::graph::random_regular_graph;
use crate::graph::regular::{repair_matching_flat, try_configuration_flat, CONFIGURATION_ATTEMPTS};
use crate::linalg::CscMatrix;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct RegularGraphCode {
    k: usize,
    n: usize,
    s: usize,
}

impl RegularGraphCode {
    /// Requires n == k (G is a square adjacency matrix) and k*s even.
    pub fn new(k: usize, n: usize, s: usize) -> Self {
        assert_eq!(k, n, "regular-graph code requires n == k (adjacency matrix)");
        assert!(s >= 1 && s < k, "need 1 <= s < k");
        assert!(k * s % 2 == 0, "k*s must be even for an s-regular graph");
        RegularGraphCode { k, n, s }
    }
}

impl GradientCode for RegularGraphCode {
    fn k(&self) -> usize {
        self.k
    }
    fn n(&self) -> usize {
        self.n
    }
    fn s(&self) -> usize {
        self.s
    }
    fn name(&self) -> &'static str {
        "s-regular"
    }

    fn assignment(&self, rng: &mut Rng) -> CscMatrix {
        let g = random_regular_graph(self.k, self.s, rng);
        CscMatrix::from_supports(self.k, g.adj)
    }

    /// Re-draw with configuration-model attempts in `scratch`'s flat
    /// buffers (identical RNG stream and accept/reject walk as
    /// `random_regular_graph`), emitting the accepted adjacency
    /// column-by-column into the reused CSC buffers. A configuration is
    /// simple with probability ≈ exp(−(s²−1)/4), so for sparse degrees
    /// (s ≤ 3) an attempt all but always lands, while denser graphs
    /// fall through to the edge-swap repair — since the flat-buffer
    /// port of the incremental repair (`repair_matching_flat`), that
    /// fallback is RNG-identical to the reference path *and*
    /// allocation-free, so s ≥ 5 re-draws run with zero steady-state
    /// heap traffic too (pinned by `tests/zero_alloc.rs`).
    fn assignment_into(&self, rng: &mut Rng, out: &mut CscMatrix, scratch: &mut AssignmentScratch) {
        let (k, s) = (self.k, self.s);
        out.rows = k;
        out.cols = self.n;
        out.col_ptr.clear();
        out.row_idx.clear();
        out.vals.clear();
        out.col_ptr.push(0);
        let mut accepted = false;
        for _ in 0..CONFIGURATION_ATTEMPTS {
            if try_configuration_flat(
                k,
                s,
                rng,
                &mut scratch.stubs,
                &mut scratch.adj_flat,
                &mut scratch.deg,
            ) {
                accepted = true;
                break;
            }
        }
        if !accepted {
            repair_matching_flat(
                k,
                s,
                rng,
                &mut scratch.stubs,
                &mut scratch.edges,
                &mut scratch.adj_flat,
                &mut scratch.deg,
                &mut scratch.bad,
            );
        }
        // Either way the sorted neighbours of v are adj_flat[v*s..(v+1)*s].
        for v in 0..k {
            for &u in &scratch.adj_flat[v * s..(v + 1) * s] {
                out.row_idx.push(u);
                out.vals.push(1.0);
            }
            out.col_ptr.push(out.row_idx.len());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_is_s_regular_both_ways() {
        let code = RegularGraphCode::new(50, 50, 6);
        let g = code.assignment(&mut Rng::new(1));
        for j in 0..50 {
            assert_eq!(g.col_nnz(j), 6);
        }
        assert!(g.row_degrees().iter().all(|&d| d == 6));
    }

    #[test]
    fn assignment_is_symmetric() {
        let code = RegularGraphCode::new(30, 30, 4);
        let g = code.assignment(&mut Rng::new(2)).to_dense();
        for i in 0..30 {
            for j in 0..30 {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
            assert_eq!(g[(i, i)], 0.0, "self-loop at {i}");
        }
    }

    #[test]
    #[should_panic(expected = "even")]
    fn odd_ks_panics() {
        RegularGraphCode::new(25, 25, 5);
    }

    #[test]
    fn dense_degree_redraw_matches_assignment_through_repair() {
        // s = 6 at k = 20: P(simple configuration) ≈ exp(−35/4), so
        // essentially every draw exhausts the attempts and lands on the
        // repair fallback — the path that must stay RNG-identical now
        // that it runs in flat buffers.
        use crate::codes::AssignmentScratch;
        let code = RegularGraphCode::new(20, 20, 6);
        let mut out = CscMatrix::empty();
        let mut scratch = AssignmentScratch::new();
        let mut ra = Rng::new(5);
        let mut rb = Rng::new(5);
        for draw in 0..10 {
            let reference = code.assignment(&mut ra);
            code.assignment_into(&mut rb, &mut out, &mut scratch);
            assert_eq!(out, reference, "draw {draw}");
        }
        assert_eq!(ra.next_u64(), rb.next_u64(), "rng diverged");
    }
}
