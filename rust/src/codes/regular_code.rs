//! s-regular graph code (the expander baseline of Raviv et al. [20],
//! paper §6): G is the adjacency matrix of a random s-regular graph on
//! k vertices. Random regular graphs are near-Ramanujan w.h.p. [15], so
//! this is the paper's practical stand-in for explicit Ramanujan
//! constructions (which are "notoriously tricky to compute").

use super::GradientCode;
use crate::graph::random_regular_graph;
use crate::linalg::CscMatrix;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct RegularGraphCode {
    k: usize,
    n: usize,
    s: usize,
}

impl RegularGraphCode {
    /// Requires n == k (G is a square adjacency matrix) and k*s even.
    pub fn new(k: usize, n: usize, s: usize) -> Self {
        assert_eq!(k, n, "regular-graph code requires n == k (adjacency matrix)");
        assert!(s >= 1 && s < k, "need 1 <= s < k");
        assert!(k * s % 2 == 0, "k*s must be even for an s-regular graph");
        RegularGraphCode { k, n, s }
    }
}

impl GradientCode for RegularGraphCode {
    fn k(&self) -> usize {
        self.k
    }
    fn n(&self) -> usize {
        self.n
    }
    fn s(&self) -> usize {
        self.s
    }
    fn name(&self) -> &'static str {
        "s-regular"
    }

    fn assignment(&self, rng: &mut Rng) -> CscMatrix {
        let g = random_regular_graph(self.k, self.s, rng);
        CscMatrix::from_supports(self.k, g.adj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_is_s_regular_both_ways() {
        let code = RegularGraphCode::new(50, 50, 6);
        let g = code.assignment(&mut Rng::new(1));
        for j in 0..50 {
            assert_eq!(g.col_nnz(j), 6);
        }
        assert!(g.row_degrees().iter().all(|&d| d == 6));
    }

    #[test]
    fn assignment_is_symmetric() {
        let code = RegularGraphCode::new(30, 30, 4);
        let g = code.assignment(&mut Rng::new(2)).to_dense();
        for i in 0..30 {
            for j in 0..30 {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
            assert_eq!(g[(i, i)], 0.0, "self-loop at {i}");
        }
    }

    #[test]
    #[should_panic(expected = "even")]
    fn odd_ks_panics() {
        RegularGraphCode::new(25, 25, 5);
    }
}
