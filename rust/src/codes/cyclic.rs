//! Cyclic repetition code — an extra baseline from the exact-recovery
//! literature (Tandon et al. [23] build their cyclic MDS codes on this
//! support pattern). Column j covers tasks {j, j+1, ..., j+s-1} mod k
//! with unit coefficients. Under approximate decoding it behaves like a
//! deterministic, maximally-spread boolean code: useful as a
//! non-random, non-blocked contrast to FRC/BGC in ablations.

use super::{AssignmentScratch, GradientCode};
use crate::linalg::CscMatrix;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct CyclicRepetitionCode {
    k: usize,
    n: usize,
    s: usize,
}

impl CyclicRepetitionCode {
    pub fn new(k: usize, n: usize, s: usize) -> Self {
        assert!(k >= 1 && n >= 1);
        assert!(s >= 1 && s <= k, "need 1 <= s <= k");
        CyclicRepetitionCode { k, n, s }
    }
}

impl GradientCode for CyclicRepetitionCode {
    fn k(&self) -> usize {
        self.k
    }
    fn n(&self) -> usize {
        self.n
    }
    fn s(&self) -> usize {
        self.s
    }
    fn name(&self) -> &'static str {
        "cyclic"
    }

    fn assignment(&self, _rng: &mut Rng) -> CscMatrix {
        let supports = (0..self.n)
            .map(|j| (0..self.s).map(|t| (j + t) % self.k).collect())
            .collect();
        CscMatrix::from_supports(self.k, supports)
    }

    /// Allocation-free re-draw (deterministic): each cyclic window is
    /// staged in `scratch.col`, sorted — `from_supports` sorts wrapped
    /// windows the same way — and appended to the reused buffers.
    fn assignment_into(&self, _rng: &mut Rng, out: &mut CscMatrix, scratch: &mut AssignmentScratch) {
        out.rows = self.k;
        out.cols = self.n;
        out.col_ptr.clear();
        out.row_idx.clear();
        out.vals.clear();
        out.col_ptr.push(0);
        let col = &mut scratch.col;
        col.reserve(self.s);
        for j in 0..self.n {
            col.clear();
            col.extend((0..self.s).map(|t| (j + t) % self.k));
            col.sort_unstable();
            for &i in col.iter() {
                out.row_idx.push(i);
                out.vals.push(1.0);
            }
            out.col_ptr.push(out.row_idx.len());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_support_is_cyclic_window() {
        let code = CyclicRepetitionCode::new(10, 10, 3);
        let g = code.assignment(&mut Rng::new(0));
        assert_eq!(g.col_support(0), &[0, 1, 2]);
        assert_eq!(g.col_support(8), &[0, 8, 9]); // wraps, sorted
    }

    #[test]
    fn balanced_row_degrees_when_n_equals_k() {
        let code = CyclicRepetitionCode::new(12, 12, 4);
        let g = code.assignment(&mut Rng::new(0));
        assert!(g.row_degrees().iter().all(|&d| d == 4));
    }

    #[test]
    fn deterministic() {
        let code = CyclicRepetitionCode::new(9, 9, 2);
        let a = code.assignment(&mut Rng::new(1));
        let b = code.assignment(&mut Rng::new(99));
        assert_eq!(a, b);
    }
}
