//! Bernoulli Gradient Code (paper §5).
//!
//! G_ij ~ Bernoulli(s/k) iid. Each worker computes s tasks in
//! expectation; the randomness is the defence against polynomial-time
//! adversaries (Thm 11: adversarial straggler selection is NP-hard in
//! general), at the cost of a worse average-case error than FRC
//! (Thm 21: err_1(A) <= C^2 k / ((1-δ) s) w.h.p. for s >= log k).

use super::{AssignmentScratch, GradientCode};
use crate::linalg::CscMatrix;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct BernoulliCode {
    k: usize,
    n: usize,
    s: usize,
}

impl BernoulliCode {
    pub fn new(k: usize, n: usize, s: usize) -> Self {
        assert!(k >= 1 && n >= 1);
        assert!(s >= 1 && s <= k, "need 1 <= s <= k");
        BernoulliCode { k, n, s }
    }

    pub fn p(&self) -> f64 {
        self.s as f64 / self.k as f64
    }
}

impl GradientCode for BernoulliCode {
    fn k(&self) -> usize {
        self.k
    }
    fn n(&self) -> usize {
        self.n
    }
    fn s(&self) -> usize {
        self.s
    }
    fn name(&self) -> &'static str {
        "BGC"
    }

    fn assignment(&self, rng: &mut Rng) -> CscMatrix {
        let p = self.p();
        let supports = (0..self.n)
            .map(|_| (0..self.k).filter(|_| rng.bernoulli(p)).collect())
            .collect();
        CscMatrix::from_supports(self.k, supports)
    }

    /// Allocation-free re-draw: Bernoulli entries stream straight into
    /// the reused CSC buffers (column-major, rows ascending — the same
    /// draw order and layout as `assignment`).
    fn assignment_into(&self, rng: &mut Rng, out: &mut CscMatrix, _scratch: &mut AssignmentScratch) {
        let p = self.p();
        out.rows = self.k;
        out.cols = self.n;
        out.col_ptr.clear();
        out.row_idx.clear();
        out.vals.clear();
        out.col_ptr.push(0);
        for _ in 0..self.n {
            for i in 0..self.k {
                if rng.bernoulli(p) {
                    out.row_idx.push(i);
                    out.vals.push(1.0);
                }
            }
            out.col_ptr.push(out.row_idx.len());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_density_close_to_s_over_k() {
        let code = BernoulliCode::new(100, 100, 10);
        let mut rng = Rng::new(7);
        let mut total = 0usize;
        let draws = 50;
        for _ in 0..draws {
            total += code.assignment(&mut rng).nnz();
        }
        let mean_nnz = total as f64 / draws as f64;
        // E[nnz] = k * n * s/k = n * s = 1000.
        assert!((mean_nnz - 1000.0).abs() < 60.0, "mean nnz {mean_nnz}");
    }

    #[test]
    fn boolean_and_dims() {
        let code = BernoulliCode::new(50, 40, 5);
        let g = code.assignment(&mut Rng::new(1));
        assert_eq!((g.rows, g.cols), (50, 40));
        assert!(g.is_boolean());
    }

    #[test]
    fn different_draws_differ() {
        let code = BernoulliCode::new(50, 50, 5);
        let mut rng = Rng::new(2);
        let a = code.assignment(&mut rng);
        let b = code.assignment(&mut rng);
        assert_ne!(a, b);
    }

    #[test]
    fn s_equals_k_gives_full_matrix() {
        let code = BernoulliCode::new(10, 5, 10);
        let g = code.assignment(&mut Rng::new(3));
        assert_eq!(g.nnz(), 50); // p = 1
    }
}
