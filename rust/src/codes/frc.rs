//! Fractional Repetition Code (paper §3, construction from Tandon et
//! al. [23]).
//!
//! G_frac is block-diagonal with k/s all-ones s x s blocks: the k tasks
//! are split into k/s groups of s, and each group is replicated on s
//! workers. Any surviving worker of a group recovers that group's s
//! tasks exactly, which is why FRC's optimal decoding error is αs where
//! α = number of groups whose workers all straggled (Thm 6-8) — and why
//! an adversary that kills whole groups forces err = k - r (Thm 10).

use super::{AssignmentScratch, GradientCode};
use crate::linalg::CscMatrix;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct FractionalRepetitionCode {
    k: usize,
    n: usize,
    s: usize,
}

impl FractionalRepetitionCode {
    /// Requires n == k (the paper's setting) and s | k.
    pub fn new(k: usize, n: usize, s: usize) -> Self {
        assert_eq!(k, n, "FRC requires n == k (paper §3)");
        assert!(s >= 1 && s <= k, "need 1 <= s <= k");
        assert_eq!(k % s, 0, "FRC requires s | k (paper assumes s divides k)");
        FractionalRepetitionCode { k, n, s }
    }

    /// The block (task-group) index of worker/column j.
    pub fn block_of_column(&self, j: usize) -> usize {
        j / self.s
    }

    /// The s task indices of block b.
    pub fn block_tasks(&self, b: usize) -> std::ops::Range<usize> {
        b * self.s..(b + 1) * self.s
    }

    pub fn num_blocks(&self) -> usize {
        self.k / self.s
    }
}

impl GradientCode for FractionalRepetitionCode {
    fn k(&self) -> usize {
        self.k
    }
    fn n(&self) -> usize {
        self.n
    }
    fn s(&self) -> usize {
        self.s
    }
    fn name(&self) -> &'static str {
        "FRC"
    }

    fn assignment(&self, _rng: &mut Rng) -> CscMatrix {
        let supports = (0..self.n)
            .map(|j| self.block_tasks(self.block_of_column(j)).collect())
            .collect();
        CscMatrix::from_supports(self.k, supports)
    }

    /// Allocation-free re-draw (deterministic: no RNG, fixed nnz = n·s,
    /// so the buffers reach steady state after one call).
    fn assignment_into(&self, _rng: &mut Rng, out: &mut CscMatrix, _scratch: &mut AssignmentScratch) {
        out.rows = self.k;
        out.cols = self.n;
        out.col_ptr.clear();
        out.row_idx.clear();
        out.vals.clear();
        out.col_ptr.push(0);
        for j in 0..self.n {
            for i in self.block_tasks(self.block_of_column(j)) {
                out.row_idx.push(i);
                out.vals.push(1.0);
            }
            out.col_ptr.push(out.row_idx.len());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_diagonal_structure() {
        let code = FractionalRepetitionCode::new(6, 6, 2);
        let g = code.assignment(&mut Rng::new(0)).to_dense();
        for i in 0..6 {
            for j in 0..6 {
                let expect = if i / 2 == j / 2 { 1.0 } else { 0.0 };
                assert_eq!(g[(i, j)], expect, "({i},{j})");
            }
        }
    }

    #[test]
    fn every_column_has_s_entries() {
        let code = FractionalRepetitionCode::new(100, 100, 10);
        let g = code.assignment(&mut Rng::new(0));
        for j in 0..100 {
            assert_eq!(g.col_nnz(j), 10);
        }
        assert_eq!(g.nnz(), 1000);
    }

    #[test]
    fn every_task_replicated_s_times() {
        let code = FractionalRepetitionCode::new(20, 20, 5);
        let g = code.assignment(&mut Rng::new(0));
        assert!(g.row_degrees().iter().all(|&d| d == 5));
    }

    #[test]
    fn columns_in_same_block_are_identical() {
        let code = FractionalRepetitionCode::new(12, 12, 3);
        let g = code.assignment(&mut Rng::new(0));
        for j in 0..12 {
            let b = code.block_of_column(j);
            assert_eq!(g.col_support(j), (b * 3..(b + 1) * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    #[should_panic(expected = "s | k")]
    fn indivisible_s_panics() {
        FractionalRepetitionCode::new(10, 10, 3);
    }

    #[test]
    #[should_panic(expected = "n == k")]
    fn wrong_n_panics() {
        FractionalRepetitionCode::new(10, 12, 2);
    }
}
