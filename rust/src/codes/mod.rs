//! Gradient codes: assignment matrices G (paper §2.2).
//!
//! A code is a k x n matrix G whose column j lists the tasks assigned to
//! worker j (support) and the coefficients of the linear combination the
//! worker returns. All the paper's codes are boolean; the trait allows
//! weighted codes too.

pub mod bgc;
pub mod cyclic;
pub mod frc;
pub mod normalized;
pub mod rbgc;
pub mod regular_code;

pub use bgc::BernoulliCode;
pub use normalized::{normalize_columns, normalized_rho, NormalizedCode};
pub use cyclic::CyclicRepetitionCode;
pub use frc::FractionalRepetitionCode;
pub use rbgc::{RegularizedBernoulliCode, ThresholdedBernoulliCode};
pub use regular_code::RegularGraphCode;

use crate::linalg::CscMatrix;
use crate::util::Rng;

/// Reusable scratch for [`GradientCode::assignment_into`] — the flat
/// buffers the constructors need while re-drawing G without allocating.
/// One per `decode::DecodeWorkspace`; each scheme uses the subset it
/// needs (rBGC and the thresholded ablation BGC: `col`; s-regular:
/// `stubs`/`adj_flat`/`deg` for the configuration draw plus
/// `edges`/`bad` for the edge-swap repair fallback; BGC/FRC write
/// straight into the output and touch none of it).
#[derive(Clone, Debug, Default)]
pub struct AssignmentScratch {
    /// Per-column support build buffer (≤ k entries).
    pub col: Vec<usize>,
    /// Configuration-model stub pool (n·s entries).
    pub stubs: Vec<usize>,
    /// Flat adjacency for graph-based codes (n·s entries).
    pub adj_flat: Vec<usize>,
    /// Per-vertex fill counts for `adj_flat` (n entries).
    pub deg: Vec<usize>,
    /// Interleaved endpoint pairs for the edge-swap repair (n·s entries).
    pub edges: Vec<usize>,
    /// Defective-edge index list for the repair loop (≤ n·s/2 entries).
    pub bad: Vec<usize>,
}

impl AssignmentScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// A gradient-code construction.
///
/// `Send + Sync` supertraits: every construction is plain immutable
/// parameter data (all randomness flows through the `rng` arguments),
/// and the sharded Monte-Carlo layer hands `&dyn GradientCode` to
/// worker threads for the panelized redraw sweeps.
pub trait GradientCode: Send + Sync {
    /// Number of tasks / functions k.
    fn k(&self) -> usize;
    /// Number of workers n.
    fn n(&self) -> usize;
    /// Target per-worker tasks s (exact or in expectation, per scheme).
    fn s(&self) -> usize;
    /// Human-readable scheme name (used in figure/table output).
    fn name(&self) -> &'static str;
    /// Build the k x n assignment matrix. Randomized schemes draw from
    /// `rng`; deterministic schemes ignore it.
    fn assignment(&self, rng: &mut Rng) -> CscMatrix;

    /// [`GradientCode::assignment`] into a caller-owned matrix, reusing
    /// its buffers (and `scratch`) so schemes that re-draw G every
    /// Monte-Carlo trial do it allocation-free at steady state.
    ///
    /// Contract, pinned by `tests/decode_parity.rs` for every scheme:
    /// draws the **identical RNG stream** and produces the **identical
    /// matrix layout** as `assignment`, so seeded simulations are
    /// unchanged when call sites switch to the `_into` path. The
    /// default implementation is the allocating fallback for codes
    /// without a specialized path (e.g. wrappers like `NormalizedCode`).
    fn assignment_into(&self, rng: &mut Rng, out: &mut CscMatrix, scratch: &mut AssignmentScratch) {
        let _ = scratch;
        *out = self.assignment(rng);
    }
}

/// The schemes compared in the paper's §6 simulations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Frc,
    Bgc,
    Rbgc,
    RegularGraph,
    Cyclic,
}

impl Scheme {
    pub fn parse(s: &str) -> Option<Scheme> {
        match s.to_ascii_lowercase().as_str() {
            "frc" => Some(Scheme::Frc),
            "bgc" => Some(Scheme::Bgc),
            "rbgc" => Some(Scheme::Rbgc),
            "regular" | "sregular" | "s-regular" | "expander" => Some(Scheme::RegularGraph),
            "cyclic" => Some(Scheme::Cyclic),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Frc => "FRC",
            Scheme::Bgc => "BGC",
            Scheme::Rbgc => "rBGC",
            Scheme::RegularGraph => "s-regular",
            Scheme::Cyclic => "cyclic",
        }
    }

    /// Instantiate the scheme at (k, n, s).
    pub fn build(&self, k: usize, n: usize, s: usize) -> Box<dyn GradientCode + Send + Sync> {
        match self {
            Scheme::Frc => Box::new(FractionalRepetitionCode::new(k, n, s)),
            Scheme::Bgc => Box::new(BernoulliCode::new(k, n, s)),
            Scheme::Rbgc => Box::new(RegularizedBernoulliCode::new(k, n, s)),
            Scheme::RegularGraph => Box::new(RegularGraphCode::new(k, n, s)),
            Scheme::Cyclic => Box::new(CyclicRepetitionCode::new(k, n, s)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every scheme's `assignment_into` must match `assignment` exactly
    /// (same RNG draws, same layout) and leave the streams in lockstep.
    #[test]
    fn assignment_into_matches_assignment_bitwise() {
        let mut out = CscMatrix::empty();
        let mut scratch = AssignmentScratch::new();
        for scheme in [Scheme::Frc, Scheme::Bgc, Scheme::Rbgc, Scheme::RegularGraph, Scheme::Cyclic]
        {
            let code = scheme.build(20, 20, 4);
            let mut ra = Rng::new(77);
            let mut rb = Rng::new(77);
            for draw in 0..15 {
                let reference = code.assignment(&mut ra);
                code.assignment_into(&mut rb, &mut out, &mut scratch);
                assert_eq!(out, reference, "{} draw {draw}", scheme.name());
            }
            assert_eq!(ra.next_u64(), rb.next_u64(), "{} rng diverged", scheme.name());
        }
    }

    #[test]
    fn scheme_parse_roundtrip() {
        for (txt, want) in [
            ("frc", Scheme::Frc),
            ("BGC", Scheme::Bgc),
            ("rbgc", Scheme::Rbgc),
            ("expander", Scheme::RegularGraph),
            ("s-regular", Scheme::RegularGraph),
            ("cyclic", Scheme::Cyclic),
        ] {
            assert_eq!(Scheme::parse(txt), Some(want));
        }
        assert_eq!(Scheme::parse("nope"), None);
    }

    #[test]
    fn build_produces_right_dims() {
        let mut rng = Rng::new(1);
        for scheme in [Scheme::Frc, Scheme::Bgc, Scheme::Rbgc, Scheme::RegularGraph, Scheme::Cyclic] {
            let code = scheme.build(20, 20, 5);
            let g = code.assignment(&mut rng);
            assert_eq!(g.rows, 20, "{}", scheme.name());
            assert_eq!(g.cols, 20, "{}", scheme.name());
        }
    }
}
