//! Gradient codes: assignment matrices G (paper §2.2).
//!
//! A code is a k x n matrix G whose column j lists the tasks assigned to
//! worker j (support) and the coefficients of the linear combination the
//! worker returns. All the paper's codes are boolean; the trait allows
//! weighted codes too.

pub mod bgc;
pub mod cyclic;
pub mod frc;
pub mod normalized;
pub mod rbgc;
pub mod regular_code;

pub use bgc::BernoulliCode;
pub use normalized::{normalize_columns, normalized_rho, NormalizedCode};
pub use cyclic::CyclicRepetitionCode;
pub use frc::FractionalRepetitionCode;
pub use rbgc::RegularizedBernoulliCode;
pub use regular_code::RegularGraphCode;

use crate::linalg::CscMatrix;
use crate::util::Rng;

/// A gradient-code construction.
pub trait GradientCode {
    /// Number of tasks / functions k.
    fn k(&self) -> usize;
    /// Number of workers n.
    fn n(&self) -> usize;
    /// Target per-worker tasks s (exact or in expectation, per scheme).
    fn s(&self) -> usize;
    /// Human-readable scheme name (used in figure/table output).
    fn name(&self) -> &'static str;
    /// Build the k x n assignment matrix. Randomized schemes draw from
    /// `rng`; deterministic schemes ignore it.
    fn assignment(&self, rng: &mut Rng) -> CscMatrix;
}

/// The schemes compared in the paper's §6 simulations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Frc,
    Bgc,
    Rbgc,
    RegularGraph,
    Cyclic,
}

impl Scheme {
    pub fn parse(s: &str) -> Option<Scheme> {
        match s.to_ascii_lowercase().as_str() {
            "frc" => Some(Scheme::Frc),
            "bgc" => Some(Scheme::Bgc),
            "rbgc" => Some(Scheme::Rbgc),
            "regular" | "sregular" | "s-regular" | "expander" => Some(Scheme::RegularGraph),
            "cyclic" => Some(Scheme::Cyclic),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Frc => "FRC",
            Scheme::Bgc => "BGC",
            Scheme::Rbgc => "rBGC",
            Scheme::RegularGraph => "s-regular",
            Scheme::Cyclic => "cyclic",
        }
    }

    /// Instantiate the scheme at (k, n, s).
    pub fn build(&self, k: usize, n: usize, s: usize) -> Box<dyn GradientCode + Send + Sync> {
        match self {
            Scheme::Frc => Box::new(FractionalRepetitionCode::new(k, n, s)),
            Scheme::Bgc => Box::new(BernoulliCode::new(k, n, s)),
            Scheme::Rbgc => Box::new(RegularizedBernoulliCode::new(k, n, s)),
            Scheme::RegularGraph => Box::new(RegularGraphCode::new(k, n, s)),
            Scheme::Cyclic => Box::new(CyclicRepetitionCode::new(k, n, s)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_parse_roundtrip() {
        for (txt, want) in [
            ("frc", Scheme::Frc),
            ("BGC", Scheme::Bgc),
            ("rbgc", Scheme::Rbgc),
            ("expander", Scheme::RegularGraph),
            ("s-regular", Scheme::RegularGraph),
            ("cyclic", Scheme::Cyclic),
        ] {
            assert_eq!(Scheme::parse(txt), Some(want));
        }
        assert_eq!(Scheme::parse("nope"), None);
    }

    #[test]
    fn build_produces_right_dims() {
        let mut rng = Rng::new(1);
        for scheme in [Scheme::Frc, Scheme::Bgc, Scheme::Rbgc, Scheme::RegularGraph, Scheme::Cyclic] {
            let code = scheme.build(20, 20, 5);
            let g = code.assignment(&mut rng);
            assert_eq!(g.rows, 20, "{}", scheme.name());
            assert_eq!(g.cols, 20, "{}", scheme.name());
        }
    }
}
