//! Panel decode workspace: W concurrent Monte-Carlo trials per kernel
//! call against one shared G.
//!
//! [`PanelWorkspace`] is the panel-width analogue of
//! [`DecodeWorkspace`](super::DecodeWorkspace): it owns the k×W
//! coverage-count panel, the flattened per-lane survivor selections,
//! and the per-lane LSQR states, and drives the multi-RHS kernels in
//! [`crate::linalg::panel`]. The Monte-Carlo layer hands it a panel of
//! trial indices (`base..base + lanes`) and an output slice; each lane
//! produces exactly the value the scalar workspace would have produced
//! for that trial index.
//!
//! # RNG-fork-per-lane contract
//!
//! Lane `l` of a panel starting at global trial index `base` uses the
//! RNG stream `root.fork(base + l)` — the *same* stream the scalar
//! Monte-Carlo loop forks for trial `base + l`. Batching therefore
//! changes neither the draws nor their per-trial order, and the ragged
//! tail (a final panel with fewer than W lanes) is just a narrower
//! panel over the same streams. This is what makes panel results
//! bit-identical to the scalar path at any width, including W = 1.
//!
//! # Which arms actually batch
//!
//! Only **fixed-G** trials share work across lanes (one G, W survivor
//! draws): the one-step arm batches the coverage/err₁ pass over the CSR
//! mirror, and the optimal arm runs the lockstep multi-RHS LSQR.
//! **Redraw** arms draw a fresh G per trial, so there is nothing to
//! share — those methods loop lanes through an internal scalar
//! [`DecodeWorkspace`](super::DecodeWorkspace), trivially preserving
//! parity while keeping the panel API uniform for callers. Non-boolean
//! G (weighted assignments) likewise falls back to the per-lane scalar
//! path, because the panel coverage kernel's exactness argument needs
//! integer-valued data.

use super::workspace::DecodeWorkspace;
use crate::codes::GradientCode;
use crate::linalg::{
    panel, CscMatrix, CsrMatrix, LsqrOptions, LsqrSummary, PanelLsqr,
};
use crate::stragglers::StragglerModel;
use crate::util::Rng;

/// Default panel width for the simulation sweeps. Chosen from
/// `benches/decode_throughput.rs` (`panel/*` records): wide enough to
/// amortize each pass over G across lanes, small enough that the k×W
/// coverage panel stays cache-resident at the paper's k = n = 1000
/// acceptance instance.
pub const DEFAULT_PANEL_WIDTH: usize = 8;

/// Reusable state for a panel of up to `width` concurrent trials
/// against a shared G. All buffers grow to the largest instance seen
/// and are reused; steady-state panel loops perform no heap allocation
/// (pinned in `tests/zero_alloc.rs`).
#[derive(Debug)]
pub struct PanelWorkspace {
    width: usize,
    /// Scalar workspace for redraw arms and non-boolean fallbacks.
    scalar: DecodeWorkspace,
    /// Row-major mirror of the standing G (explicit, like the scalar
    /// workspace's streamed paths).
    g_csr: CsrMatrix,
    mirror_boolean: bool,
    /// Coverage-count panel, lane-contiguous per column:
    /// `counts[j * lanes + l]` = column j's multiplicity in lane l.
    counts: Vec<f64>,
    /// W-lane coverage scratch for the err₁ row sweep.
    cov: Vec<f64>,
    /// Flattened per-lane survivor selections + CSR-style lane bounds.
    sel_flat: Vec<usize>,
    sel_ptr: Vec<usize>,
    sel_tmp: Vec<usize>,
    pool: Vec<usize>,
    /// Lanes with a non-degenerate selection (the ones LSQR solves).
    active: Vec<usize>,
    lsqr: PanelLsqr,
    summaries: Vec<LsqrSummary>,
    ones: Vec<f64>,
}

impl PanelWorkspace {
    pub fn new(width: usize) -> Self {
        assert!(width >= 1, "panel width must be >= 1");
        PanelWorkspace {
            width,
            scalar: DecodeWorkspace::new(),
            g_csr: CsrMatrix::empty(),
            mirror_boolean: false,
            counts: Vec::new(),
            cov: Vec::new(),
            sel_flat: Vec::new(),
            sel_ptr: Vec::new(),
            sel_tmp: Vec::new(),
            pool: Vec::new(),
            active: Vec::new(),
            lsqr: PanelLsqr::new(),
            summaries: Vec::new(),
            ones: Vec::new(),
        }
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Cache the CSR mirror of the standing G (required before
    /// [`PanelWorkspace::onestep_panel`]). Also records whether G is
    /// boolean — the panel coverage kernel's exactness precondition.
    pub fn mirror_csr(&mut self, g: &CscMatrix) {
        g.to_csr_into(&mut self.g_csr);
        self.mirror_boolean = g.is_boolean();
    }

    /// The scalar fallback workspace (exposed for warm-up in
    /// allocation-count tests).
    pub fn scalar_ws(&mut self) -> &mut DecodeWorkspace {
        &mut self.scalar
    }

    /// Draw each lane's survivor selection: lane `l` forks
    /// `root.fork(base + l)` and samples r of n columns — exactly the
    /// scalar Monte-Carlo trial's draw for trial index `base + l`.
    fn draw_selections(&mut self, n: usize, r: usize, root: &Rng, base: u64, lanes: usize) {
        self.sel_flat.clear();
        self.sel_ptr.clear();
        self.sel_ptr.push(0);
        for lane in 0..lanes {
            let mut rng = root.fork(base + lane as u64);
            rng.sample_indices_into(n, r, &mut self.pool, &mut self.sel_tmp);
            self.sel_flat.extend_from_slice(&self.sel_tmp);
            self.sel_ptr.push(self.sel_flat.len());
        }
    }

    /// Panel of fixed-G one-step trials: W survivor draws, one pass
    /// over the CSR mirror for all W err₁ values. Bit-identical per
    /// lane to [`DecodeWorkspace::onestep_trial`] on the same trial
    /// indices. Requires [`PanelWorkspace::mirror_csr`] first; falls
    /// back to the per-lane scalar path when G is not boolean.
    pub fn onestep_panel(
        &mut self,
        g: &CscMatrix,
        r: usize,
        rho: f64,
        root: &Rng,
        base: u64,
        lanes: usize,
        out: &mut [f64],
    ) {
        assert!(lanes >= 1 && lanes <= self.width, "lanes {lanes} outside 1..={}", self.width);
        assert_eq!(out.len(), lanes);
        assert!(
            self.g_csr.rows == g.rows && self.g_csr.cols == g.cols,
            "call mirror_csr(g) before the panel one-step path"
        );
        if !self.mirror_boolean {
            // Weighted G: the integer-exactness argument doesn't apply;
            // take the scalar path per lane (same results, one at a time).
            for lane in 0..lanes {
                let mut rng = root.fork(base + lane as u64);
                out[lane] = self.scalar.onestep_trial(g, r, rho, &mut rng);
            }
            return;
        }
        self.draw_selections(g.cols, r, root, base, lanes);
        self.counts.clear();
        self.counts.resize(g.cols * lanes, 0.0);
        for lane in 0..lanes {
            for &j in &self.sel_flat[self.sel_ptr[lane]..self.sel_ptr[lane + 1]] {
                self.counts[j * lanes + lane] += 1.0;
            }
        }
        self.cov.clear();
        self.cov.resize(lanes, 0.0);
        panel::err1_panel_counts(&self.g_csr, &self.counts, lanes, rho, &mut self.cov, out);
    }

    /// Panel of fixed-G optimal trials: W survivor draws, one lockstep
    /// multi-RHS LSQR over the shared G (A is never materialized).
    /// Bit-identical per lane to [`DecodeWorkspace::optimal_trial`] on
    /// the same trial indices, including the `err = k` convention for
    /// degenerate (empty / zero-nnz) selections and the
    /// `warm = Some(rho)` warm start.
    #[allow(clippy::too_many_arguments)] // mirrors the scalar trial surface + panel addressing
    pub fn optimal_panel(
        &mut self,
        g: &CscMatrix,
        r: usize,
        opts: &LsqrOptions,
        warm: Option<f64>,
        root: &Rng,
        base: u64,
        lanes: usize,
        out: &mut [f64],
    ) {
        assert!(lanes >= 1 && lanes <= self.width, "lanes {lanes} outside 1..={}", self.width);
        assert_eq!(out.len(), lanes);
        self.draw_selections(g.cols, r, root, base, lanes);
        self.active.clear();
        for lane in 0..lanes {
            let sel = &self.sel_flat[self.sel_ptr[lane]..self.sel_ptr[lane + 1]];
            if sel.is_empty() || panel::nnz_selected(g, sel) == 0 {
                // Same convention as the scalar optimal_err_on_selected:
                // nothing to solve, the residual is the whole 1_k.
                out[lane] = g.rows as f64;
            } else {
                self.active.push(lane);
            }
        }
        if self.active.is_empty() {
            return;
        }
        self.ones.clear();
        self.ones.resize(g.rows, 1.0);
        self.summaries.clear();
        self.summaries.resize(
            lanes,
            LsqrSummary { residual_norm: 0.0, iterations: 0, converged: false },
        );
        panel::lsqr_selected_panel(
            g,
            &self.sel_flat,
            &self.sel_ptr,
            &self.active,
            &self.ones,
            opts,
            warm,
            &mut self.lsqr,
            &mut self.summaries,
        );
        for &lane in &self.active {
            let s = &self.summaries[lane];
            out[lane] = s.residual_norm * s.residual_norm;
        }
    }

    /// Panel of one-step redraw trials (fresh G per lane — nothing to
    /// share, so lanes run through the scalar workspace one by one,
    /// each on its own forked stream). Bit-identical per lane to
    /// [`DecodeWorkspace::onestep_redraw_trial_with`].
    #[allow(clippy::too_many_arguments)]
    pub fn onestep_redraw_panel_with(
        &mut self,
        code: &dyn GradientCode,
        model: &dyn StragglerModel,
        rho: f64,
        root: &Rng,
        base: u64,
        lanes: usize,
        out: &mut [f64],
    ) {
        assert!(lanes >= 1 && lanes <= self.width);
        assert_eq!(out.len(), lanes);
        for lane in 0..lanes {
            let mut rng = root.fork(base + lane as u64);
            out[lane] = self.scalar.onestep_redraw_trial_with(code, model, rho, &mut rng);
        }
    }

    /// Panel of optimal redraw trials (per-lane scalar loop, see
    /// [`PanelWorkspace::onestep_redraw_panel_with`]). Bit-identical
    /// per lane to [`DecodeWorkspace::optimal_redraw_trial_with`].
    #[allow(clippy::too_many_arguments)]
    pub fn optimal_redraw_panel_with(
        &mut self,
        code: &dyn GradientCode,
        model: &dyn StragglerModel,
        opts: &LsqrOptions,
        warm: Option<f64>,
        root: &Rng,
        base: u64,
        lanes: usize,
        out: &mut [f64],
    ) {
        assert!(lanes >= 1 && lanes <= self.width);
        assert_eq!(out.len(), lanes);
        for lane in 0..lanes {
            let mut rng = root.fork(base + lane as u64);
            out[lane] = self.scalar.optimal_redraw_trial_with(code, model, opts, warm, &mut rng);
        }
    }

    /// Panel of column-normalized one-step redraw trials (per-lane
    /// scalar loop). Bit-identical per lane to
    /// [`DecodeWorkspace::onestep_normalized_redraw_trial_with`].
    #[allow(clippy::too_many_arguments)]
    pub fn onestep_normalized_redraw_panel_with(
        &mut self,
        code: &dyn GradientCode,
        model: &dyn StragglerModel,
        rho: f64,
        root: &Rng,
        base: u64,
        lanes: usize,
        out: &mut [f64],
    ) {
        assert!(lanes >= 1 && lanes <= self.width);
        assert_eq!(out.len(), lanes);
        for lane in 0..lanes {
            let mut rng = root.fork(base + lane as u64);
            out[lane] =
                self.scalar.onestep_normalized_redraw_trial_with(code, model, rho, &mut rng);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::Scheme;

    #[test]
    fn panel_onestep_lane_values_match_scalar_trials() {
        let k = 40;
        let code = Scheme::Bgc.build(k, k, 4);
        let g = code.assignment(&mut Rng::new(9));
        let (r, rho) = (30, k as f64 / (30.0 * 4.0));
        let root = Rng::new(11);
        let mut pws = PanelWorkspace::new(4);
        pws.mirror_csr(&g);
        let mut out = vec![0.0; 4];
        pws.onestep_panel(&g, r, rho, &root, 12, 4, &mut out);
        let mut sws = DecodeWorkspace::new();
        for lane in 0..4 {
            let mut rng = root.fork(12 + lane as u64);
            let scalar = sws.onestep_trial(&g, r, rho, &mut rng);
            assert_eq!(out[lane].to_bits(), scalar.to_bits(), "lane {lane}");
        }
    }

    #[test]
    fn panel_optimal_lane_values_match_scalar_trials() {
        let k = 30;
        let code = Scheme::Bgc.build(k, k, 3);
        let g = code.assignment(&mut Rng::new(5));
        let r = 22;
        let opts = LsqrOptions::default();
        let root = Rng::new(13);
        for warm in [None, Some(k as f64 / (r as f64 * 3.0))] {
            let mut pws = PanelWorkspace::new(3);
            let mut out = vec![0.0; 3];
            pws.optimal_panel(&g, r, &opts, warm, &root, 7, 3, &mut out);
            let mut sws = DecodeWorkspace::new();
            for lane in 0..3 {
                let mut rng = root.fork(7 + lane as u64);
                let scalar = sws.optimal_trial(&g, r, &opts, warm, &mut rng);
                assert_eq!(out[lane].to_bits(), scalar.to_bits(), "warm {warm:?} lane {lane}");
            }
        }
    }

    #[test]
    fn non_boolean_g_falls_back_to_scalar_path() {
        use crate::codes::normalized::normalize_columns;
        let k = 20;
        let code = Scheme::Frc.build(k, k, 4);
        let g = normalize_columns(&code.assignment(&mut Rng::new(3)));
        assert!(!g.is_boolean());
        let root = Rng::new(4);
        let mut pws = PanelWorkspace::new(4);
        pws.mirror_csr(&g);
        let mut out = vec![0.0; 4];
        pws.onestep_panel(&g, 15, 0.4, &root, 0, 4, &mut out);
        let mut sws = DecodeWorkspace::new();
        for lane in 0..4 {
            let mut rng = root.fork(lane as u64);
            let scalar = sws.onestep_trial(&g, 15, 0.4, &mut rng);
            assert_eq!(out[lane].to_bits(), scalar.to_bits(), "lane {lane}");
        }
    }
}
