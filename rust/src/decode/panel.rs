//! Panel decode workspace: W concurrent Monte-Carlo trials per kernel
//! call against one shared G.
//!
//! [`PanelWorkspace`] is the panel-width analogue of
//! [`DecodeWorkspace`](super::DecodeWorkspace): it owns the k×W
//! coverage-count panel, the flattened per-lane survivor selections,
//! and the per-lane LSQR states, and drives the multi-RHS kernels in
//! [`crate::linalg::panel`]. The Monte-Carlo layer hands it a panel of
//! trial indices (`base..base + lanes`) and an output slice; each lane
//! produces exactly the value the scalar workspace would have produced
//! for that trial index.
//!
//! # RNG-fork-per-lane contract
//!
//! Lane `l` of a panel starting at global trial index `base` uses the
//! RNG stream `root.fork(base + l)` — the *same* stream the scalar
//! Monte-Carlo loop forks for trial `base + l`. Batching therefore
//! changes neither the draws nor their per-trial order, and the ragged
//! tail (a final panel with fewer than W lanes) is just a narrower
//! panel over the same streams. This is what makes panel results
//! bit-identical to the scalar path at any width, including W = 1.
//!
//! # Which arms actually batch
//!
//! **Fixed-G** trials share the most (one G, W survivor draws): the
//! one-step arm batches the coverage/err₁ pass over the CSR mirror, and
//! the optimal arm runs the lockstep multi-RHS LSQR. Non-boolean G
//! (weighted assignments) falls back to the per-lane scalar path there,
//! because the counts-panel kernel's exactness argument needs
//! integer-valued data.
//!
//! **One-step redraw** trials draw a fresh G per lane, so no pass over
//! G is shared — but the err₁ reduction is: each lane
//! scatter-accumulates its own G's survivor coverage into a
//! lane-strided k×W panel (one `AssignmentScratch`, one workspace G
//! overwritten lane by lane), and a single fused
//! [`err1_panel_cov`](crate::linalg::err1_panel_cov) sweep reduces all
//! W lanes with the SIMD lane tiers. Lane l's scatter *is* the scalar
//! trial's scatter, addition for addition, into its own column of
//! accumulators — so the fused form is bit-identical on weighted G
//! too, no integer-exactness argument needed.
//!
//! **Optimal / normalized redraw** arms have nothing to fuse: each
//! lane's LSQR (or column normalization) runs against a *distinct* G,
//! so batching shares neither matrix passes nor reductions. Those
//! methods loop lanes through the internal scalar
//! [`DecodeWorkspace`](super::DecodeWorkspace), trivially preserving
//! parity while keeping the panel API uniform for callers.

use super::workspace::DecodeWorkspace;
use crate::codes::GradientCode;
use crate::linalg::{
    panel, CscMatrix, CsrMatrix, LsqrOptions, LsqrSummary, PanelLsqr,
};
use crate::stragglers::StragglerModel;
use crate::util::Rng;

/// Default panel width for the simulation sweeps. Chosen from
/// `benches/decode_throughput.rs` (`panel/*` records): wide enough to
/// amortize each pass over G across lanes, small enough that the k×W
/// coverage panel stays cache-resident at the paper's k = n = 1000
/// acceptance instance.
pub const DEFAULT_PANEL_WIDTH: usize = 8;

/// Reusable state for a panel of up to `width` concurrent trials
/// against a shared G. All buffers grow to the largest instance seen
/// and are reused; steady-state panel loops perform no heap allocation
/// (pinned in `tests/zero_alloc.rs`).
#[derive(Debug)]
pub struct PanelWorkspace {
    width: usize,
    /// Scalar workspace for redraw arms and non-boolean fallbacks.
    scalar: DecodeWorkspace,
    /// Row-major mirror of the standing G (explicit, like the scalar
    /// workspace's streamed paths).
    g_csr: CsrMatrix,
    mirror_boolean: bool,
    /// Coverage-count panel, lane-contiguous per column:
    /// `counts[j * lanes + l]` = column j's multiplicity in lane l.
    counts: Vec<f64>,
    /// W-lane coverage scratch for the err₁ row sweep.
    cov: Vec<f64>,
    /// Lane-strided k×W coverage panel for the fused redraw arm:
    /// `cov_panel[i * lanes + l]` = row i's coverage in lane l's G.
    cov_panel: Vec<f64>,
    /// Flattened per-lane survivor selections + CSR-style lane bounds.
    sel_flat: Vec<usize>,
    sel_ptr: Vec<usize>,
    sel_tmp: Vec<usize>,
    pool: Vec<usize>,
    /// Lanes with a non-degenerate selection (the ones LSQR solves).
    active: Vec<usize>,
    lsqr: PanelLsqr,
    summaries: Vec<LsqrSummary>,
    ones: Vec<f64>,
}

impl PanelWorkspace {
    pub fn new(width: usize) -> Self {
        assert!(width >= 1, "panel width must be >= 1");
        PanelWorkspace {
            width,
            scalar: DecodeWorkspace::new(),
            g_csr: CsrMatrix::empty(),
            mirror_boolean: false,
            counts: Vec::new(),
            cov: Vec::new(),
            cov_panel: Vec::new(),
            sel_flat: Vec::new(),
            sel_ptr: Vec::new(),
            sel_tmp: Vec::new(),
            pool: Vec::new(),
            active: Vec::new(),
            lsqr: PanelLsqr::new(),
            summaries: Vec::new(),
            ones: Vec::new(),
        }
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Cache the CSR mirror of the standing G (required before
    /// [`PanelWorkspace::onestep_panel`]). Also records whether G is
    /// boolean — the panel coverage kernel's exactness precondition.
    pub fn mirror_csr(&mut self, g: &CscMatrix) {
        g.to_csr_into(&mut self.g_csr);
        self.mirror_boolean = g.is_boolean();
    }

    /// The scalar fallback workspace (exposed for warm-up in
    /// allocation-count tests).
    pub fn scalar_ws(&mut self) -> &mut DecodeWorkspace {
        &mut self.scalar
    }

    /// Pre-size every buffer the redraw panel touches at (k, n, s) —
    /// the panel-width analogue of
    /// [`DecodeWorkspace::reserve_redraw`], so the fused redraw loop
    /// performs zero heap allocations from the very first panel
    /// (pinned by `tests/zero_alloc.rs`).
    pub fn reserve_redraw(&mut self, k: usize, n: usize, s: usize) {
        self.scalar.reserve_redraw(k, n, s);
        self.cov_panel.reserve(k * self.width);
    }

    /// Draw each lane's survivor selection: lane `l` forks
    /// `root.fork(base + l)` and samples r of n columns — exactly the
    /// scalar Monte-Carlo trial's draw for trial index `base + l`.
    fn draw_selections(&mut self, n: usize, r: usize, root: &Rng, base: u64, lanes: usize) {
        self.sel_flat.clear();
        self.sel_ptr.clear();
        self.sel_ptr.push(0);
        for lane in 0..lanes {
            let mut rng = root.fork(base + lane as u64);
            rng.sample_indices_into(n, r, &mut self.pool, &mut self.sel_tmp);
            self.sel_flat.extend_from_slice(&self.sel_tmp);
            self.sel_ptr.push(self.sel_flat.len());
        }
    }

    /// Panel of fixed-G one-step trials: W survivor draws, one pass
    /// over the CSR mirror for all W err₁ values. Bit-identical per
    /// lane to [`DecodeWorkspace::onestep_trial`] on the same trial
    /// indices. Requires [`PanelWorkspace::mirror_csr`] first; falls
    /// back to the per-lane scalar path when G is not boolean.
    pub fn onestep_panel(
        &mut self,
        g: &CscMatrix,
        r: usize,
        rho: f64,
        root: &Rng,
        base: u64,
        lanes: usize,
        out: &mut [f64],
    ) {
        assert!(lanes >= 1 && lanes <= self.width, "lanes {lanes} outside 1..={}", self.width);
        assert_eq!(out.len(), lanes);
        assert!(
            self.g_csr.rows == g.rows && self.g_csr.cols == g.cols,
            "call mirror_csr(g) before the panel one-step path"
        );
        if !self.mirror_boolean {
            // Weighted G: the integer-exactness argument doesn't apply;
            // take the scalar path per lane (same results, one at a time).
            for lane in 0..lanes {
                let mut rng = root.fork(base + lane as u64);
                out[lane] = self.scalar.onestep_trial(g, r, rho, &mut rng);
            }
            return;
        }
        self.draw_selections(g.cols, r, root, base, lanes);
        self.counts.clear();
        self.counts.resize(g.cols * lanes, 0.0);
        for lane in 0..lanes {
            for &j in &self.sel_flat[self.sel_ptr[lane]..self.sel_ptr[lane + 1]] {
                self.counts[j * lanes + lane] += 1.0;
            }
        }
        self.cov.clear();
        self.cov.resize(lanes, 0.0);
        panel::err1_panel_counts(&self.g_csr, &self.counts, lanes, rho, &mut self.cov, out);
    }

    /// Panel of fixed-G optimal trials: W survivor draws, one lockstep
    /// multi-RHS LSQR over the shared G (A is never materialized).
    /// Bit-identical per lane to [`DecodeWorkspace::optimal_trial`] on
    /// the same trial indices, including the `err = k` convention for
    /// degenerate (empty / zero-nnz) selections and the
    /// `warm = Some(rho)` warm start.
    #[allow(clippy::too_many_arguments)] // mirrors the scalar trial surface + panel addressing
    pub fn optimal_panel(
        &mut self,
        g: &CscMatrix,
        r: usize,
        opts: &LsqrOptions,
        warm: Option<f64>,
        root: &Rng,
        base: u64,
        lanes: usize,
        out: &mut [f64],
    ) {
        assert!(lanes >= 1 && lanes <= self.width, "lanes {lanes} outside 1..={}", self.width);
        assert_eq!(out.len(), lanes);
        self.draw_selections(g.cols, r, root, base, lanes);
        self.active.clear();
        for lane in 0..lanes {
            let sel = &self.sel_flat[self.sel_ptr[lane]..self.sel_ptr[lane + 1]];
            if sel.is_empty() || panel::nnz_selected(g, sel) == 0 {
                // Same convention as the scalar optimal_err_on_selected:
                // nothing to solve, the residual is the whole 1_k.
                out[lane] = g.rows as f64;
            } else {
                self.active.push(lane);
            }
        }
        if self.active.is_empty() {
            return;
        }
        self.ones.clear();
        self.ones.resize(g.rows, 1.0);
        self.summaries.clear();
        self.summaries.resize(
            lanes,
            LsqrSummary { residual_norm: 0.0, iterations: 0, converged: false },
        );
        panel::lsqr_selected_panel(
            g,
            &self.sel_flat,
            &self.sel_ptr,
            &self.active,
            &self.ones,
            opts,
            warm,
            &mut self.lsqr,
            &mut self.summaries,
        );
        for &lane in &self.active {
            let s = &self.summaries[lane];
            out[lane] = s.residual_norm * s.residual_norm;
        }
    }

    /// Panel of one-step redraw trials, fused: a fresh G per lane
    /// (drawn into the shared workspace matrix through one
    /// `AssignmentScratch`), each lane's survivor coverage
    /// scatter-accumulated into its own stride of the k×W coverage
    /// panel, and a single [`err1_panel_cov`](panel::err1_panel_cov)
    /// sweep reducing all lanes with the SIMD lane tiers.
    ///
    /// Bit-identical per lane to
    /// [`DecodeWorkspace::onestep_redraw_trial_with`]: lane `l` forks
    /// `root.fork(base + l)`, draws G and the survivor set in the same
    /// order, and its scatter into `cov_panel[.. * lanes + l]` is the
    /// scalar trial's `row_acc` scatter addition for addition — so the
    /// fusion holds on weighted G too (no integer-exactness argument
    /// needed, unlike the fixed-G counts panel).
    #[allow(clippy::too_many_arguments)]
    pub fn onestep_redraw_panel_with(
        &mut self,
        code: &dyn GradientCode,
        model: &dyn StragglerModel,
        rho: f64,
        root: &Rng,
        base: u64,
        lanes: usize,
        out: &mut [f64],
    ) {
        assert!(lanes >= 1 && lanes <= self.width);
        assert_eq!(out.len(), lanes);
        let k = code.k();
        self.cov_panel.clear();
        self.cov_panel.resize(k * lanes, 0.0);
        let (g, scratch, stragglers) = self.scalar.redraw_parts();
        for lane in 0..lanes {
            let mut rng = root.fork(base + lane as u64);
            code.assignment_into(&mut rng, g, scratch);
            debug_assert_eq!(g.rows, k);
            model.non_stragglers_into(g.cols, &mut rng, stragglers);
            for &j in &stragglers.idx {
                assert!(j < g.cols, "column {j} out of bounds ({})", g.cols);
                for p in g.col_ptr[j]..g.col_ptr[j + 1] {
                    self.cov_panel[g.row_idx[p] * lanes + lane] += g.vals[p];
                }
            }
        }
        panel::err1_panel_cov(&self.cov_panel, lanes, rho, out);
    }

    /// Panel of optimal redraw trials. Unlike the one-step redraw arm
    /// there is nothing to fuse — each lane's LSQR runs against a
    /// *distinct* fresh G, sharing neither matrix passes nor the final
    /// reduction — so lanes run through the scalar workspace one by
    /// one, each on its own forked stream. Bit-identical per lane to
    /// [`DecodeWorkspace::optimal_redraw_trial_with`].
    #[allow(clippy::too_many_arguments)]
    pub fn optimal_redraw_panel_with(
        &mut self,
        code: &dyn GradientCode,
        model: &dyn StragglerModel,
        opts: &LsqrOptions,
        warm: Option<f64>,
        root: &Rng,
        base: u64,
        lanes: usize,
        out: &mut [f64],
    ) {
        assert!(lanes >= 1 && lanes <= self.width);
        assert_eq!(out.len(), lanes);
        for lane in 0..lanes {
            let mut rng = root.fork(base + lane as u64);
            out[lane] = self.scalar.optimal_redraw_trial_with(code, model, opts, warm, &mut rng);
        }
    }

    /// Panel of column-normalized one-step redraw trials. The per-lane
    /// column normalization rebuilds a distinct weighted G per lane, so
    /// — like the optimal redraw arm — there is nothing to fuse; lanes
    /// run through the scalar workspace one by one. Bit-identical per
    /// lane to
    /// [`DecodeWorkspace::onestep_normalized_redraw_trial_with`].
    #[allow(clippy::too_many_arguments)]
    pub fn onestep_normalized_redraw_panel_with(
        &mut self,
        code: &dyn GradientCode,
        model: &dyn StragglerModel,
        rho: f64,
        root: &Rng,
        base: u64,
        lanes: usize,
        out: &mut [f64],
    ) {
        assert!(lanes >= 1 && lanes <= self.width);
        assert_eq!(out.len(), lanes);
        for lane in 0..lanes {
            let mut rng = root.fork(base + lane as u64);
            out[lane] =
                self.scalar.onestep_normalized_redraw_trial_with(code, model, rho, &mut rng);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::Scheme;

    #[test]
    fn panel_onestep_lane_values_match_scalar_trials() {
        let k = 40;
        let code = Scheme::Bgc.build(k, k, 4);
        let g = code.assignment(&mut Rng::new(9));
        let (r, rho) = (30, k as f64 / (30.0 * 4.0));
        let root = Rng::new(11);
        let mut pws = PanelWorkspace::new(4);
        pws.mirror_csr(&g);
        let mut out = vec![0.0; 4];
        pws.onestep_panel(&g, r, rho, &root, 12, 4, &mut out);
        let mut sws = DecodeWorkspace::new();
        for lane in 0..4 {
            let mut rng = root.fork(12 + lane as u64);
            let scalar = sws.onestep_trial(&g, r, rho, &mut rng);
            assert_eq!(out[lane].to_bits(), scalar.to_bits(), "lane {lane}");
        }
    }

    #[test]
    fn panel_optimal_lane_values_match_scalar_trials() {
        let k = 30;
        let code = Scheme::Bgc.build(k, k, 3);
        let g = code.assignment(&mut Rng::new(5));
        let r = 22;
        let opts = LsqrOptions::default();
        let root = Rng::new(13);
        for warm in [None, Some(k as f64 / (r as f64 * 3.0))] {
            let mut pws = PanelWorkspace::new(3);
            let mut out = vec![0.0; 3];
            pws.optimal_panel(&g, r, &opts, warm, &root, 7, 3, &mut out);
            let mut sws = DecodeWorkspace::new();
            for lane in 0..3 {
                let mut rng = root.fork(7 + lane as u64);
                let scalar = sws.optimal_trial(&g, r, &opts, warm, &mut rng);
                assert_eq!(out[lane].to_bits(), scalar.to_bits(), "warm {warm:?} lane {lane}");
            }
        }
    }

    #[test]
    fn fused_redraw_panel_matches_scalar_redraw_trials() {
        use crate::stragglers::UniformStragglers;
        let (k, s) = (30, 4);
        let model = UniformStragglers::new(0.3);
        let rho = 1.1;
        for scheme in [Scheme::Bgc, Scheme::Frc, Scheme::RegularGraph] {
            let code = scheme.build(k, k, s);
            let root = Rng::new(21);
            // Full panels, a ragged tail, and a W = 1 panel.
            for (base, lanes) in [(0u64, 4usize), (4, 3), (7, 1)] {
                let mut pws = PanelWorkspace::new(4);
                let mut out = vec![0.0; lanes];
                pws.onestep_redraw_panel_with(
                    code.as_ref(),
                    &model,
                    rho,
                    &root,
                    base,
                    lanes,
                    &mut out,
                );
                let mut sws = DecodeWorkspace::new();
                for lane in 0..lanes {
                    let mut rng = root.fork(base + lane as u64);
                    let scalar = sws.onestep_redraw_trial_with(code.as_ref(), &model, rho, &mut rng);
                    assert_eq!(
                        out[lane].to_bits(),
                        scalar.to_bits(),
                        "{} base {base} lane {lane}",
                        scheme.name()
                    );
                }
            }
        }
    }

    #[test]
    fn non_boolean_g_falls_back_to_scalar_path() {
        use crate::codes::normalized::normalize_columns;
        let k = 20;
        let code = Scheme::Frc.build(k, k, 4);
        let g = normalize_columns(&code.assignment(&mut Rng::new(3)));
        assert!(!g.is_boolean());
        let root = Rng::new(4);
        let mut pws = PanelWorkspace::new(4);
        pws.mirror_csr(&g);
        let mut out = vec![0.0; 4];
        pws.onestep_panel(&g, 15, 0.4, &root, 0, 4, &mut out);
        let mut sws = DecodeWorkspace::new();
        for lane in 0..4 {
            let mut rng = root.fork(lane as u64);
            let scalar = sws.onestep_trial(&g, 15, 0.4, &mut rng);
            assert_eq!(out[lane].to_bits(), scalar.to_bits(), "lane {lane}");
        }
    }
}
