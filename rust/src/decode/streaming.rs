//! Streaming one-step decoder — the paper's §2.2 memory argument as a
//! reference implementation, **not** a simulation hot path.
//!
//! §2.2: "we can apply the one-step decoding method even if we do not
//! have direct access to A ... avoid putting the entire matrix A into
//! memory of the master". This decoder consumes (column-support,
//! message) pairs as workers respond, maintaining only the running
//! coverage counts and payload sum — O(k + d) memory independent of r.
//! It also exposes an *early-stop* signal: once every task is covered
//! at its expected multiplicity, waiting longer cannot reduce err_1.
//!
//! # Status: superseded on the hot paths
//!
//! Nothing in the simulation or coordinator stack routes through this
//! type. The Monte-Carlo sweeps use [`super::DecodeWorkspace`] (fused /
//! streamed err₁ over the cached CSR mirror) and [`super::panel`]'s
//! multi-RHS batched kernels; the e2e coordinator decodes on the same
//! workspace spine. `StreamingOneStep` is kept as the faithful
//! ingest-one-column-at-a-time rendition of §2.2 — the O(k + d) memory
//! bound and the `fully_covered` early-stop signal are properties of
//! *that* protocol, worth stating executable — and its equivalence to
//! the batch decoder is pinned by the tests below. Reach for it only to
//! model a master that cannot hold A; everything else should use the
//! workspace layer.

/// Incremental one-step decode state (reference implementation; see
/// the module docs for why the hot paths don't use it).
#[derive(Clone, Debug)]
pub struct StreamingOneStep {
    k: usize,
    rho: f64,
    /// Σ over received columns of their support indicators (row sums).
    coverage: Vec<f64>,
    /// ρ · Σ received payloads.
    payload_sum: Vec<f64>,
    received: usize,
}

impl StreamingOneStep {
    /// `rho` is fixed up front (ρ = k/(rs) for the paper's protocol —
    /// note r must be the *planned* survivor count, e.g. the FastestR
    /// deadline parameter, since streaming can't know r in advance).
    pub fn new(k: usize, d: usize, rho: f64) -> Self {
        assert!(rho > 0.0);
        StreamingOneStep {
            k,
            rho,
            coverage: vec![0.0; k],
            payload_sum: vec![0.0; d],
            received: 0,
        }
    }

    /// Ingest one worker's response: its G-column entries and payload.
    pub fn ingest(&mut self, column: &[(usize, f64)], payload: &[f32]) {
        assert_eq!(payload.len(), self.payload_sum.len());
        for &(i, v) in column {
            assert!(i < self.k, "row {i} out of range");
            self.coverage[i] += v;
        }
        for (acc, &p) in self.payload_sum.iter_mut().zip(payload) {
            *acc += self.rho * p as f64;
        }
        self.received += 1;
    }

    pub fn received(&self) -> usize {
        self.received
    }

    /// Current one-step error ||ρ A 1 - 1_k||² given what has arrived.
    pub fn current_err1(&self) -> f64 {
        self.coverage.iter().map(|&c| (self.rho * c - 1.0).powi(2)).sum()
    }

    /// The running gradient estimate ĝ = ρ Σ msg_j.
    pub fn estimate(&self) -> Vec<f32> {
        self.payload_sum.iter().map(|&v| v as f32).collect()
    }

    /// True when every task's coverage has reached 1/ρ (its target
    /// multiplicity): more responses can only overshoot, so a master
    /// waiting for accuracy may stop gathering now.
    pub fn fully_covered(&self) -> bool {
        let target = 1.0 / self.rho;
        self.coverage.iter().all(|&c| c >= target - 1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{BernoulliCode, FractionalRepetitionCode, GradientCode};
    use crate::decode::OneStepDecoder;
    use crate::util::Rng;

    #[test]
    fn streaming_matches_batch_err1() {
        let mut rng = Rng::new(1);
        let g = BernoulliCode::new(30, 30, 5).assignment(&mut rng);
        let survivors = rng.sample_indices(30, 20);
        let a = g.select_columns(&survivors);
        let rho = 30.0 / (20.0 * 5.0);

        let mut s = StreamingOneStep::new(30, 4, rho);
        for &j in &survivors {
            let col: Vec<(usize, f64)> = g.col(j).collect();
            s.ingest(&col, &[0.0; 4]);
        }
        let batch = OneStepDecoder::new(rho).err1(&a);
        assert!((s.current_err1() - batch).abs() < 1e-10);
        assert_eq!(s.received(), 20);
    }

    #[test]
    fn estimate_accumulates_scaled_payloads() {
        let mut s = StreamingOneStep::new(4, 3, 0.5);
        s.ingest(&[(0, 1.0)], &[2.0, 0.0, 4.0]);
        s.ingest(&[(1, 1.0)], &[2.0, 2.0, 0.0]);
        let est = s.estimate();
        assert_eq!(est, vec![2.0, 1.0, 2.0]); // 0.5 * sums
    }

    #[test]
    fn error_decreases_then_is_zero_for_full_frc() {
        // FRC with all workers responding and rho = 1/s: exact recovery.
        let (k, sdeg) = (12usize, 3usize);
        let g = FractionalRepetitionCode::new(k, k, sdeg).assignment(&mut Rng::new(2));
        let rho = 1.0 / sdeg as f64;
        let mut s = StreamingOneStep::new(k, 1, rho);
        let mut last = s.current_err1();
        assert_eq!(last, k as f64);
        for j in 0..k {
            let col: Vec<(usize, f64)> = g.col(j).collect();
            s.ingest(&col, &[0.0]);
            let now = s.current_err1();
            assert!(now <= last + 1e-12, "error rose: {last} -> {now}");
            last = now;
        }
        assert!(last < 1e-12);
        assert!(s.fully_covered());
    }

    #[test]
    fn fully_covered_fires_exactly_at_target() {
        // rho = 1/2: each task needs coverage 2.
        let mut s = StreamingOneStep::new(2, 1, 0.5);
        s.ingest(&[(0, 1.0), (1, 1.0)], &[0.0]);
        assert!(!s.fully_covered());
        s.ingest(&[(0, 1.0), (1, 1.0)], &[0.0]);
        assert!(s.fully_covered());
    }

    #[test]
    fn memory_is_independent_of_streamed_columns() {
        // Structural: state size fixed by (k, d) only.
        let s = StreamingOneStep::new(1000, 10, 0.1);
        assert_eq!(s.coverage.len(), 1000);
        assert_eq!(s.payload_sum.len(), 10);
    }
}
