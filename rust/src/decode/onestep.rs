//! One-step decoding (paper Algorithm 1): x = ρ 1_r, v = ρ A 1_r.
//!
//! O(nnz) — "linear complexity in the sparsity of the input" — and
//! streamable: the master never needs A in memory, only the running sum
//! of messages. The canonical step size is ρ = k/(rs): if G has exactly
//! s entries per row and column, every row of A has ≈ rs/k entries and
//! ρ A 1_r reconstructs 1_k exactly.

use super::Decoder;
use crate::linalg::{blocked, CscMatrix, CsrMatrix};

#[derive(Clone, Copy, Debug)]
pub struct OneStepDecoder {
    /// ρ. Use `OneStepDecoder::canonical(k, r, s)` for ρ = k/(rs).
    pub rho: f64,
}

impl OneStepDecoder {
    pub fn new(rho: f64) -> Self {
        assert!(rho > 0.0, "rho must be positive");
        OneStepDecoder { rho }
    }

    /// The paper's default ρ = k / (r s).
    pub fn canonical(k: usize, r: usize, s: usize) -> Self {
        assert!(r > 0 && s > 0);
        OneStepDecoder { rho: k as f64 / (r as f64 * s as f64) }
    }

    /// err_1(A) = ||ρ A 1_r - 1_k||^2 computed in one sparse pass.
    pub fn err1(&self, a: &CscMatrix) -> f64 {
        let sums = a.row_sums();
        sums.iter().map(|&v| (self.rho * v - 1.0).powi(2)).sum()
    }

    /// err_1 on a CSR mirror of A: one contiguous row-major sweep with
    /// blocked per-row reductions — no row-sum buffer, no scatter.
    /// Bit-identical to [`OneStepDecoder::err1`] on boolean A (integer
    /// row sums); agrees to rounding on weighted A.
    pub fn err1_csr(&self, a: &CsrMatrix) -> f64 {
        let mut total = 0.0;
        for i in 0..a.rows {
            let row = &a.vals[a.row_ptr[i]..a.row_ptr[i + 1]];
            let v = blocked::sum(row);
            total += (self.rho * v - 1.0).powi(2);
        }
        total
    }
}

impl Decoder for OneStepDecoder {
    fn weights(&self, a: &CscMatrix) -> Vec<f64> {
        vec![self.rho; a.cols]
    }

    fn name(&self) -> &'static str {
        "one-step"
    }

    fn error(&self, a: &CscMatrix) -> f64 {
        // Specialized: avoids materializing the weight vector.
        self.err1(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::decode_error;

    #[test]
    fn err1_matches_generic_path() {
        let a = CscMatrix::from_supports(6, vec![vec![0, 1], vec![2, 3], vec![1, 4]]);
        let d = OneStepDecoder::new(0.7);
        let generic = decode_error(&a, &d.weights(&a));
        assert!((d.err1(&a) - generic).abs() < 1e-12);
    }

    #[test]
    fn exact_recovery_on_perfectly_regular_a() {
        // k=4, r=2, s=2, each row has rs/k = 1 entry: rho = k/(rs) = 1.
        let a = CscMatrix::from_supports(4, vec![vec![0, 1], vec![2, 3]]);
        let d = OneStepDecoder::canonical(4, 2, 2);
        assert!((d.rho - 1.0).abs() < 1e-15);
        assert_eq!(d.err1(&a), 0.0);
    }

    #[test]
    fn empty_matrix_gives_err_k() {
        let a = CscMatrix::from_supports(5, vec![vec![], vec![]]);
        let d = OneStepDecoder::new(1.0);
        assert_eq!(d.err1(&a), 5.0);
    }

    #[test]
    fn err1_csr_bit_identical_on_boolean_a() {
        let a = CscMatrix::from_supports(6, vec![vec![0, 1], vec![2, 3], vec![1, 4]]);
        let d = OneStepDecoder::new(0.7);
        assert_eq!(d.err1_csr(&a.to_csr()).to_bits(), d.err1(&a).to_bits());
    }

    #[test]
    fn err1_csr_close_on_weighted_a() {
        let a = CscMatrix::from_columns(
            4,
            vec![vec![(0, 0.3), (2, 1.7)], vec![(1, -0.4), (2, 0.9), (3, 2.2)]],
        );
        let d = OneStepDecoder::new(1.1);
        let (csc, csr) = (d.err1(&a), d.err1_csr(&a.to_csr()));
        assert!((csc - csr).abs() <= 1e-12 * (1.0 + csc.abs()), "{csc} vs {csr}");
    }

    #[test]
    fn canonical_rho_value() {
        let d = OneStepDecoder::canonical(100, 80, 5);
        assert!((d.rho - 0.25).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_rho_panics() {
        OneStepDecoder::new(0.0);
    }
}
